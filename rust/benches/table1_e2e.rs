//! Bench: regenerate **Table 1** — the end-to-end retraining breakdown
//! grid — assert its shape against the paper, and time the full flow
//! engine path (virtual-only, so the numbers measure the coordinator,
//! not PJRT).
//!
//! Run: `cargo bench --bench table1_e2e`

#[path = "harness.rs"]
mod harness;

use xloop::workflow::{render_table1, Coordinator, Mode, Scenario, TrainingMode};

fn run_cell(model: &str, mode: Mode) -> xloop::workflow::RetrainBreakdown {
    let mut c = Coordinator::paper(42).unwrap();
    c.set_training_mode(TrainingMode::VirtualOnly);
    let scenario = Scenario::table1(model, mode).unwrap();
    c.run_retraining(&scenario, None).unwrap().breakdown
}

fn main() {
    harness::group("Table 1 grid (virtual seconds)");
    let mut rows = Vec::new();
    for scenario in Scenario::table1_grid() {
        rows.push(run_cell(&scenario.model, scenario.mode));
    }
    print!("{}", render_table1(&rows));

    // paper-shape assertions
    let get = |model: &str, needle: &str| {
        rows.iter()
            .find(|r| r.model == model && r.mode_label.contains(needle))
            .unwrap()
    };
    let paper: &[(&str, &str, f64)] = &[
        ("braggnn", "Local", 1102.0),
        ("braggnn", "Cerebras", 31.0),
        ("braggnn", "SambaNova", 151.0),
        ("cookienetae", "Local", 517.0),
        ("cookienetae", "Cerebras", 15.0),
        ("cookienetae", "multi-GPU", 97.0),
    ];
    println!("\n{:<14} {:<12} {:>10} {:>10} {:>8}", "mode", "model", "paper", "ours", "ratio");
    for &(model, needle, target) in paper {
        let r = get(model, needle);
        let ratio = r.end_to_end_s / target;
        println!(
            "{needle:<14} {model:<12} {target:>10.0} {:>10.1} {ratio:>8.2}",
            r.end_to_end_s
        );
        assert!(
            (0.5..2.0).contains(&ratio),
            "{model}/{needle}: {:.1}s vs paper {target}s",
            r.end_to_end_s
        );
    }
    // ordering within each model matches the paper
    assert!(get("braggnn", "Cerebras").end_to_end_s < get("braggnn", "SambaNova").end_to_end_s);
    assert!(get("braggnn", "SambaNova").end_to_end_s < get("braggnn", "Local").end_to_end_s);
    assert!(
        get("cookienetae", "Cerebras").end_to_end_s < get("cookienetae", "multi-GPU").end_to_end_s
    );
    assert!(
        get("cookienetae", "multi-GPU").end_to_end_s < get("cookienetae", "Local").end_to_end_s
    );
    // headline >30x
    let speedup = get("braggnn", "Local").end_to_end_s / get("braggnn", "Cerebras").end_to_end_s;
    assert!(speedup > 30.0, "headline speedup {speedup:.1}");
    println!("\nheadline: {speedup:.1}x remote-vs-local (paper: >30x) — OK");

    harness::group("coordinator cost (flow engine + fabric, no PJRT training)");
    harness::bench("one remote retraining flow (virtual)", 1, 5, || {
        std::hint::black_box(run_cell("braggnn", Mode::RemoteCerebras));
    });
}
