//! Bench: regenerate **Fig. 3** — file-transfer throughput between the
//! SLAC and ALCF DTNs vs file concurrency, both directions — and time
//! the transfer simulator itself.
//!
//! Run: `cargo bench --bench fig3_transfer`

#[path = "harness.rs"]
mod harness;

use xloop::simnet::VClock;
use xloop::transfer::{TransferRequest, TransferService};

fn run_transfer(src: &str, dst: &str, bytes: u64, files: usize, k: usize) -> f64 {
    let mut svc = TransferService::paper(7);
    let mut clock = VClock::new();
    let mut req = TransferRequest::split_even("fig3", src.into(), dst.into(), bytes, files);
    req.concurrency = Some(k);
    svc.execute(&mut clock, &req).unwrap().throughput_bps()
}

fn main() {
    let bytes: u64 = 25_000_000_000;
    let files = 32;

    harness::group("Fig. 3 series — throughput (GB/s) vs concurrency");
    println!(
        "{:>12} {:>18} {:>18}",
        "concurrency", "SLAC->ALCF (GB/s)", "ALCF->SLAC (GB/s)"
    );
    let mut fwd_series = Vec::new();
    for k in [1usize, 2, 4, 8, 16, 32] {
        let fwd = run_transfer("slac#dtn", "alcf#dtn", bytes, files, k);
        let back = run_transfer("alcf#dtn", "slac#dtn", bytes, files, k);
        fwd_series.push(fwd);
        println!("{k:>12} {:>18.3} {:>18.3}", fwd / 1e9, back / 1e9);
    }
    // paper-shape assertions: monotone rise to >1 GB/s saturation
    assert!(
        fwd_series.windows(2).all(|w| w[1] >= w[0] - 1.0),
        "throughput not monotone"
    );
    assert!(fwd_series[0] < 0.5e9, "single stream should be window-bound");
    assert!(
        *fwd_series.last().unwrap() > 1.0e9,
        "saturated throughput should exceed 1 GB/s"
    );
    println!("\nshape vs paper: rises with concurrency, saturates >1 GB/s — OK");

    harness::group("simulator cost (the thing criterion would measure)");
    for (label, files, k) in [
        ("simulate 25 GB / 32 files / k=8", 32usize, 8usize),
        ("simulate 25 GB / 256 files / k=16", 256, 16),
        ("simulate 25 GB / 1024 files / k=32", 1024, 32),
    ] {
        harness::bench(label, 2, 10, || {
            std::hint::black_box(run_transfer("slac#dtn", "alcf#dtn", bytes, files, k));
        });
    }
}
