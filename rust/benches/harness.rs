//! Minimal criterion-style bench harness (criterion is not in the
//! offline crate cache — see Cargo.toml header).
//!
//! Provides warmup + timed iterations with mean/std/min/p50/p95 and
//! criterion-like one-line reporting. Shared by every bench target via
//! `#[path = "harness.rs"] mod harness;`.
#![allow(dead_code)] // each bench uses a subset of the stats fields

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: u32,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples
        .iter()
        .map(|x| (x - mean) * (x - mean))
        .sum::<f64>()
        / (samples.len().max(2) - 1) as f64;
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let stats = BenchStats {
        iters,
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: sorted[0],
        p50_s: sorted[sorted.len() / 2],
        p95_s: sorted[((sorted.len() as f64 * 0.95) as usize).min(sorted.len() - 1)],
    };
    println!(
        "{name:<52} time: [{} {} {}]  (p95 {}, {} iters)",
        fmt_time(stats.min_s),
        fmt_time(stats.mean_s),
        fmt_time(stats.mean_s + stats.std_s),
        fmt_time(stats.p95_s),
        iters
    );
    stats
}

/// Section header, criterion-group style.
pub fn group(title: &str) {
    println!("\n=== {title} ===\n");
}
