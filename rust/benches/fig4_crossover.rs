//! Bench: regenerate **Fig. 4** — conventional vs ML-surrogate total
//! processing time vs dataset size, with the crossover point — and time
//! the analytical model evaluation.
//!
//! Run: `cargo bench --bench fig4_crossover`

#[path = "harness.rs"]
mod harness;

use xloop::costmodel::CostParams;

fn main() {
    let params = CostParams::paper();

    harness::group("Fig. 4 series — total time (s) vs N");
    println!(
        "{:>12} {:>18} {:>18} {:>8}",
        "N peaks", "conventional (s)", "ML surrogate (s)", "winner"
    );
    let mut crossings = 0;
    let mut last_winner_ml = false;
    let mut n = 1e3;
    while n <= 1e9 {
        let fc = params.f_conventional_us(n) / 1e6;
        let fml = params.f_ml_us(n) / 1e6;
        let ml = fml < fc;
        if ml != last_winner_ml && n > 1e3 {
            crossings += 1;
        }
        last_winner_ml = ml;
        println!(
            "{n:>12.0e} {fc:>18.2} {fml:>18.2} {:>8}",
            if ml { "ML" } else { "conv" }
        );
        n *= 10.0;
    }
    let cross = params.crossover().unwrap();
    println!("\ncrossover N* = {:.3e} peaks", cross.n_star);

    // paper-shape assertions
    assert_eq!(crossings, 1, "exactly one crossover expected");
    assert!(
        (8.0e6..10.0e6).contains(&cross.n_star),
        "crossover {:.3e} outside the paper's regime",
        cross.n_star
    );
    assert!(params.f_conventional_us(1e4) < params.f_ml_us(1e4));
    assert!(params.f_conventional_us(1e8) > params.f_ml_us(1e8));
    println!("shape vs paper: conventional wins only for small N — OK");

    harness::group("model evaluation cost");
    harness::bench("f_conventional + f_ml, one N", 100, 1000, || {
        std::hint::black_box(params.f_conventional_us(std::hint::black_box(1e7)));
        std::hint::black_box(params.f_ml_us(std::hint::black_box(1e7)));
    });
    harness::bench("closed-form crossover", 100, 1000, || {
        std::hint::black_box(params.crossover().unwrap());
    });
}
