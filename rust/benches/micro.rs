//! Micro-benchmarks of the hot paths (the §Perf baseline/after numbers
//! in EXPERIMENTS.md come from here):
//!
//! * conventional analyzer: pseudo-Voigt LM batch labeling, serial
//!   (the seed path) vs the work-stealing pool, and fused vs split
//!   residual/Jacobian evaluation
//! * data generation: render + noise per kilopatch, serial vs pool
//! * PJRT execution: BraggNN/CookieNetAE train step + batched inference
//!   (skipped with a note when `make artifacts` has not been run)
//! * fabric: fluid allocation, JSON parse
//!
//! Run: `cargo bench --bench micro`
//! Thread count: `XLOOP_THREADS=N cargo bench --bench micro`

#[path = "harness.rs"]
mod harness;

use xloop::analysis::pseudo_voigt::{jacobian, value, N_PARAMS};
use xloop::analysis::{
    initial_guess, label_patches_serial, label_patches_timed, lm_solve, LeastSquares, LmOptions,
};
use xloop::data::{bragg, BraggConfig};
use xloop::models::{default_artifacts_dir, ModelMeta, ModelRegistry};
use xloop::pool::Pool;
use xloop::runtime::Runtime;
use xloop::simnet::{max_min_rates, DesBackend, Scheduler, Topology};
use xloop::training::{TrainState, Trainer};
use xloop::transfer::{TransferRequest, TransferService};
use xloop::util::Json;
use xloop::workflow::{run_campaign, CampaignConfig, Mode, Scenario};

/// The seed's split evaluation path: residual and Jacobian each
/// recompute the exp/Lorentzian terms (the `LeastSquares` default).
/// Kept here as the before-side of the fused-LM comparison.
struct SplitPatch<'a> {
    patch: &'a [f32],
    height: usize,
    width: usize,
}

impl LeastSquares<N_PARAMS> for SplitPatch<'_> {
    fn n_residuals(&self) -> usize {
        self.patch.len()
    }
    fn residual(&self, p: &[f64; N_PARAMS], i: usize) -> f64 {
        let y = (i / self.width) as f64;
        let x = (i % self.width) as f64;
        value(p, x, y) - self.patch[i] as f64
    }
    fn jacobian_row(&self, p: &[f64; N_PARAMS], i: usize) -> [f64; N_PARAMS] {
        let y = (i / self.width) as f64;
        let x = (i % self.width) as f64;
        jacobian(p, x, y)
    }
    fn project(&self, p: &mut [f64; N_PARAMS]) {
        p[0] = p[0].max(1e-3);
        p[1] = p[1].clamp(0.0, (self.width - 1) as f64);
        p[2] = p[2].clamp(0.0, (self.height - 1) as f64);
        p[3] = p[3].clamp(0.2, self.width as f64);
        p[4] = p[4].clamp(0.2, self.height as f64);
        p[5] = p[5].clamp(0.0, 1.0);
        p[6] = p[6].max(0.0);
    }
}

fn main() {
    let pool = Pool::global();
    println!(
        "pool: {} worker thread(s) (override with XLOOP_THREADS)\n",
        pool.threads()
    );

    // ---- conventional analyzer A: batch pseudo-Voigt labeling ----
    harness::group("conventional analyzer A — batch labeling (n = 256 noisy peaks)");
    let ds = bragg::generate(&BraggConfig::default(), 256, 3).unwrap();
    let px = 11 * 11;
    let serial = harness::bench("fit 256 peaks, serial (seed path)", 1, 5, || {
        std::hint::black_box(label_patches_serial(&ds.x[..256 * px], 256, 11, 11).unwrap());
    });
    let pooled = harness::bench("fit 256 peaks, work-stealing pool", 1, 5, || {
        std::hint::black_box(label_patches_timed(&ds.x[..256 * px], 256, 11, 11).unwrap());
    });
    println!(
        "    -> {:.0} µs/peak serial vs {:.0} µs/peak pooled = {:.2}x on {} threads",
        serial.mean_s / 256.0 * 1e6,
        pooled.mean_s / 256.0 * 1e6,
        serial.mean_s / pooled.mean_s,
        pool.threads()
    );
    println!("    (paper A: 2.44 µs on 1024 cores = 2500 µs/core)");

    // ---- fused vs split LM inner loop, single thread ----
    harness::group("LM inner loop — fused residual_jacobian vs split (64 fits, 1 thread)");
    let split = harness::bench("64 fits, split eval (seed path)", 1, 5, || {
        for i in 0..64 {
            let patch = &ds.x[i * px..(i + 1) * px];
            let prob = SplitPatch {
                patch,
                height: 11,
                width: 11,
            };
            let init = initial_guess(patch, 11, 11);
            std::hint::black_box(lm_solve(&prob, init, LmOptions::default()).unwrap());
        }
    });
    let fused = harness::bench("64 fits, fused eval", 1, 5, || {
        std::hint::black_box(label_patches_serial(&ds.x[..64 * px], 64, 11, 11).unwrap());
    });
    println!(
        "    -> {:.0} µs/fit split vs {:.0} µs/fit fused = {:.2}x single-thread",
        split.mean_s / 64.0 * 1e6,
        fused.mean_s / 64.0 * 1e6,
        split.mean_s / fused.mean_s
    );

    // ---- data generation S: per kilopatch ----
    harness::group("data generation S — render+noise per kilopatch");
    let cfg = BraggConfig::default();
    let gen_serial = harness::bench("1024 patches, serial (seed path)", 1, 10, || {
        std::hint::black_box(bragg::generate_with_pool(&Pool::new(1), &cfg, 1024, 9).unwrap());
    });
    let gen_pooled = harness::bench("1024 patches, work-stealing pool", 1, 10, || {
        std::hint::black_box(bragg::generate(&cfg, 1024, 9).unwrap());
    });
    println!(
        "    -> {:.2} ms/kilopatch serial vs {:.2} ms/kilopatch pooled = {:.2}x",
        gen_serial.mean_s * 1e3,
        gen_pooled.mean_s * 1e3,
        gen_serial.mean_s / gen_pooled.mean_s
    );

    // ---- fabric micro (no artifacts needed) ----
    harness::group("fabric micro");
    let topo = Topology::paper();
    let slac = topo.facility("slac").unwrap();
    let alcf = topo.facility("alcf").unwrap();
    let route = topo.route(slac, alcf).unwrap().to_vec();
    let routes: Vec<&[_]> = (0..64).map(|_| route.as_slice()).collect();
    harness::bench("max-min fair allocation, 64 flows", 100, 1000, || {
        std::hint::black_box(max_min_rates(&topo, &routes));
    });

    // ---- §13 DES backends: binary heap vs calendar wheel ----
    harness::group("des schedule/pop, heap vs wheel (1e6 events)");
    for (label, backend) in [
        ("1e6 events, heap (BinaryHeap)", DesBackend::Heap),
        ("1e6 events, wheel (calendar queue)", DesBackend::Wheel),
    ] {
        harness::bench(label, 1, 3, || {
            let mut sched = Scheduler::<u32>::with_backend(backend);
            let mut rng = xloop::util::Rng::new(0xD35);
            for i in 0..1_000_000u32 {
                sched.schedule_at(rng.f64() * 1e4, i);
            }
            while sched.pop().is_some() {}
        });
    }

    // ---- §13 water-fill: from-scratch reference vs incremental ----
    // tasks × 8 streaming flows each: 8 tasks = 64 flows, 64 = 512.
    // The paper fabric is one shared route (a single contention
    // component), so "incremental, cold" re-solves everything through
    // the indexed path and "cached" is the steady-state no-change hit.
    harness::group("water-fill re-solve, full vs incremental (64→512 flows)");
    for &tasks in &[8usize, 64] {
        let mut svc = TransferService::paper(1);
        for i in 0..tasks {
            let mut req = TransferRequest::split_even(
                format!("bench-{i}"),
                "slac#dtn".into(),
                "alcf#dtn".into(),
                64_000_000_000,
                32,
            );
            req.concurrency = Some(8);
            svc.submit_task(0.0, &req).unwrap();
        }
        // advance past every handshake so all windows stream
        svc.advance_to(30.0);
        let flows = tasks * 8;
        harness::bench(
            &format!("{flows} flows, full reference solve"),
            2,
            20,
            || {
                std::hint::black_box(svc.shared_stream_rates_reference());
            },
        );
        harness::bench(&format!("{flows} flows, incremental, cold"), 2, 20, || {
            svc.invalidate_rate_cache();
            std::hint::black_box(svc.current_shared_rates());
        });
        harness::bench(&format!("{flows} flows, incremental, cached"), 2, 20, || {
            std::hint::black_box(svc.current_shared_rates());
        });
    }

    // ---- §14 campaign sync: replica vs bounded-lag windows ----
    // One shot per mode (same honesty argument as campaign-scale
    // below), placed before the artifacts gate so it runs everywhere;
    // printed in the `campaign-sync:` line format that
    // scripts/parse_bench.py lifts into `sync_users_per_wall_second`.
    harness::group("campaign sync — replica vs bounded-lag windows (1e4 users)");
    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    for sync in [false, true] {
        let mut cfg = CampaignConfig::new(10_000, scenario.clone(), 30.0, 42);
        cfg.sync_wan = sync;
        let start = std::time::Instant::now();
        let rep = run_campaign(&cfg).unwrap();
        let wall = start.elapsed().as_secs_f64();
        let windows = if sync {
            format!(" ({} windows)", rep.sync_wan_windows)
        } else {
            String::new()
        };
        println!(
            "campaign-sync: {} {} users in {:.3} s = {:.1} users/s{}",
            if sync { "windowed" } else { "replica" },
            cfg.users,
            wall,
            cfg.users as f64 / wall.max(1e-9),
            windows
        );
        std::hint::black_box(rep);
    }

    // ---- PJRT paths: only with built artifacts ----
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!(
            "\n[skip] PJRT benches: artifacts missing — run `make artifacts` to include\n\
             the BraggNN/CookieNetAE train-step and inference measurements"
        );
        return;
    }
    let registry = ModelRegistry::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();

    harness::group("L2/L1 via PJRT — train step (real execution)");
    for name in ["braggnn", "cookienetae"] {
        let meta: ModelMeta = registry.get(name).unwrap().clone();
        let trainer = Trainer::new(&rt, &meta).unwrap();
        let mut state = TrainState::init(&meta).unwrap();
        let n = if name == "braggnn" { 2048 } else { 32 };
        let ds = match name {
            "braggnn" => bragg::generate(&BraggConfig::default(), n, 1).unwrap(),
            _ => xloop::data::cookiebox::generate(&xloop::data::CookieConfig::default(), n, 1)
                .unwrap(),
        };
        let idx: Vec<usize> = (0..meta.train_batch).collect();
        let (x, y) = ds.gather_batch(&idx).unwrap();
        let iters = if name == "braggnn" { 10 } else { 3 };
        let stats = harness::bench(
            &format!("{name} train step (batch {})", meta.train_batch),
            1,
            iters,
            || {
                std::hint::black_box(trainer.step(&mut state, &x, &y).unwrap());
            },
        );
        let gflops = meta.train_flops_per_step / 1e9;
        println!(
            "    -> {:.2} algorithmic GFLOP/step = {:.2} GFLOP/s effective",
            gflops,
            gflops / stats.mean_s
        );
    }

    harness::group("L2/L1 via PJRT — batched inference");
    for name in ["braggnn", "cookienetae"] {
        let meta: ModelMeta = registry.get(name).unwrap().clone();
        let exe = rt.load_hlo(&meta.infer_hlo_path()).unwrap();
        let params = TrainState::init(&meta).unwrap().params;
        let x = xloop::runtime::Tensor::zeros(
            std::iter::once(meta.infer_batch)
                .chain(meta.input_shape.iter().copied())
                .collect(),
        );
        let mut args: Vec<xla::Literal> =
            params.iter().map(|t| t.to_literal().unwrap()).collect();
        args.push(x.to_literal().unwrap());
        let stats = harness::bench(
            &format!("{name} inference (batch {})", meta.infer_batch),
            1,
            10,
            || {
                std::hint::black_box(exe.run_literals(&args).unwrap());
            },
        );
        println!(
            "    -> {:.1} µs/sample (paper E for BraggNN: 0.35 µs on batch GPU)",
            stats.mean_s / meta.infer_batch as f64 * 1e6
        );
    }

    harness::group("pallas render via PJRT");
    let pv = registry.pv().unwrap().clone();
    let mut rng = xloop::util::Rng::new(4);
    let params = bragg::sample_params(&BraggConfig::default(), 1024, &mut rng);
    harness::bench("render 1024 patches (Pallas kernel via PJRT)", 1, 10, || {
        std::hint::black_box(bragg::render_pjrt(&rt, &pv, &params).unwrap());
    });
    let meta_text = std::fs::read_to_string(dir.join("braggnn_meta.json")).unwrap();
    harness::bench("parse braggnn_meta.json", 100, 1000, || {
        std::hint::black_box(Json::parse(&meta_text).unwrap());
    });

    // ---- §13 campaign scale: whole-engine users per wall-second ----
    // Not a harness::bench (one shot per size is the honest number at
    // this scale); printed in the `campaign-scale:` line format that
    // scripts/parse_bench.py lifts into `users_per_wall_second`.
    harness::group("campaign scale — users per wall-clock second");
    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    for users in [1_000usize, 10_000, 100_000] {
        let cfg = CampaignConfig::new(users, scenario.clone(), 30.0, 42);
        let start = std::time::Instant::now();
        let rep = run_campaign(&cfg).unwrap();
        let wall = start.elapsed().as_secs_f64();
        println!(
            "campaign-scale: {} users in {:.3} s = {:.1} users/s",
            users,
            wall,
            users as f64 / wall.max(1e-9)
        );
        std::hint::black_box(rep);
    }
}
