//! Micro-benchmarks of the hot paths (the §Perf baseline/after numbers
//! in EXPERIMENTS.md come from here):
//!
//! * PJRT execution: BraggNN/CookieNetAE train step + batched inference
//! * conventional analyzer: pseudo-Voigt LM fit per peak
//! * data generation: render + noise per kilopatch
//! * fabric: fluid allocation, flow-engine dispatch, JSON parse
//!
//! Run: `cargo bench --bench micro`

#[path = "harness.rs"]
mod harness;

use xloop::analysis;
use xloop::data::{bragg, BraggConfig};
use xloop::models::{default_artifacts_dir, ModelMeta, ModelRegistry};
use xloop::runtime::Runtime;
use xloop::simnet::{max_min_rates, Topology};
use xloop::training::{TrainState, Trainer};
use xloop::util::Json;

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let registry = ModelRegistry::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();

    harness::group("L2/L1 via PJRT — train step (real execution)");
    for name in ["braggnn", "cookienetae"] {
        let meta: ModelMeta = registry.get(name).unwrap().clone();
        let trainer = Trainer::new(&rt, &meta).unwrap();
        let mut state = TrainState::init(&meta).unwrap();
        let n = if name == "braggnn" { 2048 } else { 32 };
        let ds = match name {
            "braggnn" => bragg::generate(&BraggConfig::default(), n, 1).unwrap(),
            _ => xloop::data::cookiebox::generate(&xloop::data::CookieConfig::default(), n, 1)
                .unwrap(),
        };
        let idx: Vec<usize> = (0..meta.train_batch).collect();
        let (x, y) = ds.gather_batch(&idx).unwrap();
        let iters = if name == "braggnn" { 10 } else { 3 };
        let stats = harness::bench(
            &format!("{name} train step (batch {})", meta.train_batch),
            1,
            iters,
            || {
                std::hint::black_box(trainer.step(&mut state, &x, &y).unwrap());
            },
        );
        let gflops = meta.train_flops_per_step / 1e9;
        println!(
            "    -> {:.2} algorithmic GFLOP/step = {:.2} GFLOP/s effective",
            gflops,
            gflops / stats.mean_s
        );
    }

    harness::group("L2/L1 via PJRT — batched inference");
    for name in ["braggnn", "cookienetae"] {
        let meta: ModelMeta = registry.get(name).unwrap().clone();
        let exe = rt.load_hlo(&meta.infer_hlo_path()).unwrap();
        let params = TrainState::init(&meta).unwrap().params;
        let x = xloop::runtime::Tensor::zeros(
            std::iter::once(meta.infer_batch)
                .chain(meta.input_shape.iter().copied())
                .collect(),
        );
        let mut args: Vec<xla::Literal> =
            params.iter().map(|t| t.to_literal().unwrap()).collect();
        args.push(x.to_literal().unwrap());
        let stats = harness::bench(
            &format!("{name} inference (batch {})", meta.infer_batch),
            1,
            10,
            || {
                std::hint::black_box(exe.run_literals(&args).unwrap());
            },
        );
        println!(
            "    -> {:.1} µs/sample (paper E for BraggNN: 0.35 µs on batch GPU)",
            stats.mean_s / meta.infer_batch as f64 * 1e6
        );
    }

    harness::group("conventional analyzer A — pseudo-Voigt LM fit");
    let ds = bragg::generate(&BraggConfig::default(), 256, 3).unwrap();
    let stats = harness::bench("fit 64 noisy peaks", 1, 5, || {
        std::hint::black_box(analysis::label_patches(&ds.x[..64 * 121], 64, 11, 11).unwrap());
    });
    println!(
        "    -> {:.0} µs/peak single-core (paper A: 2.44 µs on 1024 cores = 2500 µs/core)",
        stats.mean_s / 64.0 * 1e6
    );

    harness::group("data generation S");
    harness::bench("render+noise 1024 patches (rust)", 1, 10, || {
        std::hint::black_box(bragg::generate(&BraggConfig::default(), 1024, 9).unwrap());
    });
    let pv = registry.pv().unwrap().clone();
    let mut rng = xloop::util::Rng::new(4);
    let params = bragg::sample_params(&BraggConfig::default(), 1024, &mut rng);
    harness::bench("render 1024 patches (Pallas kernel via PJRT)", 1, 10, || {
        std::hint::black_box(bragg::render_pjrt(&rt, &pv, &params).unwrap());
    });

    harness::group("fabric micro");
    let topo = Topology::paper();
    let slac = topo.facility("slac").unwrap();
    let alcf = topo.facility("alcf").unwrap();
    let route = topo.route(slac, alcf).unwrap().to_vec();
    let routes: Vec<&[_]> = (0..64).map(|_| route.as_slice()).collect();
    harness::bench("max-min fair allocation, 64 flows", 100, 1000, || {
        std::hint::black_box(max_min_rates(&topo, &routes));
    });
    let meta_text = std::fs::read_to_string(dir.join("braggnn_meta.json")).unwrap();
    harness::bench("parse braggnn_meta.json", 100, 1000, || {
        std::hint::black_box(Json::parse(&meta_text).unwrap());
    });
}
