//! Metamorphic invariant suite (DESIGN.md §16 acceptance).
//!
//! Four cross-cutting invariants pinned with the in-crate PRNG (no
//! proptest in the offline cache — same seeded-case technique as
//! `properties.rs`, failing seeds printed for replay):
//!
//! 1. **Dollar partition of unity** — per-tenant bills sum to the
//!    fabric total under random mixes, spot tiers, autoscalers, and
//!    closed-loop drift (pricing, §11/§12).
//! 2. **Water-fill max-min fairness** — the shard-WAN allocator is
//!    feasible, demand-capped, work-conserving, and max-min fair on
//!    random fabrics (transfer, §14).
//! 3. **Wheel ≡ heap** — the two DES backends pop identical
//!    `(time, payload)` sequences under random schedule / cancel /
//!    pop interleavings (DES core, §13).
//! 4. **Knob-off identity** — every composed knob at its off (or
//!    provably inert) setting yields a byte-identical campaign
//!    report (§12–§16 default-path guarantee).

use xloop::costmodel::PriceBook;
use xloop::faas::Autoscaler;
use xloop::simnet::{DesBackend, Scheduler};
use xloop::util::Rng;
use xloop::workflow::{
    parse_mix, parse_spot, run_campaign, water_fill, CampaignConfig, ClosedLoopSpec, Mode,
    Placement, Scenario,
};

const CASES: u64 = 200;

fn artifacts_present() -> bool {
    xloop::models::default_artifacts_dir()
        .join("manifest.json")
        .exists()
}

// ---------------------------------------------------------------- pricing

/// Invariant: the per-tenant bills are a partition of unity over the
/// fabric total — used + idle-share + egress summed across tenants
/// equals provisioned + egress, whatever mix/spot/autoscale/closed-loop
/// combination the campaign ran under.
#[test]
fn prop_dollar_bills_partition_fabric_total() {
    if !artifacts_present() {
        return;
    }
    let book = PriceBook::paper();
    for seed in 0..8u64 {
        let mut rng = Rng::new(9000 + seed);
        let users = 2 + rng.below(3);
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(users, scenario, rng.uniform(0.5, 8.0), 100 + seed);
        if rng.chance(0.5) {
            cfg = cfg.with_mix(parse_mix("braggnn:2,cookienetae:1").unwrap());
        }
        if rng.chance(0.5) {
            cfg = cfg
                .with_spot(parse_spot("alcf#cerebras:120:5").unwrap())
                .with_checkpoint_every_s(Some(10.0));
        } else if rng.chance(0.5) {
            cfg = cfg.with_autoscale(vec![("alcf#cerebras".into(), Autoscaler::up_to(3))]);
        }
        if rng.chance(0.4) {
            cfg = cfg.with_closed_loop(Some(ClosedLoopSpec::default()));
        }
        let report = run_campaign(&cfg).unwrap();
        let d = report.cost.dollars(&book);
        assert_eq!(d.per_tenant.len(), users, "seed {seed}: bill per tenant");
        let billed: f64 = d.per_tenant.iter().map(|t| t.total_usd()).sum();
        let total = d.total_usd();
        assert!(total > 0.0, "seed {seed}: free fabric");
        assert!(
            (billed - total).abs() <= 1e-6 * total,
            "seed {seed}: bills {billed} != fabric total {total}"
        );
    }
}

// --------------------------------------------------------------- transfer

/// Invariant: `water_fill` is feasible (never exceeds cap), demand-capped,
/// work-conserving, and max-min fair — an unsatisfied claimant's
/// allocation is at least every other claimant's.
#[test]
fn prop_water_fill_is_max_min_fair() {
    // hand-pinned: 9 across demands (5, 1, 10) → the small claimant is
    // satisfied, the rest split the remainder evenly
    assert_eq!(water_fill(&[5.0, 1.0, 10.0], 9.0), vec![4.0, 1.0, 4.0]);

    for seed in 0..CASES {
        let mut rng = Rng::new(10_000 + seed);
        let n = 1 + rng.below(12);
        let demands: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 10.0)).collect();
        let cap = rng.uniform(0.0, 25.0);
        let alloc = water_fill(&demands, cap);
        assert_eq!(alloc.len(), n);
        let granted: f64 = alloc.iter().sum();
        let wanted: f64 = demands.iter().sum();
        for (i, (&a, &d)) in alloc.iter().zip(&demands).enumerate() {
            assert!(a >= 0.0, "seed {seed}: negative allocation {a}");
            assert!(a <= d + 1e-9, "seed {seed}: claimant {i} over demand");
        }
        assert!(granted <= cap + 1e-9, "seed {seed}: cap oversubscribed");
        assert!(
            (granted - wanted.min(cap)).abs() <= 1e-9 * (1.0 + wanted.min(cap)),
            "seed {seed}: not work-conserving ({granted} of {})",
            wanted.min(cap)
        );
        for (i, &a) in alloc.iter().enumerate() {
            if a < demands[i] - 1e-9 {
                // unsatisfied ⇒ nobody else got more
                for (j, &b) in alloc.iter().enumerate() {
                    assert!(
                        a >= b - 1e-9,
                        "seed {seed}: starved claimant {i} ({a}) below {j} ({b})"
                    );
                }
            }
        }
    }
}

// -------------------------------------------------------------------- des

/// Invariant: the wheel and heap backends are observationally identical —
/// the same interleaving of schedules, cancellations, and pops yields
/// the same `(time, payload)` sequence from both.
#[test]
fn prop_wheel_and_heap_pop_identically() {
    for seed in 0..CASES {
        let mut rng = Rng::new(11_000 + seed);
        let mut heap: Scheduler<u64> = Scheduler::with_backend(DesBackend::Heap);
        let mut wheel: Scheduler<u64> = Scheduler::with_backend(DesBackend::Wheel);
        let mut payload = 0u64;
        for round in 0..4 {
            let k = 1 + rng.below(32);
            let mut ids = Vec::with_capacity(k);
            for _ in 0..k {
                let dt = rng.uniform(0.0, 500.0);
                ids.push((heap.schedule_after(dt, payload), wheel.schedule_after(dt, payload)));
                payload += 1;
            }
            for (hid, wid) in &ids {
                if rng.chance(0.2) {
                    assert_eq!(
                        heap.cancel(*hid),
                        wheel.cancel(*wid),
                        "seed {seed} round {round}: cancel outcome diverged"
                    );
                }
            }
            for _ in 0..rng.below(k + 1) {
                let (a, b) = (heap.pop(), wheel.pop());
                assert_eq!(a, b, "seed {seed} round {round}: pop diverged");
                if a.is_none() {
                    break;
                }
            }
        }
        loop {
            let (a, b) = (heap.pop(), wheel.pop());
            assert_eq!(a, b, "seed {seed}: drain diverged");
            if a.is_none() {
                break;
            }
        }
    }
}

// --------------------------------------------------------------- knob-off

/// Invariant: every composed campaign knob at its off (or provably
/// inert) setting reproduces the default report byte for byte — the
/// §12–§16 guarantee that unexercised machinery leaves no trace.
#[test]
fn prop_knob_off_reports_are_byte_identical() {
    if !artifacts_present() {
        return;
    }
    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    let base = CampaignConfig::new(3, scenario, 5.0, 13);
    let baseline = format!("{:?}", run_campaign(&base).unwrap());
    let variants: Vec<(&str, CampaignConfig)> = vec![
        (
            "spot off",
            base.clone().with_spot(Vec::new()).with_checkpoint_every_s(None),
        ),
        // serial execution never contends with itself, so window sync
        // is inert at an effective shard count of 1
        ("sync-wan inert", base.clone().with_sync_wan(true)),
        // the broker score is ignored without sites behind the broker
        (
            "sites off",
            base.clone().with_sites(Vec::new()).with_placement(Placement::Dollars),
        ),
        ("closed-loop off", base.clone().with_closed_loop(None)),
    ];
    for (label, cfg) in variants {
        let got = format!("{:?}", run_campaign(&cfg).unwrap());
        assert_eq!(got, baseline, "{label}: report diverged from baseline");
    }
}
