//! Integration tests across the whole coordinator stack — flow engine +
//! faas + transfer + runtime + edge, with failure injection.
//!
//! Tests that need AOT artifacts skip silently when `make artifacts` has
//! not run (CI convention shared with the unit tests).

use xloop::faas::EndpointStatus;
use xloop::flows::ActionStatus;
use xloop::simnet::FaultModel;
use xloop::util::Json;
use xloop::workflow::{
    dnn_trainer_flow, Coordinator, FlowShape, Mode, Scenario, TrainingMode,
};

fn artifacts_present() -> bool {
    xloop::models::default_artifacts_dir()
        .join("manifest.json")
        .exists()
}

#[test]
fn full_flow_with_labeling_real_training_and_serving() {
    if !artifacts_present() {
        return;
    }
    let mut c = Coordinator::paper(99).unwrap();
    c.set_training_mode(TrainingMode::Real {
        steps_override: Some(20),
    });
    let mut scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    scenario.real_samples = 512;
    let shape = FlowShape {
        remote: true,
        with_labeling: true,
        ..Default::default()
    };
    let outcome = c.run_retraining(&scenario, Some(shape)).unwrap();
    assert!(outcome.report.succeeded);

    // the five paper actions all ran, in virtual-time order
    let ids: Vec<&str> = outcome
        .report
        .records
        .iter()
        .map(|r| r.id.as_str())
        .collect();
    assert_eq!(ids, vec!["stage_data", "label", "train", "return_model", "deploy"]);
    let mut last_end = 0.0;
    for r in &outcome.report.records {
        assert!(r.start_vt >= last_end - 1e-9, "actions overlap: {}", r.id);
        last_end = r.end_vt;
    }

    // labeling really ran the LM fitter
    let label_out = outcome.report.output("label").unwrap().get("output").clone();
    assert!(label_out.get("real_s_per_peak").as_f64().unwrap() > 0.0);
    assert!(c.world.last_label_cost_s.is_some());

    // training really ran and the deployed model serves
    assert_eq!(outcome.breakdown.real_steps, 20);
    let dataset = c.world.dataset("braggnn-train").unwrap().clone();
    let serve = c.world.edge.serve_stream(&dataset, 2).unwrap();
    assert!(serve.outputs_finite);

    // event log round-trips through JSON
    let text = outcome.report.to_json().to_string();
    let parsed = Json::parse(&text).unwrap();
    assert_eq!(parsed.get("succeeded").as_bool(), Some(true));
    assert_eq!(parsed.get("actions").as_arr().unwrap().len(), 5);
}

#[test]
fn flaky_wan_recovers_via_retries() {
    if !artifacts_present() {
        return;
    }
    let mut c = Coordinator::paper(7).unwrap();
    c.set_training_mode(TrainingMode::VirtualOnly);
    c.world.transfer.faults = FaultModel::flaky(0.25);
    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    let outcome = c.run_retraining(&scenario, None).unwrap();
    assert!(outcome.report.succeeded, "flow should absorb WAN faults");
    // faults cost time: slower than the clean fabric
    let mut clean = Coordinator::paper(7).unwrap();
    clean.set_training_mode(TrainingMode::VirtualOnly);
    let base = clean.run_retraining(&scenario, None).unwrap();
    assert!(
        outcome.breakdown.data_transfer_s.unwrap() >= base.breakdown.data_transfer_s.unwrap(),
        "faulty transfer not slower"
    );
}

#[test]
fn offline_dcai_endpoint_fails_flow_and_skips_downstream() {
    if !artifacts_present() {
        return;
    }
    let mut c = Coordinator::paper(8).unwrap();
    c.set_training_mode(TrainingMode::VirtualOnly);
    c.world
        .faas
        .as_mut()
        .unwrap()
        .endpoint_mut("alcf#cerebras")
        .unwrap()
        .status = EndpointStatus::Offline;

    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    let err = match c.run_retraining(&scenario, None) {
        Err(e) => e,
        Ok(_) => panic!("flow should fail with the DCAI offline"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("train"), "unexpected failure: {msg}");
}

#[test]
fn missing_scope_blocks_transfer_action() {
    if !artifacts_present() {
        return;
    }
    let mut c = Coordinator::paper(9).unwrap();
    c.set_training_mode(TrainingMode::VirtualOnly);
    // swap in a token lacking transfer:use
    let weak = c
        .engine
        .auth
        .issue(&c.clock, "intruder", &["compute:use", "deploy:use"], 1e9)
        .id;
    c.token = weak;
    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    let err = match c.run_retraining(&scenario, None) {
        Err(e) => e,
        Ok(_) => panic!("flow should fail without transfer scope"),
    };
    assert!(format!("{err:#}").contains("Failed"), "{err:#}");
}

#[test]
fn local_flow_has_exactly_train_and_deploy() {
    if !artifacts_present() {
        return;
    }
    let mut c = Coordinator::paper(10).unwrap();
    c.set_training_mode(TrainingMode::VirtualOnly);
    let scenario = Scenario::table1("cookienetae", Mode::LocalV100).unwrap();
    let outcome = c.run_retraining(&scenario, None).unwrap();
    let ids: Vec<&str> = outcome
        .report
        .records
        .iter()
        .map(|r| r.id.as_str())
        .collect();
    assert_eq!(ids, vec!["train", "deploy"]);
    assert!(outcome.breakdown.data_transfer_s.is_none());
}

#[test]
fn flow_definition_json_roundtrip_executes() {
    if !artifacts_present() {
        return;
    }
    // serialize the generated definition back to JSON-ish by rebuilding
    // from its own JSON source and running it
    let def = dnn_trainer_flow(&FlowShape::default()).unwrap();
    assert_eq!(def.name, "dnn-trainer-flow-remote");
    let mut c = Coordinator::paper(11).unwrap();
    c.set_training_mode(TrainingMode::VirtualOnly);
    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    let dataset = c.prepare_dataset(&scenario).unwrap();
    let input = Json::obj(vec![
        ("model", Json::str("braggnn")),
        ("dataset", Json::str(dataset)),
        ("dataset_bytes", Json::num(1e8)),
        ("train_endpoint", Json::str("alcf#cerebras")),
    ]);
    let token = c.token;
    let report = c
        .engine
        .run(&def, &input, &token, &mut c.world, &mut c.clock)
        .unwrap();
    assert!(report.succeeded);
}

#[test]
fn successive_retrainings_bump_edge_versions() {
    if !artifacts_present() {
        return;
    }
    let mut c = Coordinator::paper(12).unwrap();
    c.set_training_mode(TrainingMode::VirtualOnly);
    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    c.run_retraining(&scenario, None).unwrap();
    assert_eq!(c.world.edge.deployed().unwrap().version, 1);
    c.run_retraining(&scenario, None).unwrap();
    assert_eq!(c.world.edge.deployed().unwrap().version, 2);
    // both models can coexist on the fabric
    let cookie = Scenario::table1("cookienetae", Mode::RemoteCerebras).unwrap();
    c.run_retraining(&cookie, None).unwrap();
    assert_eq!(c.world.edge.deployed().unwrap().meta.name, "cookienetae");
}

#[test]
fn auth_validations_cover_every_action() {
    if !artifacts_present() {
        return;
    }
    let mut c = Coordinator::paper(13).unwrap();
    c.set_training_mode(TrainingMode::VirtualOnly);
    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
    let outcome = c.run_retraining(&scenario, None).unwrap();
    // one introspection per executed action (paper: every interaction is
    // authenticated)
    let executed = outcome
        .report
        .records
        .iter()
        .filter(|r| !matches!(r.status, ActionStatus::Skipped))
        .count() as u64;
    assert_eq!(c.engine.auth.validations, executed);
}
