//! Property-based tests over the coordinator substrates.
//!
//! proptest is not in the offline crate cache, so these use the same
//! technique with the in-crate PRNG: hundreds of seeded random cases per
//! invariant, failing seeds printed for replay. Each test states the
//! invariant it pins.

use xloop::analysis::{fit_patch, pseudo_voigt};
use xloop::costmodel::CostParams;
use xloop::flows::{ActionDef, FailurePolicy, FlowDefinition};
use xloop::simnet::{max_min_rates, simulate, FlowSpec, Topology, VClock};
use xloop::transfer::{TransferRequest, TransferService};
use xloop::util::{Json, Rng};

const CASES: u64 = 200;

// ------------------------------------------------------------------ fluid

/// Invariant: max-min fair rates never oversubscribe any link, and at
/// least one link is saturated (work conservation).
#[test]
fn prop_fluid_rates_feasible_and_work_conserving() {
    let topo = Topology::paper();
    let slac = topo.facility("slac").unwrap();
    let alcf = topo.facility("alcf").unwrap();
    let fwd = topo.route(slac, alcf).unwrap().to_vec();
    let rev = topo.route(alcf, slac).unwrap().to_vec();

    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(24);
        let routes: Vec<&[_]> = (0..n)
            .map(|_| {
                if rng.chance(0.5) {
                    fwd.as_slice()
                } else {
                    rev.as_slice()
                }
            })
            .collect();
        let rates = max_min_rates(&topo, &routes);
        assert!(rates.iter().all(|&r| r >= 0.0), "seed {seed}: negative rate");
        // per-link feasibility
        for li in 0..3 {
            let link = xloop::simnet::LinkId(li);
            let load: f64 = routes
                .iter()
                .zip(&rates)
                .filter(|(r, _)| r.contains(&link))
                .map(|(_, &rate)| rate)
                .sum();
            let cap = topo.link(link).capacity_bps;
            assert!(
                load <= cap * (1.0 + 1e-9),
                "seed {seed}: link {li} oversubscribed {load} > {cap}"
            );
        }
        // work conservation: every flow is bottlenecked somewhere
        let saturated = (0..3).any(|li| {
            let link = xloop::simnet::LinkId(li);
            let load: f64 = routes
                .iter()
                .zip(&rates)
                .filter(|(r, _)| r.contains(&link))
                .map(|(_, &rate)| rate)
                .sum();
            (load - topo.link(link).capacity_bps).abs() < 1.0
        });
        assert!(saturated, "seed {seed}: no saturated link");
    }
}

/// Invariant: completion times are monotone in flow size, and every flow
/// finishes no earlier than bytes/bottleneck after its arrival.
#[test]
fn prop_fluid_completion_bounds() {
    let topo = Topology::paper();
    let slac = topo.facility("slac").unwrap();
    let alcf = topo.facility("alcf").unwrap();
    let route = topo.route(slac, alcf).unwrap().to_vec();
    let bottleneck = 10.0e9 / 8.0;

    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let n = 1 + rng.below(12);
        let flows: Vec<FlowSpec> = (0..n)
            .map(|_| FlowSpec {
                route: route.clone(),
                bytes: rng.uniform(1e6, 5e9),
                arrival: rng.uniform(0.0, 10.0),
            })
            .collect();
        let res = simulate(&topo, &flows);
        for (f, r) in flows.iter().zip(&res) {
            let min_duration = f.bytes / bottleneck;
            assert!(
                r.finish >= f.arrival + min_duration - 1e-6,
                "seed {seed}: faster than line rate"
            );
            assert!(r.finish.is_finite(), "seed {seed}: unfinished flow");
        }
    }
}

// --------------------------------------------------------------- transfer

/// Invariant: duration grows with payload; per-file reports cover every
/// file exactly once; throughput never exceeds the fabric cap.
#[test]
fn prop_transfer_monotone_and_complete() {
    for seed in 0..40 {
        let mut rng = Rng::new(2000 + seed);
        let files = 1 + rng.below(24);
        let k = 1 + rng.below(12);
        let small = rng.uniform(1e7, 1e8) as u64;
        let big = small * 4;

        let mut run = |bytes: u64| {
            let mut svc = TransferService::paper(seed);
            let mut clock = VClock::new();
            let mut req = TransferRequest::split_even(
                "prop",
                "slac#dtn".into(),
                "alcf#dtn".into(),
                bytes,
                files,
            );
            req.concurrency = Some(k);
            svc.execute(&mut clock, &req).unwrap()
        };
        let rep_small = run(small);
        let rep_big = run(big);
        assert!(
            rep_big.duration() > rep_small.duration(),
            "seed {seed}: duration not monotone in bytes"
        );
        assert_eq!(rep_small.files.len(), files);
        assert!(rep_small.files.iter().all(|f| f.finish_vt.is_finite()));
        assert!(
            rep_small.throughput_bps() <= 1.25e9 * 1.001,
            "seed {seed}: throughput above fabric cap"
        );
    }
}

/// Invariant: injected faults never corrupt completion (all files finish
/// or the task errors), and a fault-free run is never slower.
#[test]
fn prop_transfer_fault_injection_safe() {
    for seed in 0..40 {
        let mut rng = Rng::new(3000 + seed);
        let p = rng.uniform(0.05, 0.5);
        let mut svc = TransferService::paper(seed);
        svc.faults = xloop::simnet::FaultModel {
            file_failure_prob: p,
            retry_backoff_s: 1.0,
            max_attempts: 8,
        };
        let mut clock = VClock::new();
        let mut req = TransferRequest::split_even(
            "prop-faulty",
            "slac#dtn".into(),
            "alcf#dtn".into(),
            500_000_000,
            8,
        );
        req.concurrency = Some(4);
        match svc.execute(&mut clock, &req) {
            Ok(rep) => {
                assert!(rep.files.iter().all(|f| f.finish_vt.is_finite()));
                let mut clean_svc = TransferService::paper(seed);
                let mut clean_clock = VClock::new();
                let clean = clean_svc.execute(&mut clean_clock, &req).unwrap();
                assert!(
                    rep.duration() >= clean.duration() - 1e-9,
                    "seed {seed}: faults made the task faster"
                );
            }
            Err(e) => {
                // hard failure allowed only via exhausted attempts
                assert!(format!("{e:#}").contains("failed"), "seed {seed}: {e:#}");
            }
        }
    }
}

// ------------------------------------------------------------------ flows

fn random_dag(rng: &mut Rng) -> FlowDefinition {
    let n = 2 + rng.below(8);
    let actions: Vec<ActionDef> = (0..n)
        .map(|i| {
            let mut deps = vec![];
            for j in 0..i {
                if rng.chance(0.3) {
                    deps.push(format!("a{j}"));
                }
            }
            ActionDef {
                id: format!("a{i}"),
                provider: "noop".into(),
                params: Json::Null,
                depends_on: deps,
                retries: 0,
                retry: xloop::flows::RetryPolicy::fixed(0.1),
                on_failure: FailurePolicy::Continue,
                is_handler: false,
            }
        })
        .collect();
    FlowDefinition::new("prop", actions).unwrap()
}

/// Invariant: the execution order of a random DAG is a valid topological
/// order covering every non-handler action exactly once.
#[test]
fn prop_flow_order_is_topological() {
    for seed in 0..CASES {
        let mut rng = Rng::new(4000 + seed);
        let def = random_dag(&mut rng);
        let order = def.order();
        assert_eq!(order.len(), def.actions.len(), "seed {seed}");
        let mut seen = std::collections::BTreeSet::new();
        for &i in order {
            for d in &def.actions[i].depends_on {
                assert!(seen.contains(d.as_str()), "seed {seed}: dep `{d}` after dependent");
            }
            assert!(seen.insert(def.actions[i].id.as_str()), "seed {seed}: duplicate");
        }
    }
}

/// Invariant: random extra edges never create acceptance of a cyclic
/// graph (closing a cycle must be rejected).
#[test]
fn prop_flow_cycles_rejected() {
    for seed in 0..CASES {
        let mut rng = Rng::new(5000 + seed);
        let def = random_dag(&mut rng);
        if def.actions.len() < 2 {
            continue;
        }
        // add a back edge from the first action in topo order to the last
        let first = def.order()[0];
        let last = *def.order().last().unwrap();
        if first == last {
            continue;
        }
        let mut actions = def.actions.clone();
        let last_id = actions[last].id.clone();
        actions[first].depends_on.push(last_id);
        // now last -> ... -> first -> last is a cycle iff first is
        // reachable from last; adding dep(first -> last) always closes
        // one since last depends (transitively or not) on nothing after
        // it — it may still be a DAG when first and last are unrelated.
        match FlowDefinition::new("maybe-cyclic", actions) {
            Ok(d) => {
                // if accepted, the order must still be valid
                let order = d.order();
                let mut seen = std::collections::BTreeSet::new();
                for &i in order {
                    for dep in &d.actions[i].depends_on {
                        assert!(seen.contains(dep.as_str()), "seed {seed}");
                    }
                    seen.insert(d.actions[i].id.as_str());
                }
            }
            Err(e) => assert!(e.to_string().contains("cycle"), "seed {seed}: {e}"),
        }
    }
}

// -------------------------------------------------------------- costmodel

/// Invariant: when the crossover exists, f_conventional < f_ml strictly
/// below N* and strictly above it the other way round.
#[test]
fn prop_crossover_separates_regimes() {
    for seed in 0..CASES {
        let mut rng = Rng::new(6000 + seed);
        let params = CostParams {
            c_move_us: rng.uniform(0.01, 1.0),
            c_analyze_us: rng.uniform(0.5, 10.0),
            c_return_us: rng.uniform(0.0, 0.1),
            c_label_return_us: rng.uniform(0.0, 0.1),
            c_estimate_us: rng.uniform(0.01, 0.5),
            t_train_us: rng.uniform(1e6, 1e8),
            t_model_move_us: rng.uniform(1e2, 1e5),
            p: rng.uniform(0.01, 0.5),
        };
        let Ok(cross) = params.crossover() else {
            continue; // surrogate never wins for this draw — fine
        };
        let lo = cross.n_star * 0.9;
        let hi = cross.n_star * 1.1;
        assert!(
            params.f_conventional_us(lo) < params.f_ml_us(lo),
            "seed {seed}: below N* conventional should win"
        );
        assert!(
            params.f_conventional_us(hi) > params.f_ml_us(hi),
            "seed {seed}: above N* ML should win"
        );
    }
}

// ------------------------------------------------------------------- json

/// Invariant: serialize → parse is the identity on random JSON values.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(128) as u8;
                            if c.is_ascii_graphic() || c == b' ' {
                                c as char
                            } else {
                                '\\'
                            }
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..CASES {
        let mut rng = Rng::new(7000 + seed);
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}: {text}");
    }
}

// --------------------------------------------------------------- analysis

/// Invariant: the LM fitter recovers the center of random clean peaks to
/// sub-0.05 px.
#[test]
fn prop_fitter_recovers_random_clean_peaks() {
    for seed in 0..60 {
        let mut rng = Rng::new(8000 + seed);
        let truth = [
            rng.uniform(50.0, 400.0),
            rng.uniform(3.0, 7.0),
            rng.uniform(3.0, 7.0),
            rng.uniform(0.8, 2.2),
            rng.uniform(0.8, 2.2),
            rng.uniform(0.1, 0.9),
            rng.uniform(0.0, 8.0),
        ];
        let mut patch = vec![0.0f32; 121];
        for r in 0..11 {
            for c in 0..11 {
                patch[r * 11 + c] = pseudo_voigt::value(&truth, c as f64, r as f64) as f32;
            }
        }
        let fit = fit_patch(&patch, 11, 11).unwrap();
        let (x, y) = fit.center();
        assert!(
            (x - truth[1]).abs() < 0.05 && (y - truth[2]).abs() < 0.05,
            "seed {seed}: truth ({}, {}) got ({x}, {y})",
            truth[1],
            truth[2]
        );
    }
}

// ------------------------------------------------------------------- rng

/// Invariant: dataset generation is a pure function of its seed.
#[test]
fn prop_dataset_determinism() {
    for seed in 0..20 {
        let a = xloop::data::bragg::generate(&xloop::data::BraggConfig::default(), 16, seed)
            .unwrap();
        let b = xloop::data::bragg::generate(&xloop::data::BraggConfig::default(), 16, seed)
            .unwrap();
        assert_eq!(a.x, b.x, "seed {seed}");
        assert_eq!(a.y, b.y, "seed {seed}");
    }
}
