//! Parameter templating: `${input.x.y}` and `${result.action.key}`.
//!
//! Globus Flows passes state between actions by referencing the flow
//! input and prior action outputs; this is the equivalent for our JSON
//! action parameters. A string that is *exactly* one `${...}` reference
//! is replaced by the referenced JSON value (preserving its type);
//! references embedded in longer strings are stringified in place.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Resolve all templates in `params` against the flow `input` and the
/// `outputs` of previously completed actions.
pub fn resolve_params(
    params: &Json,
    input: &Json,
    outputs: &BTreeMap<String, Json>,
) -> Result<Json> {
    Ok(match params {
        Json::Str(s) => resolve_string(s, input, outputs)?,
        Json::Arr(items) => Json::Arr(
            items
                .iter()
                .map(|v| resolve_params(v, input, outputs))
                .collect::<Result<_>>()?,
        ),
        Json::Obj(map) => Json::Obj(
            map.iter()
                .map(|(k, v)| Ok((k.clone(), resolve_params(v, input, outputs)?)))
                .collect::<Result<_>>()?,
        ),
        other => other.clone(),
    })
}

fn resolve_string(
    s: &str,
    input: &Json,
    outputs: &BTreeMap<String, Json>,
) -> Result<Json> {
    // whole-string reference keeps the referenced type
    if let Some(path) = s
        .strip_prefix("${")
        .and_then(|r| r.strip_suffix("}"))
        .filter(|p| !p.contains("${"))
    {
        if !s[2..s.len() - 1].contains('}') {
            return Ok(lookup(path, input, outputs)?.clone());
        }
    }
    // embedded references: stringify each
    let mut out = String::new();
    let mut rest = s;
    while let Some(start) = rest.find("${") {
        out.push_str(&rest[..start]);
        let after = &rest[start + 2..];
        let end = after
            .find('}')
            .with_context(|| format!("unterminated template in `{s}`"))?;
        let path = &after[..end];
        let v = lookup(path, input, outputs)?;
        match v {
            Json::Str(inner) => out.push_str(inner),
            other => out.push_str(&other.to_string()),
        }
        rest = &after[end + 1..];
    }
    out.push_str(rest);
    Ok(Json::Str(out))
}

fn lookup<'a>(
    path: &str,
    input: &'a Json,
    outputs: &'a BTreeMap<String, Json>,
) -> Result<&'a Json> {
    let mut parts = path.split('.');
    let root = parts.next().context("empty template path")?;
    let mut cur: &Json = match root {
        "input" => input,
        "result" => {
            let action = parts
                .next()
                .with_context(|| format!("`${{result...}}` needs an action id in `{path}`"))?;
            outputs
                .get(action)
                .with_context(|| format!("no completed action `{action}` for `${{{path}}}`"))?
        }
        other => bail!("template root must be `input` or `result`, got `{other}`"),
    };
    for key in parts {
        let next = cur.get(key);
        if next.is_null() && cur.get(key) == &Json::Null {
            // distinguish "missing" from literal null by map lookup
            match cur.as_obj() {
                Some(m) if m.contains_key(key) => {}
                _ => bail!("template `${{{path}}}`: key `{key}` not found"),
            }
        }
        cur = cur.get(key);
    }
    Ok(cur)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Json, BTreeMap<String, Json>) {
        let input = Json::parse(r#"{"model": "braggnn", "n": 5, "dst": {"host": "edge1"}}"#)
            .unwrap();
        let mut outputs = BTreeMap::new();
        outputs.insert(
            "train".to_string(),
            Json::parse(r#"{"loss": 0.25, "artifact": "m.bin"}"#).unwrap(),
        );
        (input, outputs)
    }

    #[test]
    fn whole_string_keeps_type() {
        let (input, outputs) = setup();
        let p = Json::parse(r#"{"count": "${input.n}", "loss": "${result.train.loss}"}"#)
            .unwrap();
        let r = resolve_params(&p, &input, &outputs).unwrap();
        assert_eq!(r.get("count"), &Json::Num(5.0));
        assert_eq!(r.get("loss"), &Json::Num(0.25));
    }

    #[test]
    fn embedded_references_stringify() {
        let (input, outputs) = setup();
        let p = Json::str("deploy ${input.model} (loss=${result.train.loss}) to ${input.dst.host}");
        let r = resolve_params(&p, &input, &outputs).unwrap();
        assert_eq!(
            r.as_str(),
            Some("deploy braggnn (loss=0.25) to edge1")
        );
    }

    #[test]
    fn nested_structures_resolved() {
        let (input, outputs) = setup();
        let p = Json::parse(r#"{"a": ["${input.model}", {"b": "${result.train.artifact}"}]}"#)
            .unwrap();
        let r = resolve_params(&p, &input, &outputs).unwrap();
        assert_eq!(r.get("a").at(0).as_str(), Some("braggnn"));
        assert_eq!(r.get("a").at(1).get("b").as_str(), Some("m.bin"));
    }

    #[test]
    fn errors_are_specific() {
        let (input, outputs) = setup();
        for (tpl, needle) in [
            ("${result.ghost.x}", "no completed action"),
            ("${weird.x}", "root"),
            ("${input.missing}", "not found"),
            ("prefix ${input.n", "unterminated"),
        ] {
            let err = resolve_params(&Json::str(tpl), &input, &outputs).unwrap_err();
            assert!(err.to_string().contains(needle), "{tpl}: {err}");
        }
    }

    #[test]
    fn non_template_strings_untouched() {
        let (input, outputs) = setup();
        let p = Json::str("plain string $no-brace {also}");
        let r = resolve_params(&p, &input, &outputs).unwrap();
        assert_eq!(r.as_str(), Some("plain string $no-brace {also}"));
    }
}
