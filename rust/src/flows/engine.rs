//! The flow run engine: executes validated `FlowDefinition`s against a
//! set of registered action providers, with template parameter passing,
//! per-action authentication, retries, failure policies, and a full
//! event log whose virtual-time spans become the Table 1 breakdown.
//!
//! Discrete-event execution model (DESIGN.md §3): action providers never
//! touch the clock. `ActionProvider::start` fires at a virtual instant
//! and returns an [`Effect`] — either a scheduled completion (`Done`
//! with a duration) or a [`Ticket`] for work submitted to a shared
//! fabric (WAN transfers, faas queues) whose completion time depends on
//! contention and is resolved later through the [`FabricHost`] context.
//! A [`FlowRun`] is therefore resumable: `FlowEngine::poll` advances it
//! as far as the current virtual time allows and reports what it is
//! waiting for, so N runs interleave correctly under one event loop
//! (`workflow::campaign`). The synchronous `run` drives a single run to
//! completion over the same machinery — the degenerate N=1 case, with
//! bit-identical timings to the pre-DES engine.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::definition::{FailurePolicy, FlowDefinition};
use super::template::resolve_params;
use crate::auth::{AuthService, TokenId};
use crate::simnet::VClock;
use crate::util::Json;

/// Handle for work submitted to a shared fabric; resolved by the
/// context's [`FabricHost::take_ready`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Ticket(pub u64);

/// How an action started at time `t` completes.
#[derive(Debug)]
pub enum Effect {
    /// Completes `duration` virtual seconds after its start.
    Done { duration: f64, output: Json },
    /// Submitted to a shared fabric; the run parks until the ticket
    /// resolves (completion time depends on contention).
    Pending(Ticket),
}

impl Effect {
    /// A completion with no virtual-time cost.
    pub fn instant(output: Json) -> Effect {
        Effect::Done {
            duration: 0.0,
            output,
        }
    }

    /// A completion `duration` seconds after the action body fired.
    pub fn after(duration: f64, output: Json) -> Effect {
        Effect::Done { duration, output }
    }
}

/// One pluggable action kind (Transfer, Compute, Deploy, ...).
///
/// `Send` supertrait: providers are stateless handles onto the context,
/// and the flow engine (inside a campaign shard) crosses pool-worker
/// threads at bounded-lag window barriers.
pub trait ActionProvider<C>: Send {
    /// Provider name referenced by `ActionDef::provider`.
    fn name(&self) -> &'static str;

    /// Auth scope a token must carry to invoke this provider.
    fn scope(&self) -> String {
        format!("{}:use", self.name())
    }

    /// Begin the action at virtual time `now` and return its scheduled
    /// completion. Providers must not advance any clock: fixed-cost work
    /// returns `Effect::Done { duration, .. }`, shared-fabric work
    /// submits and returns `Effect::Pending`.
    fn start(&self, ctx: &mut C, now: f64, params: &Json) -> Result<Effect>;
}

/// Capability the engine needs from its context to resolve `Pending`
/// effects: shared fabrics that advance in virtual time and complete
/// tickets. Contexts without fabrics implement this trivially (every
/// method returning "nothing pending").
pub trait FabricHost {
    /// Earliest future virtual time at which any fabric changes state.
    fn next_fabric_event(&mut self) -> Option<f64>;

    /// Advance all fabrics to `t`, completing work due by then.
    fn advance_fabrics(&mut self, t: f64);

    /// Consume the outcome of a ticket if complete: `(finish_vt, result)`.
    fn take_ready(&mut self, ticket: Ticket) -> Option<(f64, Result<Json>)>;
}

/// Outcome of one action inside a run.
#[derive(Debug, Clone, PartialEq)]
pub enum ActionStatus {
    Success,
    Failed(String),
    /// not run because a dependency failed or the run aborted
    Skipped,
}

/// Event-log entry for one action.
#[derive(Debug, Clone)]
pub struct ActionRecord {
    pub id: String,
    pub provider: String,
    pub attempts: u32,
    pub start_vt: f64,
    pub end_vt: f64,
    pub status: ActionStatus,
}

impl ActionRecord {
    pub fn duration(&self) -> f64 {
        self.end_vt - self.start_vt
    }
}

/// Full record of one flow run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub flow: String,
    pub start_vt: f64,
    pub end_vt: f64,
    pub succeeded: bool,
    pub records: Vec<ActionRecord>,
    /// successful action outputs by action id
    pub outputs: BTreeMap<String, Json>,
}

impl RunReport {
    pub fn duration(&self) -> f64 {
        self.end_vt - self.start_vt
    }

    pub fn record(&self, id: &str) -> Result<&ActionRecord> {
        self.records
            .iter()
            .find(|r| r.id == id)
            .with_context(|| format!("run has no action `{id}`"))
    }

    pub fn output(&self, id: &str) -> Result<&Json> {
        self.outputs
            .get(id)
            .with_context(|| format!("no output recorded for `{id}`"))
    }

    /// Serialize the event log (persisted by the CLI for every run).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("flow", Json::str(self.flow.clone())),
            ("start_vt", Json::num(self.start_vt)),
            ("end_vt", Json::num(self.end_vt)),
            ("succeeded", Json::Bool(self.succeeded)),
            (
                "actions",
                Json::arr(
                    self.records
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::str(r.id.clone())),
                                ("provider", Json::str(r.provider.clone())),
                                ("attempts", Json::num(r.attempts as f64)),
                                ("start_vt", Json::num(r.start_vt)),
                                ("end_vt", Json::num(r.end_vt)),
                                (
                                    "status",
                                    match &r.status {
                                        ActionStatus::Success => Json::str("success"),
                                        ActionStatus::Skipped => Json::str("skipped"),
                                        ActionStatus::Failed(m) => Json::obj(vec![(
                                            "failed",
                                            Json::str(m.clone()),
                                        )]),
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// What a poll left the run doing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RunPoll {
    /// Blocked until this absolute virtual time (a scheduled completion).
    WaitUntil(f64),
    /// Blocked on a fabric ticket; progress requires `advance_fabrics`.
    Blocked,
    Finished,
}

/// Where an in-flight action stands.
enum Phase {
    /// Provider not yet invoked; the body fires at `InFlight::body_at`.
    Start,
    /// `Done` effect completing at `t`.
    FinishAt { t: f64, output: Json },
    /// Waiting on a fabric ticket.
    Await { ticket: Ticket },
    /// A failed attempt; the next attempt fires at `t`.
    RetryAt { t: f64 },
    /// Terminal failure at `t` with the recorded message.
    FailAt { t: f64, msg: String },
}

/// One action being executed (possibly a catch handler).
struct InFlight {
    action_id: String,
    provider: String,
    /// order position to resume at once this action settles
    resume_pos: usize,
    is_handler: bool,
    /// action start (dispatch begins here)
    start_vt: f64,
    /// when auth fires and attempts begin: start + dispatch + introspection
    body_at: f64,
    attempts: u32,
    params: Option<Json>,
    phase: Phase,
}

/// A resumable flow run. Owns its definition/input so N runs can
/// interleave without lifetime entanglement.
pub struct FlowRun {
    def: FlowDefinition,
    input: Json,
    token: TokenId,
    start_vt: f64,
    /// the run's frontier: end of the last settled step
    t: f64,
    order_pos: usize,
    statuses: BTreeMap<String, ActionStatus>,
    outputs: BTreeMap<String, Json>,
    records: Vec<ActionRecord>,
    aborted: bool,
    in_flight: Option<InFlight>,
    finished: bool,
}

impl FlowRun {
    pub fn flow_name(&self) -> &str {
        &self.def.name
    }

    pub fn start_vt(&self) -> f64 {
        self.start_vt
    }

    /// End of the last settled step (final end time once finished).
    pub fn end_vt(&self) -> f64 {
        self.t
    }

    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Consume the run into its report (meaningful once finished).
    pub fn into_report(self) -> RunReport {
        let succeeded = self.finished
            && !self.aborted
            && self
                .records
                .iter()
                .all(|r| matches!(r.status, ActionStatus::Success));
        RunReport {
            flow: self.def.name.clone(),
            start_vt: self.start_vt,
            end_vt: self.t,
            succeeded,
            records: self.records,
            outputs: self.outputs,
        }
    }
}

/// Internal step outcome while polling.
enum StepOut {
    Progress,
    Wait(f64),
    Blocked,
}

/// The engine: providers + auth + dispatch overhead accounting.
pub struct FlowEngine<C> {
    providers: BTreeMap<&'static str, Box<dyn ActionProvider<C>>>,
    pub auth: AuthService,
    /// flows-service bookkeeping charged per action dispatch
    pub dispatch_overhead_s: f64,
}

impl<C> Default for FlowEngine<C> {
    fn default() -> Self {
        FlowEngine {
            providers: BTreeMap::new(),
            auth: AuthService::new(),
            dispatch_overhead_s: 0.2,
        }
    }
}

impl<C> FlowEngine<C> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register_provider(&mut self, p: Box<dyn ActionProvider<C>>) -> Result<()> {
        let name = p.name();
        if self.providers.contains_key(name) {
            bail!("provider `{name}` already registered");
        }
        self.providers.insert(name, p);
        Ok(())
    }

    pub fn provider_names(&self) -> Vec<&'static str> {
        self.providers.keys().copied().collect()
    }

    /// Fan independent *real* CPU work out on the process-wide
    /// work-stealing pool, returning results in task order. Virtual-time
    /// accounting stays with the caller — this is the entry point action
    /// providers (labeling, rendering, future engine stages) use for the
    /// compute that actually burns cycles; `XLOOP_THREADS=1` forces the
    /// deterministic serial mode.
    pub fn scope<'env, R: Send>(&self, tasks: Vec<crate::pool::ScopeTask<'env, R>>) -> Vec<R> {
        crate::pool::scope(tasks)
    }

    /// Validate and open a resumable run starting at virtual time `now`.
    pub fn begin(
        &self,
        def: &FlowDefinition,
        input: &Json,
        token: &TokenId,
        now: f64,
    ) -> Result<FlowRun> {
        // all providers referenced must exist before we start
        for a in &def.actions {
            if !self.providers.contains_key(a.provider.as_str()) {
                bail!(
                    "flow `{}`: no provider `{}` (have: {})",
                    def.name,
                    a.provider,
                    self.provider_names().join(", ")
                );
            }
        }
        Ok(FlowRun {
            def: def.clone(),
            input: input.clone(),
            token: *token,
            start_vt: now,
            t: now,
            order_pos: 0,
            statuses: BTreeMap::new(),
            outputs: BTreeMap::new(),
            records: Vec::new(),
            aborted: false,
            in_flight: None,
            finished: false,
        })
    }

    /// Advance a run as far as the current virtual time `now` allows.
    /// Idempotent at a fixed `now`; call again after time advances or
    /// fabrics complete work.
    pub fn poll(&mut self, run: &mut FlowRun, ctx: &mut C, now: f64) -> Result<RunPoll>
    where
        C: FabricHost,
    {
        loop {
            if run.finished {
                return Ok(RunPoll::Finished);
            }
            if run.in_flight.is_some() {
                match self.step_in_flight(run, ctx, now)? {
                    StepOut::Progress => continue,
                    StepOut::Wait(t) => return Ok(RunPoll::WaitUntil(t)),
                    StepOut::Blocked => return Ok(RunPoll::Blocked),
                }
            }
            // nothing in flight: settle skips, launch the next action, or
            // finish the run
            if run.order_pos >= run.def.order().len() {
                run.finished = true;
                return Ok(RunPoll::Finished);
            }
            let idx = run.def.order()[run.order_pos];
            let action = &run.def.actions[idx];
            let dep_ok = action
                .depends_on
                .iter()
                .all(|d| matches!(run.statuses.get(d.as_str()), Some(ActionStatus::Success)));
            if run.aborted || !dep_ok {
                run.statuses
                    .insert(action.id.clone(), ActionStatus::Skipped);
                run.records.push(ActionRecord {
                    id: action.id.clone(),
                    provider: action.provider.clone(),
                    attempts: 0,
                    start_vt: run.t,
                    end_vt: run.t,
                    status: ActionStatus::Skipped,
                });
                run.order_pos += 1;
                continue;
            }
            let id = action.id.clone();
            let resume = run.order_pos + 1;
            self.launch(run, &id, resume, false);
        }
    }

    /// Put an action in flight starting at the run's frontier.
    fn launch(&self, run: &mut FlowRun, action_id: &str, resume_pos: usize, is_handler: bool) {
        let provider = run
            .def
            .action(action_id)
            .map(|a| a.provider.clone())
            .unwrap_or_default();
        // same accumulation order as the pre-DES engine: dispatch is
        // charged first, then token introspection
        let body_at = (run.t + self.dispatch_overhead_s) + self.auth.introspection_s;
        run.in_flight = Some(InFlight {
            action_id: action_id.to_string(),
            provider,
            resume_pos,
            is_handler,
            start_vt: run.t,
            body_at,
            attempts: 0,
            params: None,
            phase: Phase::Start,
        });
    }

    fn step_in_flight(&mut self, run: &mut FlowRun, ctx: &mut C, now: f64) -> Result<StepOut>
    where
        C: FabricHost,
    {
        let mut fl = run.in_flight.take().expect("in-flight action");
        loop {
            match std::mem::replace(&mut fl.phase, Phase::Start) {
                Phase::Start => {
                    if now < fl.body_at {
                        let at = fl.body_at;
                        fl.phase = Phase::Start;
                        run.in_flight = Some(fl);
                        return Ok(StepOut::Wait(at));
                    }
                    let body_at = fl.body_at;
                    // authenticate this action (paper: every interaction
                    // goes through Globus Auth)
                    let scope = self
                        .providers
                        .get(fl.provider.as_str())
                        .with_context(|| format!("no provider `{}`", fl.provider))?
                        .scope();
                    if let Err(e) = self.auth.check(body_at, &run.token, &scope) {
                        return self.settle_failure(run, fl, body_at, format!("auth: {e:#}"));
                    }
                    let action = run.def.action(&fl.action_id)?;
                    let params = match resolve_params(&action.params, &run.input, &run.outputs)
                    {
                        Ok(p) => p,
                        Err(e) => {
                            return self.settle_failure(
                                run,
                                fl,
                                body_at,
                                format!("template: {e:#}"),
                            )
                        }
                    };
                    fl.params = Some(params);
                    fl.phase = self.attempt(run, &mut fl, ctx, body_at)?;
                }
                Phase::FinishAt { t, output } => {
                    if now < t {
                        fl.phase = Phase::FinishAt { t, output };
                        run.in_flight = Some(fl);
                        return Ok(StepOut::Wait(t));
                    }
                    return Ok(self.settle_success(run, fl, t, output));
                }
                Phase::Await { ticket } => match ctx.take_ready(ticket) {
                    None => {
                        fl.phase = Phase::Await { ticket };
                        run.in_flight = Some(fl);
                        return Ok(StepOut::Blocked);
                    }
                    Some((tf, Ok(output))) => {
                        return Ok(self.settle_success(run, fl, tf, output));
                    }
                    Some((tf, Err(e))) => {
                        let action = run.def.action(&fl.action_id)?;
                        if fl.attempts <= action.retries {
                            log::warn!(
                                "action `{}` attempt {} failed, retrying: {e:#}",
                                action.id,
                                fl.attempts
                            );
                            fl.phase = Phase::RetryAt {
                                t: tf + action.retry.delay_after(&action.id, fl.attempts),
                            };
                        } else {
                            return self.settle_failure(run, fl, tf, format!("{e:#}"));
                        }
                    }
                },
                Phase::RetryAt { t } => {
                    if now < t {
                        fl.phase = Phase::RetryAt { t };
                        run.in_flight = Some(fl);
                        return Ok(StepOut::Wait(t));
                    }
                    fl.phase = self.attempt(run, &mut fl, ctx, t)?;
                }
                Phase::FailAt { t, msg } => {
                    return self.settle_failure(run, fl, t, msg);
                }
            }
        }
    }

    /// Invoke the provider for one attempt at virtual time `at`.
    fn attempt(
        &mut self,
        run: &FlowRun,
        fl: &mut InFlight,
        ctx: &mut C,
        at: f64,
    ) -> Result<Phase> {
        fl.attempts += 1;
        let action = run.def.action(&fl.action_id)?;
        let provider = self
            .providers
            .get(fl.provider.as_str())
            .with_context(|| format!("no provider `{}`", fl.provider))?;
        let params = fl.params.as_ref().expect("params resolved before attempt");
        match provider.start(ctx, at, params) {
            Ok(Effect::Done { duration, output }) => {
                anyhow::ensure!(
                    duration >= 0.0 && duration.is_finite(),
                    "action `{}` returned a bad duration {duration}",
                    action.id
                );
                Ok(Phase::FinishAt {
                    t: at + duration,
                    output,
                })
            }
            Ok(Effect::Pending(ticket)) => Ok(Phase::Await { ticket }),
            Err(e) if fl.attempts <= action.retries => {
                log::warn!(
                    "action `{}` attempt {} failed, retrying: {e:#}",
                    action.id,
                    fl.attempts
                );
                Ok(Phase::RetryAt {
                    t: at + action.retry.delay_after(&action.id, fl.attempts),
                })
            }
            Err(e) => Ok(Phase::FailAt {
                t: at,
                msg: format!("{e:#}"),
            }),
        }
    }

    fn settle_success(&self, run: &mut FlowRun, fl: InFlight, tf: f64, output: Json) -> StepOut {
        run.statuses
            .insert(fl.action_id.clone(), ActionStatus::Success);
        run.records.push(ActionRecord {
            id: fl.action_id.clone(),
            provider: fl.provider,
            attempts: fl.attempts,
            start_vt: fl.start_vt,
            end_vt: tf,
            status: ActionStatus::Success,
        });
        run.outputs.insert(fl.action_id, output);
        run.t = tf;
        run.order_pos = fl.resume_pos;
        if fl.is_handler {
            // a handler only runs on failure; the run is failed either way
            run.aborted = true;
        }
        run.in_flight = None;
        StepOut::Progress
    }

    /// Record a terminal action failure at `tf` and apply its policy.
    fn settle_failure(
        &self,
        run: &mut FlowRun,
        fl: InFlight,
        tf: f64,
        msg: String,
    ) -> Result<StepOut> {
        run.statuses
            .insert(fl.action_id.clone(), ActionStatus::Failed(msg.clone()));
        run.records.push(ActionRecord {
            id: fl.action_id.clone(),
            provider: fl.provider.clone(),
            attempts: fl.attempts,
            start_vt: fl.start_vt,
            end_vt: tf,
            status: ActionStatus::Failed(msg),
        });
        run.t = tf;
        run.order_pos = fl.resume_pos;
        run.in_flight = None;
        if fl.is_handler {
            run.aborted = true;
            return Ok(StepOut::Progress);
        }
        match run.def.action(&fl.action_id)?.on_failure.clone() {
            FailurePolicy::Abort => run.aborted = true,
            FailurePolicy::Continue => {}
            FailurePolicy::Catch(handler) => {
                self.launch(run, &handler, fl.resume_pos, true);
            }
        }
        Ok(StepOut::Progress)
    }

    /// Execute a flow to completion (callers persist the report). Drives
    /// the resumable machinery synchronously — the degenerate N=1 case.
    pub fn run(
        &mut self,
        def: &FlowDefinition,
        input: &Json,
        token: &TokenId,
        ctx: &mut C,
        clock: &mut VClock,
    ) -> Result<RunReport>
    where
        C: FabricHost,
    {
        let mut fr = self.begin(def, input, token, clock.now())?;
        loop {
            match self.poll(&mut fr, ctx, clock.now())? {
                RunPoll::Finished => {
                    clock.advance_to(fr.end_vt());
                    return Ok(fr.into_report());
                }
                RunPoll::WaitUntil(t) => clock.advance_to(t),
                RunPoll::Blocked => {
                    let t = ctx
                        .next_fabric_event()
                        .context("flow run blocked on a fabric with no pending events")?;
                    ctx.advance_fabrics(t);
                    clock.advance_to(t);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flows::definition::{ActionDef, RetryPolicy};

    /// Test context: a scratch value, a failure switch, and a one-shot
    /// "timer fabric" for Pending effects.
    #[derive(Default)]
    struct Ctx {
        log: Vec<String>,
        fail_times: u32,
        /// ticket -> (fires_at, Ok-output or Err-message, fired)
        timers: Vec<(f64, Result<Json, String>, bool)>,
        fabric_now: f64,
    }

    impl Ctx {
        fn arm_timer(&mut self, fires_at: f64, outcome: Result<Json, String>) -> Ticket {
            self.timers.push((fires_at, outcome, false));
            Ticket(self.timers.len() as u64 - 1)
        }
    }

    impl FabricHost for Ctx {
        fn next_fabric_event(&mut self) -> Option<f64> {
            self.timers
                .iter()
                .filter(|(_, _, fired)| !fired)
                .map(|(t, _, _)| *t)
                .fold(None, |acc, t| {
                    Some(acc.map_or(t, |a: f64| a.min(t)))
                })
        }

        fn advance_fabrics(&mut self, t: f64) {
            self.fabric_now = self.fabric_now.max(t);
        }

        fn take_ready(&mut self, ticket: Ticket) -> Option<(f64, Result<Json>)> {
            let (t, outcome, fired) = self.timers.get_mut(ticket.0 as usize)?;
            if *fired || *t > self.fabric_now {
                return None;
            }
            *fired = true;
            Some((
                *t,
                match outcome {
                    Ok(v) => Ok(v.clone()),
                    Err(m) => Err(anyhow::anyhow!("{m}")),
                },
            ))
        }
    }

    struct Work;
    impl ActionProvider<Ctx> for Work {
        fn name(&self) -> &'static str {
            "work"
        }
        fn start(&self, ctx: &mut Ctx, _now: f64, params: &Json) -> Result<Effect> {
            let label = params.get("label").as_str().unwrap_or("?").to_string();
            if ctx.fail_times > 0 {
                ctx.fail_times -= 1;
                bail!("transient failure");
            }
            let secs = params.get("secs").as_f64().unwrap_or(1.0);
            ctx.log.push(label.clone());
            Ok(Effect::after(secs, Json::obj(vec![("did", Json::str(label))])))
        }
    }

    struct Cleanup;
    impl ActionProvider<Ctx> for Cleanup {
        fn name(&self) -> &'static str {
            "cleanup"
        }
        fn start(&self, ctx: &mut Ctx, _: f64, _: &Json) -> Result<Effect> {
            ctx.log.push("cleanup".into());
            Ok(Effect::instant(Json::Null))
        }
    }

    /// A fabric-backed provider: arms a timer `secs` out and parks.
    struct Slow;
    impl ActionProvider<Ctx> for Slow {
        fn name(&self) -> &'static str {
            "slow"
        }
        fn start(&self, ctx: &mut Ctx, now: f64, params: &Json) -> Result<Effect> {
            let secs = params.get("secs").as_f64().unwrap_or(1.0);
            if ctx.fail_times > 0 {
                ctx.fail_times -= 1;
                let t = ctx.arm_timer(now + secs, Err("fabric task failed".into()));
                return Ok(Effect::Pending(t));
            }
            let t = ctx.arm_timer(
                now + secs,
                Ok(Json::obj(vec![("fabric", Json::Bool(true))])),
            );
            Ok(Effect::Pending(t))
        }
    }

    fn engine() -> (FlowEngine<Ctx>, TokenId) {
        let mut e = FlowEngine::<Ctx>::new();
        e.register_provider(Box::new(Work)).unwrap();
        e.register_provider(Box::new(Cleanup)).unwrap();
        e.register_provider(Box::new(Slow)).unwrap();
        let clock = VClock::new();
        let token = e
            .auth
            .issue(&clock, "user", &["work:use", "cleanup:use", "slow:use"], 1e9)
            .id;
        (e, token)
    }

    fn action(id: &str, deps: &[&str], params: Json) -> ActionDef {
        ActionDef {
            id: id.into(),
            provider: "work".into(),
            params,
            depends_on: deps.iter().map(|s| s.to_string()).collect(),
            retries: 0,
            retry: RetryPolicy::fixed(1.0),
            on_failure: FailurePolicy::Abort,
            is_handler: false,
        }
    }

    #[test]
    fn linear_flow_passes_outputs_and_accounts_time() {
        let (mut e, token) = engine();
        let def = FlowDefinition::new(
            "f",
            vec![
                action(
                    "a",
                    &[],
                    Json::obj(vec![
                        ("label", Json::str("stage")),
                        ("secs", Json::num(5.0)),
                    ]),
                ),
                action(
                    "b",
                    &["a"],
                    Json::obj(vec![
                        ("label", Json::str("${result.a.did}-next")),
                        ("secs", Json::num(2.0)),
                    ]),
                ),
            ],
        )
        .unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(rep.succeeded);
        assert_eq!(ctx.log, vec!["stage", "stage-next"]);
        // durations: 5 + 2 + 2*(dispatch 0.2 + auth 0.05)
        assert!((rep.duration() - 7.5).abs() < 1e-9, "{}", rep.duration());
        assert_eq!(rep.record("a").unwrap().attempts, 1);
        assert_eq!(
            rep.output("b").unwrap().get("did").as_str(),
            Some("stage-next")
        );
        assert_eq!(clock.now(), rep.end_vt);
    }

    #[test]
    fn retries_then_succeeds() {
        let (mut e, token) = engine();
        let mut a = action("a", &[], Json::obj(vec![("label", Json::str("x"))]));
        a.retries = 3;
        a.retry = RetryPolicy::fixed(2.0);
        let def = FlowDefinition::new("f", vec![a]).unwrap();
        let mut ctx = Ctx {
            fail_times: 2,
            ..Default::default()
        };
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(rep.succeeded);
        assert_eq!(rep.record("a").unwrap().attempts, 3);
        assert!(clock.now() >= 4.0); // two backoffs charged
    }

    /// Capped exponential backoff with jitter: the nominal 1/2/4 s
    /// schedule is charged between attempts (±25% jitter), and because
    /// the jitter stream is seeded by (action id, attempt), the whole
    /// run replays bit-identically.
    #[test]
    fn exponential_backoff_schedule_is_deterministic() {
        let run_once = || {
            let (mut e, token) = engine();
            let mut a = action("a", &[], Json::obj(vec![("label", Json::str("x"))]));
            a.retries = 3;
            a.retry = RetryPolicy {
                base_s: 1.0,
                cap_s: 8.0,
                multiplier: 2.0,
                jitter: 0.25,
            };
            let def = FlowDefinition::new("f", vec![a]).unwrap();
            let mut ctx = Ctx {
                fail_times: 3,
                ..Default::default()
            };
            let mut clock = VClock::new();
            let rep = e
                .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
                .unwrap();
            assert!(rep.succeeded);
            assert_eq!(rep.record("a").unwrap().attempts, 4);
            rep.duration()
        };
        let d1 = run_once();
        // 1 + 2 + 4 = 7 s nominal backoff, each delay jittered ±25%,
        // plus the one-time dispatch/auth overhead
        assert!(d1 > 7.0 * 0.75 && d1 < 0.5 + 7.0 * 1.25, "{d1}");
        assert_eq!(d1, run_once());
    }

    #[test]
    fn abort_skips_dependents() {
        let (mut e, token) = engine();
        let def = FlowDefinition::new(
            "f",
            vec![
                action("a", &[], Json::obj(vec![("label", Json::str("x"))])),
                action("b", &["a"], Json::Null),
            ],
        )
        .unwrap();
        let mut ctx = Ctx {
            fail_times: 1,
            ..Default::default()
        };
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(!rep.succeeded);
        assert_eq!(rep.record("b").unwrap().status, ActionStatus::Skipped);
    }

    #[test]
    fn catch_runs_handler() {
        let (mut e, token) = engine();
        let mut a = action("a", &[], Json::Null);
        a.on_failure = FailurePolicy::Catch("h".into());
        let mut h = action("h", &[], Json::Null);
        h.provider = "cleanup".into();
        h.is_handler = true;
        let def = FlowDefinition::new("f", vec![a, h]).unwrap();
        let mut ctx = Ctx {
            fail_times: 1,
            ..Default::default()
        };
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(!rep.succeeded);
        assert_eq!(ctx.log, vec!["cleanup"]);
        assert_eq!(rep.record("h").unwrap().status, ActionStatus::Success);
    }

    #[test]
    fn missing_scope_fails_action() {
        let (mut e, _) = engine();
        let clock0 = VClock::new();
        let weak = e.auth.issue(&clock0, "user", &["cleanup:use"], 1e9).id;
        let def =
            FlowDefinition::new("f", vec![action("a", &[], Json::Null)]).unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let rep = e.run(&def, &Json::Null, &weak, &mut ctx, &mut clock).unwrap();
        assert!(!rep.succeeded);
        match &rep.record("a").unwrap().status {
            ActionStatus::Failed(m) => assert!(m.contains("auth"), "{m}"),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn unknown_provider_rejected_upfront() {
        let (mut e, token) = engine();
        let mut a = action("a", &[], Json::Null);
        a.provider = "ghost".into();
        let def = FlowDefinition::new("f", vec![a]).unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        assert!(e.run(&def, &Json::Null, &token, &mut ctx, &mut clock).is_err());
    }

    #[test]
    fn scope_fans_real_compute_out_in_order() {
        let (e, _) = engine();
        let weights = vec![3.0f64, 1.0, 4.0, 1.0, 5.0];
        let w = weights.as_slice();
        let tasks: Vec<crate::pool::ScopeTask<f64>> = (0..w.len())
            .map(|i| Box::new(move || w[i] * w[i]) as crate::pool::ScopeTask<f64>)
            .collect();
        let out = e.scope(tasks);
        assert_eq!(out, vec![9.0, 1.0, 16.0, 1.0, 25.0]);
    }

    #[test]
    fn report_serializes() {
        let (mut e, token) = engine();
        let def = FlowDefinition::new(
            "f",
            vec![action("a", &[], Json::obj(vec![("label", Json::str("x"))]))],
        )
        .unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let rep = e.run(&def, &Json::Null, &token, &mut ctx, &mut clock).unwrap();
        let j = rep.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("flow").as_str(), Some("f"));
        assert_eq!(parsed.get("actions").at(0).get("status").as_str(), Some("success"));
    }

    /// The tentpole property: two independent runs interleave correctly
    /// under poll — the shorter one finishes first in virtual time even
    /// though both were started together and polled in a fixed order.
    #[test]
    fn two_runs_interleave_under_poll() {
        let (mut e, token) = engine();
        let def_a = FlowDefinition::new(
            "fa",
            vec![action(
                "a",
                &[],
                Json::obj(vec![("label", Json::str("a")), ("secs", Json::num(5.0))]),
            )],
        )
        .unwrap();
        let def_b = FlowDefinition::new(
            "fb",
            vec![action(
                "b",
                &[],
                Json::obj(vec![("label", Json::str("b")), ("secs", Json::num(2.0))]),
            )],
        )
        .unwrap();
        let mut ctx = Ctx::default();
        let mut ra = e.begin(&def_a, &Json::Null, &token, 0.0).unwrap();
        let mut rb = e.begin(&def_b, &Json::Null, &token, 0.0).unwrap();

        // both dispatch first at 0.25
        assert_eq!(e.poll(&mut ra, &mut ctx, 0.0).unwrap(), RunPoll::WaitUntil(0.25));
        assert_eq!(e.poll(&mut rb, &mut ctx, 0.0).unwrap(), RunPoll::WaitUntil(0.25));
        // at 0.25 both bodies fire (in poll order) and park until done
        assert_eq!(e.poll(&mut ra, &mut ctx, 0.25).unwrap(), RunPoll::WaitUntil(5.25));
        assert_eq!(e.poll(&mut rb, &mut ctx, 0.25).unwrap(), RunPoll::WaitUntil(2.25));
        assert_eq!(ctx.log, vec!["a", "b"]);
        // b completes while a is still in flight
        assert_eq!(e.poll(&mut rb, &mut ctx, 2.25).unwrap(), RunPoll::Finished);
        assert_eq!(e.poll(&mut ra, &mut ctx, 2.25).unwrap(), RunPoll::WaitUntil(5.25));
        assert_eq!(e.poll(&mut ra, &mut ctx, 5.25).unwrap(), RunPoll::Finished);

        let rep_a = ra.into_report();
        let rep_b = rb.into_report();
        assert!(rep_a.succeeded && rep_b.succeeded);
        assert!(rep_b.end_vt < rep_a.end_vt);
        assert_eq!(rep_b.end_vt, 2.25);
        assert_eq!(rep_a.end_vt, 5.25);
    }

    /// Pending effects park the run until the fabric resolves the ticket.
    #[test]
    fn pending_effect_resolves_through_fabric() {
        let (mut e, token) = engine();
        let mut a = action("a", &[], Json::obj(vec![("secs", Json::num(3.0))]));
        a.provider = "slow".into();
        let def = FlowDefinition::new("f", vec![a]).unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(rep.succeeded);
        // 0.25 dispatch+auth, then 3 s in the fabric
        assert!((rep.duration() - 3.25).abs() < 1e-9, "{}", rep.duration());
        assert_eq!(
            rep.output("a").unwrap().get("fabric").as_bool(),
            Some(true)
        );
        assert_eq!(clock.now(), 3.25);
    }

    /// A ticket that resolves to an error consumes an attempt and is
    /// retried with backoff, exactly like an inline failure.
    #[test]
    fn fabric_failure_is_retried() {
        let (mut e, token) = engine();
        let mut a = action("a", &[], Json::obj(vec![("secs", Json::num(2.0))]));
        a.provider = "slow".into();
        a.retries = 1;
        a.retry = RetryPolicy::fixed(1.0);
        let def = FlowDefinition::new("f", vec![a]).unwrap();
        let mut ctx = Ctx {
            fail_times: 1,
            ..Default::default()
        };
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(rep.succeeded, "{:?}", rep.records);
        assert_eq!(rep.record("a").unwrap().attempts, 2);
        // 0.25 overhead + 2 s failed attempt + 1 s backoff + 2 s retry
        assert!((rep.duration() - 5.25).abs() < 1e-9, "{}", rep.duration());
    }
}
