//! The flow run engine: executes a validated `FlowDefinition` against a
//! set of registered action providers, with template parameter passing,
//! per-action authentication, retries, failure policies, and a full
//! event log whose virtual-time spans become the Table 1 breakdown.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::definition::{FailurePolicy, FlowDefinition};
use super::template::resolve_params;
use crate::auth::{AuthService, TokenId};
use crate::simnet::VClock;
use crate::util::Json;

/// One pluggable action kind (Transfer, Compute, Deploy, ...).
pub trait ActionProvider<C> {
    /// Provider name referenced by `ActionDef::provider`.
    fn name(&self) -> &'static str;

    /// Auth scope a token must carry to invoke this provider.
    fn scope(&self) -> String {
        format!("{}:use", self.name())
    }

    /// Run the action. Advance `clock` by however long it takes.
    fn execute(&self, ctx: &mut C, clock: &mut VClock, params: &Json) -> Result<Json>;
}

/// Outcome of one action inside a run.
#[derive(Debug, Clone, PartialEq)]
pub enum ActionStatus {
    Success,
    Failed(String),
    /// not run because a dependency failed or the run aborted
    Skipped,
}

/// Event-log entry for one action.
#[derive(Debug, Clone)]
pub struct ActionRecord {
    pub id: String,
    pub provider: String,
    pub attempts: u32,
    pub start_vt: f64,
    pub end_vt: f64,
    pub status: ActionStatus,
}

impl ActionRecord {
    pub fn duration(&self) -> f64 {
        self.end_vt - self.start_vt
    }
}

/// Full record of one flow run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub flow: String,
    pub start_vt: f64,
    pub end_vt: f64,
    pub succeeded: bool,
    pub records: Vec<ActionRecord>,
    /// successful action outputs by action id
    pub outputs: BTreeMap<String, Json>,
}

impl RunReport {
    pub fn duration(&self) -> f64 {
        self.end_vt - self.start_vt
    }

    pub fn record(&self, id: &str) -> Result<&ActionRecord> {
        self.records
            .iter()
            .find(|r| r.id == id)
            .with_context(|| format!("run has no action `{id}`"))
    }

    pub fn output(&self, id: &str) -> Result<&Json> {
        self.outputs
            .get(id)
            .with_context(|| format!("no output recorded for `{id}`"))
    }

    /// Serialize the event log (persisted by the CLI for every run).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("flow", Json::str(self.flow.clone())),
            ("start_vt", Json::num(self.start_vt)),
            ("end_vt", Json::num(self.end_vt)),
            ("succeeded", Json::Bool(self.succeeded)),
            (
                "actions",
                Json::arr(
                    self.records
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::str(r.id.clone())),
                                ("provider", Json::str(r.provider.clone())),
                                ("attempts", Json::num(r.attempts as f64)),
                                ("start_vt", Json::num(r.start_vt)),
                                ("end_vt", Json::num(r.end_vt)),
                                (
                                    "status",
                                    match &r.status {
                                        ActionStatus::Success => Json::str("success"),
                                        ActionStatus::Skipped => Json::str("skipped"),
                                        ActionStatus::Failed(m) => Json::obj(vec![(
                                            "failed",
                                            Json::str(m.clone()),
                                        )]),
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The engine: providers + auth + dispatch overhead accounting.
pub struct FlowEngine<C> {
    providers: BTreeMap<&'static str, Box<dyn ActionProvider<C>>>,
    pub auth: AuthService,
    /// flows-service bookkeeping charged per action dispatch
    pub dispatch_overhead_s: f64,
}

impl<C> Default for FlowEngine<C> {
    fn default() -> Self {
        FlowEngine {
            providers: BTreeMap::new(),
            auth: AuthService::new(),
            dispatch_overhead_s: 0.2,
        }
    }
}

impl<C> FlowEngine<C> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register_provider(&mut self, p: Box<dyn ActionProvider<C>>) -> Result<()> {
        let name = p.name();
        if self.providers.contains_key(name) {
            bail!("provider `{name}` already registered");
        }
        self.providers.insert(name, p);
        Ok(())
    }

    pub fn provider_names(&self) -> Vec<&'static str> {
        self.providers.keys().copied().collect()
    }

    /// Fan independent *real* CPU work out on the process-wide
    /// work-stealing pool, returning results in task order. Virtual-time
    /// accounting stays with the caller — this is the entry point action
    /// providers (labeling, rendering, future engine stages) use for the
    /// compute that actually burns cycles; `XLOOP_THREADS=1` forces the
    /// deterministic serial mode.
    pub fn scope<'env, R: Send>(&self, tasks: Vec<crate::pool::ScopeTask<'env, R>>) -> Vec<R> {
        crate::pool::scope(tasks)
    }

    /// Execute a flow to completion (callers persist the report).
    pub fn run(
        &mut self,
        def: &FlowDefinition,
        input: &Json,
        token: &TokenId,
        ctx: &mut C,
        clock: &mut VClock,
    ) -> Result<RunReport> {
        // all providers referenced must exist before we start
        for a in &def.actions {
            if !self.providers.contains_key(a.provider.as_str()) {
                bail!(
                    "flow `{}`: no provider `{}` (have: {})",
                    def.name,
                    a.provider,
                    self.provider_names().join(", ")
                );
            }
        }

        let start_vt = clock.now();
        let mut outputs: BTreeMap<String, Json> = BTreeMap::new();
        let mut statuses: BTreeMap<String, ActionStatus> = BTreeMap::new();
        let mut records: Vec<ActionRecord> = Vec::new();
        let mut aborted = false;

        for &idx in def.order() {
            let action = &def.actions[idx];
            let dep_ok = action
                .depends_on
                .iter()
                .all(|d| matches!(statuses.get(d.as_str()), Some(ActionStatus::Success)));
            if aborted || !dep_ok {
                statuses.insert(action.id.clone(), ActionStatus::Skipped);
                records.push(ActionRecord {
                    id: action.id.clone(),
                    provider: action.provider.clone(),
                    attempts: 0,
                    start_vt: clock.now(),
                    end_vt: clock.now(),
                    status: ActionStatus::Skipped,
                });
                continue;
            }

            let (record, output) =
                self.run_action(def, &action.id, input, &outputs, token, ctx, clock)?;
            let failed = matches!(record.status, ActionStatus::Failed(_));
            statuses.insert(action.id.clone(), record.status.clone());
            if let Some(v) = output {
                outputs.insert(action.id.clone(), v);
            }
            records.push(record);

            if failed {
                match &action.on_failure {
                    FailurePolicy::Abort => aborted = true,
                    FailurePolicy::Continue => {}
                    FailurePolicy::Catch(handler) => {
                        let (h, hout) =
                            self.run_action(def, handler, input, &outputs, token, ctx, clock)?;
                        statuses.insert(handler.clone(), h.status.clone());
                        if let Some(v) = hout {
                            outputs.insert(handler.clone(), v);
                        }
                        records.push(h);
                        aborted = true;
                    }
                }
            }
        }

        let succeeded = !aborted
            && records
                .iter()
                .all(|r| matches!(r.status, ActionStatus::Success));
        Ok(RunReport {
            flow: def.name.clone(),
            start_vt,
            end_vt: clock.now(),
            succeeded,
            records,
            outputs,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn run_action(
        &mut self,
        def: &FlowDefinition,
        id: &str,
        input: &Json,
        outputs: &BTreeMap<String, Json>,
        token: &TokenId,
        ctx: &mut C,
        clock: &mut VClock,
    ) -> Result<(ActionRecord, Option<Json>)> {
        let action = def.action(id)?;
        let provider = self
            .providers
            .get(action.provider.as_str())
            .with_context(|| format!("no provider `{}`", action.provider))?;

        let start_vt = clock.now();
        clock.advance(self.dispatch_overhead_s);

        let fail = |status: String, clock: &VClock| {
            (
                ActionRecord {
                    id: action.id.clone(),
                    provider: action.provider.clone(),
                    attempts: 0,
                    start_vt,
                    end_vt: clock.now(),
                    status: ActionStatus::Failed(status),
                },
                None,
            )
        };

        // authenticate this action (paper: every interaction goes through
        // Globus Auth)
        if let Err(e) = self.auth.validate(clock, token, &provider.scope()) {
            return Ok(fail(format!("auth: {e:#}"), clock));
        }

        let params = match resolve_params(&action.params, input, outputs) {
            Ok(p) => p,
            Err(e) => return Ok(fail(format!("template: {e:#}"), clock)),
        };

        let mut attempts = 0;
        let outcome = loop {
            attempts += 1;
            match provider.execute(ctx, clock, &params) {
                Ok(v) => break Ok(v),
                Err(e) if attempts <= action.retries => {
                    log::warn!(
                        "action `{}` attempt {attempts} failed, retrying: {e:#}",
                        action.id
                    );
                    clock.advance(action.retry_backoff_s);
                }
                Err(e) => break Err(e),
            }
        };

        Ok(match outcome {
            Ok(v) => (
                ActionRecord {
                    id: action.id.clone(),
                    provider: action.provider.clone(),
                    attempts,
                    start_vt,
                    end_vt: clock.now(),
                    status: ActionStatus::Success,
                },
                Some(v),
            ),
            Err(e) => (
                ActionRecord {
                    id: action.id.clone(),
                    provider: action.provider.clone(),
                    attempts,
                    start_vt,
                    end_vt: clock.now(),
                    status: ActionStatus::Failed(format!("{e:#}")),
                },
                None,
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flows::definition::ActionDef;

    /// Test context: a scratch value + a failure switch.
    #[derive(Default)]
    struct Ctx {
        log: Vec<String>,
        fail_times: u32,
    }

    struct Work;
    impl ActionProvider<Ctx> for Work {
        fn name(&self) -> &'static str {
            "work"
        }
        fn execute(&self, ctx: &mut Ctx, clock: &mut VClock, params: &Json) -> Result<Json> {
            let label = params.get("label").as_str().unwrap_or("?").to_string();
            if ctx.fail_times > 0 {
                ctx.fail_times -= 1;
                bail!("transient failure");
            }
            clock.advance(params.get("secs").as_f64().unwrap_or(1.0));
            ctx.log.push(label.clone());
            Ok(Json::obj(vec![("did", Json::str(label))]))
        }
    }

    struct Cleanup;
    impl ActionProvider<Ctx> for Cleanup {
        fn name(&self) -> &'static str {
            "cleanup"
        }
        fn execute(&self, ctx: &mut Ctx, _: &mut VClock, _: &Json) -> Result<Json> {
            ctx.log.push("cleanup".into());
            Ok(Json::Null)
        }
    }

    fn engine() -> (FlowEngine<Ctx>, TokenId) {
        let mut e = FlowEngine::<Ctx>::new();
        e.register_provider(Box::new(Work)).unwrap();
        e.register_provider(Box::new(Cleanup)).unwrap();
        let clock = VClock::new();
        let token = e
            .auth
            .issue(&clock, "user", &["work:use", "cleanup:use"], 1e9)
            .id;
        (e, token)
    }

    fn action(id: &str, deps: &[&str], params: Json) -> ActionDef {
        ActionDef {
            id: id.into(),
            provider: "work".into(),
            params,
            depends_on: deps.iter().map(|s| s.to_string()).collect(),
            retries: 0,
            retry_backoff_s: 1.0,
            on_failure: FailurePolicy::Abort,
            is_handler: false,
        }
    }

    #[test]
    fn linear_flow_passes_outputs_and_accounts_time() {
        let (mut e, token) = engine();
        let def = FlowDefinition::new(
            "f",
            vec![
                action(
                    "a",
                    &[],
                    Json::obj(vec![
                        ("label", Json::str("stage")),
                        ("secs", Json::num(5.0)),
                    ]),
                ),
                action(
                    "b",
                    &["a"],
                    Json::obj(vec![
                        ("label", Json::str("${result.a.did}-next")),
                        ("secs", Json::num(2.0)),
                    ]),
                ),
            ],
        )
        .unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(rep.succeeded);
        assert_eq!(ctx.log, vec!["stage", "stage-next"]);
        // durations: 5 + 2 + 2*(dispatch 0.2 + auth 0.05)
        assert!((rep.duration() - 7.5).abs() < 1e-9, "{}", rep.duration());
        assert_eq!(rep.record("a").unwrap().attempts, 1);
        assert_eq!(
            rep.output("b").unwrap().get("did").as_str(),
            Some("stage-next")
        );
    }

    #[test]
    fn retries_then_succeeds() {
        let (mut e, token) = engine();
        let mut a = action("a", &[], Json::obj(vec![("label", Json::str("x"))]));
        a.retries = 3;
        a.retry_backoff_s = 2.0;
        let def = FlowDefinition::new("f", vec![a]).unwrap();
        let mut ctx = Ctx {
            fail_times: 2,
            ..Default::default()
        };
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(rep.succeeded);
        assert_eq!(rep.record("a").unwrap().attempts, 3);
        assert!(clock.now() >= 4.0); // two backoffs charged
    }

    #[test]
    fn abort_skips_dependents() {
        let (mut e, token) = engine();
        let def = FlowDefinition::new(
            "f",
            vec![
                action("a", &[], Json::obj(vec![("label", Json::str("x"))])),
                action("b", &["a"], Json::Null),
            ],
        )
        .unwrap();
        let mut ctx = Ctx {
            fail_times: 1,
            ..Default::default()
        };
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(!rep.succeeded);
        assert_eq!(rep.record("b").unwrap().status, ActionStatus::Skipped);
    }

    #[test]
    fn catch_runs_handler() {
        let (mut e, token) = engine();
        let mut a = action("a", &[], Json::Null);
        a.on_failure = FailurePolicy::Catch("h".into());
        let mut h = action("h", &[], Json::Null);
        h.provider = "cleanup".into();
        h.is_handler = true;
        let def = FlowDefinition::new("f", vec![a, h]).unwrap();
        let mut ctx = Ctx {
            fail_times: 1,
            ..Default::default()
        };
        let mut clock = VClock::new();
        let rep = e
            .run(&def, &Json::Null, &token, &mut ctx, &mut clock)
            .unwrap();
        assert!(!rep.succeeded);
        assert_eq!(ctx.log, vec!["cleanup"]);
        assert_eq!(rep.record("h").unwrap().status, ActionStatus::Success);
    }

    #[test]
    fn missing_scope_fails_action() {
        let (mut e, _) = engine();
        let clock0 = VClock::new();
        let weak = e.auth.issue(&clock0, "user", &["cleanup:use"], 1e9).id;
        let def =
            FlowDefinition::new("f", vec![action("a", &[], Json::Null)]).unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let rep = e.run(&def, &Json::Null, &weak, &mut ctx, &mut clock).unwrap();
        assert!(!rep.succeeded);
        match &rep.record("a").unwrap().status {
            ActionStatus::Failed(m) => assert!(m.contains("auth"), "{m}"),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn unknown_provider_rejected_upfront() {
        let (mut e, token) = engine();
        let mut a = action("a", &[], Json::Null);
        a.provider = "ghost".into();
        let def = FlowDefinition::new("f", vec![a]).unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        assert!(e.run(&def, &Json::Null, &token, &mut ctx, &mut clock).is_err());
    }

    #[test]
    fn scope_fans_real_compute_out_in_order() {
        let (e, _) = engine();
        let weights = vec![3.0f64, 1.0, 4.0, 1.0, 5.0];
        let w = weights.as_slice();
        let tasks: Vec<crate::pool::ScopeTask<f64>> = (0..w.len())
            .map(|i| Box::new(move || w[i] * w[i]) as crate::pool::ScopeTask<f64>)
            .collect();
        let out = e.scope(tasks);
        assert_eq!(out, vec![9.0, 1.0, 16.0, 1.0, 25.0]);
    }

    #[test]
    fn report_serializes() {
        let (mut e, token) = engine();
        let def = FlowDefinition::new(
            "f",
            vec![action("a", &[], Json::obj(vec![("label", Json::str("x"))]))],
        )
        .unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let rep = e.run(&def, &Json::Null, &token, &mut ctx, &mut clock).unwrap();
        let j = rep.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("flow").as_str(), Some("f"));
        assert_eq!(parsed.get("actions").at(0).get("status").as_str(), Some("success"));
    }
}
