//! Globus Flows analog: declarative action orchestration.
//!
//! * `definition` — flows as validated JSON DAGs of actions;
//! * `template`  — `${input...}` / `${result...}` parameter passing;
//! * `engine`    — the run engine: auth per action, retries, failure
//!   policies (abort/continue/catch), and a virtual-time event log.
//!
//! Concrete action providers (Transfer, Compute, Deploy) live in
//! `crate::workflow::providers` because they need the `World` context.

pub mod definition;
pub mod engine;
pub mod template;

pub use definition::{ActionDef, FailurePolicy, FlowDefinition, RetryPolicy};
pub use engine::{
    ActionProvider, ActionRecord, ActionStatus, Effect, FabricHost, FlowEngine, FlowRun,
    RunPoll, RunReport, Ticket,
};
pub use template::resolve_params;
