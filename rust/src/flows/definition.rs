//! Flow definitions: declarative DAGs of actions (Globus Flows analog).
//!
//! A *Flow* "represents a single process that orchestrates a series of
//! services/actions into a self contained operation ... a declaratively
//! defined ordering of Action Providers with condition handling" (§3).
//! Definitions are plain JSON (see `workflow::dnn_trainer_flow` for the
//! paper's flow) and validated for unique ids, resolvable dependencies,
//! and acyclicity at load time.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Context, Result};

use crate::util::{Json, Rng};

/// Salt for the retry-jitter RNG stream, mixed with the action id and
/// attempt number so each (action, attempt) pair draws an independent
/// but fully reproducible jitter factor.
const RETRY_JITTER_SALT: u64 = 0x52E7_1A7E_BAC0_FF5A;

/// FNV-1a over the action id: a stable, dependency-free way to fold a
/// string into the jitter seed.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Backoff schedule for a failed action's retries (capped exponential
/// with deterministic jitter).
///
/// The delay after the `k`-th failed attempt (`k` = 1, 2, …) is
/// `min(cap_s, base_s · multiplier^(k−1))`, optionally scaled by a
/// jitter factor uniform in `[1 − jitter, 1 + jitter)`. The jitter draw
/// is seeded from the action id and attempt number — retry storms
/// decorrelate across actions, yet every run of the same flow replays
/// the identical schedule.
///
/// The default (`multiplier` 1.0, `jitter` 0.0, `cap_s` ∞) reproduces
/// the original fixed-interval behavior bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// delay after the first failed attempt, virtual seconds
    pub base_s: f64,
    /// upper bound on any single delay (`f64::INFINITY` = uncapped)
    pub cap_s: f64,
    /// geometric growth per failed attempt; 1.0 = fixed interval
    pub multiplier: f64,
    /// jitter amplitude in [0, 1); 0.0 = none
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy::fixed(5.0)
    }
}

impl RetryPolicy {
    /// Fixed-interval retries every `base_s` seconds — the pre-policy
    /// behavior.
    pub fn fixed(base_s: f64) -> RetryPolicy {
        RetryPolicy {
            base_s,
            cap_s: f64::INFINITY,
            multiplier: 1.0,
            jitter: 0.0,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.base_s.is_finite() || self.base_s < 0.0 {
            bail!("retry base_s must be finite and >= 0, got {}", self.base_s);
        }
        if !(self.cap_s > 0.0) {
            bail!("retry cap_s must be > 0, got {}", self.cap_s);
        }
        if !self.multiplier.is_finite() || self.multiplier < 1.0 {
            bail!("retry multiplier must be finite and >= 1, got {}", self.multiplier);
        }
        if !self.jitter.is_finite() || !(0.0..1.0).contains(&self.jitter) {
            bail!("retry jitter must be in [0, 1), got {}", self.jitter);
        }
        Ok(())
    }

    /// The delay to wait after `attempt` attempts have failed
    /// (`attempt` ≥ 1, as the engine counts them).
    pub fn delay_after(&self, action_id: &str, attempt: u32) -> f64 {
        let k = attempt.max(1);
        let mut delay = self.base_s * self.multiplier.powi(k as i32 - 1);
        if delay > self.cap_s {
            delay = self.cap_s;
        }
        if self.jitter > 0.0 {
            let mut rng = Rng::new(
                RETRY_JITTER_SALT
                    ^ fnv1a(action_id)
                    ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            delay *= 1.0 + self.jitter * (2.0 * rng.f64() - 1.0);
        }
        delay
    }
}

/// What to do when an action exhausts its retries.
#[derive(Debug, Clone, PartialEq)]
pub enum FailurePolicy {
    /// fail the run immediately (default)
    Abort,
    /// record the failure, skip dependents, keep running independents
    Continue,
    /// run the named handler action, then fail the run
    Catch(String),
}

/// One action in a flow.
#[derive(Debug, Clone)]
pub struct ActionDef {
    pub id: String,
    /// action-provider name (must be registered on the engine)
    pub provider: String,
    /// parameters; strings may contain `${input...}` / `${result...}`
    pub params: Json,
    pub depends_on: Vec<String>,
    pub retries: u32,
    /// backoff schedule between failed attempts
    pub retry: RetryPolicy,
    pub on_failure: FailurePolicy,
    /// handler actions only run via `FailurePolicy::Catch`
    pub is_handler: bool,
}

/// A validated flow definition.
#[derive(Debug, Clone)]
pub struct FlowDefinition {
    pub name: String,
    pub actions: Vec<ActionDef>,
    /// topological execution order over non-handler actions
    order: Vec<usize>,
}

impl FlowDefinition {
    pub fn new(name: impl Into<String>, actions: Vec<ActionDef>) -> Result<FlowDefinition> {
        let mut def = FlowDefinition {
            name: name.into(),
            actions,
            order: vec![],
        };
        def.validate()?;
        Ok(def)
    }

    /// Execution order (indices into `actions`), handlers excluded.
    pub fn order(&self) -> &[usize] {
        &self.order
    }

    pub fn action(&self, id: &str) -> Result<&ActionDef> {
        self.actions
            .iter()
            .find(|a| a.id == id)
            .with_context(|| format!("flow `{}` has no action `{id}`", self.name))
    }

    fn validate(&mut self) -> Result<()> {
        if self.actions.is_empty() {
            bail!("flow `{}` has no actions", self.name);
        }
        let mut ids = BTreeSet::new();
        for a in &self.actions {
            if !ids.insert(a.id.as_str()) {
                bail!("duplicate action id `{}`", a.id);
            }
        }
        let index: BTreeMap<&str, usize> = self
            .actions
            .iter()
            .enumerate()
            .map(|(i, a)| (a.id.as_str(), i))
            .collect();
        for a in &self.actions {
            for d in &a.depends_on {
                if !index.contains_key(d.as_str()) {
                    bail!("action `{}` depends on unknown `{d}`", a.id);
                }
            }
            if let FailurePolicy::Catch(h) = &a.on_failure {
                let hi = *index
                    .get(h.as_str())
                    .with_context(|| format!("action `{}` catches unknown `{h}`", a.id))?;
                if !self.actions[hi].is_handler {
                    bail!("catch target `{h}` must be declared as a handler");
                }
            }
            if a.is_handler && !a.depends_on.is_empty() {
                bail!("handler `{}` cannot have dependencies", a.id);
            }
            a.retry
                .validate()
                .with_context(|| format!("action `{}` retry policy", a.id))?;
        }
        // Kahn topological sort over non-handler actions
        let mut indeg: Vec<usize> = self
            .actions
            .iter()
            .map(|a| if a.is_handler { usize::MAX } else { a.depends_on.len() })
            .collect();
        let mut queue: Vec<usize> = indeg
            .iter()
            .enumerate()
            .filter(|&(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut order = Vec::new();
        while let Some(i) = queue.pop() {
            order.push(i);
            for (j, b) in self.actions.iter().enumerate() {
                if !b.is_handler && b.depends_on.iter().any(|d| d == &self.actions[i].id) {
                    indeg[j] -= 1;
                    if indeg[j] == 0 {
                        queue.push(j);
                    }
                }
            }
        }
        // keep declaration order among ready actions for determinism
        order.sort_by_key(|&i| {
            (
                self.depth(i),
                i,
            )
        });
        let expected = self.actions.iter().filter(|a| !a.is_handler).count();
        if order.len() != expected {
            bail!("flow `{}` has a dependency cycle", self.name);
        }
        self.order = order;
        Ok(())
    }

    /// Longest dependency chain above action `i` (for stable ordering).
    fn depth(&self, i: usize) -> usize {
        self.actions[i]
            .depends_on
            .iter()
            .map(|d| {
                let j = self.actions.iter().position(|a| &a.id == d).unwrap();
                1 + self.depth(j)
            })
            .max()
            .unwrap_or(0)
    }

    /// Parse from JSON:
    /// `{"name": ..., "actions": [{"id","provider","params","depends_on",
    ///   "retries","retry_backoff_s","retry_cap_s","retry_multiplier",
    ///   "retry_jitter","on_failure","handler"}]}`
    /// `on_failure`: "abort" (default) | "continue" | {"catch": "id"}.
    /// The retry keys default to fixed-interval `retry_backoff_s` (5 s)
    /// with no cap, growth, or jitter — see [`RetryPolicy`].
    pub fn from_json(j: &Json) -> Result<FlowDefinition> {
        let name = j.get("name").as_str().context("flow missing `name`")?;
        let actions = j
            .get("actions")
            .as_arr()
            .context("flow missing `actions`")?
            .iter()
            .map(|a| {
                let on_failure = match a.get("on_failure") {
                    Json::Null => FailurePolicy::Abort,
                    v => match v.as_str() {
                        Some("abort") => FailurePolicy::Abort,
                        Some("continue") => FailurePolicy::Continue,
                        Some(other) => bail!("unknown on_failure `{other}`"),
                        None => FailurePolicy::Catch(
                            v.get("catch")
                                .as_str()
                                .context("on_failure object needs `catch`")?
                                .to_string(),
                        ),
                    },
                };
                Ok(ActionDef {
                    id: a.get("id").as_str().context("action `id`")?.to_string(),
                    provider: a
                        .get("provider")
                        .as_str()
                        .context("action `provider`")?
                        .to_string(),
                    params: a.get("params").clone(),
                    depends_on: match a.get("depends_on").as_arr() {
                        Some(arr) => arr
                            .iter()
                            .map(|d| Ok(d.as_str().context("dep name")?.to_string()))
                            .collect::<Result<_>>()?,
                        None => vec![],
                    },
                    retries: a.get("retries").as_u64().unwrap_or(0) as u32,
                    retry: RetryPolicy {
                        base_s: a.get("retry_backoff_s").as_f64().unwrap_or(5.0),
                        cap_s: a.get("retry_cap_s").as_f64().unwrap_or(f64::INFINITY),
                        multiplier: a.get("retry_multiplier").as_f64().unwrap_or(1.0),
                        jitter: a.get("retry_jitter").as_f64().unwrap_or(0.0),
                    },
                    on_failure,
                    is_handler: a.get("handler").as_bool().unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        FlowDefinition::new(name, actions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn action(id: &str, deps: &[&str]) -> ActionDef {
        ActionDef {
            id: id.into(),
            provider: "noop".into(),
            params: Json::Null,
            depends_on: deps.iter().map(|s| s.to_string()).collect(),
            retries: 0,
            retry: RetryPolicy::fixed(1.0),
            on_failure: FailurePolicy::Abort,
            is_handler: false,
        }
    }

    #[test]
    fn topological_order_respects_deps() {
        let def = FlowDefinition::new(
            "f",
            vec![
                action("c", &["a", "b"]),
                action("a", &[]),
                action("b", &["a"]),
            ],
        )
        .unwrap();
        let ids: Vec<&str> = def.order().iter().map(|&i| def.actions[i].id.as_str()).collect();
        assert_eq!(ids, vec!["a", "b", "c"]);
    }

    #[test]
    fn cycle_detected() {
        let err = FlowDefinition::new(
            "f",
            vec![action("a", &["b"]), action("b", &["a"])],
        )
        .unwrap_err();
        assert!(err.to_string().contains("cycle"), "{err}");
    }

    #[test]
    fn duplicate_and_unknown_rejected() {
        assert!(FlowDefinition::new("f", vec![action("a", &[]), action("a", &[])]).is_err());
        assert!(FlowDefinition::new("f", vec![action("a", &["ghost"])]).is_err());
        assert!(FlowDefinition::new("f", vec![]).is_err());
    }

    #[test]
    fn catch_must_point_at_handler() {
        let mut a = action("a", &[]);
        a.on_failure = FailurePolicy::Catch("h".into());
        let mut h = action("h", &[]);
        h.is_handler = false;
        let err = FlowDefinition::new("f", vec![a.clone(), h.clone()]).unwrap_err();
        assert!(err.to_string().contains("handler"), "{err}");
        h.is_handler = true;
        assert!(FlowDefinition::new("f", vec![a, h]).is_ok());
    }

    #[test]
    fn parses_json_definition() {
        let j = Json::parse(
            r#"{
          "name": "demo",
          "actions": [
            {"id": "stage", "provider": "transfer", "params": {"bytes": 100}},
            {"id": "train", "provider": "compute", "depends_on": ["stage"],
             "retries": 2, "on_failure": {"catch": "cleanup"}},
            {"id": "cleanup", "provider": "noop", "handler": true}
          ]
        }"#,
        )
        .unwrap();
        let def = FlowDefinition::from_json(&j).unwrap();
        assert_eq!(def.name, "demo");
        assert_eq!(def.actions.len(), 3);
        assert_eq!(def.order().len(), 2); // handler excluded
        assert_eq!(def.action("train").unwrap().retries, 2);
        assert_eq!(
            def.action("train").unwrap().on_failure,
            FailurePolicy::Catch("cleanup".into())
        );
        // retry keys default to the fixed-interval policy
        assert_eq!(def.action("train").unwrap().retry, RetryPolicy::fixed(5.0));
    }

    /// The default policy must reproduce the pre-policy fixed-interval
    /// schedule *bit-for-bit*: `delay_after` returns exactly `base_s`
    /// for every attempt, which is what `t + retry_backoff_s` computed.
    #[test]
    fn default_retry_policy_is_bit_identical_to_fixed_interval() {
        let p = RetryPolicy::fixed(5.0);
        for k in 1..=10 {
            assert_eq!(p.delay_after("any-action", k), 5.0);
        }
        let p = RetryPolicy::fixed(0.25);
        assert_eq!(p.delay_after("stage", 1), 0.25);
        assert_eq!(p.delay_after("stage", 7), 0.25);
    }

    #[test]
    fn retry_policy_grows_caps_and_jitters_deterministically() {
        let p = RetryPolicy {
            base_s: 2.0,
            cap_s: 30.0,
            multiplier: 2.0,
            jitter: 0.0,
        };
        // 2, 4, 8, 16, then capped
        assert_eq!(p.delay_after("a", 1), 2.0);
        assert_eq!(p.delay_after("a", 2), 4.0);
        assert_eq!(p.delay_after("a", 3), 8.0);
        assert_eq!(p.delay_after("a", 4), 16.0);
        assert_eq!(p.delay_after("a", 5), 30.0);
        assert_eq!(p.delay_after("a", 20), 30.0);

        let j = RetryPolicy {
            jitter: 0.5,
            ..p.clone()
        };
        for k in 1..=8 {
            let base = p.delay_after("a", k);
            let d = j.delay_after("a", k);
            // jittered delay stays inside [1 − jitter, 1 + jitter) × base
            assert!(d >= base * 0.5 && d < base * 1.5, "attempt {k}: {d} vs {base}");
            // pure function of (action id, attempt): replays identically
            assert_eq!(d, j.delay_after("a", k));
        }
        // different actions decorrelate (same attempt, different draw)
        assert_ne!(j.delay_after("a", 1), j.delay_after("b", 1));
        // so do successive attempts of one action
        assert_ne!(
            j.delay_after("a", 1) / p.delay_after("a", 1),
            j.delay_after("a", 2) / p.delay_after("a", 2)
        );
    }

    #[test]
    fn retry_policy_validation() {
        assert!(RetryPolicy::fixed(5.0).validate().is_ok());
        assert!(RetryPolicy::fixed(-1.0).validate().is_err());
        assert!(RetryPolicy::fixed(f64::NAN).validate().is_err());
        let bad_cap = RetryPolicy {
            cap_s: 0.0,
            ..RetryPolicy::fixed(1.0)
        };
        assert!(bad_cap.validate().is_err());
        let bad_mult = RetryPolicy {
            multiplier: 0.5,
            ..RetryPolicy::fixed(1.0)
        };
        assert!(bad_mult.validate().is_err());
        let bad_jitter = RetryPolicy {
            jitter: 1.0,
            ..RetryPolicy::fixed(1.0)
        };
        assert!(bad_jitter.validate().is_err());
        // a bad policy is rejected at flow validation time, with context
        let mut a = action("a", &[]);
        a.retry.multiplier = 0.0;
        let err = FlowDefinition::new("f", vec![a]).unwrap_err();
        assert!(format!("{err:#}").contains("retry policy"), "{err:#}");
    }

    #[test]
    fn parses_retry_policy_keys() {
        let j = Json::parse(
            r#"{
          "name": "demo",
          "actions": [
            {"id": "t", "provider": "compute", "retries": 4,
             "retry_backoff_s": 2.0, "retry_cap_s": 30.0,
             "retry_multiplier": 2.0, "retry_jitter": 0.25}
          ]
        }"#,
        )
        .unwrap();
        let def = FlowDefinition::from_json(&j).unwrap();
        assert_eq!(
            def.action("t").unwrap().retry,
            RetryPolicy {
                base_s: 2.0,
                cap_s: 30.0,
                multiplier: 2.0,
                jitter: 0.25,
            }
        );
        // invalid values are rejected at load time
        let j = Json::parse(
            r#"{"name": "demo", "actions":
                [{"id": "t", "provider": "compute", "retry_jitter": 2.0}]}"#,
        )
        .unwrap();
        assert!(FlowDefinition::from_json(&j).is_err());
    }
}
