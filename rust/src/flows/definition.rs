//! Flow definitions: declarative DAGs of actions (Globus Flows analog).
//!
//! A *Flow* "represents a single process that orchestrates a series of
//! services/actions into a self contained operation ... a declaratively
//! defined ordering of Action Providers with condition handling" (§3).
//! Definitions are plain JSON (see `workflow::dnn_trainer_flow` for the
//! paper's flow) and validated for unique ids, resolvable dependencies,
//! and acyclicity at load time.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// What to do when an action exhausts its retries.
#[derive(Debug, Clone, PartialEq)]
pub enum FailurePolicy {
    /// fail the run immediately (default)
    Abort,
    /// record the failure, skip dependents, keep running independents
    Continue,
    /// run the named handler action, then fail the run
    Catch(String),
}

/// One action in a flow.
#[derive(Debug, Clone)]
pub struct ActionDef {
    pub id: String,
    /// action-provider name (must be registered on the engine)
    pub provider: String,
    /// parameters; strings may contain `${input...}` / `${result...}`
    pub params: Json,
    pub depends_on: Vec<String>,
    pub retries: u32,
    pub retry_backoff_s: f64,
    pub on_failure: FailurePolicy,
    /// handler actions only run via `FailurePolicy::Catch`
    pub is_handler: bool,
}

/// A validated flow definition.
#[derive(Debug, Clone)]
pub struct FlowDefinition {
    pub name: String,
    pub actions: Vec<ActionDef>,
    /// topological execution order over non-handler actions
    order: Vec<usize>,
}

impl FlowDefinition {
    pub fn new(name: impl Into<String>, actions: Vec<ActionDef>) -> Result<FlowDefinition> {
        let mut def = FlowDefinition {
            name: name.into(),
            actions,
            order: vec![],
        };
        def.validate()?;
        Ok(def)
    }

    /// Execution order (indices into `actions`), handlers excluded.
    pub fn order(&self) -> &[usize] {
        &self.order
    }

    pub fn action(&self, id: &str) -> Result<&ActionDef> {
        self.actions
            .iter()
            .find(|a| a.id == id)
            .with_context(|| format!("flow `{}` has no action `{id}`", self.name))
    }

    fn validate(&mut self) -> Result<()> {
        if self.actions.is_empty() {
            bail!("flow `{}` has no actions", self.name);
        }
        let mut ids = BTreeSet::new();
        for a in &self.actions {
            if !ids.insert(a.id.as_str()) {
                bail!("duplicate action id `{}`", a.id);
            }
        }
        let index: BTreeMap<&str, usize> = self
            .actions
            .iter()
            .enumerate()
            .map(|(i, a)| (a.id.as_str(), i))
            .collect();
        for a in &self.actions {
            for d in &a.depends_on {
                if !index.contains_key(d.as_str()) {
                    bail!("action `{}` depends on unknown `{d}`", a.id);
                }
            }
            if let FailurePolicy::Catch(h) = &a.on_failure {
                let hi = *index
                    .get(h.as_str())
                    .with_context(|| format!("action `{}` catches unknown `{h}`", a.id))?;
                if !self.actions[hi].is_handler {
                    bail!("catch target `{h}` must be declared as a handler");
                }
            }
            if a.is_handler && !a.depends_on.is_empty() {
                bail!("handler `{}` cannot have dependencies", a.id);
            }
        }
        // Kahn topological sort over non-handler actions
        let mut indeg: Vec<usize> = self
            .actions
            .iter()
            .map(|a| if a.is_handler { usize::MAX } else { a.depends_on.len() })
            .collect();
        let mut queue: Vec<usize> = indeg
            .iter()
            .enumerate()
            .filter(|&(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut order = Vec::new();
        while let Some(i) = queue.pop() {
            order.push(i);
            for (j, b) in self.actions.iter().enumerate() {
                if !b.is_handler && b.depends_on.iter().any(|d| d == &self.actions[i].id) {
                    indeg[j] -= 1;
                    if indeg[j] == 0 {
                        queue.push(j);
                    }
                }
            }
        }
        // keep declaration order among ready actions for determinism
        order.sort_by_key(|&i| {
            (
                self.depth(i),
                i,
            )
        });
        let expected = self.actions.iter().filter(|a| !a.is_handler).count();
        if order.len() != expected {
            bail!("flow `{}` has a dependency cycle", self.name);
        }
        self.order = order;
        Ok(())
    }

    /// Longest dependency chain above action `i` (for stable ordering).
    fn depth(&self, i: usize) -> usize {
        self.actions[i]
            .depends_on
            .iter()
            .map(|d| {
                let j = self.actions.iter().position(|a| &a.id == d).unwrap();
                1 + self.depth(j)
            })
            .max()
            .unwrap_or(0)
    }

    /// Parse from JSON:
    /// `{"name": ..., "actions": [{"id","provider","params","depends_on",
    ///   "retries","retry_backoff_s","on_failure","handler"}]}`
    /// `on_failure`: "abort" (default) | "continue" | {"catch": "id"}.
    pub fn from_json(j: &Json) -> Result<FlowDefinition> {
        let name = j.get("name").as_str().context("flow missing `name`")?;
        let actions = j
            .get("actions")
            .as_arr()
            .context("flow missing `actions`")?
            .iter()
            .map(|a| {
                let on_failure = match a.get("on_failure") {
                    Json::Null => FailurePolicy::Abort,
                    v => match v.as_str() {
                        Some("abort") => FailurePolicy::Abort,
                        Some("continue") => FailurePolicy::Continue,
                        Some(other) => bail!("unknown on_failure `{other}`"),
                        None => FailurePolicy::Catch(
                            v.get("catch")
                                .as_str()
                                .context("on_failure object needs `catch`")?
                                .to_string(),
                        ),
                    },
                };
                Ok(ActionDef {
                    id: a.get("id").as_str().context("action `id`")?.to_string(),
                    provider: a
                        .get("provider")
                        .as_str()
                        .context("action `provider`")?
                        .to_string(),
                    params: a.get("params").clone(),
                    depends_on: match a.get("depends_on").as_arr() {
                        Some(arr) => arr
                            .iter()
                            .map(|d| Ok(d.as_str().context("dep name")?.to_string()))
                            .collect::<Result<_>>()?,
                        None => vec![],
                    },
                    retries: a.get("retries").as_u64().unwrap_or(0) as u32,
                    retry_backoff_s: a.get("retry_backoff_s").as_f64().unwrap_or(5.0),
                    on_failure,
                    is_handler: a.get("handler").as_bool().unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        FlowDefinition::new(name, actions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn action(id: &str, deps: &[&str]) -> ActionDef {
        ActionDef {
            id: id.into(),
            provider: "noop".into(),
            params: Json::Null,
            depends_on: deps.iter().map(|s| s.to_string()).collect(),
            retries: 0,
            retry_backoff_s: 1.0,
            on_failure: FailurePolicy::Abort,
            is_handler: false,
        }
    }

    #[test]
    fn topological_order_respects_deps() {
        let def = FlowDefinition::new(
            "f",
            vec![
                action("c", &["a", "b"]),
                action("a", &[]),
                action("b", &["a"]),
            ],
        )
        .unwrap();
        let ids: Vec<&str> = def.order().iter().map(|&i| def.actions[i].id.as_str()).collect();
        assert_eq!(ids, vec!["a", "b", "c"]);
    }

    #[test]
    fn cycle_detected() {
        let err = FlowDefinition::new(
            "f",
            vec![action("a", &["b"]), action("b", &["a"])],
        )
        .unwrap_err();
        assert!(err.to_string().contains("cycle"), "{err}");
    }

    #[test]
    fn duplicate_and_unknown_rejected() {
        assert!(FlowDefinition::new("f", vec![action("a", &[]), action("a", &[])]).is_err());
        assert!(FlowDefinition::new("f", vec![action("a", &["ghost"])]).is_err());
        assert!(FlowDefinition::new("f", vec![]).is_err());
    }

    #[test]
    fn catch_must_point_at_handler() {
        let mut a = action("a", &[]);
        a.on_failure = FailurePolicy::Catch("h".into());
        let mut h = action("h", &[]);
        h.is_handler = false;
        let err = FlowDefinition::new("f", vec![a.clone(), h.clone()]).unwrap_err();
        assert!(err.to_string().contains("handler"), "{err}");
        h.is_handler = true;
        assert!(FlowDefinition::new("f", vec![a, h]).is_ok());
    }

    #[test]
    fn parses_json_definition() {
        let j = Json::parse(
            r#"{
          "name": "demo",
          "actions": [
            {"id": "stage", "provider": "transfer", "params": {"bytes": 100}},
            {"id": "train", "provider": "compute", "depends_on": ["stage"],
             "retries": 2, "on_failure": {"catch": "cleanup"}},
            {"id": "cleanup", "provider": "noop", "handler": true}
          ]
        }"#,
        )
        .unwrap();
        let def = FlowDefinition::from_json(&j).unwrap();
        assert_eq!(def.name, "demo");
        assert_eq!(def.actions.len(), 3);
        assert_eq!(def.order().len(), 2); // handler excluded
        assert_eq!(def.action("train").unwrap().retries, 2);
        assert_eq!(
            def.action("train").unwrap().on_failure,
            FailurePolicy::Catch("cleanup".into())
        );
    }
}
