//! The funcX service: function registry, task submission, per-endpoint
//! capacity slots with FIFO queues, and the result store.
//!
//! Discrete-event execution (DESIGN.md §4): `enqueue` records a task and
//! schedules its eligibility (dispatch latency + cold start); the task
//! *starts* only when one of its endpoint's capacity slots is free — the
//! gap between eligibility and start is multi-tenant queue wait, the
//! quantity the campaign layer studies. `advance_to` drives queued tasks
//! through start and completion up to a virtual time; the synchronous
//! `submit` drives a single task to completion over the same machinery
//! (the degenerate single-tenant case, bit-identical to the pre-DES
//! behaviour).

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Context, Result};

use super::endpoint::{EndpointStatus, FaasEndpoint};
use crate::simnet::VClock;
use crate::util::Json;

/// Registered function handle.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FuncId(pub String);

/// Submitted task handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskId(pub u64);

/// Task lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskStatus {
    /// waiting for dispatch latency and/or a free capacity slot
    Queued,
    /// body executing (observable only mid-`advance_to`)
    Running,
    Success(Json),
    Failed(String),
}

impl TaskStatus {
    pub fn is_complete(&self) -> bool {
        matches!(self, TaskStatus::Success(_) | TaskStatus::Failed(_))
    }
}

/// Accounting record for one task.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub id: TaskId,
    pub func: FuncId,
    pub endpoint: String,
    pub submitted_vt: f64,
    /// when dispatch latency (+cold start) ended and the task could have
    /// started had a slot been free
    pub eligible_vt: f64,
    pub started_vt: f64,
    pub finished_vt: f64,
    pub status: TaskStatus,
}

impl TaskRecord {
    /// Time spent executing the body (excludes queue/cold-start).
    pub fn exec_secs(&self) -> f64 {
        self.finished_vt - self.started_vt
    }

    /// Dispatch overhead (fixed latency + cold start + slot queue wait).
    pub fn overhead_secs(&self) -> f64 {
        self.started_vt - self.submitted_vt
    }

    /// Pure multi-tenant queue wait: time spent eligible but waiting for
    /// a capacity slot. Zero whenever the endpoint is uncontended.
    pub fn queue_wait_secs(&self) -> f64 {
        (self.started_vt - self.eligible_vt).max(0.0)
    }
}

type FuncBody<C> = Box<dyn Fn(&mut C, &mut VClock, &Json) -> Result<Json>>;

/// The federated FaaS fabric, generic over the execution context `C`.
pub struct FaasService<C> {
    funcs: BTreeMap<FuncId, FuncBody<C>>,
    endpoints: BTreeMap<String, FaasEndpoint>,
    tasks: Vec<TaskRecord>,
    /// FIFO queue of not-yet-started tasks per endpoint
    queues: BTreeMap<String, VecDeque<TaskId>>,
    /// per-endpoint slot free-at times (len == endpoint capacity)
    slots: BTreeMap<String, Vec<f64>>,
    /// started tasks whose completion has not been reported yet
    running: BTreeMap<String, Vec<(TaskId, f64)>>,
    /// per-endpoint start time of the most recently started task: the
    /// queue is strictly FIFO, so no task starts before the one ahead of
    /// it did (keeps start events monotone even though the first task
    /// pays the cold start and is eligible *later* than the second)
    last_start: BTreeMap<String, f64>,
    /// queued args awaiting start
    args: BTreeMap<u64, Json>,
    /// completions a sync `submit` drained on other tasks' behalf —
    /// re-delivered by the next `advance_to` so fabric drivers never
    /// miss one when the sync and queued APIs are mixed
    unclaimed: Vec<(f64, TaskId)>,
}

impl<C> Default for FaasService<C> {
    fn default() -> Self {
        FaasService {
            funcs: BTreeMap::new(),
            endpoints: BTreeMap::new(),
            tasks: Vec::new(),
            queues: BTreeMap::new(),
            slots: BTreeMap::new(),
            running: BTreeMap::new(),
            last_start: BTreeMap::new(),
            args: BTreeMap::new(),
            unclaimed: Vec::new(),
        }
    }
}

impl<C> FaasService<C> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a function; returns its handle (idempotent by name is NOT
    /// allowed — re-registering a name is an error, as in funcX where each
    /// registration mints a new UUID; we keep names unique for clarity).
    pub fn register_function(
        &mut self,
        name: &str,
        body: impl Fn(&mut C, &mut VClock, &Json) -> Result<Json> + 'static,
    ) -> Result<FuncId> {
        let id = FuncId(name.to_string());
        if self.funcs.contains_key(&id) {
            bail!("function `{name}` already registered");
        }
        self.funcs.insert(id.clone(), Box::new(body));
        Ok(id)
    }

    pub fn register_endpoint(&mut self, ep: FaasEndpoint) -> Result<()> {
        if self.endpoints.contains_key(&ep.id) {
            bail!("faas endpoint `{}` already registered", ep.id);
        }
        self.queues.insert(ep.id.clone(), VecDeque::new());
        self.slots.insert(ep.id.clone(), vec![0.0; ep.capacity]);
        self.running.insert(ep.id.clone(), Vec::new());
        self.last_start.insert(ep.id.clone(), 0.0);
        self.endpoints.insert(ep.id.clone(), ep);
        Ok(())
    }

    pub fn endpoint_mut(&mut self, id: &str) -> Result<&mut FaasEndpoint> {
        self.endpoints
            .get_mut(id)
            .with_context(|| format!("unknown faas endpoint `{id}`"))
    }

    /// Queue a task at virtual time `now`. The body runs when the
    /// dispatch latency has elapsed *and* a capacity slot is free (driven
    /// by `advance_to`). Offline endpoints fail the task immediately —
    /// recorded, not panicked, mirroring funcX's fire-and-forget model.
    pub fn enqueue(
        &mut self,
        now: f64,
        endpoint_id: &str,
        func: &FuncId,
        args: &Json,
    ) -> Result<TaskId> {
        if !self.funcs.contains_key(func) {
            bail!("unknown function `{}`", func.0);
        }
        let ep = self
            .endpoints
            .get_mut(endpoint_id)
            .with_context(|| format!("unknown faas endpoint `{endpoint_id}`"))?;
        let task_id = TaskId(self.tasks.len() as u64 + 1);
        if ep.status == EndpointStatus::Offline {
            self.tasks.push(TaskRecord {
                id: task_id,
                func: func.clone(),
                endpoint: endpoint_id.to_string(),
                submitted_vt: now,
                eligible_vt: now,
                started_vt: now,
                finished_vt: now,
                status: TaskStatus::Failed(format!("endpoint `{endpoint_id}` offline")),
            });
            return Ok(task_id);
        }
        let overhead = ep.next_dispatch_overhead();
        self.tasks.push(TaskRecord {
            id: task_id,
            func: func.clone(),
            endpoint: endpoint_id.to_string(),
            submitted_vt: now,
            eligible_vt: now + overhead,
            started_vt: f64::NAN,
            finished_vt: f64::NAN,
            status: TaskStatus::Queued,
        });
        self.queues
            .get_mut(endpoint_id)
            .expect("queue exists for registered endpoint")
            .push_back(task_id);
        self.args.insert(task_id.0, args.clone());
        Ok(task_id)
    }

    /// Earliest future virtual time at which the fabric changes state: a
    /// queued head starting, or a running task completing.
    pub fn next_event_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        for (ep_id, q) in &self.queues {
            if let Some(&head) = q.front() {
                t = t.min(self.start_instant(ep_id, head));
            }
        }
        for running in self.running.values() {
            for &(_, finish) in running {
                t = t.min(finish);
            }
        }
        t.is_finite().then_some(t)
    }

    /// Drive the fabric to virtual time `t`: start every queued task whose
    /// start instant (eligible + slot availability) is <= `t`, in global
    /// start-time order (deterministic tie-break by endpoint id), and
    /// return the tasks that completed by `t` in completion order.
    pub fn advance_to(&mut self, ctx: &mut C, t: f64) -> Vec<TaskId> {
        loop {
            // earliest startable head across endpoints
            let mut best: Option<(f64, String)> = None;
            for (ep_id, q) in &self.queues {
                if let Some(&head) = q.front() {
                    let st = self.start_instant(ep_id, head);
                    if st <= t && best.as_ref().map(|(bt, _)| st < *bt).unwrap_or(true) {
                        best = Some((st, ep_id.clone()));
                    }
                }
            }
            let Some((st, ep_id)) = best else { break };
            self.start_task(ctx, &ep_id, st);
        }
        // report completions due by t
        let mut done: Vec<(f64, TaskId)> = Vec::new();
        for running in self.running.values_mut() {
            running.retain(|&(id, finish)| {
                if finish <= t {
                    done.push((finish, id));
                    false
                } else {
                    true
                }
            });
        }
        // plus any a sync `submit` consumed on other tasks' behalf
        let mut i = 0;
        while i < self.unclaimed.len() {
            if self.unclaimed[i].0 <= t {
                done.push(self.unclaimed.swap_remove(i));
            } else {
                i += 1;
            }
        }
        done.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1 .0.cmp(&b.1 .0)));
        done.into_iter().map(|(_, id)| id).collect()
    }

    /// When the queue head of `ep_id` can start: its eligibility, the
    /// earliest slot, and the FIFO constraint (never before the task
    /// ahead of it started).
    fn start_instant(&self, ep_id: &str, head: TaskId) -> f64 {
        let free = self.slots[ep_id]
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        self.rec(head)
            .eligible_vt
            .max(free)
            .max(self.last_start[ep_id])
    }

    /// Run the queue head of `ep_id` at start time `st`.
    fn start_task(&mut self, ctx: &mut C, ep_id: &str, st: f64) {
        let id = self
            .queues
            .get_mut(ep_id)
            .expect("queue")
            .pop_front()
            .expect("head");
        let args = self.args.remove(&id.0).expect("queued args");
        let idx = (id.0 - 1) as usize;
        self.tasks[idx].started_vt = st;
        self.tasks[idx].status = TaskStatus::Running;
        let func = self.tasks[idx].func.clone();
        // measure the body's virtual duration on a scratch clock anchored
        // at the start instant (bodies advance time; they never see the
        // global clock under the DES scheduler)
        let mut scratch = VClock::starting_at(st);
        let status = {
            let body = self.funcs.get(&func).expect("checked at enqueue");
            match body(ctx, &mut scratch, &args) {
                Ok(v) => TaskStatus::Success(v),
                Err(e) => TaskStatus::Failed(format!("{e:#}")),
            }
        };
        let finish = scratch.now();
        self.tasks[idx].finished_vt = finish;
        self.tasks[idx].status = status;
        // occupy the earliest-free slot until the body's finish time
        let slots = self.slots.get_mut(ep_id).expect("slots");
        let si = slots
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .expect("capacity >= 1");
        slots[si] = finish;
        *self.last_start.get_mut(ep_id).expect("last_start") = st;
        self.running
            .get_mut(ep_id)
            .expect("running")
            .push((id, finish));
    }

    /// Submit a function to an endpoint and run it to completion in
    /// virtual time — the single-tenant convenience over the queue
    /// machinery. Returns the task handle; failures are recorded (and
    /// surfaced via `result()`), not panicked.
    pub fn submit(
        &mut self,
        ctx: &mut C,
        clock: &mut VClock,
        endpoint_id: &str,
        func: &FuncId,
        args: &Json,
    ) -> Result<TaskId> {
        let id = self.enqueue(clock.now(), endpoint_id, func, args)?;
        let mut reclaim = |svc: &mut Self, reported: Vec<TaskId>| {
            for tid in reported {
                if tid != id {
                    let ft = svc.rec(tid).finished_vt;
                    svc.unclaimed.push((ft, tid));
                }
            }
        };
        while !self.rec(id).status.is_complete() {
            let Some(t) = self.next_event_time() else {
                bail!("faas fabric stalled driving task {id:?}");
            };
            let reported = self.advance_to(ctx, t);
            reclaim(self, reported);
        }
        let finished = self.rec(id).finished_vt;
        // flush our own completion report so no stale event lingers for a
        // later fabric driver; completions of *other* queued tasks that
        // this drive happened to consume go back to `unclaimed`
        let reported = self.advance_to(ctx, finished);
        reclaim(self, reported);
        if finished > clock.now() {
            clock.advance_to(finished);
        }
        Ok(id)
    }

    fn rec(&self, id: TaskId) -> &TaskRecord {
        &self.tasks[(id.0 - 1) as usize]
    }

    pub fn record(&self, id: TaskId) -> Result<&TaskRecord> {
        self.tasks
            .iter()
            .find(|t| t.id == id)
            .with_context(|| format!("unknown task {id:?}"))
    }

    /// The task's output, or an error if it failed (or has not run yet).
    pub fn result(&self, id: TaskId) -> Result<&Json> {
        match &self.record(id)?.status {
            TaskStatus::Success(v) => Ok(v),
            TaskStatus::Failed(msg) => bail!("task {id:?} failed: {msg}"),
            TaskStatus::Queued | TaskStatus::Running => {
                bail!("task {id:?} has not completed")
            }
        }
    }

    pub fn records(&self) -> &[TaskRecord] {
        &self.tasks
    }

    /// Tasks currently queued (not yet started) on an endpoint.
    pub fn queue_depth(&self, endpoint_id: &str) -> usize {
        self.queues.get(endpoint_id).map(|q| q.len()).unwrap_or(0)
    }

    /// Fan independent *real* CPU work out on the process-wide
    /// work-stealing pool (results in task order). Function bodies that
    /// do heavy compute — batch fitting, rendering — call this so one
    /// knob (`XLOOP_THREADS`) governs parallelism across the whole
    /// fabric; virtual-time accounting stays with the caller.
    pub fn scope<'env, R: Send>(&self, tasks: Vec<crate::pool::ScopeTask<'env, R>>) -> Vec<R> {
        crate::pool::scope(tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::FacilityId;

    #[derive(Default)]
    struct Ctx {
        calls: u32,
    }

    fn setup() -> (FaasService<Ctx>, FuncId) {
        let mut svc = FaasService::<Ctx>::new();
        svc.register_endpoint(FaasEndpoint::new("alcf#gpu", FacilityId(1)))
            .unwrap();
        let f = svc
            .register_function("train", |ctx: &mut Ctx, clock, args| {
                ctx.calls += 1;
                let secs = args.get("secs").as_f64().unwrap_or(1.0);
                clock.advance(secs);
                Ok(Json::obj(vec![("trained", Json::Bool(true))]))
            })
            .unwrap();
        (svc, f)
    }

    #[test]
    fn submit_runs_and_accounts_time() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let args = Json::obj(vec![("secs", Json::num(19.0))]);
        let t = svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &args).unwrap();
        let rec = svc.record(t).unwrap();
        assert_eq!(rec.overhead_secs(), 3.0); // queue 1 + cold start 2
        assert_eq!(rec.exec_secs(), 19.0);
        assert_eq!(rec.queue_wait_secs(), 0.0); // uncontended
        assert_eq!(clock.now(), 22.0);
        assert_eq!(ctx.calls, 1);
        assert!(svc.result(t).unwrap().get("trained").as_bool().unwrap());
    }

    #[test]
    fn second_task_skips_cold_start() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let args = Json::obj(vec![("secs", Json::num(1.0))]);
        svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &args).unwrap();
        let before = clock.now();
        let t2 = svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &args).unwrap();
        assert_eq!(svc.record(t2).unwrap().overhead_secs(), 1.0);
        assert_eq!(clock.now() - before, 2.0);
    }

    #[test]
    fn body_error_is_recorded_not_fatal() {
        let mut svc = FaasService::<Ctx>::new();
        svc.register_endpoint(FaasEndpoint::new("e", FacilityId(0)))
            .unwrap();
        let f = svc
            .register_function("boom", |_, _, _| anyhow::bail!("kaput"))
            .unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let t = svc.submit(&mut ctx, &mut clock, "e", &f, &Json::Null).unwrap();
        let err = svc.result(t).unwrap_err();
        assert!(err.to_string().contains("kaput"), "{err}");
    }

    #[test]
    fn offline_endpoint_fails_fast() {
        let (mut svc, f) = setup();
        svc.endpoint_mut("alcf#gpu").unwrap().status = EndpointStatus::Offline;
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let t = svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &Json::Null).unwrap();
        assert!(svc.result(t).is_err());
        assert_eq!(clock.now(), 0.0); // nothing charged
        assert_eq!(ctx.calls, 0);
    }

    #[test]
    fn unknown_endpoint_and_function() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        assert!(svc.submit(&mut ctx, &mut clock, "nope", &f, &Json::Null).is_err());
        let bad = FuncId("ghost".into());
        assert!(svc
            .submit(&mut ctx, &mut clock, "alcf#gpu", &bad, &Json::Null)
            .is_err());
    }

    /// Capacity 1 + concurrent submissions = FIFO queue wait: the second
    /// task is eligible long before the first finishes and must wait for
    /// the slot; the third waits for both.
    #[test]
    fn fifo_queue_wait_on_contended_endpoint() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        // three 10 s tasks all submitted at t=0
        let args = Json::obj(vec![("secs", Json::num(10.0))]);
        let t1 = svc.enqueue(0.0, "alcf#gpu", &f, &args).unwrap();
        let t2 = svc.enqueue(0.0, "alcf#gpu", &f, &args).unwrap();
        let t3 = svc.enqueue(0.0, "alcf#gpu", &f, &args).unwrap();
        assert_eq!(svc.queue_depth("alcf#gpu"), 3);

        // drive the fabric to completion
        while let Some(t) = svc.next_event_time() {
            svc.advance_to(&mut ctx, t);
        }
        // t1: eligible at 3 (queue 1 + cold 2), starts 3, ends 13
        let r1 = svc.record(t1).unwrap().clone();
        assert_eq!(r1.eligible_vt, 3.0);
        assert_eq!(r1.started_vt, 3.0);
        assert_eq!(r1.finished_vt, 13.0);
        assert_eq!(r1.queue_wait_secs(), 0.0);
        // t2: eligible at 1, waits for the slot until 13, ends 23
        let r2 = svc.record(t2).unwrap().clone();
        assert_eq!(r2.eligible_vt, 1.0);
        assert_eq!(r2.started_vt, 13.0);
        assert_eq!(r2.queue_wait_secs(), 12.0);
        assert_eq!(r2.finished_vt, 23.0);
        // t3: waits for t2's completion
        let r3 = svc.record(t3).unwrap().clone();
        assert_eq!(r3.started_vt, 23.0);
        assert_eq!(r3.queue_wait_secs(), 22.0);
        assert_eq!(ctx.calls, 3);
    }

    /// More capacity slots admit more tasks at once.
    #[test]
    fn capacity_two_runs_pairs_concurrently() {
        let mut svc = FaasService::<Ctx>::new();
        svc.register_endpoint(
            FaasEndpoint::new("alcf#cluster", FacilityId(1)).with_capacity(2),
        )
        .unwrap();
        let f = svc
            .register_function("work", |ctx: &mut Ctx, clock, _| {
                ctx.calls += 1;
                clock.advance(10.0);
                Ok(Json::Null)
            })
            .unwrap();
        let mut ctx = Ctx::default();
        let ids: Vec<TaskId> = (0..4)
            .map(|_| svc.enqueue(0.0, "alcf#cluster", &f, &Json::Null).unwrap())
            .collect();
        while let Some(t) = svc.next_event_time() {
            svc.advance_to(&mut ctx, t);
        }
        // FIFO: the head pays the cold start (eligible 3); the second is
        // eligible at 1 but never starts before the task ahead of it, so
        // both slots fill at t=3; the next pair starts when the slots
        // free at 13
        let starts: Vec<f64> = ids
            .iter()
            .map(|&i| svc.record(i).unwrap().started_vt)
            .collect();
        assert_eq!(starts, vec![3.0, 3.0, 13.0, 13.0]);
    }

    /// Mixing the sync and queued APIs must not lose completions: a
    /// `submit` that drives the fabric past another queued task's finish
    /// re-delivers that completion to the next `advance_to` caller.
    #[test]
    fn sync_submit_does_not_swallow_queued_completions() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let t1 = svc
            .enqueue(0.0, "alcf#gpu", &f, &Json::obj(vec![("secs", Json::num(5.0))]))
            .unwrap();
        let mut clock = VClock::new();
        let t2 = svc
            .submit(
                &mut ctx,
                &mut clock,
                "alcf#gpu",
                &f,
                &Json::obj(vec![("secs", Json::num(1.0))]),
            )
            .unwrap();
        // t1 (queued first, capacity 1) ran to completion during the drive
        assert!(svc.record(t1).unwrap().status.is_complete());
        // ...but its completion is still delivered to the fabric driver
        let done = svc.advance_to(&mut ctx, clock.now());
        assert!(done.contains(&t1), "{done:?}");
        assert!(!done.contains(&t2), "own task reported twice: {done:?}");
    }

    /// advance_to only reports completions due by the horizon; partial
    /// advances leave later completions pending.
    #[test]
    fn advance_to_respects_horizon() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let args = Json::obj(vec![("secs", Json::num(10.0))]);
        let t1 = svc.enqueue(0.0, "alcf#gpu", &f, &args).unwrap();
        let done = svc.advance_to(&mut ctx, 5.0);
        assert!(done.is_empty()); // started at 3, finishes at 13
        assert_eq!(svc.record(t1).unwrap().started_vt, 3.0);
        let done = svc.advance_to(&mut ctx, 13.0);
        assert_eq!(done, vec![t1]);
        // no double reporting
        assert!(svc.advance_to(&mut ctx, 20.0).is_empty());
    }

    #[test]
    fn scope_fans_real_compute_out_in_order() {
        let (svc, _) = setup();
        let tasks: Vec<crate::pool::ScopeTask<u64>> = (0..16)
            .map(|i| Box::new(move || (i as u64 + 1) * 10) as crate::pool::ScopeTask<u64>)
            .collect();
        let out = svc.scope(tasks);
        assert_eq!(out, (1..=16).map(|i| i * 10).collect::<Vec<u64>>());
    }

    #[test]
    fn duplicate_registration_rejected() {
        let (mut svc, _) = setup();
        assert!(svc.register_function("train", |_, _, _| Ok(Json::Null)).is_err());
        assert!(svc
            .register_endpoint(FaasEndpoint::new("alcf#gpu", FacilityId(1)))
            .is_err());
    }
}
