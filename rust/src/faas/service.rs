//! The funcX service: function registry, task submission, per-endpoint
//! capacity slots with policy-ordered queues, and the result store.
//!
//! Discrete-event execution (DESIGN.md §4): `enqueue` records a task and
//! schedules its eligibility (dispatch latency + cold start); the task
//! *starts* only when one of its endpoint's capacity slots is free — the
//! gap between eligibility and start is multi-tenant queue wait, the
//! quantity the campaign layer studies. *Which* queued task takes a
//! freed slot is delegated to a pluggable [`SchedPolicy`] (DESIGN.md
//! §9); the default [`Fifo`] policy is bit-identical to the pre-policy
//! strict-FIFO core. `advance_to` drives queued tasks through start and
//! completion up to a virtual time — interleaving autoscaler capacity
//! changes ([`Autoscaler`]) and starts in virtual-time order — and the
//! synchronous `submit` drives a single task to completion over the
//! same machinery (the degenerate single-tenant case, bit-identical to
//! the pre-DES behaviour). Planned outages (`begin_outage`/
//! `end_outage`) fail running tasks for the flow layer to retry while
//! the queue itself survives the window.

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Context, Result};

use super::endpoint::{EndpointStatus, FaasEndpoint};
use super::sched::{Autoscaler, Fifo, QueueView, ScalingEvent, SchedPolicy, SchedTask, TaskMeta};
use crate::simnet::VClock;
use crate::util::Json;

/// Registered function handle.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FuncId(pub String);

/// Submitted task handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskId(pub u64);

/// Task lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskStatus {
    /// waiting for dispatch latency and/or a free capacity slot
    Queued,
    /// body executing (observable only mid-`advance_to`)
    Running,
    Success(Json),
    Failed(String),
}

impl TaskStatus {
    pub fn is_complete(&self) -> bool {
        matches!(self, TaskStatus::Success(_) | TaskStatus::Failed(_))
    }
}

/// Accounting record for one task.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub id: TaskId,
    pub func: FuncId,
    pub endpoint: String,
    pub submitted_vt: f64,
    /// when dispatch latency (+cold start) ended and the task could have
    /// started had a slot been free
    pub eligible_vt: f64,
    pub started_vt: f64,
    pub finished_vt: f64,
    pub status: TaskStatus,
    /// scheduler-relevant metadata (tenant, priority, duration estimate)
    pub meta: TaskMeta,
}

impl TaskRecord {
    /// Time spent executing the body (excludes queue/cold-start).
    pub fn exec_secs(&self) -> f64 {
        self.finished_vt - self.started_vt
    }

    /// Dispatch overhead (fixed latency + cold start + slot queue wait).
    pub fn overhead_secs(&self) -> f64 {
        self.started_vt - self.submitted_vt
    }

    /// Pure multi-tenant queue wait: time spent eligible but waiting for
    /// a capacity slot. Zero whenever the endpoint is uncontended.
    pub fn queue_wait_secs(&self) -> f64 {
        (self.started_vt - self.eligible_vt).max(0.0)
    }
}

/// A gang displaced by a spot reclaim (`FaasService::reclaim_spot`):
/// everything the workflow layer's migration planner needs to reassign
/// it and resume from its last checkpoint (DESIGN.md §12).
#[derive(Debug, Clone)]
pub struct Displaced {
    pub task: TaskId,
    /// scheduler metadata of the original enqueue (tenant, priority,
    /// gang width, checkpoint cadence) — the resume re-enters a queue
    /// with the same identity, so a re-preemption composes
    pub meta: TaskMeta,
    /// body seconds persisted in the last whole checkpoint before the
    /// reclaim (the resume replays from here; `0.0` when the task was
    /// not checkpointable — all progress is lost)
    pub checkpointed_s: f64,
    /// body seconds actually executed on the source endpoint before
    /// the reclaim (billed there: the wire does not refund preemption)
    pub elapsed_s: f64,
    /// the full body duration of the original run
    pub full_s: f64,
    /// the original task's output. Under the run-at-start execution
    /// model the body's side effects already happened at start; the
    /// resume replays only the remaining *time* and re-emits this.
    pub output: Json,
}

impl Displaced {
    /// Body seconds the resume still has to execute.
    pub fn remaining_s(&self) -> f64 {
        (self.full_s - self.checkpointed_s).max(0.0)
    }
}

// `Send` so a campaign shard (which owns its World, faas included) can
// migrate between pool workers at bounded-lag window barriers.
type FuncBody<C> = Box<dyn Fn(&mut C, &mut VClock, &Json) -> Result<Json> + Send>;

/// Autoscaler config plus its runtime state for one endpoint.
struct AutoState {
    cfg: Autoscaler,
    /// a provision in flight completes (slot usable) at this time
    pending_at: Option<f64>,
    /// tenant whose waiting demand fired the in-flight provision
    /// (recorded into the `ScalingEvent` when it completes; 0 = untagged)
    pending_user: u32,
    /// last capacity change (cooldown reference)
    last_action_vt: f64,
}

/// The federated FaaS fabric, generic over the execution context `C`.
pub struct FaasService<C> {
    funcs: BTreeMap<FuncId, FuncBody<C>>,
    endpoints: BTreeMap<String, FaasEndpoint>,
    tasks: Vec<TaskRecord>,
    /// not-yet-started tasks per endpoint, in arrival order; the
    /// scheduling policy decides which index starts next
    queues: BTreeMap<String, VecDeque<TaskId>>,
    /// per-endpoint slot free-at times (len == endpoint capacity)
    slots: BTreeMap<String, Vec<f64>>,
    /// started tasks whose completion has not been reported yet
    running: BTreeMap<String, Vec<(TaskId, f64)>>,
    /// per-endpoint start time of the most recently started task (the
    /// FIFO policy's start-monotonicity floor: no task starts before the
    /// one ahead of it did, even though the first task pays the cold
    /// start and is eligible *later* than the second)
    last_start: BTreeMap<String, f64>,
    /// queued args awaiting start
    args: BTreeMap<u64, Json>,
    /// completions owed to the next `advance_to` caller: ones a sync
    /// `submit` drained on other tasks' behalf, and tasks an outage
    /// failed mid-run — fabric drivers never miss either
    unclaimed: Vec<(f64, TaskId)>,
    /// which queued task starts when a slot frees (DESIGN.md §9)
    policy: Box<dyn SchedPolicy>,
    /// per-endpoint elasticity (absent = fixed capacity)
    autoscalers: BTreeMap<String, AutoState>,
    /// last enqueue/start/outage instant per autoscaled endpoint — the
    /// idle-window reference for scale-down decisions
    last_activity: BTreeMap<String, f64>,
    /// every capacity change applied (campaign reporting)
    scaling: Vec<ScalingEvent>,
}

impl<C> Default for FaasService<C> {
    fn default() -> Self {
        FaasService {
            funcs: BTreeMap::new(),
            endpoints: BTreeMap::new(),
            tasks: Vec::new(),
            queues: BTreeMap::new(),
            slots: BTreeMap::new(),
            running: BTreeMap::new(),
            last_start: BTreeMap::new(),
            args: BTreeMap::new(),
            unclaimed: Vec::new(),
            policy: Box::new(Fifo),
            autoscalers: BTreeMap::new(),
            last_activity: BTreeMap::new(),
            scaling: Vec::new(),
        }
    }
}

impl<C> FaasService<C> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a function; returns its handle (idempotent by name is NOT
    /// allowed — re-registering a name is an error, as in funcX where each
    /// registration mints a new UUID; we keep names unique for clarity).
    pub fn register_function(
        &mut self,
        name: &str,
        body: impl Fn(&mut C, &mut VClock, &Json) -> Result<Json> + Send + 'static,
    ) -> Result<FuncId> {
        let id = FuncId(name.to_string());
        if self.funcs.contains_key(&id) {
            bail!("function `{name}` already registered");
        }
        self.funcs.insert(id.clone(), Box::new(body));
        Ok(id)
    }

    pub fn register_endpoint(&mut self, ep: FaasEndpoint) -> Result<()> {
        if self.endpoints.contains_key(&ep.id) {
            bail!("faas endpoint `{}` already registered", ep.id);
        }
        self.queues.insert(ep.id.clone(), VecDeque::new());
        self.slots.insert(ep.id.clone(), vec![0.0; ep.capacity]);
        self.running.insert(ep.id.clone(), Vec::new());
        self.last_start.insert(ep.id.clone(), 0.0);
        self.endpoints.insert(ep.id.clone(), ep);
        Ok(())
    }

    pub fn endpoint_mut(&mut self, id: &str) -> Result<&mut FaasEndpoint> {
        self.endpoints
            .get_mut(id)
            .with_context(|| format!("unknown faas endpoint `{id}`"))
    }

    /// Every registered endpoint, in id order (cost accounting reads
    /// base capacities from here).
    pub fn endpoints(&self) -> impl Iterator<Item = &FaasEndpoint> {
        self.endpoints.values()
    }

    /// Resize an endpoint's base capacity (heterogeneous campaigns
    /// size the trainer to the widest gang in the mix). Like
    /// `set_policy`, rejected once tasks are in flight — decisions
    /// already exposed through `next_event_time` must not shift.
    pub fn set_capacity(&mut self, endpoint_id: &str, capacity: usize) -> Result<()> {
        // NB: a started task's record is already terminal (the body ran
        // on a scratch clock at start), so `is_complete` alone would
        // miss it — `running` is what still holds slot leases
        if self.tasks.iter().any(|t| !t.status.is_complete())
            || self.running.values().any(|r| !r.is_empty())
        {
            bail!("cannot resize capacity with tasks in flight");
        }
        let capacity = capacity.max(1);
        let ep = self
            .endpoints
            .get_mut(endpoint_id)
            .with_context(|| format!("unknown faas endpoint `{endpoint_id}`"))?;
        self.slots
            .get_mut(endpoint_id)
            .expect("slots exist for registered endpoint")
            .resize(capacity, 0.0);
        ep.capacity = capacity;
        Ok(())
    }

    /// Replace the scheduling policy. Must be called before any task is
    /// enqueued — switching mid-queue would re-order decisions already
    /// exposed through `next_event_time`.
    pub fn set_policy(&mut self, policy: Box<dyn SchedPolicy>) -> Result<()> {
        if self.tasks.iter().any(|t| !t.status.is_complete()) {
            bail!("cannot switch scheduling policy with tasks in flight");
        }
        self.policy = policy;
        Ok(())
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Attach an autoscaler to an endpoint. The endpoint's current
    /// capacity is clamped into `[min_capacity, max_capacity]`.
    pub fn set_autoscaler(&mut self, endpoint_id: &str, cfg: Autoscaler) -> Result<()> {
        let ep = self
            .endpoints
            .get_mut(endpoint_id)
            .with_context(|| format!("unknown faas endpoint `{endpoint_id}`"))?;
        let min = cfg.min_capacity.max(1);
        let max = cfg.max_capacity.max(min);
        let cfg = Autoscaler {
            min_capacity: min,
            max_capacity: max,
            ..cfg
        };
        let slots = self.slots.get_mut(endpoint_id).expect("slots");
        while slots.len() < min {
            slots.push(0.0);
        }
        while slots.len() > max {
            slots.pop();
        }
        ep.capacity = slots.len();
        self.autoscalers.insert(
            endpoint_id.to_string(),
            AutoState {
                cfg,
                pending_at: None,
                pending_user: 0,
                last_action_vt: f64::NEG_INFINITY,
            },
        );
        self.last_activity.entry(endpoint_id.to_string()).or_insert(0.0);
        Ok(())
    }

    /// Every capacity change autoscalers have applied, in virtual-time
    /// order.
    pub fn scaling_log(&self) -> &[ScalingEvent] {
        &self.scaling
    }

    /// Queue a task at virtual time `now`. The body runs when the
    /// dispatch latency has elapsed *and* the scheduling policy grants
    /// it a capacity slot (driven by `advance_to`). Offline endpoints
    /// fail the task immediately — recorded, not panicked, mirroring
    /// funcX's fire-and-forget model; endpoints that are `Down` (a
    /// planned outage) accept the task into the surviving queue.
    pub fn enqueue(
        &mut self,
        now: f64,
        endpoint_id: &str,
        func: &FuncId,
        args: &Json,
    ) -> Result<TaskId> {
        self.enqueue_with_meta(now, endpoint_id, func, args, TaskMeta::default())
    }

    /// `enqueue` with scheduler metadata (tenant, priority class, cost
    /// model duration estimate, gang width) attached for the policy to
    /// use. A gang (`meta.slots > 1`) occupies its full width of
    /// capacity slots atomically for the whole run; widths the endpoint
    /// can never satisfy (above current capacity and above any attached
    /// autoscaler's `max_capacity`) are rejected here rather than
    /// deadlocking the queue.
    pub fn enqueue_with_meta(
        &mut self,
        now: f64,
        endpoint_id: &str,
        func: &FuncId,
        args: &Json,
        meta: TaskMeta,
    ) -> Result<TaskId> {
        if !self.funcs.contains_key(func) {
            bail!("unknown function `{}`", func.0);
        }
        let mut meta = meta;
        meta.slots = meta.width();
        if let Some(slots) = self.slots.get(endpoint_id) {
            let limit = self
                .autoscalers
                .get(endpoint_id)
                .map(|a| a.cfg.max_capacity)
                .unwrap_or(0)
                .max(slots.len());
            if meta.slots > limit {
                bail!(
                    "gang of {} slot(s) can never fit on `{endpoint_id}` \
                     (capacity limit {limit})",
                    meta.slots
                );
            }
        }
        let ep = self
            .endpoints
            .get_mut(endpoint_id)
            .with_context(|| format!("unknown faas endpoint `{endpoint_id}`"))?;
        let task_id = TaskId(self.tasks.len() as u64 + 1);
        if ep.status == EndpointStatus::Offline {
            self.tasks.push(TaskRecord {
                id: task_id,
                func: func.clone(),
                endpoint: endpoint_id.to_string(),
                submitted_vt: now,
                eligible_vt: now,
                started_vt: now,
                finished_vt: now,
                status: TaskStatus::Failed(format!("endpoint `{endpoint_id}` offline")),
                meta,
            });
            return Ok(task_id);
        }
        let overhead = ep.next_dispatch_overhead();
        self.tasks.push(TaskRecord {
            id: task_id,
            func: func.clone(),
            endpoint: endpoint_id.to_string(),
            submitted_vt: now,
            eligible_vt: now + overhead,
            started_vt: f64::NAN,
            finished_vt: f64::NAN,
            status: TaskStatus::Queued,
            meta,
        });
        self.queues
            .get_mut(endpoint_id)
            .expect("queue exists for registered endpoint")
            .push_back(task_id);
        self.args.insert(task_id.0, args.clone());
        self.note_activity(endpoint_id, now);
        self.autoscale_check(endpoint_id, now);
        Ok(task_id)
    }

    /// Earliest future virtual time at which the fabric changes state: a
    /// queued task starting (per the policy), a running task completing,
    /// an autoscaler provision finishing, or an idle-release deadline.
    pub fn next_event_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        for ep_id in self.queues.keys() {
            if let Some((_, st)) = self.pending_start(ep_id) {
                t = t.min(st);
            }
        }
        for running in self.running.values() {
            for &(_, finish) in running {
                t = t.min(finish);
            }
        }
        for (ep_id, auto) in &self.autoscalers {
            if let Some(p) = auto.pending_at {
                t = t.min(p);
            }
            if let Some(d) = self.scale_down_deadline(ep_id) {
                t = t.min(d);
            }
        }
        t.is_finite().then_some(t)
    }

    /// Drive the fabric to virtual time `t`: interleave autoscaler
    /// capacity changes and policy-granted task starts in global
    /// virtual-time order (deterministic tie-break by endpoint id;
    /// provisions apply before same-instant starts so a freshly usable
    /// slot is visible, starts before same-instant idle releases so a
    /// claimable slot is never released under a startable task), and
    /// return the tasks that completed by `t` in completion order.
    pub fn advance_to(&mut self, ctx: &mut C, t: f64) -> Vec<TaskId> {
        loop {
            // earliest due provision completion across endpoints
            let mut prov: Option<(f64, String)> = None;
            for (ep_id, auto) in &self.autoscalers {
                if let Some(p) = auto.pending_at {
                    if p <= t && prov.as_ref().map(|(bt, _)| p < *bt).unwrap_or(true) {
                        prov = Some((p, ep_id.clone()));
                    }
                }
            }
            // earliest policy-granted start across endpoints
            let mut best: Option<(f64, usize, String)> = None;
            for ep_id in self.queues.keys() {
                if let Some((idx, st)) = self.pending_start(ep_id) {
                    if st <= t && best.as_ref().map(|(bt, _, _)| st < *bt).unwrap_or(true) {
                        best = Some((st, idx, ep_id.clone()));
                    }
                }
            }
            // earliest due idle release
            let mut down: Option<(f64, String)> = None;
            for ep_id in self.autoscalers.keys() {
                if let Some(d) = self.scale_down_deadline(ep_id) {
                    if d <= t && down.as_ref().map(|(bt, _)| d < *bt).unwrap_or(true) {
                        down = Some((d, ep_id.clone()));
                    }
                }
            }
            let pt = prov.as_ref().map(|(p, _)| *p).unwrap_or(f64::INFINITY);
            let st = best.as_ref().map(|(s, _, _)| *s).unwrap_or(f64::INFINITY);
            let dt = down.as_ref().map(|(d, _)| *d).unwrap_or(f64::INFINITY);
            if pt.is_finite() && pt <= st && pt <= dt {
                let (p, ep_id) = prov.expect("provision chosen");
                self.apply_provision(&ep_id, p);
            } else if st.is_finite() && st <= dt {
                let (st, idx, ep_id) = best.expect("start chosen");
                self.start_task(ctx, &ep_id, idx, st);
            } else if dt.is_finite() {
                let (d, ep_id) = down.expect("release chosen");
                self.apply_scale_down(&ep_id, d);
            } else {
                break;
            }
        }
        // report completions due by t
        let mut done: Vec<(f64, TaskId)> = Vec::new();
        for running in self.running.values_mut() {
            running.retain(|&(id, finish)| {
                if finish <= t {
                    done.push((finish, id));
                    false
                } else {
                    true
                }
            });
        }
        // plus completions owed from sync `submit` drives and outages
        let mut i = 0;
        while i < self.unclaimed.len() {
            if self.unclaimed[i].0 <= t {
                done.push(self.unclaimed.swap_remove(i));
            } else {
                i += 1;
            }
        }
        done.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1 .0.cmp(&b.1 .0)));
        done.into_iter().map(|(_, id)| id).collect()
    }

    /// The policy's decision for `ep_id`: which queue index starts next
    /// and when. `None` when the queue is empty or the endpoint is not
    /// accepting starts (Down/Offline).
    ///
    /// Materializes an O(queue) view per call — priority/SJF/backfill
    /// genuinely rescan the whole queue at every decision point, and at
    /// simulation scale (tens of queued tasks, a handful of endpoints)
    /// the allocation is noise next to the fabric advance. Revisit with
    /// a cached view if campaigns grow to thousands of queued tasks.
    fn pending_start(&self, ep_id: &str) -> Option<(usize, f64)> {
        if self.endpoints[ep_id].status != EndpointStatus::Online {
            return None;
        }
        let q = &self.queues[ep_id];
        if q.is_empty() {
            return None;
        }
        let tasks: Vec<SchedTask> = q
            .iter()
            .map(|&id| {
                let r = self.rec(id);
                SchedTask {
                    id,
                    submitted_vt: r.submitted_vt,
                    eligible_vt: r.eligible_vt,
                    meta: &r.meta,
                }
            })
            .collect();
        let mut slot_free: Vec<f64> = self.slots[ep_id].clone();
        slot_free.sort_by(f64::total_cmp);
        let view = QueueView {
            tasks: &tasks,
            slot_free: &slot_free,
            last_start_vt: self.last_start[ep_id],
        };
        let pick = self.policy.pick(&view)?;
        // an infinite start means "nothing can run until capacity
        // grows" (a gang wider than current capacity waiting for a
        // provision); report no pending start rather than a due event
        if !pick.start_vt.is_finite() {
            return None;
        }
        Some((pick.queue_idx, pick.start_vt))
    }

    /// Run the task at queue index `idx` of `ep_id` at start time `st`.
    fn start_task(&mut self, ctx: &mut C, ep_id: &str, idx: usize, st: f64) {
        let id = self
            .queues
            .get_mut(ep_id)
            .expect("queue")
            .remove(idx)
            .expect("picked index in range");
        let args = self.args.remove(&id.0).expect("queued args");
        let idx = (id.0 - 1) as usize;
        self.tasks[idx].started_vt = st;
        self.tasks[idx].status = TaskStatus::Running;
        let func = self.tasks[idx].func.clone();
        // measure the body's virtual duration on a scratch clock anchored
        // at the start instant (bodies advance time; they never see the
        // global clock under the DES scheduler)
        let mut scratch = VClock::starting_at(st);
        let status = {
            let body = self.funcs.get(&func).expect("checked at enqueue");
            match body(ctx, &mut scratch, &args) {
                Ok(v) => TaskStatus::Success(v),
                Err(e) => TaskStatus::Failed(format!("{e:#}")),
            }
        };
        let finish = scratch.now();
        self.tasks[idx].finished_vt = finish;
        self.tasks[idx].status = status;
        // occupy the gang's full width of earliest-free slots until the
        // body's finish time — acquired together, released together
        // (never a partial hold)
        let width = self.tasks[idx].meta.width();
        let slots = self.slots.get_mut(ep_id).expect("slots");
        debug_assert!(width <= slots.len(), "policy started an unsatisfiable gang");
        let mut order: Vec<usize> = (0..slots.len()).collect();
        order.sort_by(|&a, &b| slots[a].total_cmp(&slots[b]).then(a.cmp(&b)));
        for &si in order.iter().take(width) {
            slots[si] = finish;
        }
        *self.last_start.get_mut(ep_id).expect("last_start") = st;
        self.running
            .get_mut(ep_id)
            .expect("running")
            .push((id, finish));
        self.note_activity(ep_id, st);
    }

    /// Record queue/slot activity on an autoscaled endpoint (the
    /// idle-window reference for scale-down).
    fn note_activity(&mut self, ep_id: &str, vt: f64) {
        if self.autoscalers.contains_key(ep_id) {
            let e = self.last_activity.entry(ep_id.to_string()).or_insert(0.0);
            *e = e.max(vt);
        }
    }

    /// Trigger a scale-up provision if the waiting queue is deep enough
    /// and no provision is in flight. A trigger landing inside the
    /// cooldown window is deferred, not dropped: the provision is
    /// scheduled from the cooldown's end, so sustained pressure keeps
    /// stepping capacity toward the max one cooldown apart. Called
    /// whenever the waiting count can have grown (enqueue, provision
    /// completion, outage recovery).
    fn autoscale_check(&mut self, ep_id: &str, now: f64) {
        let Some(auto) = self.autoscalers.get(ep_id) else {
            return;
        };
        let cap = self.slots.get(ep_id).map(|s| s.len()).unwrap_or(0);
        if auto.pending_at.is_some() || cap >= auto.cfg.max_capacity {
            return;
        }
        // gang-weighted: a width-k gang is k slots of unmet demand
        let waiting = self.waiting_depth(ep_id);
        // a queued gang wider than current capacity can NEVER start
        // without a provision — that is unconditional pressure, even
        // below the configured waiting threshold (otherwise a lone
        // wide gang under a high `scale_up_waiting` would deadlock).
        // One scan finds it; it doubles as the attribution candidate.
        let too_wide = self
            .queues
            .get(ep_id)
            .and_then(|q| q.iter().find(|&&id| self.rec(id).meta.width() > cap));
        if waiting < auto.cfg.scale_up_waiting && too_wide.is_none() {
            return;
        }
        // whose demand is this? the unsatisfiable gang when one forced
        // the trigger, else the head of the waiting queue — recorded so
        // the eventual ScalingEvent (and its waste) is attributable to
        // a tenant (DESIGN.md §11)
        let trigger_user = too_wide
            .or_else(|| self.queues.get(ep_id).and_then(|q| q.front()))
            .map(|&id| self.rec(id).meta.user)
            .unwrap_or(0);
        let auto = self.autoscalers.get_mut(ep_id).expect("checked above");
        let trigger = now.max(auto.last_action_vt + auto.cfg.cooldown_s);
        auto.pending_at = Some(trigger + auto.cfg.provision_delay_s);
        auto.pending_user = trigger_user;
    }

    /// A provision completed at `p`: the new slot becomes usable.
    fn apply_provision(&mut self, ep_id: &str, p: f64) {
        let auto = self.autoscalers.get_mut(ep_id).expect("autoscaled");
        auto.pending_at = None;
        auto.last_action_vt = p;
        let trigger_user = auto.pending_user;
        let slots = self.slots.get_mut(ep_id).expect("slots");
        slots.push(p);
        let capacity = slots.len();
        self.endpoints.get_mut(ep_id).expect("endpoint").capacity = capacity;
        self.scaling.push(ScalingEvent {
            vt: p,
            endpoint: ep_id.to_string(),
            capacity,
            trigger_user,
        });
        self.note_activity(ep_id, p);
        // the queue may still be deep enough for another step (the
        // cooldown spaces consecutive provisions out)
        self.autoscale_check(ep_id, p);
    }

    /// When the endpoint's excess idle capacity is due for release:
    /// requires an empty waiting queue, capacity above the floor, and a
    /// continuously free slot for `scale_down_idle_s` (measured from the
    /// later of the earliest slot-free time and the last queue/slot
    /// activity), no earlier than the cooldown allows.
    fn scale_down_deadline(&self, ep_id: &str) -> Option<f64> {
        let auto = self.autoscalers.get(ep_id)?;
        if !auto.cfg.scale_down_idle_s.is_finite() {
            return None;
        }
        // a non-Online endpoint's free slots are reclaimed or waiting
        // capacity, not idleness — a spot reclaim (or outage) must not
        // double-count as an autoscaler idle release
        if self.endpoints[ep_id].status != EndpointStatus::Online {
            return None;
        }
        let slots = &self.slots[ep_id];
        if slots.len() <= auto.cfg.min_capacity || !self.queues[ep_id].is_empty() {
            return None;
        }
        let min_free = slots.iter().cloned().fold(f64::INFINITY, f64::min);
        let idle_from = min_free.max(self.last_activity.get(ep_id).copied().unwrap_or(0.0));
        Some((idle_from + auto.cfg.scale_down_idle_s).max(auto.last_action_vt + auto.cfg.cooldown_s))
    }

    /// Release the earliest-free slot at `d` (the idle deadline).
    fn apply_scale_down(&mut self, ep_id: &str, d: f64) {
        let slots = self.slots.get_mut(ep_id).expect("slots");
        let i = slots
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .expect("capacity >= 1");
        slots.remove(i);
        let capacity = slots.len();
        self.endpoints.get_mut(ep_id).expect("endpoint").capacity = capacity;
        let auto = self.autoscalers.get_mut(ep_id).expect("autoscaled");
        auto.last_action_vt = d;
        self.last_activity.insert(ep_id.to_string(), d);
        self.scaling.push(ScalingEvent {
            vt: d,
            endpoint: ep_id.to_string(),
            capacity,
            // releases are the facility reclaiming idle capacity, not
            // any tenant's demand
            trigger_user: 0,
        });
    }

    /// Begin a planned outage at `now`: the endpoint stops accepting
    /// starts (status `Down`), running tasks are failed at `now` — their
    /// completions are delivered to the next `advance_to` caller so the
    /// flow layer's retry machinery sees them — and the waiting queue
    /// survives for re-dispatch after `end_outage`.
    pub fn begin_outage(&mut self, endpoint_id: &str, now: f64) -> Result<()> {
        let ep = self
            .endpoints
            .get_mut(endpoint_id)
            .with_context(|| format!("unknown faas endpoint `{endpoint_id}`"))?;
        if ep.status == EndpointStatus::Down {
            return Ok(()); // already down: nothing more to interrupt
        }
        ep.status = EndpointStatus::Down;
        let killed: Vec<(TaskId, f64)> = self
            .running
            .get_mut(endpoint_id)
            .expect("running")
            .drain(..)
            .collect();
        for (id, _scheduled_finish) in killed {
            let idx = (id.0 - 1) as usize;
            self.tasks[idx].finished_vt = now;
            self.tasks[idx].status = TaskStatus::Failed(format!(
                "endpoint `{endpoint_id}` went down mid-run"
            ));
            self.unclaimed.push((now, id));
        }
        // the interrupted slots free immediately (nothing is running)
        for s in self.slots.get_mut(endpoint_id).expect("slots") {
            *s = s.min(now);
        }
        self.note_activity(endpoint_id, now);
        Ok(())
    }

    /// End a planned outage at `now`: the endpoint accepts starts again.
    /// Slot availability is floored at `now` so surviving queued tasks
    /// re-dispatch at recovery, never retroactively inside the window.
    pub fn end_outage(&mut self, endpoint_id: &str, now: f64) -> Result<()> {
        let ep = self
            .endpoints
            .get_mut(endpoint_id)
            .with_context(|| format!("unknown faas endpoint `{endpoint_id}`"))?;
        ep.status = EndpointStatus::Online;
        for s in self.slots.get_mut(endpoint_id).expect("slots") {
            *s = s.max(now);
        }
        self.note_activity(endpoint_id, now);
        self.autoscale_check(endpoint_id, now);
        Ok(())
    }

    /// A spot preemption was *announced* at `now`: the endpoint stops
    /// accepting new starts (status `Down`) for the grace window, but —
    /// unlike `begin_outage` — running gangs are NOT killed. They keep
    /// executing toward their checkpoint boundaries (or completion)
    /// until [`reclaim_spot`](Self::reclaim_spot) fires at the end of
    /// the grace period. The waiting queue survives, exactly as for a
    /// planned outage.
    pub fn spot_warn(&mut self, endpoint_id: &str, now: f64) -> Result<()> {
        let ep = self
            .endpoints
            .get_mut(endpoint_id)
            .with_context(|| format!("unknown faas endpoint `{endpoint_id}`"))?;
        if ep.status == EndpointStatus::Down {
            return Ok(()); // already down (outage or earlier warning)
        }
        ep.status = EndpointStatus::Down;
        self.note_activity(endpoint_id, now);
        Ok(())
    }

    /// The grace window expired at `now`: the facility takes the spot
    /// slots back. Running gangs that finished inside the window drain
    /// normally (their completions are still owed to the next
    /// `advance_to` caller); the rest are cut at their last whole
    /// checkpoint boundary (`floor(elapsed / checkpoint_every_s) *
    /// checkpoint_every_s` body seconds survive) and returned as
    /// [`Displaced`] gangs for the caller's migration planner. Their
    /// records are rewritten to fail at `now` — the elapsed body time
    /// stays billed on this endpoint — but they are *not* delivered as
    /// completions: the caller owns resolving each displaced task
    /// (resume elsewhere, or give up and deliver the failure).
    pub fn reclaim_spot(&mut self, endpoint_id: &str, now: f64) -> Result<Vec<Displaced>> {
        let ep = self
            .endpoints
            .get_mut(endpoint_id)
            .with_context(|| format!("unknown faas endpoint `{endpoint_id}`"))?;
        ep.status = EndpointStatus::Down;
        let lease: Vec<(TaskId, f64)> = self
            .running
            .get_mut(endpoint_id)
            .expect("running")
            .drain(..)
            .collect();
        let mut displaced = Vec::new();
        for (id, finish) in lease {
            if finish <= now {
                // finished during the grace window: a normal
                // completion, still owed to the next advance_to caller
                self.unclaimed.push((finish, id));
                continue;
            }
            let idx = (id.0 - 1) as usize;
            let rec = &self.tasks[idx];
            let full_s = finish - rec.started_vt;
            let elapsed_s = (now - rec.started_vt).max(0.0);
            let checkpointed_s = rec
                .meta
                .checkpoint_every_s
                .filter(|c| *c > 0.0)
                .map(|c| (elapsed_s / c).floor() * c)
                .unwrap_or(0.0)
                .min(elapsed_s);
            let output = match &rec.status {
                TaskStatus::Success(v) => Some(v.clone()),
                _ => None,
            };
            let meta = rec.meta.clone();
            self.tasks[idx].finished_vt = now;
            self.tasks[idx].status = TaskStatus::Failed(format!(
                "endpoint `{endpoint_id}` spot capacity reclaimed mid-run"
            ));
            match output {
                Some(output) => displaced.push(Displaced {
                    task: id,
                    meta,
                    checkpointed_s,
                    elapsed_s,
                    full_s,
                    output,
                }),
                // the body had already failed at start: nothing to
                // resume — deliver the failure so the flow layer's
                // retry machinery sees it, as under an outage
                None => self.unclaimed.push((now, id)),
            }
        }
        // the reclaimed slots free immediately (nothing is running)
        for s in self.slots.get_mut(endpoint_id).expect("slots") {
            *s = s.min(now);
        }
        self.note_activity(endpoint_id, now);
        Ok(displaced)
    }

    /// Predicted multi-tenant queue wait for a width-`width` gang
    /// enqueued on `ep_id` at `now`: when `width` slots are next
    /// simultaneously free (the k-th order statistic of slot free-at
    /// times) plus the queued work already ahead of it spread over the
    /// endpoint's capacity. `INFINITY` when the gang can never fit.
    /// This is the sched-side input to the migration planner's cost
    /// function (DESIGN.md §12) — an estimate, not a promise: the
    /// policy may reorder.
    pub fn predicted_gang_wait(&self, ep_id: &str, width: usize, now: f64) -> f64 {
        let Some(slots) = self.slots.get(ep_id) else {
            return f64::INFINITY;
        };
        let width = width.max(1);
        if width > slots.len() {
            return f64::INFINITY;
        }
        let mut free: Vec<f64> = slots.clone();
        free.sort_by(f64::total_cmp);
        let gang_free = free[width - 1].max(now);
        let queued_work: f64 = self
            .queues
            .get(ep_id)
            .map(|q| {
                q.iter()
                    .map(|&id| {
                        let r = self.rec(id);
                        r.meta.est_duration_s.unwrap_or(0.0) * r.meta.width() as f64
                    })
                    .sum()
            })
            .unwrap_or(0.0);
        (gang_free - now) + queued_work / slots.len() as f64
    }

    /// Submit a function to an endpoint and run it to completion in
    /// virtual time — the single-tenant convenience over the queue
    /// machinery. Returns the task handle; failures are recorded (and
    /// surfaced via `result()`), not panicked.
    pub fn submit(
        &mut self,
        ctx: &mut C,
        clock: &mut VClock,
        endpoint_id: &str,
        func: &FuncId,
        args: &Json,
    ) -> Result<TaskId> {
        let id = self.enqueue(clock.now(), endpoint_id, func, args)?;
        let mut reclaim = |svc: &mut Self, reported: Vec<TaskId>| {
            for tid in reported {
                if tid != id {
                    let ft = svc.rec(tid).finished_vt;
                    svc.unclaimed.push((ft, tid));
                }
            }
        };
        while !self.rec(id).status.is_complete() {
            let Some(t) = self.next_event_time() else {
                bail!("faas fabric stalled driving task {id:?}");
            };
            let reported = self.advance_to(ctx, t);
            reclaim(self, reported);
        }
        let finished = self.rec(id).finished_vt;
        // flush our own completion report so no stale event lingers for a
        // later fabric driver; completions of *other* queued tasks that
        // this drive happened to consume go back to `unclaimed`
        let reported = self.advance_to(ctx, finished);
        reclaim(self, reported);
        if finished > clock.now() {
            clock.advance_to(finished);
        }
        Ok(id)
    }

    fn rec(&self, id: TaskId) -> &TaskRecord {
        &self.tasks[(id.0 - 1) as usize]
    }

    pub fn record(&self, id: TaskId) -> Result<&TaskRecord> {
        self.tasks
            .iter()
            .find(|t| t.id == id)
            .with_context(|| format!("unknown task {id:?}"))
    }

    /// The task's output, or an error if it failed (or has not run yet).
    pub fn result(&self, id: TaskId) -> Result<&Json> {
        match &self.record(id)?.status {
            TaskStatus::Success(v) => Ok(v),
            TaskStatus::Failed(msg) => bail!("task {id:?} failed: {msg}"),
            TaskStatus::Queued | TaskStatus::Running => {
                bail!("task {id:?} has not completed")
            }
        }
    }

    pub fn records(&self) -> &[TaskRecord] {
        &self.tasks
    }

    /// Slot demand currently *admitted* to an endpoint: waiting for
    /// capacity **plus** started-but-unfinished, with a width-`k` gang
    /// counting `k` (it holds — or will hold — `k` slots, and that is
    /// the pressure an operator or autoscaler dashboard must see). The
    /// figure is policy-independent — re-ordering the queue never
    /// changes it. Use [`waiting_depth`](Self::waiting_depth) for the
    /// not-yet-started demand alone (the autoscaler's scale-up
    /// trigger).
    pub fn queue_depth(&self, endpoint_id: &str) -> usize {
        let running: usize = self
            .running
            .get(endpoint_id)
            .map(|r| r.iter().map(|&(id, _)| self.rec(id).meta.width()).sum())
            .unwrap_or(0);
        self.waiting_depth(endpoint_id) + running
    }

    /// Slot demand admitted but not yet started on an endpoint (a
    /// width-`k` gang counts `k`).
    pub fn waiting_depth(&self, endpoint_id: &str) -> usize {
        self.queues
            .get(endpoint_id)
            .map(|q| q.iter().map(|&id| self.rec(id).meta.width()).sum())
            .unwrap_or(0)
    }

    /// Fan independent *real* CPU work out on the process-wide
    /// work-stealing pool (results in task order). Function bodies that
    /// do heavy compute — batch fitting, rendering — call this so one
    /// knob (`XLOOP_THREADS`) governs parallelism across the whole
    /// fabric; virtual-time accounting stays with the caller.
    pub fn scope<'env, R: Send>(&self, tasks: Vec<crate::pool::ScopeTask<'env, R>>) -> Vec<R> {
        crate::pool::scope(tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::FacilityId;

    #[derive(Default)]
    struct Ctx {
        calls: u32,
    }

    fn setup() -> (FaasService<Ctx>, FuncId) {
        let mut svc = FaasService::<Ctx>::new();
        svc.register_endpoint(FaasEndpoint::new("alcf#gpu", FacilityId(1)))
            .unwrap();
        let f = svc
            .register_function("train", |ctx: &mut Ctx, clock, args| {
                ctx.calls += 1;
                let secs = args.get("secs").as_f64().unwrap_or(1.0);
                clock.advance(secs);
                Ok(Json::obj(vec![("trained", Json::Bool(true))]))
            })
            .unwrap();
        (svc, f)
    }

    #[test]
    fn submit_runs_and_accounts_time() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let args = Json::obj(vec![("secs", Json::num(19.0))]);
        let t = svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &args).unwrap();
        let rec = svc.record(t).unwrap();
        assert_eq!(rec.overhead_secs(), 3.0); // queue 1 + cold start 2
        assert_eq!(rec.exec_secs(), 19.0);
        assert_eq!(rec.queue_wait_secs(), 0.0); // uncontended
        assert_eq!(clock.now(), 22.0);
        assert_eq!(ctx.calls, 1);
        assert!(svc.result(t).unwrap().get("trained").as_bool().unwrap());
    }

    #[test]
    fn second_task_skips_cold_start() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let args = Json::obj(vec![("secs", Json::num(1.0))]);
        svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &args).unwrap();
        let before = clock.now();
        let t2 = svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &args).unwrap();
        assert_eq!(svc.record(t2).unwrap().overhead_secs(), 1.0);
        assert_eq!(clock.now() - before, 2.0);
    }

    #[test]
    fn body_error_is_recorded_not_fatal() {
        let mut svc = FaasService::<Ctx>::new();
        svc.register_endpoint(FaasEndpoint::new("e", FacilityId(0)))
            .unwrap();
        let f = svc
            .register_function("boom", |_, _, _| anyhow::bail!("kaput"))
            .unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let t = svc.submit(&mut ctx, &mut clock, "e", &f, &Json::Null).unwrap();
        let err = svc.result(t).unwrap_err();
        assert!(err.to_string().contains("kaput"), "{err}");
    }

    #[test]
    fn offline_endpoint_fails_fast() {
        let (mut svc, f) = setup();
        svc.endpoint_mut("alcf#gpu").unwrap().status = EndpointStatus::Offline;
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let t = svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &Json::Null).unwrap();
        assert!(svc.result(t).is_err());
        assert_eq!(clock.now(), 0.0); // nothing charged
        assert_eq!(ctx.calls, 0);
    }

    #[test]
    fn unknown_endpoint_and_function() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        assert!(svc.submit(&mut ctx, &mut clock, "nope", &f, &Json::Null).is_err());
        let bad = FuncId("ghost".into());
        assert!(svc
            .submit(&mut ctx, &mut clock, "alcf#gpu", &bad, &Json::Null)
            .is_err());
    }

    /// Capacity 1 + concurrent submissions = FIFO queue wait: the second
    /// task is eligible long before the first finishes and must wait for
    /// the slot; the third waits for both.
    #[test]
    fn fifo_queue_wait_on_contended_endpoint() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        // three 10 s tasks all submitted at t=0
        let args = Json::obj(vec![("secs", Json::num(10.0))]);
        let t1 = svc.enqueue(0.0, "alcf#gpu", &f, &args).unwrap();
        let t2 = svc.enqueue(0.0, "alcf#gpu", &f, &args).unwrap();
        let t3 = svc.enqueue(0.0, "alcf#gpu", &f, &args).unwrap();
        assert_eq!(svc.queue_depth("alcf#gpu"), 3);

        // drive the fabric to completion
        while let Some(t) = svc.next_event_time() {
            svc.advance_to(&mut ctx, t);
        }
        // t1: eligible at 3 (queue 1 + cold 2), starts 3, ends 13
        let r1 = svc.record(t1).unwrap().clone();
        assert_eq!(r1.eligible_vt, 3.0);
        assert_eq!(r1.started_vt, 3.0);
        assert_eq!(r1.finished_vt, 13.0);
        assert_eq!(r1.queue_wait_secs(), 0.0);
        // t2: eligible at 1, waits for the slot until 13, ends 23
        let r2 = svc.record(t2).unwrap().clone();
        assert_eq!(r2.eligible_vt, 1.0);
        assert_eq!(r2.started_vt, 13.0);
        assert_eq!(r2.queue_wait_secs(), 12.0);
        assert_eq!(r2.finished_vt, 23.0);
        // t3: waits for t2's completion
        let r3 = svc.record(t3).unwrap().clone();
        assert_eq!(r3.started_vt, 23.0);
        assert_eq!(r3.queue_wait_secs(), 22.0);
        assert_eq!(ctx.calls, 3);
    }

    /// More capacity slots admit more tasks at once.
    #[test]
    fn capacity_two_runs_pairs_concurrently() {
        let mut svc = FaasService::<Ctx>::new();
        svc.register_endpoint(
            FaasEndpoint::new("alcf#cluster", FacilityId(1)).with_capacity(2),
        )
        .unwrap();
        let f = svc
            .register_function("work", |ctx: &mut Ctx, clock, _| {
                ctx.calls += 1;
                clock.advance(10.0);
                Ok(Json::Null)
            })
            .unwrap();
        let mut ctx = Ctx::default();
        let ids: Vec<TaskId> = (0..4)
            .map(|_| svc.enqueue(0.0, "alcf#cluster", &f, &Json::Null).unwrap())
            .collect();
        while let Some(t) = svc.next_event_time() {
            svc.advance_to(&mut ctx, t);
        }
        // FIFO: the head pays the cold start (eligible 3); the second is
        // eligible at 1 but never starts before the task ahead of it, so
        // both slots fill at t=3; the next pair starts when the slots
        // free at 13
        let starts: Vec<f64> = ids
            .iter()
            .map(|&i| svc.record(i).unwrap().started_vt)
            .collect();
        assert_eq!(starts, vec![3.0, 3.0, 13.0, 13.0]);
    }

    /// Mixing the sync and queued APIs must not lose completions: a
    /// `submit` that drives the fabric past another queued task's finish
    /// re-delivers that completion to the next `advance_to` caller.
    #[test]
    fn sync_submit_does_not_swallow_queued_completions() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let t1 = svc
            .enqueue(0.0, "alcf#gpu", &f, &Json::obj(vec![("secs", Json::num(5.0))]))
            .unwrap();
        let mut clock = VClock::new();
        let t2 = svc
            .submit(
                &mut ctx,
                &mut clock,
                "alcf#gpu",
                &f,
                &Json::obj(vec![("secs", Json::num(1.0))]),
            )
            .unwrap();
        // t1 (queued first, capacity 1) ran to completion during the drive
        assert!(svc.record(t1).unwrap().status.is_complete());
        // ...but its completion is still delivered to the fabric driver
        let done = svc.advance_to(&mut ctx, clock.now());
        assert!(done.contains(&t1), "{done:?}");
        assert!(!done.contains(&t2), "own task reported twice: {done:?}");
    }

    /// advance_to only reports completions due by the horizon; partial
    /// advances leave later completions pending.
    #[test]
    fn advance_to_respects_horizon() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let args = Json::obj(vec![("secs", Json::num(10.0))]);
        let t1 = svc.enqueue(0.0, "alcf#gpu", &f, &args).unwrap();
        let done = svc.advance_to(&mut ctx, 5.0);
        assert!(done.is_empty()); // started at 3, finishes at 13
        assert_eq!(svc.record(t1).unwrap().started_vt, 3.0);
        let done = svc.advance_to(&mut ctx, 13.0);
        assert_eq!(done, vec![t1]);
        // no double reporting
        assert!(svc.advance_to(&mut ctx, 20.0).is_empty());
    }

    #[test]
    fn scope_fans_real_compute_out_in_order() {
        let (svc, _) = setup();
        let tasks: Vec<crate::pool::ScopeTask<u64>> = (0..16)
            .map(|i| Box::new(move || (i as u64 + 1) * 10) as crate::pool::ScopeTask<u64>)
            .collect();
        let out = svc.scope(tasks);
        assert_eq!(out, (1..=16).map(|i| i * 10).collect::<Vec<u64>>());
    }

    #[test]
    fn duplicate_registration_rejected() {
        let (mut svc, _) = setup();
        assert!(svc.register_function("train", |_, _, _| Ok(Json::Null)).is_err());
        assert!(svc
            .register_endpoint(FaasEndpoint::new("alcf#gpu", FacilityId(1)))
            .is_err());
    }

    // ---- scheduling policies, autoscaling, outages (DESIGN.md §9) ----

    use crate::faas::sched::{Autoscaler, PolicyKind};

    fn drive(svc: &mut FaasService<Ctx>, ctx: &mut Ctx) {
        while let Some(t) = svc.next_event_time() {
            svc.advance_to(ctx, t);
        }
    }

    fn meta(priority: i64, est: Option<f64>) -> TaskMeta {
        TaskMeta {
            priority,
            est_duration_s: est,
            ..TaskMeta::default()
        }
    }

    fn gang(est: Option<f64>, slots: usize) -> TaskMeta {
        TaskMeta {
            est_duration_s: est,
            slots,
            ..TaskMeta::default()
        }
    }

    fn secs(s: f64) -> Json {
        Json::obj(vec![("secs", Json::num(s))])
    }

    /// Satellite pin: an explicitly-set `Fifo` policy replays the
    /// contended-endpoint trace of the default service bit for bit
    /// (start/finish/queue-wait of every task identical).
    #[test]
    fn explicit_fifo_policy_is_bit_identical_to_default() {
        let run = |explicit: bool| {
            let (mut svc, f) = setup();
            if explicit {
                svc.set_policy(PolicyKind::Fifo.build()).unwrap();
            }
            let mut ctx = Ctx::default();
            for s in [10.0, 4.0, 7.0] {
                svc.enqueue(0.0, "alcf#gpu", &f, &secs(s)).unwrap();
            }
            drive(&mut svc, &mut ctx);
            svc.records()
                .iter()
                .map(|r| (r.started_vt, r.finished_vt, r.queue_wait_secs()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(false), run(true));
    }

    /// `queue_depth` counts waiting + running consistently across
    /// policies; `waiting_depth` is the not-yet-started subset.
    #[test]
    fn queue_depth_counts_waiting_plus_running() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        for _ in 0..3 {
            svc.enqueue(0.0, "alcf#gpu", &f, &secs(10.0)).unwrap();
        }
        assert_eq!(svc.queue_depth("alcf#gpu"), 3);
        assert_eq!(svc.waiting_depth("alcf#gpu"), 3);
        // first task starts at 3 (finishes 13): one running + two waiting
        svc.advance_to(&mut ctx, 5.0);
        assert_eq!(svc.waiting_depth("alcf#gpu"), 2);
        assert_eq!(svc.queue_depth("alcf#gpu"), 3);
        // its completion is reported: running drains
        svc.advance_to(&mut ctx, 13.0);
        assert_eq!(svc.queue_depth("alcf#gpu"), 2);
        drive(&mut svc, &mut ctx);
        assert_eq!(svc.queue_depth("alcf#gpu"), 0);
        assert_eq!(svc.queue_depth("no-such-endpoint"), 0);
    }

    /// Satellite: `Priority` with aging never starves the low-priority
    /// task — it overtakes high-priority work submitted long after it.
    /// Without aging the same workload runs it dead last.
    #[test]
    fn priority_aging_prevents_starvation() {
        let run = |aging_s: f64| {
            let (mut svc, f) = setup();
            svc.set_policy(Box::new(crate::faas::Priority { aging_s })).unwrap();
            let mut ctx = Ctx::default();
            // A (pri 1) pays the cold start; L (pri 0) then competes
            // against a stream of later high-priority arrivals
            let _a = svc
                .enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(10.0), meta(1, None))
                .unwrap();
            let l = svc
                .enqueue_with_meta(2.0, "alcf#gpu", &f, &secs(10.0), meta(0, None))
                .unwrap();
            let b = svc
                .enqueue_with_meta(5.0, "alcf#gpu", &f, &secs(10.0), meta(1, None))
                .unwrap();
            let c = svc
                .enqueue_with_meta(15.0, "alcf#gpu", &f, &secs(10.0), meta(1, None))
                .unwrap();
            let d = svc
                .enqueue_with_meta(25.0, "alcf#gpu", &f, &secs(10.0), meta(1, None))
                .unwrap();
            drive(&mut svc, &mut ctx);
            (
                svc.record(l).unwrap().started_vt,
                svc.record(b).unwrap().started_vt,
                svc.record(c).unwrap().started_vt,
                svc.record(d).unwrap().started_vt,
            )
        };
        // aging 10 s/level: L has out-aged the 1-level gap by the third
        // decision and starts before C and D
        let (l, b, c, d) = run(10.0);
        assert_eq!(b, 13.0);
        assert_eq!(l, 23.0, "aged low-priority task not scheduled");
        assert_eq!((c, d), (33.0, 43.0));
        // no aging: strictly by class — L runs last
        let (l, _, c, d) = run(f64::INFINITY);
        assert!(l > c && l > d, "low-priority should starve to the back: {l}");
        assert_eq!(l, 43.0);
    }

    /// Shortest-job-first uses the cost-model estimates: the short task
    /// leapfrogs the long head as soon as the head's cold start opens a
    /// decision point.
    #[test]
    fn sjf_runs_short_eligible_job_first() {
        let (mut svc, f) = setup();
        svc.set_policy(PolicyKind::Sjf.build()).unwrap();
        let mut ctx = Ctx::default();
        let long = svc
            .enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(10.0), meta(0, Some(10.0)))
            .unwrap();
        let short = svc
            .enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(1.0), meta(0, Some(1.0)))
            .unwrap();
        drive(&mut svc, &mut ctx);
        // short is eligible at 1 (no cold start: second enqueue), long at
        // 3; SJF dispatches short at the first decision instant
        assert_eq!(svc.record(short).unwrap().started_vt, 1.0);
        assert_eq!(svc.record(long).unwrap().started_vt, 3.0);
    }

    /// Satellite: EASY backfill fills the cold-start hole with a short
    /// job but never delays the head of line — the head's start time is
    /// identical to plain FIFO's.
    #[test]
    fn backfill_fills_hole_without_delaying_head() {
        let run = |kind: PolicyKind| {
            let (mut svc, f) = setup();
            svc.set_policy(kind.build()).unwrap();
            let mut ctx = Ctx::default();
            let head = svc
                .enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(10.0), meta(0, Some(10.0)))
                .unwrap();
            let mid = svc
                .enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(5.0), meta(0, Some(5.0)))
                .unwrap();
            let short = svc
                .enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(1.5), meta(0, Some(1.5)))
                .unwrap();
            drive(&mut svc, &mut ctx);
            (
                svc.record(head).unwrap().started_vt,
                svc.record(mid).unwrap().started_vt,
                svc.record(short).unwrap().started_vt,
            )
        };
        let (fifo_head, fifo_mid, fifo_short) = run(PolicyKind::Fifo);
        assert_eq!((fifo_head, fifo_mid, fifo_short), (3.0, 13.0, 18.0));
        let (bf_head, bf_mid, bf_short) = run(PolicyKind::Backfill);
        // the 1.5 s job fits in the [1, 3) cold-start hole; the 5 s job
        // does not and must wait behind the head
        assert_eq!(bf_short, 1.0);
        assert_eq!(bf_head, fifo_head, "backfill delayed the head of line");
        assert_eq!(bf_mid, 13.0);
    }

    /// Autoscaler: queue pressure adds a slot after the provisioning
    /// delay (shrinking the makespan), and sustained idleness releases
    /// it back to the floor.
    #[test]
    fn autoscaler_grows_under_load_and_shrinks_when_idle() {
        let (mut svc, f) = setup();
        svc.set_autoscaler(
            "alcf#gpu",
            Autoscaler {
                min_capacity: 1,
                max_capacity: 2,
                scale_up_waiting: 2,
                provision_delay_s: 5.0,
                scale_down_idle_s: 20.0,
                cooldown_s: 1.0,
            },
        )
        .unwrap();
        let mut ctx = Ctx::default();
        let ids: Vec<TaskId> = (0..4)
            .map(|_| svc.enqueue(0.0, "alcf#gpu", &f, &secs(10.0)).unwrap())
            .collect();
        drive(&mut svc, &mut ctx);
        let starts: Vec<f64> = ids
            .iter()
            .map(|&i| svc.record(i).unwrap().started_vt)
            .collect();
        // t1 at 3 (cold start); the slot provisioned at 5 takes t2; the
        // remaining pair lands as slots free — vs [3, 13, 23, 33] fixed
        assert_eq!(starts, vec![3.0, 5.0, 13.0, 15.0]);
        // grown to 2, then released 20 idle seconds after the released
        // slot last freed (vt 23)
        let log = svc.scaling_log();
        assert_eq!(log.len(), 2, "{log:?}");
        assert_eq!((log[0].vt, log[0].capacity), (5.0, 2));
        assert_eq!((log[1].vt, log[1].capacity), (43.0, 1));
        assert_eq!(ctx.calls, 4);
    }

    /// Scale-ups are attributable: the `ScalingEvent` records the
    /// tenant whose waiting demand fired the trigger (the head of the
    /// waiting queue at that instant), and idle releases record no
    /// tenant — the hook the campaign's per-tenant waste attribution
    /// hangs off (DESIGN.md §11).
    #[test]
    fn scale_up_trigger_attributed_to_waiting_tenant() {
        let (mut svc, f) = setup();
        svc.set_autoscaler(
            "alcf#gpu",
            Autoscaler {
                min_capacity: 1,
                max_capacity: 2,
                scale_up_waiting: 2,
                provision_delay_s: 5.0,
                scale_down_idle_s: 20.0,
                cooldown_s: 1.0,
            },
        )
        .unwrap();
        let mut ctx = Ctx::default();
        let user = |u: u32| TaskMeta {
            user: u,
            ..TaskMeta::default()
        };
        svc.enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(10.0), user(7))
            .unwrap();
        // this enqueue crosses the waiting threshold while user 7's
        // task heads the queue
        svc.enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(10.0), user(8))
            .unwrap();
        drive(&mut svc, &mut ctx);
        let log = svc.scaling_log();
        assert!(
            log.iter().any(|e| e.capacity == 2 && e.trigger_user == 7),
            "scale-up not attributed to the queue head: {log:?}"
        );
        assert!(
            log.iter()
                .filter(|e| e.capacity == 1)
                .all(|e| e.trigger_user == 0),
            "idle release attributed to a tenant: {log:?}"
        );
    }

    /// A planned outage fails the running task (delivered to the next
    /// `advance_to` for the flow layer to retry), parks the queue, and
    /// re-dispatches survivors at recovery — never inside the window.
    #[test]
    fn outage_fails_running_and_requeues_queued() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let t1 = svc.enqueue(0.0, "alcf#gpu", &f, &secs(10.0)).unwrap();
        let t2 = svc.enqueue(0.0, "alcf#gpu", &f, &secs(10.0)).unwrap();
        svc.advance_to(&mut ctx, 3.0); // t1 running (3 -> 13)
        svc.begin_outage("alcf#gpu", 5.0).unwrap();
        // t1 failed at the outage instant, reported on the next advance
        let done = svc.advance_to(&mut ctx, 6.0);
        assert_eq!(done, vec![t1]);
        let r1 = svc.record(t1).unwrap();
        assert_eq!(r1.finished_vt, 5.0);
        assert!(matches!(&r1.status, TaskStatus::Failed(m) if m.contains("down")));
        // enqueue during the outage joins the surviving queue
        let t3 = svc.enqueue(6.0, "alcf#gpu", &f, &secs(10.0)).unwrap();
        assert_eq!(svc.waiting_depth("alcf#gpu"), 2);
        assert!(svc.next_event_time().is_none(), "nothing can start while down");
        svc.end_outage("alcf#gpu", 20.0).unwrap();
        drive(&mut svc, &mut ctx);
        assert_eq!(svc.record(t2).unwrap().started_vt, 20.0);
        assert_eq!(svc.record(t3).unwrap().started_vt, 30.0);
        assert!(svc.record(t2).unwrap().status.is_complete());
        // double-begin is a no-op; unknown endpoints error
        svc.begin_outage("alcf#gpu", 50.0).unwrap();
        svc.begin_outage("alcf#gpu", 51.0).unwrap();
        assert!(svc.begin_outage("ghost", 0.0).is_err());
        assert!(svc.end_outage("ghost", 0.0).is_err());
        svc.end_outage("alcf#gpu", 60.0).unwrap();
    }

    /// Policy swaps are rejected while tasks are in flight (decisions
    /// already exposed through `next_event_time` must not re-order).
    #[test]
    fn policy_swap_rejected_mid_queue() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        svc.enqueue(0.0, "alcf#gpu", &f, &secs(1.0)).unwrap();
        assert!(svc.set_policy(PolicyKind::Sjf.build()).is_err());
        drive(&mut svc, &mut ctx);
        assert!(svc.set_policy(PolicyKind::Sjf.build()).is_ok());
        assert_eq!(svc.policy_name(), "sjf");
    }

    // ---- gang scheduling (DESIGN.md §10) ----

    fn setup_wide(capacity: usize) -> (FaasService<Ctx>, FuncId) {
        let mut svc = FaasService::<Ctx>::new();
        svc.register_endpoint(
            FaasEndpoint::new("alcf#wide", FacilityId(1)).with_capacity(capacity),
        )
        .unwrap();
        let f = svc
            .register_function("train", |ctx: &mut Ctx, clock, args| {
                ctx.calls += 1;
                let secs = args.get("secs").as_f64().unwrap_or(1.0);
                clock.advance(secs);
                Ok(Json::Null)
            })
            .unwrap();
        (svc, f)
    }

    /// Tentpole pin: a width-2 gang acquires both capacity slots
    /// atomically — it waits until they are simultaneously free (no
    /// partial hold on the idle slot), and work behind it queues in
    /// FIFO order.
    #[test]
    fn gang_acquires_full_width_atomically() {
        let (mut svc, f) = setup_wide(2);
        let mut ctx = Ctx::default();
        let t1 = svc
            .enqueue_with_meta(0.0, "alcf#wide", &f, &secs(10.0), gang(Some(10.0), 1))
            .unwrap();
        let t2 = svc
            .enqueue_with_meta(0.0, "alcf#wide", &f, &secs(10.0), gang(Some(10.0), 2))
            .unwrap();
        let t3 = svc
            .enqueue_with_meta(0.0, "alcf#wide", &f, &secs(2.0), gang(Some(2.0), 1))
            .unwrap();
        // t1 runs 3..13 on one slot; the width-2 gang leaves the other
        // slot idle (forbidden partial hold) until both free at 13
        svc.advance_to(&mut ctx, 5.0);
        assert_eq!(svc.record(t1).unwrap().started_vt, 3.0);
        assert_eq!(svc.waiting_depth("alcf#wide"), 3); // gang 2 + single 1
        assert_eq!(svc.queue_depth("alcf#wide"), 4); // + running width 1
        drive(&mut svc, &mut ctx);
        assert_eq!(svc.record(t2).unwrap().started_vt, 13.0);
        assert_eq!(svc.record(t2).unwrap().finished_vt, 23.0);
        // the single-slot task behind the gang starts only when the
        // gang releases both slots
        assert_eq!(svc.record(t3).unwrap().started_vt, 23.0);
    }

    /// Satellite regression: `queue_depth`/`waiting_depth` count a
    /// width-k gang as k toward endpoint pressure — the demand figure
    /// the autoscaler's scale-up trigger reads.
    #[test]
    fn queue_depth_counts_gang_width() {
        let (mut svc, f) = setup_wide(2);
        let mut ctx = Ctx::default();
        svc.enqueue_with_meta(0.0, "alcf#wide", &f, &secs(10.0), gang(Some(10.0), 2))
            .unwrap();
        svc.enqueue_with_meta(0.0, "alcf#wide", &f, &secs(10.0), gang(Some(10.0), 1))
            .unwrap();
        assert_eq!(svc.waiting_depth("alcf#wide"), 3);
        assert_eq!(svc.queue_depth("alcf#wide"), 3);
        // gang starts at 3 (cold start) on both slots: 2 running + 1 waiting
        svc.advance_to(&mut ctx, 5.0);
        assert_eq!(svc.waiting_depth("alcf#wide"), 1);
        assert_eq!(svc.queue_depth("alcf#wide"), 3);
        drive(&mut svc, &mut ctx);
        assert_eq!(svc.queue_depth("alcf#wide"), 0);
    }

    /// Satellite pin: EASY backfill fills the drain hole in front of a
    /// multi-slot gang with a short job, but the gang at head-of-line
    /// starts at exactly its FIFO instant — never delayed.
    #[test]
    fn backfill_never_delays_gang_at_head() {
        let run = |kind: PolicyKind| {
            let (mut svc, f) = setup_wide(2);
            svc.set_policy(kind.build()).unwrap();
            let mut ctx = Ctx::default();
            let long = svc
                .enqueue_with_meta(0.0, "alcf#wide", &f, &secs(20.0), gang(Some(20.0), 1))
                .unwrap();
            let wide = svc
                .enqueue_with_meta(0.0, "alcf#wide", &f, &secs(10.0), gang(Some(10.0), 2))
                .unwrap();
            let short = svc
                .enqueue_with_meta(0.0, "alcf#wide", &f, &secs(2.0), gang(Some(2.0), 1))
                .unwrap();
            drive(&mut svc, &mut ctx);
            (
                svc.record(long).unwrap().started_vt,
                svc.record(wide).unwrap().started_vt,
                svc.record(short).unwrap().started_vt,
            )
        };
        // FIFO: long 3..23 on one slot, the gang waits for both (23),
        // the short job trails the gang
        let (f_long, f_wide, f_short) = run(PolicyKind::Fifo);
        assert_eq!((f_long, f_wide, f_short), (3.0, 23.0, 33.0));
        // backfill: the 2 s job fits the [1, 3) cold-start hole; the
        // gang still starts at 23 — its reservation is untouched
        let (b_long, b_wide, b_short) = run(PolicyKind::Backfill);
        assert_eq!(b_short, 1.0);
        assert_eq!(b_long, f_long);
        assert_eq!(b_wide, f_wide, "backfill delayed the gang at head-of-line");
    }

    /// A gang wider than the endpoint can ever provide is rejected at
    /// enqueue (deadlock prevention); with an autoscaler whose max
    /// covers the width, the gang instead waits for provisions — and
    /// an unsatisfiable gang is *unconditional* scale-up pressure,
    /// even below the configured waiting threshold (a lone wide gang
    /// under a high `scale_up_waiting` must not deadlock).
    #[test]
    fn gang_wider_than_capacity_waits_for_autoscaler() {
        let (mut svc, f) = setup_wide(2);
        let err = svc
            .enqueue_with_meta(0.0, "alcf#wide", &f, &secs(1.0), gang(Some(1.0), 3))
            .unwrap_err();
        assert!(err.to_string().contains("can never fit"), "{err}");

        let (mut svc, f) = setup_wide(2);
        svc.set_autoscaler(
            "alcf#wide",
            Autoscaler {
                min_capacity: 2,
                max_capacity: 4,
                // deliberately above the gang's weighted demand of 3:
                // only the unsatisfiable-width pressure can trigger
                scale_up_waiting: 10,
                provision_delay_s: 5.0,
                scale_down_idle_s: f64::INFINITY,
                cooldown_s: 1.0,
            },
        )
        .unwrap();
        let mut ctx = Ctx::default();
        let t = svc
            .enqueue_with_meta(0.0, "alcf#wide", &f, &secs(10.0), gang(Some(10.0), 3))
            .unwrap();
        drive(&mut svc, &mut ctx);
        // the slot lands at 5 and the gang starts the instant its
        // width is satisfiable (eligibility 3 < 5); capacity stops at
        // exactly the needed width — the threshold still gates growth
        // beyond it
        let rec = svc.record(t).unwrap();
        assert_eq!(rec.started_vt, 5.0);
        assert_eq!(rec.finished_vt, 15.0);
        let log = svc.scaling_log();
        assert_eq!(log.len(), 1, "{log:?}");
        assert_eq!((log[0].vt, log[0].capacity), (5.0, 3));
    }

    // ---- spot capacity tier (DESIGN.md §12) ----

    /// Tentpole pin: a spot warning stops new starts but lets the
    /// running task keep executing; the reclaim at the end of the grace
    /// window cuts it at its last whole checkpoint boundary and hands
    /// it back as a `Displaced` gang — not a delivered completion.
    #[test]
    fn spot_reclaim_cuts_running_task_at_checkpoint_boundary() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let m = TaskMeta {
            est_duration_s: Some(20.0),
            checkpoint_every_s: Some(3.0),
            ..TaskMeta::default()
        };
        // runs 3..23 (cold start 2 + queue latency 1)
        let t1 = svc
            .enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(20.0), m)
            .unwrap();
        svc.advance_to(&mut ctx, 5.0);
        svc.spot_warn("alcf#gpu", 8.0).unwrap();
        // the warning is not a kill: nothing is reported as failed
        assert!(svc.advance_to(&mut ctx, 9.0).is_empty());
        let displaced = svc.reclaim_spot("alcf#gpu", 10.0).unwrap();
        assert_eq!(displaced.len(), 1);
        let d = &displaced[0];
        assert_eq!(d.task, t1);
        // elapsed 7 s of a 20 s body; checkpoints at 3/6 → 6 s survive
        assert_eq!(d.elapsed_s, 7.0);
        assert_eq!(d.full_s, 20.0);
        assert_eq!(d.checkpointed_s, 6.0);
        assert_eq!(d.remaining_s(), 14.0);
        assert!(d.output.get("trained").as_bool().unwrap());
        // the record bills the elapsed time here and fails at the
        // reclaim instant, but the completion is NOT delivered — the
        // caller owns resolving the displaced gang
        let rec = svc.record(t1).unwrap();
        assert_eq!(rec.finished_vt, 10.0);
        assert_eq!(rec.exec_secs(), 7.0);
        assert!(matches!(&rec.status, TaskStatus::Failed(msg) if msg.contains("reclaimed")));
        assert!(svc.advance_to(&mut ctx, 50.0).is_empty());
    }

    /// A task that finishes inside the grace window drains normally —
    /// its completion is still delivered, and the reclaim displaces
    /// nothing. A non-checkpointable task loses all progress.
    #[test]
    fn grace_window_drain_and_uncheckpointed_loss() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        // runs 3..8: the warning at 4 announces a reclaim at 9
        let t1 = svc.enqueue(0.0, "alcf#gpu", &f, &secs(5.0)).unwrap();
        svc.advance_to(&mut ctx, 4.0);
        svc.spot_warn("alcf#gpu", 4.0).unwrap();
        assert!(svc.reclaim_spot("alcf#gpu", 9.0).unwrap().is_empty());
        let done = svc.advance_to(&mut ctx, 9.0);
        assert_eq!(done, vec![t1]);
        assert!(matches!(svc.record(t1).unwrap().status, TaskStatus::Success(_)));
        // restore (same machinery as outage recovery), then preempt a
        // task with no checkpoint cadence: zero progress survives
        svc.end_outage("alcf#gpu", 10.0).unwrap();
        svc.enqueue(10.0, "alcf#gpu", &f, &secs(10.0)).unwrap();
        svc.advance_to(&mut ctx, 12.0); // starts at 11 (no cold start now)
        svc.spot_warn("alcf#gpu", 12.0).unwrap();
        let displaced = svc.reclaim_spot("alcf#gpu", 14.0).unwrap();
        assert_eq!(displaced.len(), 1);
        assert_eq!(displaced[0].checkpointed_s, 0.0);
        assert_eq!(displaced[0].remaining_s(), 10.0);
        // unknown endpoints error on every spot entry point
        assert!(svc.spot_warn("ghost", 0.0).is_err());
        assert!(svc.reclaim_spot("ghost", 0.0).is_err());
    }

    /// `predicted_gang_wait` reads the slot order statistic plus the
    /// queued backlog, and reports infinity for unsatisfiable widths.
    #[test]
    fn predicted_gang_wait_estimates_backlog() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        assert_eq!(svc.predicted_gang_wait("alcf#gpu", 1, 0.0), 0.0);
        assert_eq!(svc.predicted_gang_wait("alcf#gpu", 2, 0.0), f64::INFINITY);
        assert_eq!(svc.predicted_gang_wait("ghost", 1, 0.0), f64::INFINITY);
        // one task running 3..13, nothing queued: the wait at 5 is the
        // 8 s left on the slot
        svc.enqueue_with_meta(0.0, "alcf#gpu", &f, &secs(10.0), meta(0, Some(10.0)))
            .unwrap();
        svc.advance_to(&mut ctx, 5.0);
        assert_eq!(svc.predicted_gang_wait("alcf#gpu", 1, 5.0), 8.0);
        // a queued 10 s estimate adds its work over capacity 1
        svc.enqueue_with_meta(5.0, "alcf#gpu", &f, &secs(10.0), meta(0, Some(10.0)))
            .unwrap();
        assert_eq!(svc.predicted_gang_wait("alcf#gpu", 1, 5.0), 18.0);
    }
}
