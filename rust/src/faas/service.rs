//! The funcX service: function registry, task submission, result store.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::endpoint::{EndpointStatus, FaasEndpoint};
use crate::simnet::VClock;
use crate::util::Json;

/// Registered function handle.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FuncId(pub String);

/// Submitted task handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskId(pub u64);

/// Task lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskStatus {
    Success(Json),
    Failed(String),
}

/// Accounting record for one executed task.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub id: TaskId,
    pub func: FuncId,
    pub endpoint: String,
    pub submitted_vt: f64,
    pub started_vt: f64,
    pub finished_vt: f64,
    pub status: TaskStatus,
}

impl TaskRecord {
    /// Time spent executing the body (excludes queue/cold-start).
    pub fn exec_secs(&self) -> f64 {
        self.finished_vt - self.started_vt
    }

    /// Dispatch overhead (queue wait + cold start).
    pub fn overhead_secs(&self) -> f64 {
        self.started_vt - self.submitted_vt
    }
}

type FuncBody<C> = Box<dyn Fn(&mut C, &mut VClock, &Json) -> Result<Json>>;

/// The federated FaaS fabric, generic over the execution context `C`.
pub struct FaasService<C> {
    funcs: BTreeMap<FuncId, FuncBody<C>>,
    endpoints: BTreeMap<String, FaasEndpoint>,
    tasks: Vec<TaskRecord>,
}

impl<C> Default for FaasService<C> {
    fn default() -> Self {
        FaasService {
            funcs: BTreeMap::new(),
            endpoints: BTreeMap::new(),
            tasks: Vec::new(),
        }
    }
}

impl<C> FaasService<C> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a function; returns its handle (idempotent by name is NOT
    /// allowed — re-registering a name is an error, as in funcX where each
    /// registration mints a new UUID; we keep names unique for clarity).
    pub fn register_function(
        &mut self,
        name: &str,
        body: impl Fn(&mut C, &mut VClock, &Json) -> Result<Json> + 'static,
    ) -> Result<FuncId> {
        let id = FuncId(name.to_string());
        if self.funcs.contains_key(&id) {
            bail!("function `{name}` already registered");
        }
        self.funcs.insert(id.clone(), Box::new(body));
        Ok(id)
    }

    pub fn register_endpoint(&mut self, ep: FaasEndpoint) -> Result<()> {
        if self.endpoints.contains_key(&ep.id) {
            bail!("faas endpoint `{}` already registered", ep.id);
        }
        self.endpoints.insert(ep.id.clone(), ep);
        Ok(())
    }

    pub fn endpoint_mut(&mut self, id: &str) -> Result<&mut FaasEndpoint> {
        self.endpoints
            .get_mut(id)
            .with_context(|| format!("unknown faas endpoint `{id}`"))
    }

    /// Submit a function to an endpoint and run it to completion in
    /// virtual time. Returns the task handle; failures are recorded (and
    /// surfaced via `result()`), not panicked, mirroring funcX's
    /// fire-and-forget model.
    pub fn submit(
        &mut self,
        ctx: &mut C,
        clock: &mut VClock,
        endpoint_id: &str,
        func: &FuncId,
        args: &Json,
    ) -> Result<TaskId> {
        let submitted_vt = clock.now();
        let ep = self
            .endpoints
            .get_mut(endpoint_id)
            .with_context(|| format!("unknown faas endpoint `{endpoint_id}`"))?;
        let task_id = TaskId(self.tasks.len() as u64 + 1);
        if ep.status == EndpointStatus::Offline {
            self.tasks.push(TaskRecord {
                id: task_id,
                func: func.clone(),
                endpoint: endpoint_id.to_string(),
                submitted_vt,
                started_vt: submitted_vt,
                finished_vt: submitted_vt,
                status: TaskStatus::Failed(format!("endpoint `{endpoint_id}` offline")),
            });
            return Ok(task_id);
        }
        let overhead = ep.next_dispatch_overhead();
        clock.advance(overhead);
        let started_vt = clock.now();

        let body = self
            .funcs
            .get(func)
            .with_context(|| format!("unknown function `{}`", func.0))?;
        let status = match body(ctx, clock, args) {
            Ok(v) => TaskStatus::Success(v),
            Err(e) => TaskStatus::Failed(format!("{e:#}")),
        };
        self.tasks.push(TaskRecord {
            id: task_id,
            func: func.clone(),
            endpoint: endpoint_id.to_string(),
            submitted_vt,
            started_vt,
            finished_vt: clock.now(),
            status,
        });
        Ok(task_id)
    }

    pub fn record(&self, id: TaskId) -> Result<&TaskRecord> {
        self.tasks
            .iter()
            .find(|t| t.id == id)
            .with_context(|| format!("unknown task {id:?}"))
    }

    /// The task's output, or an error if it failed.
    pub fn result(&self, id: TaskId) -> Result<&Json> {
        match &self.record(id)?.status {
            TaskStatus::Success(v) => Ok(v),
            TaskStatus::Failed(msg) => bail!("task {id:?} failed: {msg}"),
        }
    }

    pub fn records(&self) -> &[TaskRecord] {
        &self.tasks
    }

    /// Fan independent *real* CPU work out on the process-wide
    /// work-stealing pool (results in task order). Function bodies that
    /// do heavy compute — batch fitting, rendering — call this so one
    /// knob (`XLOOP_THREADS`) governs parallelism across the whole
    /// fabric; virtual-time accounting stays with the caller.
    pub fn scope<'env, R: Send>(&self, tasks: Vec<crate::pool::ScopeTask<'env, R>>) -> Vec<R> {
        crate::pool::scope(tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::FacilityId;

    #[derive(Default)]
    struct Ctx {
        calls: u32,
    }

    fn setup() -> (FaasService<Ctx>, FuncId) {
        let mut svc = FaasService::<Ctx>::new();
        svc.register_endpoint(FaasEndpoint::new("alcf#gpu", FacilityId(1)))
            .unwrap();
        let f = svc
            .register_function("train", |ctx: &mut Ctx, clock, args| {
                ctx.calls += 1;
                let secs = args.get("secs").as_f64().unwrap_or(1.0);
                clock.advance(secs);
                Ok(Json::obj(vec![("trained", Json::Bool(true))]))
            })
            .unwrap();
        (svc, f)
    }

    #[test]
    fn submit_runs_and_accounts_time() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let args = Json::obj(vec![("secs", Json::num(19.0))]);
        let t = svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &args).unwrap();
        let rec = svc.record(t).unwrap();
        assert_eq!(rec.overhead_secs(), 3.0); // queue 1 + cold start 2
        assert_eq!(rec.exec_secs(), 19.0);
        assert_eq!(clock.now(), 22.0);
        assert_eq!(ctx.calls, 1);
        assert!(svc.result(t).unwrap().get("trained").as_bool().unwrap());
    }

    #[test]
    fn second_task_skips_cold_start() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let args = Json::obj(vec![("secs", Json::num(1.0))]);
        svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &args).unwrap();
        let before = clock.now();
        let t2 = svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &args).unwrap();
        assert_eq!(svc.record(t2).unwrap().overhead_secs(), 1.0);
        assert_eq!(clock.now() - before, 2.0);
    }

    #[test]
    fn body_error_is_recorded_not_fatal() {
        let mut svc = FaasService::<Ctx>::new();
        svc.register_endpoint(FaasEndpoint::new("e", FacilityId(0)))
            .unwrap();
        let f = svc
            .register_function("boom", |_, _, _| anyhow::bail!("kaput"))
            .unwrap();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let t = svc.submit(&mut ctx, &mut clock, "e", &f, &Json::Null).unwrap();
        let err = svc.result(t).unwrap_err();
        assert!(err.to_string().contains("kaput"), "{err}");
    }

    #[test]
    fn offline_endpoint_fails_fast() {
        let (mut svc, f) = setup();
        svc.endpoint_mut("alcf#gpu").unwrap().status = EndpointStatus::Offline;
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        let t = svc.submit(&mut ctx, &mut clock, "alcf#gpu", &f, &Json::Null).unwrap();
        assert!(svc.result(t).is_err());
        assert_eq!(clock.now(), 0.0); // nothing charged
        assert_eq!(ctx.calls, 0);
    }

    #[test]
    fn unknown_endpoint_and_function() {
        let (mut svc, f) = setup();
        let mut ctx = Ctx::default();
        let mut clock = VClock::new();
        assert!(svc.submit(&mut ctx, &mut clock, "nope", &f, &Json::Null).is_err());
        let bad = FuncId("ghost".into());
        assert!(svc
            .submit(&mut ctx, &mut clock, "alcf#gpu", &bad, &Json::Null)
            .is_err());
    }

    #[test]
    fn scope_fans_real_compute_out_in_order() {
        let (svc, _) = setup();
        let tasks: Vec<crate::pool::ScopeTask<u64>> = (0..16)
            .map(|i| Box::new(move || (i as u64 + 1) * 10) as crate::pool::ScopeTask<u64>)
            .collect();
        let out = svc.scope(tasks);
        assert_eq!(out, (1..=16).map(|i| i * 10).collect::<Vec<u64>>());
    }

    #[test]
    fn duplicate_registration_rejected() {
        let (mut svc, _) = setup();
        assert!(svc.register_function("train", |_, _, _| Ok(Json::Null)).is_err());
        assert!(svc
            .register_endpoint(FaasEndpoint::new("alcf#gpu", FacilityId(1)))
            .is_err());
    }
}
