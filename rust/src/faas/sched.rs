//! Pluggable scheduling policies and endpoint autoscaling for the faas
//! fabric (DESIGN.md §9).
//!
//! The queueing core of [`super::service::FaasService`] stores tasks in
//! arrival order; *which* queued task starts when a capacity slot frees
//! — and at what instant — is delegated to a [`SchedPolicy`]. The
//! policy sees per-task metadata ([`TaskMeta`]: tenant, priority class,
//! cost-model duration estimate) plus the endpoint's slot state and
//! returns a [`Pick`]. Four policies ship:
//!
//! * [`Fifo`] — strict arrival order with the start-monotonicity
//!   constraint the pre-policy service hard-coded; **bit-identical** to
//!   the PR 2 queueing core (pinned by the service and campaign tests).
//! * [`Priority`] — highest effective priority first, where waiting
//!   tasks *age* upward (`aging_s` seconds of wait = one priority
//!   level) so low-priority work is never starved indefinitely.
//! * [`ShortestJobFirst`] — smallest duration estimate first among the
//!   tasks eligible at the decision instant (unknown estimates run
//!   last).
//! * [`EasyBackfill`] — FIFO with EASY backfilling: the head of line
//!   holds a reservation at the earliest instant it could start, and a
//!   later task may jump ahead only if, by its duration estimate, it
//!   finishes before that reservation. With accurate estimates the
//!   head's start is never delayed relative to plain FIFO (test-pinned).
//!
//! [`Autoscaler`] is the per-endpoint elasticity config: capacity slots
//! are added when the waiting queue is deep (after a provisioning
//! delay) and removed after sustained idleness, with a cooldown between
//! actions. The service folds provision completions and idle deadlines
//! into its `next_event_time`, so the same `simnet::des`-driven event
//! loop that drives queue starts also drives scaling (DESIGN.md §9).

use anyhow::{bail, Result};

use super::service::TaskId;

/// Scheduler-relevant metadata attached to a task at enqueue time.
#[derive(Debug, Clone, Default)]
pub struct TaskMeta {
    /// submitting tenant (campaign user index, 1-based; 0 = untagged)
    pub user: u32,
    /// static priority class; larger = more urgent
    pub priority: i64,
    /// estimated body duration in virtual seconds (from `costmodel` /
    /// the accelerator models). `None` = unknown: `ShortestJobFirst`
    /// runs it last and `EasyBackfill` refuses to gamble on it.
    pub est_duration_s: Option<f64>,
}

/// A queued task as a policy sees it.
#[derive(Debug)]
pub struct SchedTask<'a> {
    pub id: TaskId,
    pub submitted_vt: f64,
    /// when dispatch latency (+cold start) ends and the body could run
    pub eligible_vt: f64,
    pub meta: &'a TaskMeta,
}

/// Endpoint queue state at a scheduling decision.
#[derive(Debug)]
pub struct QueueView<'a> {
    /// queued tasks in arrival order (index 0 = head of line)
    pub tasks: &'a [SchedTask<'a>],
    /// earliest instant any capacity slot is free
    pub slot_free_vt: f64,
    /// start time of the most recently started task on this endpoint
    /// (the FIFO monotonicity floor; only `Fifo` applies it)
    pub last_start_vt: f64,
}

impl QueueView<'_> {
    /// Earliest instant any queued task could start: the first free
    /// slot, but no earlier than the soonest eligibility.
    fn decision_vt(&self) -> f64 {
        let min_elig = self
            .tasks
            .iter()
            .map(|t| t.eligible_vt)
            .fold(f64::INFINITY, f64::min);
        self.slot_free_vt.max(min_elig)
    }

    /// Tasks that are eligible at the decision instant.
    fn eligible_at<'b>(&'b self, t: f64) -> impl Iterator<Item = (usize, &'b SchedTask<'b>)> {
        self.tasks
            .iter()
            .enumerate()
            .filter(move |(_, task)| task.eligible_vt <= t + 1e-9)
    }
}

/// A policy's decision: which queued task starts, and when.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pick {
    /// index into `QueueView::tasks`
    pub queue_idx: usize,
    pub start_vt: f64,
}

/// Decides which queued task starts when a capacity slot frees.
///
/// Invariants every policy must uphold: `pick` returns `Some` whenever
/// the queue is non-empty (the service relies on this for stall
/// detection), `start_vt >= max(slot_free_vt, chosen task's
/// eligible_vt)`, and the decision is a pure function of the view (no
/// interior state), which is what keeps campaign replays deterministic.
pub trait SchedPolicy {
    fn name(&self) -> &'static str;
    fn pick(&self, q: &QueueView) -> Option<Pick>;
}

/// Strict arrival order — bit-identical to the pre-policy queueing core.
///
/// The head starts at `max(eligible, slot_free, last_start)`: the
/// `last_start` floor keeps start events monotone even though the first
/// task pays the cold start and is eligible *later* than the second.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl SchedPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&self, q: &QueueView) -> Option<Pick> {
        let head = q.tasks.first()?;
        Some(Pick {
            queue_idx: 0,
            start_vt: head
                .eligible_vt
                .max(q.slot_free_vt)
                .max(q.last_start_vt),
        })
    }
}

/// Highest effective priority first, with aging: a task's effective
/// priority is `priority + waited / aging_s`, so anything that waits
/// `aging_s * Δpriority` seconds overtakes a Δpriority-level gap and
/// nothing starves indefinitely. `aging_s = f64::INFINITY` disables
/// aging (pure static priority — starvation-prone, kept for tests).
/// Ties break by arrival order.
#[derive(Debug, Clone, Copy)]
pub struct Priority {
    pub aging_s: f64,
}

impl Default for Priority {
    fn default() -> Self {
        Priority {
            aging_s: DEFAULT_AGING_S,
        }
    }
}

/// One priority level per five minutes of wait — long enough that
/// classes matter under transient contention, short enough that a
/// low-priority retraining is never parked behind an endless stream of
/// urgent jobs.
pub const DEFAULT_AGING_S: f64 = 300.0;

impl SchedPolicy for Priority {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn pick(&self, q: &QueueView) -> Option<Pick> {
        q.tasks.first()?;
        let now = q.decision_vt();
        let effective = |t: &SchedTask| {
            let aged = if self.aging_s.is_finite() && self.aging_s > 0.0 {
                (now - t.submitted_vt).max(0.0) / self.aging_s
            } else {
                0.0
            };
            t.meta.priority as f64 + aged
        };
        let (idx, _) = q
            .eligible_at(now)
            .fold(None::<(usize, f64)>, |best, (i, t)| {
                let e = effective(t);
                match best {
                    // strictly-greater keeps the earliest arrival on ties
                    Some((_, be)) if e <= be => best,
                    _ => Some((i, e)),
                }
            })?;
        Some(Pick {
            queue_idx: idx,
            start_vt: now,
        })
    }
}

/// Smallest duration estimate first among the tasks eligible at the
/// decision instant; unknown estimates sort last; ties break by
/// arrival order.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShortestJobFirst;

impl SchedPolicy for ShortestJobFirst {
    fn name(&self) -> &'static str {
        "sjf"
    }

    fn pick(&self, q: &QueueView) -> Option<Pick> {
        q.tasks.first()?;
        let now = q.decision_vt();
        let (idx, _) = q
            .eligible_at(now)
            .fold(None::<(usize, f64)>, |best, (i, t)| {
                let est = t.meta.est_duration_s.unwrap_or(f64::INFINITY);
                match best {
                    Some((_, be)) if est >= be => best,
                    _ => Some((i, est)),
                }
            })?;
        Some(Pick {
            queue_idx: idx,
            start_vt: now,
        })
    }
}

/// EASY backfilling: the head of line reserves the earliest instant it
/// could start (`max(eligible, slot_free)`); while a hole exists before
/// that reservation (the slot frees before the head is eligible — cold
/// start, dispatch latency, post-outage re-dispatch), later tasks are
/// scanned in arrival order and the first whose *estimated* completion
/// fits inside the hole starts immediately. Tasks without an estimate
/// never backfill. With accurate estimates the head's start time is
/// identical to plain FIFO's (test-pinned: `EasyBackfill` never delays
/// the head of line).
#[derive(Debug, Clone, Copy, Default)]
pub struct EasyBackfill;

impl SchedPolicy for EasyBackfill {
    fn name(&self) -> &'static str {
        "backfill"
    }

    fn pick(&self, q: &QueueView) -> Option<Pick> {
        let head = q.tasks.first()?;
        let head_start = head.eligible_vt.max(q.slot_free_vt);
        if head.eligible_vt > q.slot_free_vt {
            // hole in front of the reservation: [slot_free, head_start)
            for (i, t) in q.tasks.iter().enumerate().skip(1) {
                let cand_start = t.eligible_vt.max(q.slot_free_vt);
                let Some(est) = t.meta.est_duration_s else {
                    continue;
                };
                if cand_start < head_start - 1e-9 && cand_start + est <= head_start + 1e-9 {
                    return Some(Pick {
                        queue_idx: i,
                        start_vt: cand_start,
                    });
                }
            }
        }
        Some(Pick {
            queue_idx: 0,
            start_vt: head_start,
        })
    }
}

/// Parseable policy selector (CLI `--policy`, campaign config).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PolicyKind {
    #[default]
    Fifo,
    Priority {
        aging_s: f64,
    },
    Sjf,
    Backfill,
}

impl PolicyKind {
    /// Parse `fifo`, `priority`, `priority:<aging_s>`, `sjf`, `backfill`.
    pub fn parse(s: &str) -> Result<PolicyKind> {
        Ok(match s {
            "fifo" => PolicyKind::Fifo,
            "sjf" | "shortest" | "shortest-job-first" => PolicyKind::Sjf,
            "backfill" | "easy-backfill" => PolicyKind::Backfill,
            "priority" => PolicyKind::Priority {
                aging_s: DEFAULT_AGING_S,
            },
            other => {
                if let Some(aging) = other.strip_prefix("priority:") {
                    let aging_s: f64 = aging
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad aging seconds `{aging}`"))?;
                    if aging_s.is_nan() || aging_s <= 0.0 {
                        bail!("aging seconds must be positive, got {aging_s}");
                    }
                    PolicyKind::Priority { aging_s }
                } else {
                    bail!(
                        "unknown policy `{other}` (fifo, priority[:aging_s], sjf, backfill)"
                    )
                }
            }
        })
    }

    pub fn build(&self) -> Box<dyn SchedPolicy> {
        match *self {
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::Priority { aging_s } => Box::new(Priority { aging_s }),
            PolicyKind::Sjf => Box::new(ShortestJobFirst),
            PolicyKind::Backfill => Box::new(EasyBackfill),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::Priority { .. } => "priority",
            PolicyKind::Sjf => "sjf",
            PolicyKind::Backfill => "backfill",
        }
    }
}

/// Per-endpoint elasticity: scale capacity slots up under queue
/// pressure and back down after sustained idleness (DESIGN.md §9).
///
/// One action at a time: at most one provision can be in flight, and
/// `cooldown_s` must elapse between consecutive capacity changes. A new
/// slot becomes usable `provision_delay_s` after its trigger (node
/// boot / container spin-up); an idle slot is released only after the
/// endpoint has had a free slot and an empty queue for
/// `scale_down_idle_s` continuous virtual seconds.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub min_capacity: usize,
    pub max_capacity: usize,
    /// scale up when this many tasks are waiting (queued, not started)
    pub scale_up_waiting: usize,
    pub provision_delay_s: f64,
    pub scale_down_idle_s: f64,
    pub cooldown_s: f64,
}

impl Autoscaler {
    /// Elastic from one slot up to `max_capacity`, with defaults sized
    /// for the campaign fabric (30 s provisioning, 2-deep trigger,
    /// 120 s idle release, 60 s cooldown).
    pub fn up_to(max_capacity: usize) -> Autoscaler {
        Autoscaler {
            min_capacity: 1,
            max_capacity: max_capacity.max(1),
            scale_up_waiting: 2,
            provision_delay_s: 30.0,
            scale_down_idle_s: 120.0,
            cooldown_s: 60.0,
        }
    }
}

/// One capacity change applied by an autoscaler (campaign reporting).
#[derive(Debug, Clone)]
pub struct ScalingEvent {
    pub vt: f64,
    pub endpoint: String,
    /// capacity after the change
    pub capacity: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(priority: i64, est: Option<f64>) -> TaskMeta {
        TaskMeta {
            user: 0,
            priority,
            est_duration_s: est,
        }
    }

    fn view<'a>(
        tasks: &'a [SchedTask<'a>],
        slot_free_vt: f64,
        last_start_vt: f64,
    ) -> QueueView<'a> {
        QueueView {
            tasks,
            slot_free_vt,
            last_start_vt,
        }
    }

    #[test]
    fn fifo_matches_legacy_start_formula() {
        let m = TaskMeta::default();
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 3.0,
                meta: &m,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &m,
            },
        ];
        // head not eligible yet: starts at its eligibility
        let p = Fifo.pick(&view(&tasks, 0.0, 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 3.0 });
        // slot busy past eligibility: starts when the slot frees
        let p = Fifo.pick(&view(&tasks, 13.0, 3.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 13.0 });
        // last_start floor dominates (second task behind a cold head)
        let second = &tasks[1..];
        let p = Fifo.pick(&view(second, 0.0, 3.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 3.0 });
    }

    #[test]
    fn priority_prefers_urgent_but_aging_overtakes() {
        let low = meta(0, None);
        let high = meta(2, None);
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &low,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 100.0,
                eligible_vt: 101.0,
                meta: &high,
            },
        ];
        // fresh decision at 101: high wins (0 + ~1 age < 2)
        let p = Priority { aging_s: 300.0 }
            .pick(&view(&tasks, 101.0, 0.0))
            .unwrap();
        assert_eq!(p.queue_idx, 1);
        // late decision: the low task has aged 2 levels past the gap
        let p = Priority { aging_s: 300.0 }
            .pick(&view(&tasks, 700.0, 0.0))
            .unwrap();
        assert_eq!(p.queue_idx, 0);
        // no aging: high always wins
        let p = Priority {
            aging_s: f64::INFINITY,
        }
        .pick(&view(&tasks, 700.0, 0.0))
        .unwrap();
        assert_eq!(p.queue_idx, 1);
    }

    #[test]
    fn priority_ties_break_by_arrival() {
        let a = meta(1, None);
        let b = meta(1, None);
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 5.0,
                eligible_vt: 6.0,
                meta: &a,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 5.0,
                eligible_vt: 6.0,
                meta: &b,
            },
        ];
        let p = Priority::default().pick(&view(&tasks, 10.0, 0.0)).unwrap();
        assert_eq!(p.queue_idx, 0);
    }

    #[test]
    fn sjf_picks_shortest_known_estimate() {
        let long = meta(0, Some(100.0));
        let short = meta(0, Some(2.0));
        let unknown = meta(0, None);
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &long,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &unknown,
            },
            SchedTask {
                id: TaskId(3),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &short,
            },
        ];
        let p = ShortestJobFirst.pick(&view(&tasks, 5.0, 0.0)).unwrap();
        assert_eq!(p.queue_idx, 2);
        assert_eq!(p.start_vt, 5.0);
    }

    #[test]
    fn sjf_ignores_tasks_not_yet_eligible() {
        let short_late = meta(0, Some(1.0));
        let long_now = meta(0, Some(50.0));
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &long_now,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 9.0,
                eligible_vt: 10.0,
                meta: &short_late,
            },
        ];
        // decision at slot_free=2: only the long task is eligible
        let p = ShortestJobFirst.pick(&view(&tasks, 2.0, 0.0)).unwrap();
        assert_eq!(p.queue_idx, 0);
        assert_eq!(p.start_vt, 2.0);
    }

    #[test]
    fn backfill_fills_cold_start_hole_without_delaying_head() {
        let head = meta(0, Some(10.0));
        let fits = meta(0, Some(1.5));
        let too_long = meta(0, Some(5.0));
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 3.0, // cold start
                meta: &head,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &too_long,
            },
            SchedTask {
                id: TaskId(3),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &fits,
            },
        ];
        // hole is [0, 3): the 5 s task does not fit, the 1.5 s one does
        let p = EasyBackfill.pick(&view(&tasks, 0.0, 0.0)).unwrap();
        assert_eq!(p.queue_idx, 2);
        assert_eq!(p.start_vt, 1.0);
        // no hole (slot frees after head eligibility): plain FIFO head
        let p = EasyBackfill.pick(&view(&tasks, 7.0, 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 7.0 });
    }

    #[test]
    fn backfill_never_gambles_on_unknown_estimates() {
        let head = meta(0, Some(10.0));
        let unknown = meta(0, None);
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 3.0,
                meta: &head,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &unknown,
            },
        ];
        let p = EasyBackfill.pick(&view(&tasks, 0.0, 0.0)).unwrap();
        assert_eq!(p.queue_idx, 0);
        assert_eq!(p.start_vt, 3.0);
    }

    #[test]
    fn policy_kind_parses_and_builds() {
        assert_eq!(PolicyKind::parse("fifo").unwrap(), PolicyKind::Fifo);
        assert_eq!(PolicyKind::parse("sjf").unwrap(), PolicyKind::Sjf);
        assert_eq!(
            PolicyKind::parse("backfill").unwrap(),
            PolicyKind::Backfill
        );
        assert_eq!(
            PolicyKind::parse("priority").unwrap(),
            PolicyKind::Priority {
                aging_s: DEFAULT_AGING_S
            }
        );
        assert_eq!(
            PolicyKind::parse("priority:60").unwrap(),
            PolicyKind::Priority { aging_s: 60.0 }
        );
        assert!(PolicyKind::parse("priority:-1").is_err());
        assert!(PolicyKind::parse("lifo").is_err());
        assert_eq!(PolicyKind::Backfill.build().name(), "backfill");
        assert_eq!(PolicyKind::default().label(), "fifo");
    }

    #[test]
    fn autoscaler_up_to_clamps() {
        let a = Autoscaler::up_to(0);
        assert_eq!(a.max_capacity, 1);
        assert_eq!(a.min_capacity, 1);
        let a = Autoscaler::up_to(8);
        assert_eq!(a.max_capacity, 8);
    }
}
