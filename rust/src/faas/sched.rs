//! Pluggable scheduling policies and endpoint autoscaling for the faas
//! fabric (DESIGN.md §9, §10).
//!
//! The queueing core of [`super::service::FaasService`] stores tasks in
//! arrival order; *which* queued task starts when capacity frees — and
//! at what instant — is delegated to a [`SchedPolicy`]. The policy sees
//! per-task metadata ([`TaskMeta`]: tenant, priority class, cost-model
//! duration estimate, gang width) plus the endpoint's full slot state
//! and returns a [`Pick`]. Four policies ship:
//!
//! * [`Fifo`] — strict arrival order with the start-monotonicity
//!   constraint the pre-policy service hard-coded; **bit-identical** to
//!   the PR 2 queueing core for single-slot tasks (pinned by the
//!   service and campaign tests).
//! * [`Priority`] — highest effective priority first, where waiting
//!   tasks *age* upward (`aging_s` seconds of wait = one priority
//!   level) so low-priority work is never starved indefinitely.
//! * [`ShortestJobFirst`] — smallest duration estimate first among the
//!   tasks startable at the decision instant (unknown estimates run
//!   last).
//! * [`EasyBackfill`] — FIFO with EASY backfilling: the head of line
//!   holds a reservation at the earliest instant it could start, and a
//!   later task may jump ahead only if it cannot delay that
//!   reservation — either its *estimated* completion fits inside the
//!   hole, or it runs entirely on slots the head does not need. With
//!   accurate estimates the head's start is never delayed relative to
//!   plain FIFO (test-pinned).
//!
//! **Gangs** (DESIGN.md §10): a task whose `TaskMeta::slots` is `k > 1`
//! acquires `k` capacity slots *atomically* — it starts only at an
//! instant when `k` slots are simultaneously free, and partial holds
//! are forbidden (a gang never camps on some slots while waiting for
//! the rest), which is what keeps FIFO deadlock-free. The widened
//! [`QueueView`] therefore exposes every slot's free time, and
//! [`QueueView::free_for`] answers "when are `k` slots free at once"
//! (the `k`-th order statistic). Draining toward a wide gang opens real
//! capacity holes — the first situation where `EasyBackfill` genuinely
//! reorders work rather than just absorbing cold starts.
//!
//! [`Autoscaler`] is the per-endpoint elasticity config: capacity slots
//! are added when the waiting queue is deep (after a provisioning
//! delay) and removed after sustained idleness, with a cooldown between
//! actions. The service folds provision completions and idle deadlines
//! into its `next_event_time`, so the same `simnet::des`-driven event
//! loop that drives queue starts also drives scaling (DESIGN.md §9).
//! Each applied change is logged as a [`ScalingEvent`] carrying the
//! tenant whose demand fired it — the hook the campaign layer's
//! slot-hour and dollar cost accounting (provisioned / used /
//! scale-up-waste integrals, per-tenant waste attribution) hangs off
//! (DESIGN.md §10–§11).

use anyhow::{bail, Result};

use super::service::TaskId;

/// Slack tolerance for virtual-time comparisons inside policies.
const EPS: f64 = 1e-9;

/// Why a task entered the fabric — exogenous (Poisson / per-class
/// arrival plans) or admitted by the closed-loop drift trigger
/// (DESIGN.md §16). Carried through failover resumes so the
/// campaign's cost attribution can integrate drift-attributed
/// slot-seconds across migrations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TaskOrigin {
    /// An externally-planned arrival (the default for every pre-§16
    /// path, so existing constructors are unchanged).
    #[default]
    Exogenous,
    /// Admitted by a serving-drift trigger (`--closed-loop`).
    Drift,
}

/// Scheduler-relevant metadata attached to a task at enqueue time.
#[derive(Debug, Clone)]
pub struct TaskMeta {
    /// submitting tenant (campaign user index, 1-based; 0 = untagged)
    pub user: u32,
    /// static priority class; larger = more urgent
    pub priority: i64,
    /// estimated body duration in virtual seconds (from `costmodel` /
    /// the accelerator models). `None` = unknown: `ShortestJobFirst`
    /// runs it last and `EasyBackfill` refuses to gamble on it.
    pub est_duration_s: Option<f64>,
    /// gang width: how many capacity slots the task occupies for its
    /// whole run. All `slots` entries are acquired atomically at start
    /// and released together at completion; `0` is normalized to `1`
    /// at enqueue.
    pub slots: usize,
    /// checkpoint cadence in virtual seconds of *body* progress.
    /// `Some(c)` means the task persists a resumable checkpoint every
    /// `c` seconds of execution; on a spot preemption the service can
    /// drain it to the last whole boundary (`floor(elapsed / c) * c`)
    /// instead of losing everything (`FaasService::reclaim_spot`).
    /// `None` = not checkpointable: preemption wastes all progress.
    pub checkpoint_every_s: Option<f64>,
    /// Provenance for cost attribution: who caused this work to exist
    /// (DESIGN.md §16). Defaults to [`TaskOrigin::Exogenous`].
    pub origin: TaskOrigin,
}

impl Default for TaskMeta {
    fn default() -> Self {
        TaskMeta {
            user: 0,
            priority: 0,
            est_duration_s: None,
            slots: 1,
            checkpoint_every_s: None,
            origin: TaskOrigin::Exogenous,
        }
    }
}

impl TaskMeta {
    /// Gang width with the zero-normalization applied.
    pub fn width(&self) -> usize {
        self.slots.max(1)
    }
}

/// A queued task as a policy sees it.
#[derive(Debug)]
pub struct SchedTask<'a> {
    pub id: TaskId,
    pub submitted_vt: f64,
    /// when dispatch latency (+cold start) ends and the body could run
    pub eligible_vt: f64,
    pub meta: &'a TaskMeta,
}

impl SchedTask<'_> {
    pub fn width(&self) -> usize {
        self.meta.width()
    }
}

/// Endpoint queue state at a scheduling decision.
#[derive(Debug)]
pub struct QueueView<'a> {
    /// queued tasks in arrival order (index 0 = head of line)
    pub tasks: &'a [SchedTask<'a>],
    /// free-at time of every capacity slot, **sorted ascending** —
    /// `slot_free[k-1]` is the earliest instant `k` slots are all free
    pub slot_free: &'a [f64],
    /// start time of the most recently started task on this endpoint
    /// (the FIFO monotonicity floor; only `Fifo` applies it)
    pub last_start_vt: f64,
}

impl QueueView<'_> {
    /// Current capacity slot count.
    pub fn capacity(&self) -> usize {
        self.slot_free.len()
    }

    /// Earliest instant at which `width` slots are simultaneously free
    /// (the `width`-th order statistic of the slot free times).
    /// `f64::INFINITY` when the endpoint cannot currently provide
    /// `width` slots — the gang waits (e.g. for an autoscaler
    /// provision); the service never exposes an infinite start through
    /// `next_event_time`.
    pub fn free_for(&self, width: usize) -> f64 {
        let width = width.max(1);
        if width > self.slot_free.len() {
            f64::INFINITY
        } else {
            self.slot_free[width - 1]
        }
    }

    /// Earliest instant any single slot is free.
    pub fn slot_free_vt(&self) -> f64 {
        self.free_for(1)
    }

    /// Number of slots free at instant `t`.
    pub fn avail_at(&self, t: f64) -> usize {
        self.slot_free.iter().filter(|&&f| f <= t + EPS).count()
    }

    /// The earliest instant `task` could start: its full gang width
    /// free and its dispatch eligibility elapsed.
    pub fn earliest_start(&self, task: &SchedTask) -> f64 {
        task.eligible_vt.max(self.free_for(task.width()))
    }

    /// Earliest instant *any* queued task could start — the decision
    /// instant for the reordering policies.
    fn decision_vt(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| self.earliest_start(t))
            .fold(f64::INFINITY, f64::min)
    }

    /// Tasks that could start at instant `t` (gang width free,
    /// eligibility elapsed).
    fn startable_at<'b>(&'b self, t: f64) -> impl Iterator<Item = (usize, &'b SchedTask<'b>)> {
        self.tasks
            .iter()
            .enumerate()
            .filter(move |(_, task)| self.earliest_start(task) <= t + EPS)
    }
}

/// A policy's decision: which queued task starts, and when.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pick {
    /// index into `QueueView::tasks`
    pub queue_idx: usize,
    pub start_vt: f64,
}

/// Decides which queued task starts when capacity frees.
///
/// Invariants every policy must uphold: `pick` returns `Some` whenever
/// the queue is non-empty (the service relies on this for stall
/// detection; a pick whose `start_vt` is `f64::INFINITY` means
/// "nothing can start until capacity grows"), `start_vt >=
/// max(free_for(chosen width), chosen task's eligible_vt)`, and the
/// decision is a pure function of the view (no interior state), which
/// is what keeps campaign replays deterministic.
///
/// `Send` supertrait: policies are plain config structs, and the faas
/// service (inside a campaign shard's World) crosses pool-worker
/// threads at bounded-lag window barriers.
pub trait SchedPolicy: Send {
    fn name(&self) -> &'static str;
    fn pick(&self, q: &QueueView) -> Option<Pick>;
}

/// Strict arrival order — bit-identical to the pre-policy queueing core
/// for single-slot tasks.
///
/// The head starts at `max(eligible, free_for(width), last_start)`: the
/// `last_start` floor keeps start events monotone even though the first
/// task pays the cold start and is eligible *later* than the second. A
/// gang at the head blocks everything behind it until its full width
/// frees — never camping on a partial hold — so FIFO cannot deadlock.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl SchedPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&self, q: &QueueView) -> Option<Pick> {
        let head = q.tasks.first()?;
        Some(Pick {
            queue_idx: 0,
            start_vt: head
                .eligible_vt
                .max(q.free_for(head.width()))
                .max(q.last_start_vt),
        })
    }
}

/// Highest effective priority first, with aging: a task's effective
/// priority is `priority + waited / aging_s`. Every waiter ages at the
/// same rate, so what closes a Δpriority gap is the *submit-time* gap:
/// work submitted `aging_s · Δpriority` seconds before a more urgent
/// arrival outranks it — a stream of later high-priority submissions
/// cannot starve parked low-priority work indefinitely (test-pinned at
/// the service level). `aging_s = f64::INFINITY` disables aging (pure
/// static priority — starvation-prone, kept for tests). Ties break by
/// arrival order. Only tasks whose full gang width is free at the
/// decision instant compete — Priority (like SJF) holds **no width
/// reservation**, so under sustained narrow load a wide gang can be
/// bypassed indefinitely regardless of its aged priority; use FIFO or
/// EasyBackfill (which reserve for the head) when gang service
/// guarantees matter.
#[derive(Debug, Clone, Copy)]
pub struct Priority {
    pub aging_s: f64,
}

impl Default for Priority {
    fn default() -> Self {
        Priority {
            aging_s: DEFAULT_AGING_S,
        }
    }
}

/// One priority level per five minutes of wait — long enough that
/// classes matter under transient contention, short enough that a
/// low-priority retraining is never parked behind an endless stream of
/// urgent jobs.
pub const DEFAULT_AGING_S: f64 = 300.0;

impl SchedPolicy for Priority {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn pick(&self, q: &QueueView) -> Option<Pick> {
        q.tasks.first()?;
        let now = q.decision_vt();
        if !now.is_finite() {
            // every queued task is a gang wider than current capacity:
            // nothing can start until the endpoint scales up
            return Some(Pick {
                queue_idx: 0,
                start_vt: f64::INFINITY,
            });
        }
        let effective = |t: &SchedTask| {
            let aged = if self.aging_s.is_finite() && self.aging_s > 0.0 {
                (now - t.submitted_vt).max(0.0) / self.aging_s
            } else {
                0.0
            };
            t.meta.priority as f64 + aged
        };
        let (idx, _) = q
            .startable_at(now)
            .fold(None::<(usize, f64)>, |best, (i, t)| {
                let e = effective(t);
                match best {
                    // strictly-greater keeps the earliest arrival on ties
                    Some((_, be)) if e <= be => best,
                    _ => Some((i, e)),
                }
            })?;
        Some(Pick {
            queue_idx: idx,
            start_vt: now,
        })
    }
}

/// Smallest duration estimate first among the tasks startable at the
/// decision instant; unknown estimates sort last; ties break by
/// arrival order. Like [`Priority`], SJF holds no width reservation:
/// a wide gang competes only at instants where its full width is
/// free, and sustained narrow load can bypass it indefinitely (the
/// classic SJF starvation mode, widened).
#[derive(Debug, Clone, Copy, Default)]
pub struct ShortestJobFirst;

impl SchedPolicy for ShortestJobFirst {
    fn name(&self) -> &'static str {
        "sjf"
    }

    fn pick(&self, q: &QueueView) -> Option<Pick> {
        q.tasks.first()?;
        let now = q.decision_vt();
        if !now.is_finite() {
            return Some(Pick {
                queue_idx: 0,
                start_vt: f64::INFINITY,
            });
        }
        let (idx, _) = q
            .startable_at(now)
            .fold(None::<(usize, f64)>, |best, (i, t)| {
                let est = t.meta.est_duration_s.unwrap_or(f64::INFINITY);
                match best {
                    Some((_, be)) if est >= be => best,
                    _ => Some((i, est)),
                }
            })?;
        Some(Pick {
            queue_idx: idx,
            start_vt: now,
        })
    }
}

/// EASY backfilling: the head of line reserves the earliest instant its
/// full gang width could start (`max(eligible, free_for(width))`);
/// while a hole exists before that reservation — the head waits for a
/// cold start, dispatch latency, post-outage re-dispatch, or for
/// enough slots to drain toward its gang width — later tasks are
/// scanned in arrival order and the first that provably cannot delay
/// the reservation starts immediately. A candidate qualifies if either
///
/// 1. its *estimated* completion lands before the reservation (the
///    borrowed slots are back in time), or
/// 2. it fits entirely on slots the head does not need: at the
///    reservation instant the endpoint has at least `head_width +
///    candidate_width` slots free.
///
/// Tasks without an estimate never backfill under rule 1 (no
/// gambling). With exact estimates the head's start time is identical
/// to plain FIFO's (test-pinned: `EasyBackfill` never delays the head
/// of line, gang or not).
#[derive(Debug, Clone, Copy, Default)]
pub struct EasyBackfill;

impl SchedPolicy for EasyBackfill {
    fn name(&self) -> &'static str {
        "backfill"
    }

    fn pick(&self, q: &QueueView) -> Option<Pick> {
        let head = q.tasks.first()?;
        let head_start = head.eligible_vt.max(q.free_for(head.width()));
        // An infinite reservation (the head gang waits for capacity the
        // endpoint does not have yet — an autoscaler provision) is an
        // *unknown* one: backfilling against it could occupy slots past
        // the provision instant and delay the head arbitrarily, so no
        // one jumps ahead until the reservation is real.
        if head_start.is_finite() {
            for (i, t) in q.tasks.iter().enumerate().skip(1) {
                let cand_start = q.earliest_start(t);
                if cand_start >= head_start - EPS {
                    continue; // no hole in front of the reservation
                }
                let fits_in_hole = t
                    .meta
                    .est_duration_s
                    .map(|est| cand_start + est <= head_start + EPS)
                    .unwrap_or(false);
                let spare_slots = q.avail_at(head_start) >= head.width() + t.width();
                if fits_in_hole || spare_slots {
                    return Some(Pick {
                        queue_idx: i,
                        start_vt: cand_start,
                    });
                }
            }
        }
        Some(Pick {
            queue_idx: 0,
            start_vt: head_start,
        })
    }
}

/// Parseable policy selector (CLI `--policy`, campaign config).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PolicyKind {
    #[default]
    Fifo,
    Priority {
        aging_s: f64,
    },
    Sjf,
    Backfill,
}

impl PolicyKind {
    /// Parse `fifo`, `priority`, `priority:<aging_s>`, `sjf`, `backfill`.
    pub fn parse(s: &str) -> Result<PolicyKind> {
        Ok(match s {
            "fifo" => PolicyKind::Fifo,
            "sjf" | "shortest" | "shortest-job-first" => PolicyKind::Sjf,
            "backfill" | "easy-backfill" => PolicyKind::Backfill,
            "priority" => PolicyKind::Priority {
                aging_s: DEFAULT_AGING_S,
            },
            other => {
                if let Some(aging) = other.strip_prefix("priority:") {
                    let aging_s: f64 = aging
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad aging seconds `{aging}`"))?;
                    if aging_s.is_nan() || aging_s <= 0.0 {
                        bail!("aging seconds must be positive, got {aging_s}");
                    }
                    PolicyKind::Priority { aging_s }
                } else {
                    bail!(
                        "unknown policy `{other}` (fifo, priority[:aging_s], sjf, backfill)"
                    )
                }
            }
        })
    }

    pub fn build(&self) -> Box<dyn SchedPolicy> {
        match *self {
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::Priority { aging_s } => Box::new(Priority { aging_s }),
            PolicyKind::Sjf => Box::new(ShortestJobFirst),
            PolicyKind::Backfill => Box::new(EasyBackfill),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::Priority { .. } => "priority",
            PolicyKind::Sjf => "sjf",
            PolicyKind::Backfill => "backfill",
        }
    }
}

/// Per-endpoint elasticity: scale capacity slots up under queue
/// pressure and back down after sustained idleness (DESIGN.md §9).
///
/// One action at a time: at most one provision can be in flight, and
/// `cooldown_s` must elapse between consecutive capacity changes. A new
/// slot becomes usable `provision_delay_s` after its trigger (node
/// boot / container spin-up); an idle slot is released only after the
/// endpoint has had a free slot and an empty queue for
/// `scale_down_idle_s` continuous virtual seconds.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub min_capacity: usize,
    pub max_capacity: usize,
    /// scale up when this many slot-demands are waiting (queued, not
    /// started; a width-`k` gang counts `k`)
    pub scale_up_waiting: usize,
    pub provision_delay_s: f64,
    pub scale_down_idle_s: f64,
    pub cooldown_s: f64,
}

impl Autoscaler {
    /// Elastic from one slot up to `max_capacity`, with defaults sized
    /// for the campaign fabric (30 s provisioning, 2-deep trigger,
    /// 120 s idle release, 60 s cooldown).
    pub fn up_to(max_capacity: usize) -> Autoscaler {
        Autoscaler {
            min_capacity: 1,
            max_capacity: max_capacity.max(1),
            scale_up_waiting: 2,
            provision_delay_s: 30.0,
            scale_down_idle_s: 120.0,
            cooldown_s: 60.0,
        }
    }
}

/// One capacity change applied by an autoscaler (campaign reporting
/// and slot-hour / dollar cost accounting, DESIGN.md §10–§11).
#[derive(Debug, Clone)]
pub struct ScalingEvent {
    pub vt: f64,
    pub endpoint: String,
    /// capacity after the change
    pub capacity: usize,
    /// tenant whose queued demand fired the scale-up trigger (the first
    /// waiting task at the trigger instant — or, when a too-wide gang
    /// forced unconditional pressure, that gang's tenant). `0` for
    /// scale-downs and untagged work. This is what lets the campaign's
    /// cost accounting attribute scale-up *waste* to the tenant who
    /// asked for the capacity (DESIGN.md §11).
    pub trigger_user: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(priority: i64, est: Option<f64>) -> TaskMeta {
        TaskMeta {
            priority,
            est_duration_s: est,
            ..TaskMeta::default()
        }
    }

    fn gang(est: Option<f64>, slots: usize) -> TaskMeta {
        TaskMeta {
            est_duration_s: est,
            slots,
            ..TaskMeta::default()
        }
    }

    fn view<'a>(
        tasks: &'a [SchedTask<'a>],
        slot_free: &'a [f64],
        last_start_vt: f64,
    ) -> QueueView<'a> {
        debug_assert!(slot_free.windows(2).all(|w| w[0] <= w[1]), "sorted input");
        QueueView {
            tasks,
            slot_free,
            last_start_vt,
        }
    }

    #[test]
    fn fifo_matches_legacy_start_formula() {
        let m = TaskMeta::default();
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 3.0,
                meta: &m,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &m,
            },
        ];
        // head not eligible yet: starts at its eligibility
        let p = Fifo.pick(&view(&tasks, &[0.0], 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 3.0 });
        // slot busy past eligibility: starts when the slot frees
        let p = Fifo.pick(&view(&tasks, &[13.0], 3.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 13.0 });
        // last_start floor dominates (second task behind a cold head)
        let second = &tasks[1..];
        let p = Fifo.pick(&view(second, &[0.0], 3.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 3.0 });
    }

    #[test]
    fn free_for_is_the_order_statistic() {
        let m = TaskMeta::default();
        let tasks: Vec<SchedTask> = vec![SchedTask {
            id: TaskId(1),
            submitted_vt: 0.0,
            eligible_vt: 0.0,
            meta: &m,
        }];
        let q = view(&tasks, &[2.0, 5.0, 9.0], 0.0);
        assert_eq!(q.capacity(), 3);
        assert_eq!(q.free_for(1), 2.0);
        assert_eq!(q.free_for(2), 5.0);
        assert_eq!(q.free_for(3), 9.0);
        assert_eq!(q.free_for(4), f64::INFINITY);
        assert_eq!(q.avail_at(5.0), 2);
        assert_eq!(q.avail_at(1.0), 0);
    }

    /// A gang at the head waits for its full width — it starts when the
    /// k-th slot frees, not when the first does (no partial holds).
    #[test]
    fn fifo_gang_waits_for_full_width() {
        let g = gang(Some(10.0), 2);
        let tasks = vec![SchedTask {
            id: TaskId(1),
            submitted_vt: 0.0,
            eligible_vt: 1.0,
            meta: &g,
        }];
        let p = Fifo.pick(&view(&tasks, &[3.0, 8.0], 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 8.0 });
        // wider than capacity: waits for a provision (infinite for now)
        let wide = gang(Some(10.0), 3);
        let tasks = vec![SchedTask {
            id: TaskId(1),
            submitted_vt: 0.0,
            eligible_vt: 1.0,
            meta: &wide,
        }];
        let p = Fifo.pick(&view(&tasks, &[3.0, 8.0], 0.0)).unwrap();
        assert_eq!(p.start_vt, f64::INFINITY);
    }

    /// Aging credits each task its *own* wait, so what closes a
    /// priority gap is the submit-time gap over `aging_s`: a task
    /// submitted `gap` seconds earlier is `gap / aging_s` effective
    /// levels ahead of a later arrival, at every decision instant.
    #[test]
    fn priority_prefers_urgent_but_aging_overtakes() {
        let low = meta(0, None);
        let high = meta(2, None);
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &low,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 100.0,
                eligible_vt: 101.0,
                meta: &high,
            },
        ];
        // slow aging (300 s/level): the 100 s head start is worth only
        // a third of a level — the 2-level gap holds, high wins at any
        // decision instant
        for slot_free in [101.0, 700.0] {
            let p = Priority { aging_s: 300.0 }
                .pick(&view(&tasks, &[slot_free], 0.0))
                .unwrap();
            assert_eq!(p.queue_idx, 1, "at slot_free {slot_free}");
        }
        // fast aging (40 s/level): the same head start is worth 2.5
        // levels — the low task overtakes the moment both compete
        let p = Priority { aging_s: 40.0 }
            .pick(&view(&tasks, &[101.0], 0.0))
            .unwrap();
        assert_eq!(p.queue_idx, 0);
        // no aging: strictly by class
        let p = Priority {
            aging_s: f64::INFINITY,
        }
        .pick(&view(&tasks, &[700.0], 0.0))
        .unwrap();
        assert_eq!(p.queue_idx, 1);
    }

    #[test]
    fn priority_ties_break_by_arrival() {
        let a = meta(1, None);
        let b = meta(1, None);
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 5.0,
                eligible_vt: 6.0,
                meta: &a,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 5.0,
                eligible_vt: 6.0,
                meta: &b,
            },
        ];
        let p = Priority::default().pick(&view(&tasks, &[10.0], 0.0)).unwrap();
        assert_eq!(p.queue_idx, 0);
    }

    /// A gang wider than a freed slot does not compete at a decision
    /// instant where only narrower work fits — the single-slot task runs
    /// and the gang keeps waiting for its width.
    #[test]
    fn priority_gang_not_startable_yields_to_narrow_work() {
        let wide = gang(None, 2); // priority 0, width 2
        let narrow = meta(0, None);
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &wide,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &narrow,
            },
        ];
        // one slot frees at 2, the second only at 50: the gang cannot
        // start before 50, the narrow task can start at 2
        let p = Priority::default().pick(&view(&tasks, &[2.0, 50.0], 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 1, start_vt: 2.0 });
    }

    #[test]
    fn sjf_picks_shortest_known_estimate() {
        let long = meta(0, Some(100.0));
        let short = meta(0, Some(2.0));
        let unknown = meta(0, None);
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &long,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &unknown,
            },
            SchedTask {
                id: TaskId(3),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &short,
            },
        ];
        let p = ShortestJobFirst.pick(&view(&tasks, &[5.0], 0.0)).unwrap();
        assert_eq!(p.queue_idx, 2);
        assert_eq!(p.start_vt, 5.0);
    }

    #[test]
    fn sjf_ignores_tasks_not_yet_eligible() {
        let short_late = meta(0, Some(1.0));
        let long_now = meta(0, Some(50.0));
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &long_now,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 9.0,
                eligible_vt: 10.0,
                meta: &short_late,
            },
        ];
        // decision at slot_free=2: only the long task is eligible
        let p = ShortestJobFirst.pick(&view(&tasks, &[2.0], 0.0)).unwrap();
        assert_eq!(p.queue_idx, 0);
        assert_eq!(p.start_vt, 2.0);
    }

    #[test]
    fn backfill_fills_cold_start_hole_without_delaying_head() {
        let head = meta(0, Some(10.0));
        let fits = meta(0, Some(1.5));
        let too_long = meta(0, Some(5.0));
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 3.0, // cold start
                meta: &head,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &too_long,
            },
            SchedTask {
                id: TaskId(3),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &fits,
            },
        ];
        // hole is [0, 3): the 5 s task does not fit, the 1.5 s one does
        let p = EasyBackfill.pick(&view(&tasks, &[0.0], 0.0)).unwrap();
        assert_eq!(p.queue_idx, 2);
        assert_eq!(p.start_vt, 1.0);
        // no hole (slot frees after head eligibility): plain FIFO head
        let p = EasyBackfill.pick(&view(&tasks, &[7.0], 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 7.0 });
    }

    #[test]
    fn backfill_never_gambles_on_unknown_estimates() {
        let head = meta(0, Some(10.0));
        let unknown = meta(0, None);
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 3.0,
                meta: &head,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &unknown,
            },
        ];
        let p = EasyBackfill.pick(&view(&tasks, &[0.0], 0.0)).unwrap();
        assert_eq!(p.queue_idx, 0);
        assert_eq!(p.start_vt, 3.0);
    }

    /// A gang head draining toward its width opens a hole: the slots
    /// already free form the eligibility hole a short job can fill.
    #[test]
    fn backfill_fills_gang_drain_hole() {
        let head = gang(Some(100.0), 2);
        let long = meta(0, Some(50.0));
        let short = meta(0, Some(3.0));
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &head,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &long,
            },
            SchedTask {
                id: TaskId(3),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &short,
            },
        ];
        // slot 0 is free now, slot 1 frees at 10: the gang reserves 10;
        // the 3 s job fits in the [1, 10) drain hole, the 50 s one not
        let p = EasyBackfill.pick(&view(&tasks, &[0.0, 10.0], 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 2, start_vt: 1.0 });
        // the hole closed (both slots free before eligibility): head runs
        let p = EasyBackfill.pick(&view(&tasks, &[0.0, 0.5], 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 1.0 });
    }

    /// Rule 2: a long candidate may run on slots the head does not
    /// need — even past the reservation — but only when enough slots
    /// are free at the reservation instant for both.
    #[test]
    fn backfill_uses_spare_slots_beyond_the_reservation() {
        let head = gang(Some(100.0), 1);
        let long = meta(0, Some(500.0));
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 6.0, // re-dispatch gap: reservation at 6
                meta: &head,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &long,
            },
        ];
        // capacity 2, both free: at the reservation (6) two slots are
        // free, head needs 1 — the 500 s task can take the spare now
        let p = EasyBackfill.pick(&view(&tasks, &[0.0, 0.0], 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 1, start_vt: 1.0 });
        // capacity 1: the same candidate would steal the head's slot
        let p = EasyBackfill.pick(&view(&tasks, &[0.0], 0.0)).unwrap();
        assert_eq!(p, Pick { queue_idx: 0, start_vt: 6.0 });
    }

    /// No backfilling against an *infinite* reservation: while the head
    /// gang waits for capacity the endpoint does not have yet, an
    /// estimated candidate could run past the (unknown) provision
    /// instant and delay the head arbitrarily — so nothing jumps ahead.
    #[test]
    fn backfill_refuses_infinite_head_reservation() {
        let head = gang(Some(10.0), 2); // wider than the 1-slot endpoint
        let est = meta(0, Some(1000.0));
        let tasks = vec![
            SchedTask {
                id: TaskId(1),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &head,
            },
            SchedTask {
                id: TaskId(2),
                submitted_vt: 0.0,
                eligible_vt: 1.0,
                meta: &est,
            },
        ];
        let p = EasyBackfill.pick(&view(&tasks, &[0.0], 0.0)).unwrap();
        assert_eq!(p.queue_idx, 0);
        assert_eq!(p.start_vt, f64::INFINITY);
    }

    #[test]
    fn policy_kind_parses_and_builds() {
        assert_eq!(PolicyKind::parse("fifo").unwrap(), PolicyKind::Fifo);
        assert_eq!(PolicyKind::parse("sjf").unwrap(), PolicyKind::Sjf);
        assert_eq!(
            PolicyKind::parse("backfill").unwrap(),
            PolicyKind::Backfill
        );
        assert_eq!(
            PolicyKind::parse("easy-backfill").unwrap(),
            PolicyKind::Backfill
        );
        assert_eq!(
            PolicyKind::parse("priority").unwrap(),
            PolicyKind::Priority {
                aging_s: DEFAULT_AGING_S
            }
        );
        assert_eq!(
            PolicyKind::parse("priority:60").unwrap(),
            PolicyKind::Priority { aging_s: 60.0 }
        );
        assert!(PolicyKind::parse("priority:-1").is_err());
        assert!(PolicyKind::parse("lifo").is_err());
        assert_eq!(PolicyKind::Backfill.build().name(), "backfill");
        assert_eq!(PolicyKind::default().label(), "fifo");
    }

    #[test]
    fn task_meta_width_normalizes_zero() {
        assert_eq!(TaskMeta::default().width(), 1);
        assert_eq!(gang(None, 0).width(), 1);
        assert_eq!(gang(None, 4).width(), 4);
    }

    #[test]
    fn autoscaler_up_to_clamps() {
        let a = Autoscaler::up_to(0);
        assert_eq!(a.max_capacity, 1);
        assert_eq!(a.min_capacity, 1);
        let a = Autoscaler::up_to(8);
        assert_eq!(a.max_capacity, 8);
    }
}
