//! funcX analog: a federated function-as-a-service fabric.
//!
//! "funcX ... offers the ability to turn any computing resource,
//! including clouds, clusters, supercomputers, edge-AI devices and DCAI
//! systems into a function-serving endpoint" (paper §3). Here:
//!
//! * a **function** is registered once and addressed by `FuncId`;
//! * an **endpoint** binds a facility + dispatch overheads (dispatch
//!   latency, cold start) + capacity slots, and can be taken offline for
//!   failure injection or `Down` for a planned outage window;
//! * **enqueue/advance_to** drive tasks through per-endpoint queues
//!   under the discrete-event scheduler — concurrent tenants contend
//!   for capacity slots and experience queue wait (DESIGN.md §4), and a
//!   pluggable **scheduling policy** (`sched`: FIFO, priority+aging,
//!   shortest-job-first, EASY backfill) decides who takes freed
//!   capacity (DESIGN.md §9); a **gang** (`TaskMeta::slots > 1`)
//!   acquires its full width of slots atomically — no partial holds
//!   (DESIGN.md §10);
//! * an optional per-endpoint **autoscaler** grows/shrinks capacity
//!   slots on queue pressure with provisioning delay and cooldown;
//! * **submit** is the single-tenant convenience: it drives one task to
//!   completion against the caller's clock, recording a task whose
//!   status/result can be polled later (fire-and-forget semantics).
//!
//! The service is generic over the context type `C` so the workflow layer
//! can pass its `World` while unit tests use lightweight mocks.

pub mod endpoint;
pub mod sched;
pub mod service;

pub use endpoint::{CapacityTier, EndpointStatus, FaasEndpoint};
pub use sched::{
    Autoscaler, EasyBackfill, Fifo, Pick, PolicyKind, Priority, QueueView, ScalingEvent,
    SchedPolicy, SchedTask, ShortestJobFirst, TaskMeta, TaskOrigin,
};
pub use service::{Displaced, FaasService, FuncId, TaskId, TaskRecord, TaskStatus};
