//! funcX endpoints: function-serving daemons pinned to facilities.

use crate::simnet::FacilityId;

/// Endpoint liveness (heartbeat-derived in real funcX).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EndpointStatus {
    Online,
    /// Deregistered: submissions fail immediately (funcX's
    /// fire-and-forget error path).
    Offline,
    /// Temporarily down (a planned `FaultPlan` outage window): the
    /// facility queue survives — new and queued tasks wait, nothing
    /// starts, and running tasks were failed-with-retry when the
    /// outage began (`FaasService::begin_outage`).
    Down,
}

/// How the facility provisions this endpoint's capacity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CapacityTier {
    /// Reserved capacity: slots stay up until a `FaultPlan` outage or
    /// an explicit status change takes them away.
    OnDemand,
    /// Preemptible capacity: cheaper per slot-hour (the `:spot` class
    /// suffix in `PriceBook`), but the facility may reclaim the whole
    /// endpoint at any time. Reclaims arrive as a stochastic process
    /// with exponential inter-preemption gaps of mean `preempt_rate_s`
    /// virtual seconds, and each reclaim is announced `grace_s` seconds
    /// ahead — the window a running gang has to drain to its last
    /// checkpoint boundary before the slots disappear
    /// (`FaasService::spot_warn` / `reclaim_spot`).
    Spot {
        /// mean virtual seconds between preemption announcements
        preempt_rate_s: f64,
        /// announced warning-to-reclaim window in virtual seconds
        grace_s: f64,
    },
}

/// A function-serving endpoint deployed at a facility.
#[derive(Debug, Clone)]
pub struct FaasEndpoint {
    pub id: String,
    pub facility: FacilityId,
    /// fixed dispatch latency every task pays before it can start
    /// (broker round trip + endpoint poll interval)
    pub queue_latency_s: f64,
    /// first-task worker spin-up (container/venv activation)
    pub cold_start_s: f64,
    pub status: EndpointStatus,
    /// tasks executed so far (cold start applies only to the first)
    pub tasks_run: u64,
    /// concurrent execution slots — a Cerebras endpoint runs one training
    /// job at a time (capacity 1, the default), a cluster endpoint can
    /// run many. Tasks beyond capacity wait in a queue ordered by the
    /// service's scheduling policy; that wait is the multi-tenant queue
    /// time the campaign layer measures. An `Autoscaler` may grow and
    /// shrink this at runtime — the field always reflects the *current*
    /// slot count.
    pub capacity: usize,
    /// on-demand (reserved) vs spot (preemptible) capacity
    pub tier: CapacityTier,
}

impl FaasEndpoint {
    pub fn new(id: impl Into<String>, facility: FacilityId) -> FaasEndpoint {
        FaasEndpoint {
            id: id.into(),
            facility,
            queue_latency_s: 1.0,
            cold_start_s: 2.0,
            status: EndpointStatus::Online,
            tasks_run: 0,
            capacity: 1,
            tier: CapacityTier::OnDemand,
        }
    }

    /// Builder: set the number of concurrent execution slots.
    pub fn with_capacity(mut self, capacity: usize) -> FaasEndpoint {
        self.capacity = capacity.max(1);
        self
    }

    /// Builder: set the capacity tier (default `OnDemand`).
    pub fn with_tier(mut self, tier: CapacityTier) -> FaasEndpoint {
        self.tier = tier;
        self
    }

    /// Whether this endpoint is preemptible spot capacity.
    pub fn is_spot(&self) -> bool {
        matches!(self.tier, CapacityTier::Spot { .. })
    }

    /// Dispatch overhead for the next task, then mark it counted.
    pub fn next_dispatch_overhead(&mut self) -> f64 {
        let cold = if self.tasks_run == 0 {
            self.cold_start_s
        } else {
            0.0
        };
        self.tasks_run += 1;
        self.queue_latency_s + cold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_start_only_once() {
        let mut ep = FaasEndpoint::new("alcf#cerebras", FacilityId(1));
        assert_eq!(ep.next_dispatch_overhead(), 3.0);
        assert_eq!(ep.next_dispatch_overhead(), 1.0);
        assert_eq!(ep.next_dispatch_overhead(), 1.0);
    }

    #[test]
    fn capacity_defaults_to_one_slot() {
        let ep = FaasEndpoint::new("alcf#cerebras", FacilityId(1));
        assert_eq!(ep.capacity, 1);
        let ep = FaasEndpoint::new("alcf#cluster", FacilityId(1)).with_capacity(64);
        assert_eq!(ep.capacity, 64);
        let ep = FaasEndpoint::new("x", FacilityId(0)).with_capacity(0);
        assert_eq!(ep.capacity, 1); // clamped
    }

    #[test]
    fn tier_defaults_to_on_demand() {
        let ep = FaasEndpoint::new("alcf#cerebras", FacilityId(1));
        assert_eq!(ep.tier, CapacityTier::OnDemand);
        assert!(!ep.is_spot());
        let ep = ep.with_tier(CapacityTier::Spot {
            preempt_rate_s: 900.0,
            grace_s: 120.0,
        });
        assert!(ep.is_spot());
    }
}
