//! funcX endpoints: function-serving daemons pinned to facilities.

use crate::simnet::FacilityId;

/// Endpoint liveness (heartbeat-derived in real funcX).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EndpointStatus {
    Online,
    Offline,
}

/// A function-serving endpoint deployed at a facility.
#[derive(Debug, Clone)]
pub struct FaasEndpoint {
    pub id: String,
    pub facility: FacilityId,
    /// seconds a task waits in the endpoint's queue before starting
    pub queue_latency_s: f64,
    /// first-task worker spin-up (container/venv activation)
    pub cold_start_s: f64,
    pub status: EndpointStatus,
    /// tasks executed so far (cold start applies only to the first)
    pub tasks_run: u64,
}

impl FaasEndpoint {
    pub fn new(id: impl Into<String>, facility: FacilityId) -> FaasEndpoint {
        FaasEndpoint {
            id: id.into(),
            facility,
            queue_latency_s: 1.0,
            cold_start_s: 2.0,
            status: EndpointStatus::Online,
            tasks_run: 0,
        }
    }

    /// Dispatch overhead for the next task, then mark it counted.
    pub fn next_dispatch_overhead(&mut self) -> f64 {
        let cold = if self.tasks_run == 0 {
            self.cold_start_s
        } else {
            0.0
        };
        self.tasks_run += 1;
        self.queue_latency_s + cold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_start_only_once() {
        let mut ep = FaasEndpoint::new("alcf#cerebras", FacilityId(1));
        assert_eq!(ep.next_dispatch_overhead(), 3.0);
        assert_eq!(ep.next_dispatch_overhead(), 1.0);
        assert_eq!(ep.next_dispatch_overhead(), 1.0);
    }
}
