//! `xloop` — the leader CLI for the geographically distributed DNN
//! retraining fabric (XLOOP 2021 reproduction).
//!
//! Subcommands:
//!   table1    reproduce Table 1 (end-to-end retraining breakdown grid)
//!   retrain   run one DNNTrainerFlow scenario (real PJRT training)
//!   campaign  N concurrent users on the shared fabric (queueing study)
//!   fig3      transfer-throughput sweep (Fig. 3)
//!   fig4      conventional-vs-ML crossover curves (Fig. 4)
//!   serve     retrain, deploy, then stream inference at the edge
//!   info      runtime/artifact status

use anyhow::{bail, Result};

use xloop::costmodel::{CostParams, PriceBook};
use xloop::faas::{Autoscaler, PolicyKind};
use xloop::simnet::{FaultPlan, VClock};
use xloop::transfer::{TransferRequest, TransferService};
use xloop::util::cli::Options;
use xloop::util::stats::{human_bytes, human_secs};
use xloop::workflow::{
    parse_mix, parse_sites, parse_spot, render_table1, run_campaign, CampaignConfig,
    CampaignReport, ClosedLoopSpec, Coordinator, Mode, MixEntry, Placement, Scenario, SpotSpec,
    TrainingMode,
};

fn main() {
    xloop::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "table1" => cmd_table1(rest),
        "retrain" => cmd_retrain(rest),
        "campaign" => cmd_campaign(rest),
        "fig3" => cmd_fig3(rest),
        "fig4" => cmd_fig4(rest),
        "serve" => cmd_serve(rest),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `xloop help`)"),
    }
}

fn print_usage() {
    println!(
        "xloop — bridging data-center AI systems with edge computing\n\
         \n\
         usage: xloop <command> [options]\n\
         \n\
         commands:\n\
           table1    reproduce Table 1 (retraining time breakdown grid)\n\
           retrain   run one retraining flow (--model, --mode, --real-steps)\n\
           campaign  N users' retrainings on the shared fabric (--users,\n\
                     --interarrival, --loads for a crossover sweep; --policy,\n\
                     --autoscale, --faults, --mix, --compare-policies for the\n\
                     scheduling/elasticity/fault study; --prices and\n\
                     --cost-sweep for the dollar-denominated cost study;\n\
                     --spot, --checkpoint-every for preemptible capacity\n\
                     with checkpointed failover; --sites, --placement for\n\
                     brokered multi-site federation)\n\
           fig3      WAN transfer throughput vs concurrency (Fig. 3)\n\
           fig4      conventional vs ML-surrogate crossover (Fig. 4)\n\
           serve     retrain + deploy + stream edge inference\n\
           info      artifact/runtime status\n\
         \n\
         run a command with --help for its options"
    );
}

fn cmd_table1(args: &[String]) -> Result<()> {
    let opts = Options::new()
        .flag("real", "run real PJRT training steps in each cell")
        .opt("seed", "42", "fabric seed");
    if args.iter().any(|a| a == "--help") {
        print!("{}", opts.usage("xloop table1"));
        return Ok(());
    }
    let p = opts.parse(args).map_err(anyhow::Error::msg)?;
    let seed: u64 = p.get_usize("seed")? as u64;

    let mut rows = Vec::new();
    for scenario in Scenario::table1_grid() {
        // fresh fabric per row: the paper measured independent runs
        let mut c = Coordinator::paper(seed)?;
        c.set_training_mode(if p.get_bool("real") {
            TrainingMode::Real {
                steps_override: None,
            }
        } else {
            TrainingMode::VirtualOnly
        });
        log::info!("running {} / {}", scenario.model, scenario.mode.label());
        let outcome = c.run_retraining(&scenario, None)?;
        rows.push(outcome.breakdown);
    }
    println!("\nTable 1 — end-to-end retraining breakdown (virtual seconds)\n");
    print!("{}", render_table1(&rows));
    println!("\npaper reference: BraggNN 1102/31/151 s, CookieNetAE 517/15/97 s end-to-end");
    Ok(())
}

fn cmd_retrain(args: &[String]) -> Result<()> {
    let opts = Options::new()
        .opt("model", "braggnn", "model to retrain (braggnn|cookienetae)")
        .opt("mode", "remote-cerebras", "training mode")
        .opt("real-steps", "0", "real PJRT steps (0 = recipe default)")
        .opt("samples", "0", "real dataset samples (0 = scenario default)")
        .opt("seed", "42", "fabric seed")
        .opt("config", "", "JSON config file (fabric/scenario overrides)")
        .flag("virtual-only", "skip real training (time modeling only)")
        .flag("events", "print the flow event log");
    if args.iter().any(|a| a == "--help") {
        print!("{}", opts.usage("xloop retrain"));
        return Ok(());
    }
    let p = opts.parse(args).map_err(anyhow::Error::msg)?;

    let config = match p.get("config") {
        "" => xloop::config::Config::default(),
        path => xloop::config::Config::load(std::path::Path::new(path))?,
    };
    let mode = Mode::parse(p.get("mode"))?;
    let mut scenario = Scenario::table1(p.get("model"), mode)?;
    scenario.seed = p.get_usize("seed")? as u64;
    if p.get_usize("samples")? > 0 {
        scenario.real_samples = p.get_usize("samples")?;
    }
    config.apply_scenario(&mut scenario);

    let mut c = Coordinator::paper(scenario.seed)?;
    config.apply(&mut c)?;
    c.set_training_mode(if p.get_bool("virtual-only") {
        TrainingMode::VirtualOnly
    } else {
        TrainingMode::Real {
            steps_override: match p.get_usize("real-steps")? {
                0 => None,
                n => Some(n as u64),
            },
        }
    });

    let outcome = c.run_retraining(&scenario, None)?;
    let b = &outcome.breakdown;
    println!("model: {} | mode: {}", b.model, b.mode_label);
    if let Some(s) = b.data_transfer_s {
        println!("  data transfer : {}", human_secs(s));
    }
    println!("  training      : {}", human_secs(b.training_s));
    if let Some(s) = b.model_transfer_s {
        println!("  model transfer: {}", human_secs(s));
    }
    println!("  end-to-end    : {}", human_secs(b.end_to_end_s));
    if let Some(loss) = b.final_loss {
        println!("  real steps    : {} (final loss {loss:.5})", b.real_steps);
    }
    if p.get_bool("events") {
        println!("\nevent log:\n{}", outcome.report.to_json());
    }
    Ok(())
}

fn cmd_campaign(args: &[String]) -> Result<()> {
    let opts = Options::new()
        .opt(
            "users",
            "8",
            "number of concurrent users (scientific notation accepted, e.g. 1e6)",
        )
        .opt(
            "shards",
            "0",
            "partition users across N parallel fabric shards (0 = auto: serial \
             up to --shard-users users, then one shard per --shard-users; \
             reports are thread-count-invariant)",
        )
        .opt(
            "shard-users",
            "0",
            "users per shard for the auto-split (0 = built-in 4096; the \
             XLOOP_SHARD_USERS env var overrides the built-in); ignored when \
             --shards is explicit",
        )
        .flag(
            "sync-wan",
            "bounded-lag window synchronization across shards: shards advance \
             in lock-step virtual-time windows and share the physical WAN via \
             a demand ledger + water-fill, instead of each shard claiming the \
             full pipe (default: independent fabric replicas)",
        )
        .opt("model", "braggnn", "model to retrain (braggnn|cookienetae)")
        .opt("mode", "remote-cerebras", "training mode")
        .opt(
            "interarrival",
            "60",
            "mean seconds between user arrivals (Poisson; 0 = all at once)",
        )
        .opt(
            "loads",
            "",
            "comma-separated mean inter-arrival sweep; prints remote-vs-local \
             turnaround vs load (crossover study)",
        )
        .opt(
            "policy",
            "fifo",
            "faas scheduling policy: fifo | priority[:aging_s] | sjf | backfill",
        )
        .opt(
            "priorities",
            "",
            "comma-separated per-user priority classes, cycled over users \
             (empty = uniform; ordering applies under --policy priority)",
        )
        .opt(
            "autoscale",
            "0",
            "autoscale the training endpoint up to N capacity slots (0 = off)",
        )
        .opt(
            "faults",
            "",
            "fault plan, e.g. outage=alcf#cerebras@500..2000,wan=0.25@100..1500",
        )
        .opt(
            "mix",
            "",
            "heterogeneous tenant mix: model:weight[:gang_slots[:rate_s[:burst=F@D]]] \
             entries, e.g. braggnn:0.7:1,cookienetae:0.3:4 (empty = every user runs \
             --model); a rate/burst on any entry switches to per-class arrival streams",
        )
        .opt(
            "prices",
            "",
            "price the fabric in dollars: class:$_per_slot_hour entries plus optional \
             egress:$_per_GB, e.g. cerebras:42.0,cluster:1.8,egress:0.09 (`paper` = \
             built-in list prices; empty = slot-hours only)",
        )
        .opt(
            "spot",
            "",
            "preemptible capacity: endpoint:mean_gap_s:grace_s entries, e.g. \
             alcf#cerebras:900:30 — the endpoint is reclaimed at seeded exponential \
             intervals after a grace-period warning; running gangs drain to their \
             last checkpoint and fail over (empty = all capacity on-demand)",
        )
        .opt(
            "checkpoint-every",
            "0",
            "checkpoint cadence for training gangs, in body seconds (0 = training is \
             not checkpointable: a spot preemption loses all progress)",
        )
        .opt(
            "sites",
            "",
            "extra federation sites behind the placement broker: semicolon-joined \
             name:classes:gbps:latency_ms:egress_per_gb[:resident] entries, e.g. \
             nersc:cerebras+gpu8:25:5:0.02:braggnn — classes and resident models \
             join with `+`; whole-site outages come from --faults site=name@a..b \
             (empty = the paper's fixed SLAC->ALCF path, no broker)",
        )
        .opt(
            "placement",
            "turnaround",
            "which score the broker minimizes across --sites: turnaround (predicted \
             staging + gang queue wait) | dollars (predicted slot + egress dollars)",
        )
        .flag(
            "compare-policies",
            "run the same campaign under every policy and print a comparison table",
        )
        .flag(
            "cost-sweep",
            "sweep arrival load (--loads or a default grid) and print the remote-vs-\
             local crossover in dollars AND turnaround (uses --prices, default `paper`)",
        )
        .flag(
            "closed-loop",
            "close the edge loop (DESIGN.md §16): replace the Poisson arrival plan \
             with per-user serving-drift streams — each user serves batches on the \
             edge device until their fit-residual EWMA trips the trigger, which \
             admits their retraining flow; the completed retrain hot-swaps the \
             served model (default: exogenous arrivals)",
        )
        .opt(
            "drift-threshold",
            "0.35",
            "EWMA fit-residual level that fires a retrain trigger (with \
             --closed-loop; must be finite and > 0)",
        )
        .opt(
            "serve-rate",
            "0.1",
            "served batches per virtual second per user (with --closed-loop; the \
             default when the flag is passed alone)",
        )
        .opt("seed", "42", "arrival/fabric seed");
    if args.iter().any(|a| a == "--help") {
        print!("{}", opts.usage("xloop campaign"));
        return Ok(());
    }
    let p = opts.parse(args).map_err(anyhow::Error::msg)?;
    let users = parse_count(p.get("users"))?;
    anyhow::ensure!(users > 0, "--users must be at least 1");
    let shards = parse_count(p.get("shards"))?;
    let shard_users = parse_count(p.get("shard-users"))?;
    let sync_wan = p.get_bool("sync-wan");
    let seed = p.get_usize("seed")? as u64;
    let mode = Mode::parse(p.get("mode"))?;
    let scenario = Scenario::table1(p.get("model"), mode)?;

    let policy = PolicyKind::parse(p.get("policy"))?;
    let priorities = parse_priorities(p.get("priorities"))?;
    let autoscale_max = p.get_usize("autoscale")?;
    let faults = match p.get("faults") {
        "" => FaultPlan::default(),
        spec => FaultPlan::parse(spec)?,
    };
    let mix: Vec<MixEntry> = parse_mix(p.get("mix"))?;
    let spot: Vec<SpotSpec> = parse_spot(p.get("spot"))?;
    let checkpoint_every = match p.get_f64("checkpoint-every")? {
        s if s == 0.0 => None,
        s => Some(s),
    };
    let prices: Option<PriceBook> = match p.get("prices") {
        "" => None,
        "paper" => Some(PriceBook::paper()),
        spec => Some(PriceBook::parse(spec)?),
    };
    let sites = parse_sites(p.get("sites"))?;
    let placement = Placement::parse(p.get("placement"))?;
    // --drift-threshold / --serve-rate refine the loop; without
    // --closed-loop they are inert and the campaign is byte-identical
    // to the knob-less default
    let closed_loop: Option<ClosedLoopSpec> = if p.get_bool("closed-loop") {
        let spec = ClosedLoopSpec {
            threshold: p.get_f64("drift-threshold")?,
            serve_rate: p.get_f64("serve-rate")?,
            ..ClosedLoopSpec::default()
        };
        spec.validate()?;
        Some(spec)
    } else {
        None
    };
    // anything beyond the PR 2 default enables the enriched report
    let enriched = !matches!(policy, PolicyKind::Fifo)
        || !priorities.is_empty()
        || autoscale_max > 0
        || !faults.is_empty()
        || !mix.is_empty()
        || prices.is_some()
        || !spot.is_empty()
        || checkpoint_every.is_some()
        // the §14 knobs report their sharding/window summary there;
        // plain --shards stays out so the scale job's stdout is
        // byte-identical to the replica-mode golden
        || sync_wan
        || shard_users > 0
        || !sites.is_empty()
        || closed_loop.is_some();
    let mk_cfg = |scenario: &Scenario, mean: f64, kind: PolicyKind| {
        let autoscale = if autoscale_max > 0 {
            vec![(
                scenario.mode.train_endpoint().to_string(),
                Autoscaler::up_to(autoscale_max),
            )]
        } else {
            Vec::new()
        };
        CampaignConfig::default()
            .with_users(users)
            .with_scenario(scenario.clone())
            .with_interarrival_s(mean)
            .with_seed(seed)
            .with_policy(kind)
            .with_priorities(priorities.clone())
            .with_autoscale(autoscale)
            .with_faults(faults.clone())
            .with_mix(mix.clone())
            .with_spot(spot.clone())
            .with_checkpoint_every_s(checkpoint_every)
            .with_shards(shards)
            .with_shard_users(shard_users)
            .with_sync_wan(sync_wan)
            .with_sites(sites.clone())
            .with_placement(placement)
            .with_closed_loop(closed_loop)
    };

    let mean = p.get_f64("interarrival")?;
    if p.get_bool("cost-sweep") {
        let book = prices.clone().unwrap_or_else(PriceBook::paper);
        let loads = match p.get("loads") {
            "" => "600,120,60,30,15",
            spec => spec,
        };
        return campaign_cost_sweep(loads, users, &scenario, policy, &book, &mk_cfg);
    }
    if p.get_bool("compare-policies") {
        return campaign_policy_sweep(&scenario, mean, prices.as_ref(), &mk_cfg);
    }
    if !p.get("loads").is_empty() {
        return campaign_load_sweep(p.get("loads"), users, &scenario, policy, &mk_cfg);
    }

    let wall_start = std::time::Instant::now();
    let report = run_campaign(&mk_cfg(&scenario, mean, policy))?;
    // the scale metric goes to stderr so stdout stays byte-diffable
    // across runs and backends (the campaign-golden / campaign-scale
    // CI jobs diff stdout only)
    let wall = wall_start.elapsed().as_secs_f64();
    eprintln!(
        "campaign-scale: {} users in {:.3} s = {:.1} users/s",
        users,
        wall,
        users as f64 / wall.max(1e-9)
    );

    println!(
        "\nCampaign — {} user(s), {} / {}, mean inter-arrival {}\n",
        users,
        scenario.model,
        mode.label(),
        human_secs(report.mean_interarrival_s),
    );
    // the model/gang columns exist only under --mix, keeping the
    // default table byte-identical to the pre-mix CLI
    let show_mix = !mix.is_empty();
    if show_mix {
        println!(
            "{:>5} {:>13} {:>5} {:>12} {:>14} {:>13} {:>15} {:>14}",
            "user",
            "model",
            "gang",
            "arrival (s)",
            "data xfer (s)",
            "train (s)",
            "model xfer (s)",
            "turnaround (s)"
        );
    } else {
        println!(
            "{:>5} {:>12} {:>14} {:>13} {:>15} {:>14}",
            "user", "arrival (s)", "data xfer (s)", "train (s)", "model xfer (s)", "turnaround (s)"
        );
    }
    for u in &report.users {
        let fmt = |v: Option<f64>| match v {
            Some(s) => format!("{s:.1}"),
            None => "N/A".to_string(),
        };
        if show_mix {
            print!("{:>5} {:>13} {:>5} ", u.user, u.model, u.gang_slots);
        } else {
            print!("{:>5} ", u.user);
        }
        match &u.breakdown {
            Some(b) => println!(
                "{:>12.1} {:>14} {:>13.1} {:>15} {:>14.1}",
                u.arrival_vt,
                fmt(b.data_transfer_s),
                b.training_s,
                fmt(b.model_transfer_s),
                u.turnaround_s
            ),
            None => println!(
                "{:>12.1} {:>14} {:>13} {:>15} {:>14.1}",
                u.arrival_vt, "-", "FAILED", "-", u.turnaround_s
            ),
        }
    }
    println!(
        "\nturnaround: p50 {} | p95 {} | max {} | makespan {}",
        human_secs(report.turnaround_percentile(50.0)),
        human_secs(report.turnaround_percentile(95.0)),
        human_secs(report.max_turnaround_s()),
        human_secs(report.makespan_s),
    );
    if report.mean_task_throughput_bps > 0.0 {
        println!(
            "mean per-task transfer goodput: {:.3} GB/s",
            report.mean_task_throughput_bps / 1e9
        );
    }
    println!("\nfaas endpoint load (queue wait from capacity contention):");
    println!(
        "{:>16} {:>7} {:>16} {:>16}",
        "endpoint", "tasks", "mean wait (s)", "max wait (s)"
    );
    for l in &report.endpoint_loads {
        println!(
            "{:>16} {:>7} {:>16.1} {:>16.1}",
            l.endpoint,
            l.tasks,
            l.mean_queue_wait_s(),
            l.max_queue_wait_s
        );
    }
    if enriched {
        print_enriched_report(&report, prices.as_ref());
    }
    Ok(())
}

/// Parse a non-negative count, accepting scientific notation (`1e6`)
/// for the stress sizes the scale study uses.
fn parse_count(raw: &str) -> Result<usize> {
    let raw = raw.trim();
    if let Ok(n) = raw.parse::<usize>() {
        return Ok(n);
    }
    let f: f64 = raw
        .parse()
        .map_err(|_| anyhow::anyhow!("bad count `{raw}` (want an integer or 1e6-style float)"))?;
    anyhow::ensure!(
        f.is_finite() && (0.0..=1e12).contains(&f) && f.fract() == 0.0,
        "bad count `{raw}` (want a whole non-negative number)"
    );
    Ok(f as usize)
}

fn parse_priorities(spec: &str) -> Result<Vec<i64>> {
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(
            tok.parse()
                .map_err(|_| anyhow::anyhow!("bad priority class `{tok}`"))?,
        );
    }
    Ok(out)
}

/// The DESIGN.md §9 additions to the campaign report: scheduling
/// policy, per-user fairness (slowdown percentiles, Jain's index),
/// autoscaling events, failed users — plus, under `--prices`, the
/// DESIGN.md §11 dollar block (provisioned/used/waste/egress dollars
/// and the per-tenant bills that sum to the fabric total). Printed only
/// when a non-default knob is set, keeping `--policy fifo` output
/// byte-identical to the pre-policy CLI.
fn print_enriched_report(report: &CampaignReport, prices: Option<&PriceBook>) {
    // sharded/windowed execution summary (DESIGN.md §13/§14): only when
    // the partition or the sync executor actually did something
    if report.shards > 1 || report.sync_wan_windows > 0 {
        let sync = if report.sync_wan_windows > 0 {
            format!(
                " | sync-wan: {} bounded-lag window(s)",
                report.sync_wan_windows
            )
        } else {
            String::new()
        };
        println!(
            "\nsharding: {} shard(s) x up to {} user(s) each{}",
            report.shards, report.shard_users, sync
        );
    }
    let f = &report.fairness;
    println!(
        "\nscheduling policy: {} | per-user slowdown: mean {:.3} | p50 {:.3} | p95 {:.3} | max {:.3}",
        report.policy.label(),
        f.mean_slowdown,
        f.p50_slowdown,
        f.p95_slowdown,
        f.max_slowdown,
    );
    println!("Jain fairness index over per-user slowdowns: {:.4}", f.jain);
    let c = &report.cost;
    println!(
        "\ncost — provisioned {:.3} slot-h | used {:.3} slot-h | scale-up waste {:.3} slot-h",
        c.total_provisioned_slot_s() / 3600.0,
        c.total_used_slot_s() / 3600.0,
        c.total_scaleup_waste_slot_s() / 3600.0,
    );
    println!(
        "{:>16} {:>10} {:>12} {:>12} {:>6} {:>14}",
        "endpoint", "base→peak", "prov (sl-h)", "used (sl-h)", "util", "waste (sl-h)"
    );
    for e in &c.endpoints {
        println!(
            "{:>16} {:>10} {:>12.4} {:>12.4} {:>5.0}% {:>14.4}",
            e.endpoint,
            format!("{}→{}", e.base_capacity, e.peak_capacity),
            e.provisioned_slot_s / 3600.0,
            e.used_slot_s / 3600.0,
            e.utilization() * 100.0,
            e.scaleup_waste_slot_s() / 3600.0,
        );
    }
    let attributed: Vec<String> = c
        .per_user_slot_s
        .iter()
        .enumerate()
        .map(|(i, s)| format!("u{} {:.4}", i + 1, s / 3600.0))
        .collect();
    println!("per-tenant attributed slot-h: {}", attributed.join(" | "));
    if let Some(book) = prices {
        let d = report.cost.dollars(book);
        println!(
            "\ncost ($) — provisioned ${:.2} | used ${:.2} | scale-up waste ${:.2} | \
             egress ${:.2} ({:.2} GB) | fabric total ${:.2}",
            d.provisioned_usd(),
            d.used_usd(),
            d.scaleup_waste_usd(),
            d.egress_usd,
            d.egress_bytes / 1e9,
            d.total_usd(),
        );
        println!(
            "{:>16} {:>10} {:>12} {:>12} {:>12}",
            "endpoint", "$/slot-h", "prov ($)", "used ($)", "waste ($)"
        );
        for e in &d.endpoints {
            println!(
                "{:>16} {:>10.2} {:>12.2} {:>12.2} {:>12.2}",
                e.endpoint,
                e.rate_per_slot_hour,
                e.provisioned_usd,
                e.used_usd,
                e.scaleup_waste_usd,
            );
        }
        let bills: Vec<String> = d
            .per_tenant
            .iter()
            .map(|t| {
                format!(
                    "u{} ${:.2} (compute ${:.2} + idle ${:.2} + egress ${:.2}; \
                     waste memo ${:.2})",
                    t.user,
                    t.total_usd(),
                    t.used_usd,
                    t.idle_share_usd,
                    t.egress_usd,
                    t.scaleup_waste_usd
                )
            })
            .collect();
        println!(
            "per-tenant bill (sums to the fabric total): {}",
            bills.join(" | ")
        );
    }
    // the DESIGN.md §15 federation block: per-site placement breakdown
    // plus the site-outage reroute line the CI smoke leg greps for
    if let Some(fed) = &report.federation {
        println!(
            "\nfederation — {} site(s), placement by {}:",
            fed.sites.len(),
            fed.placement.as_str(),
        );
        println!(
            "{:>10} {:>8} {:>14} {:>12}",
            "site", "placed", "resident hits", "egress $/GB"
        );
        for s in &fed.sites {
            println!(
                "{:>10} {:>8} {:>14} {:>12.2}",
                s.name, s.placed, s.resident_hits, s.egress_per_gb
            );
        }
        println!(
            "site outages: {} gang(s) rerouted off dark sites | {} stranded",
            fed.reroutes, fed.stranded
        );
    }
    if let Some(s) = &report.spot {
        println!(
            "\nspot capacity: {} preemption(s) | {} gang(s) displaced | \
             {} local + {} WAN migration(s) | {} stranded",
            s.preemptions, s.displaced, s.local_migrations, s.wan_migrations, s.stranded
        );
        println!(
            "checkpointed work kept {} | lost past last checkpoint {} | \
             checkpoint bytes over WAN {}",
            human_secs(s.checkpointed_s),
            human_secs(s.lost_s),
            human_bytes(s.migration_bytes as f64),
        );
    }
    // the DESIGN.md §16 closed-loop block: drift/trigger activity plus
    // the staleness line the CI smoke leg greps for
    if let Some(c) = &report.closed_loop {
        println!(
            "\nclosed loop — served {} batch(es) | drift triggers {} ({} forced, \
             {} suppressed) | retrains admitted {} | hot swaps {}",
            c.batches_served, c.triggers, c.forced_triggers, c.suppressed,
            c.retrains_admitted, c.hot_swaps,
        );
        println!(
            "staleness {} | accuracy-loss integral {:.4} | edge busy {} | \
             drift-attributed {:.1} slot-s",
            human_secs(c.staleness_s),
            c.accuracy_loss,
            human_secs(c.edge_busy_s),
            c.drift_slot_s,
        );
    }
    if !report.scaling.is_empty() {
        let peak = report.scaling.iter().map(|e| e.capacity).max().unwrap_or(0);
        println!(
            "autoscaling: {} capacity change(s), peak {} slot(s):",
            report.scaling.len(),
            peak
        );
        for e in &report.scaling {
            println!("  vt {:>10.1}  {:<16} -> {} slot(s)", e.vt, e.endpoint, e.capacity);
        }
    }
    if !report.failed_users.is_empty() {
        println!(
            "users failed under the fault/spot plan (retries exhausted): {:?}",
            report.failed_users
        );
    }
}

/// Run the identical campaign under every scheduling policy and
/// compare turnaround tails and fairness — the policy-comparison sweep
/// (EXPERIMENTS.md §Scheduling). With `--prices`, a `$ prov` column
/// dollarizes each policy's provisioned capacity (DESIGN.md §11).
fn campaign_policy_sweep(
    scenario: &Scenario,
    mean: f64,
    prices: Option<&PriceBook>,
    mk_cfg: &dyn Fn(&Scenario, f64, PolicyKind) -> CampaignConfig,
) -> Result<()> {
    println!(
        "\nPolicy comparison — {} / {}, mean inter-arrival {}\n",
        scenario.model,
        scenario.mode.label(),
        human_secs(mean)
    );
    print!(
        "{:>10} {:>10} {:>10} {:>10} {:>11} {:>10} {:>8} {:>11} {:>7}",
        "policy", "p50 (s)", "p95 (s)", "max (s)", "mean slow", "max slow", "jain",
        "slot-h prov", "failed"
    );
    if prices.is_some() {
        print!(" {:>11}", "$ prov");
    }
    println!();
    for kind in [
        PolicyKind::Fifo,
        PolicyKind::Sjf,
        PolicyKind::Backfill,
        PolicyKind::Priority {
            aging_s: xloop::faas::sched::DEFAULT_AGING_S,
        },
    ] {
        let report = run_campaign(&mk_cfg(scenario, mean, kind))?;
        let f = &report.fairness;
        print!(
            "{:>10} {:>10.1} {:>10.1} {:>10.1} {:>11.3} {:>10.3} {:>8.4} {:>11.3} {:>7}",
            kind.label(),
            report.turnaround_percentile(50.0),
            report.turnaround_percentile(95.0),
            report.max_turnaround_s(),
            f.mean_slowdown,
            f.max_slowdown,
            f.jain,
            report.cost.total_provisioned_slot_s() / 3600.0,
            report.failed_users.len(),
        );
        if let Some(book) = prices {
            print!(" {:>11.2}", report.cost.dollars(book).provisioned_usd());
        }
        println!();
    }
    println!(
        "\n(identical arrivals/fabric per row; slowdown = turnaround over\n\
         its queue-wait-free counterfactual, Jain index 1.0 = every user\n\
         slowed equally; slot-h prov = total capacity the fabric had to\n\
         keep powered over the campaign — the dollars-proxy a policy's\n\
         makespan drives)"
    );
    Ok(())
}

/// Parse a `--loads` sweep spec: comma-joined mean inter-arrival
/// seconds (shared by the load and cost sweeps).
fn parse_loads(spec: &str) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(tok.parse().map_err(|_| {
            anyhow::anyhow!("bad load `{tok}` (mean inter-arrival seconds)")
        })?);
    }
    Ok(out)
}

/// Sweep arrival load and price the remote-vs-local choice in dollars
/// AND turnaround (DESIGN.md §11, EXPERIMENTS.md §Cost) — the paper's
/// crossover analysis with real units on both axes: the remote DCAI
/// turns a retraining around ~30x faster, but its premium slot rate
/// plus WAN egress means the facility pays for that speed. The table
/// shows at which load each side of the tradeoff wins.
fn campaign_cost_sweep(
    loads: &str,
    users: usize,
    scenario: &Scenario,
    policy: PolicyKind,
    book: &PriceBook,
    mk_cfg: &dyn Fn(&Scenario, f64, PolicyKind) -> CampaignConfig,
) -> Result<()> {
    let local_scenario = Scenario::table1(&scenario.model, Mode::LocalV100)?;
    println!(
        "\nCost sweep — {} users, {} remote ({}) vs local V100, in $ and turnaround\n",
        users,
        scenario.model,
        scenario.mode.label()
    );
    println!(
        "{:>16} {:>12} {:>10} {:>12} {:>10} {:>9} {:>9}",
        "interarrival (s)", "remote p50", "remote $", "local p50", "local $", "$ winner",
        "t winner"
    );
    for mean in parse_loads(loads)? {
        let remote = run_campaign(&mk_cfg(scenario, mean, policy))?;
        // the local V100 never crosses the WAN: its side of the
        // comparison runs broker-less even under --sites
        let mut local_cfg = mk_cfg(&local_scenario, mean, policy);
        local_cfg.sites.clear();
        let local = run_campaign(&local_cfg)?;
        let remote_usd = remote.cost.dollars(book).total_usd();
        let local_usd = local.cost.dollars(book).total_usd();
        let (rp50, lp50) = (
            remote.turnaround_percentile(50.0),
            local.turnaround_percentile(50.0),
        );
        println!(
            "{:>16.1} {:>12.1} {:>10.2} {:>12.1} {:>10.2} {:>9} {:>9}",
            mean,
            rp50,
            remote_usd,
            lp50,
            local_usd,
            if remote_usd <= local_usd { "remote" } else { "local" },
            if rp50 <= lp50 { "remote" } else { "local" },
        );
    }
    println!(
        "\n(p50 of arrival-to-deployed turnaround in virtual seconds; $ = fabric\n\
         total — every provisioned slot-dollar over the campaign window plus WAN\n\
         egress. The remote side buys ~30x turnaround with premium slot rates\n\
         and egress; the local side pays cheap slot-hours over a much longer\n\
         makespan. Prices per --prices; see DESIGN.md \u{a7}11.)"
    );

    // the spot axis (DESIGN.md §12): with --spot set, re-run the remote
    // side against an on-demand clone of the same fabric — discounted
    // spot slot-hours plus migration egress and checkpoint-replay
    // latency vs full-price uninterrupted capacity
    let probe = mk_cfg(scenario, 60.0, policy);
    if !probe.spot.is_empty() {
        println!(
            "\nSpot axis — preemptible capacity (checkpoint + failover) vs on-demand\n"
        );
        println!(
            "{:>16} {:>10} {:>12} {:>12} {:>14} {:>9} {:>9}",
            "interarrival (s)", "spot $", "spot p95", "on-demand $", "on-demand p95", "$ winner",
            "t winner"
        );
        for mean in parse_loads(loads)? {
            let spot_rep = run_campaign(&mk_cfg(scenario, mean, policy))?;
            let mut od_cfg = mk_cfg(scenario, mean, policy);
            od_cfg.spot.clear();
            od_cfg.checkpoint_every_s = None;
            let od_rep = run_campaign(&od_cfg)?;
            let spot_usd = spot_rep.cost.dollars(book).total_usd();
            let od_usd = od_rep.cost.dollars(book).total_usd();
            let (sp95, op95) = (
                spot_rep.turnaround_percentile(95.0),
                od_rep.turnaround_percentile(95.0),
            );
            println!(
                "{:>16.1} {:>10.2} {:>12.1} {:>12.2} {:>14.1} {:>9} {:>9}",
                mean,
                spot_usd,
                sp95,
                od_usd,
                op95,
                if spot_usd <= od_usd { "spot" } else { "on-dem" },
                if sp95 <= op95 { "spot" } else { "on-dem" },
            );
        }
        println!(
            "\n(same arrivals/fabric per row; the spot side bills discounted\n\
             `class:spot` slot rates but pays preemption tax — checkpoint replay,\n\
             migration egress, grace-window drain — in its turnaround tail.\n\
             See DESIGN.md \u{a7}12.)"
        );
    }

    // the federation axis (DESIGN.md §15): with --sites set, sweep an
    // egress-price asymmetry — scaling the extra sites' $/GB while the
    // home site keeps list price — under dollars placement, and watch
    // the broker shift traffic (and the bill) between sites
    if !probe.sites.is_empty() {
        let mean = parse_loads(loads)?.last().copied().unwrap_or(60.0);
        println!(
            "\nFederation axis — egress-price asymmetry under dollars placement \
             (mean inter-arrival {mean:.1} s)\n"
        );
        println!(
            "{:>14} {:>12} {}",
            "egress scale", "fabric $", "placed per site"
        );
        for scale in [0.0, 0.5, 1.0, 2.0, 4.0] {
            let mut cfg = mk_cfg(scenario, mean, policy).with_placement(Placement::Dollars);
            for site in &mut cfg.sites {
                let egress = site.book.egress_per_gb * scale;
                site.book = site.book.clone().with_egress(egress);
            }
            let rep = run_campaign(&cfg)?;
            let fed = rep
                .federation
                .as_ref()
                .expect("--sites implies a federation block");
            let placed: Vec<String> = fed
                .sites
                .iter()
                .map(|s| format!("{} {}", s.name, s.placed))
                .collect();
            println!(
                "{:>14.2} {:>12.2} {}",
                scale,
                rep.cost.dollars(book).total_usd(),
                placed.join(" | ")
            );
        }
        println!(
            "\n(same arrivals/fabric per row; only the extra sites' egress $/GB\n\
             scales — cheap egress pulls dollars-placement off the home site,\n\
             pricey egress pushes it back. See DESIGN.md \u{a7}15.)"
        );
    }
    Ok(())
}

/// Sweep arrival load and compare the chosen remote mode against the
/// local V100 — the loaded-facility extension of Table 1/Fig. 4: at what
/// load does queue wait erase the remote DCAI's raw-speed advantage?
fn campaign_load_sweep(
    loads: &str,
    users: usize,
    scenario: &Scenario,
    policy: PolicyKind,
    mk_cfg: &dyn Fn(&Scenario, f64, PolicyKind) -> CampaignConfig,
) -> Result<()> {
    let local_scenario = Scenario::table1(&scenario.model, Mode::LocalV100)?;
    println!(
        "\nCampaign load sweep — {} users, {} remote ({}) vs local V100\n",
        users,
        scenario.model,
        scenario.mode.label()
    );
    println!(
        "{:>16} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "interarrival (s)", "remote p50", "remote p95", "local p50", "local p95", "winner"
    );
    for mean in parse_loads(loads)? {
        let remote = run_campaign(&mk_cfg(scenario, mean, policy))?;
        // broker-less local side, as in the cost sweep
        let mut local_cfg = mk_cfg(&local_scenario, mean, policy);
        local_cfg.sites.clear();
        let local = run_campaign(&local_cfg)?;
        let (rp50, rp95) = (
            remote.turnaround_percentile(50.0),
            remote.turnaround_percentile(95.0),
        );
        let (lp50, lp95) = (
            local.turnaround_percentile(50.0),
            local.turnaround_percentile(95.0),
        );
        println!(
            "{:>16.1} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>8}",
            mean,
            rp50,
            rp95,
            lp50,
            lp95,
            if rp50 <= lp50 { "remote" } else { "local" }
        );
    }
    println!(
        "\n(p50/p95 of arrival-to-deployed turnaround, virtual seconds; queue wait\n\
         on the capacity-1 DCAI endpoints plus shared-WAN slowdown vs the local\n\
         V100's slow-but-private training)"
    );
    Ok(())
}

fn cmd_fig3(args: &[String]) -> Result<()> {
    let opts = Options::new()
        .opt("gb", "25", "payload size in GB")
        .opt("files", "32", "number of files")
        .opt("seed", "7", "fabric seed");
    if args.iter().any(|a| a == "--help") {
        print!("{}", opts.usage("xloop fig3"));
        return Ok(());
    }
    let p = opts.parse(args).map_err(anyhow::Error::msg)?;
    let bytes = (p.get_f64("gb")? * 1e9) as u64;
    let files = p.get_usize("files")?;
    let seed = p.get_usize("seed")? as u64;

    println!(
        "Fig. 3 — Globus-style transfer throughput, {} in {files} files\n",
        human_bytes(bytes as f64)
    );
    println!("{:>12} {:>18} {:>18}", "concurrency", "SLAC->ALCF (GB/s)", "ALCF->SLAC (GB/s)");
    for k in [1usize, 2, 4, 8, 16, 32] {
        if k > files {
            break;
        }
        let mut fwd_svc = TransferService::paper(seed);
        let mut clock = VClock::new();
        let mut req = TransferRequest::split_even(
            "fig3-fwd",
            "slac#dtn".into(),
            "alcf#dtn".into(),
            bytes,
            files,
        );
        req.concurrency = Some(k);
        let fwd = fwd_svc.execute(&mut clock, &req)?;

        let mut back_svc = TransferService::paper(seed + 1);
        let mut clock = VClock::new();
        let mut req = TransferRequest::split_even(
            "fig3-back",
            "alcf#dtn".into(),
            "slac#dtn".into(),
            bytes,
            files,
        );
        req.concurrency = Some(k);
        let back = back_svc.execute(&mut clock, &req)?;
        println!(
            "{k:>12} {:>18.3} {:>18.3}",
            fwd.throughput_bps() / 1e9,
            back.throughput_bps() / 1e9
        );
    }
    println!("\npaper reference: >1 GB/s with concurrent files over one 10 Gbps DTN NIC");
    Ok(())
}

fn cmd_fig4(args: &[String]) -> Result<()> {
    let opts = Options::new();
    if args.iter().any(|a| a == "--help") {
        print!("{}", opts.usage("xloop fig4"));
        return Ok(());
    }
    let params = CostParams::paper();
    println!("Fig. 4 — conventional vs ML-surrogate total processing time\n");
    println!(
        "{:>12} {:>18} {:>18} {:>8}",
        "N peaks", "conventional (s)", "ML surrogate (s)", "winner"
    );
    let mut n = 1e3;
    while n <= 1e9 {
        let fc = params.f_conventional_us(n) / 1e6;
        let fml = params.f_ml_us(n) / 1e6;
        println!(
            "{:>12.0e} {:>18.2} {:>18.2} {:>8}",
            n,
            fc,
            fml,
            if fc <= fml { "conv" } else { "ML" }
        );
        n *= 10.0;
    }
    let cross = params.crossover()?;
    println!(
        "\ncrossover at N* = {:.2e} peaks (paper Fig. 4: conventional wins only for small N)",
        cross.n_star
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let opts = Options::new()
        .opt("model", "braggnn", "model to serve")
        .opt("real-steps", "40", "real PJRT training steps before deploy")
        .opt("batches", "20", "inference batches to stream")
        .opt("seed", "42", "fabric seed");
    if args.iter().any(|a| a == "--help") {
        print!("{}", opts.usage("xloop serve"));
        return Ok(());
    }
    let p = opts.parse(args).map_err(anyhow::Error::msg)?;

    let mut scenario = Scenario::table1(p.get("model"), Mode::RemoteCerebras)?;
    scenario.seed = p.get_usize("seed")? as u64;
    let mut c = Coordinator::paper(scenario.seed)?;
    c.set_training_mode(TrainingMode::Real {
        steps_override: Some(p.get_usize("real-steps")? as u64),
    });
    let outcome = c.run_retraining(&scenario, None)?;
    println!(
        "retrained {} in {} (virtual), loss {:?}",
        scenario.model,
        human_secs(outcome.breakdown.end_to_end_s),
        outcome.breakdown.final_loss
    );

    let dataset = c.world.dataset(&format!("{}-train", scenario.model))?.clone();
    let rep = c.world.edge.serve_stream(&dataset, p.get_usize("batches")? as u64)?;
    println!(
        "edge serving: {} samples in {} batches | real mean {} p99 {} | {} samples/s | modeled edge time {}",
        rep.samples,
        rep.batches,
        human_secs(rep.real_mean_s),
        human_secs(rep.real_p99_s),
        rep.real_throughput as u64,
        human_secs(rep.virtual_total_s),
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = xloop::models::default_artifacts_dir();
    println!("artifacts dir: {dir:?}");
    if !dir.join("manifest.json").exists() {
        println!("artifacts NOT built — run `make artifacts`");
        return Ok(());
    }
    let registry = xloop::models::ModelRegistry::load(&dir)?;
    let rt = xloop::runtime::Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    for name in registry.names() {
        let m = registry.get(name)?;
        println!(
            "  {name}: {} params, train batch {}, {:.2} GFLOP/step, sample {} B",
            m.param_count,
            m.train_batch,
            m.train_flops_per_step / 1e9,
            m.sample_bytes
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::parse_count;

    #[test]
    fn counts_parse_plain_and_scientific() {
        assert_eq!(parse_count("8").unwrap(), 8);
        assert_eq!(parse_count(" 20000 ").unwrap(), 20000);
        assert_eq!(parse_count("1e6").unwrap(), 1_000_000);
        assert_eq!(parse_count("2.5e3").unwrap(), 2500);
        assert_eq!(parse_count("0").unwrap(), 0);
        assert!(parse_count("1.5").is_err());
        assert!(parse_count("-3").is_err());
        assert!(parse_count("1e13").is_err());
        assert!(parse_count("lots").is_err());
    }
}
