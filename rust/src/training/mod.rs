//! Training driver: every optimizer step is a real PJRT execution of the
//! AOT train-step artifact (L2+L1), driven from rust. Virtual-time
//! accounting for the paper's DCAI devices happens in the workflow layer
//! via `accel` models; this module measures *real* compute and produces
//! *real* loss curves.

pub mod state;
pub mod trainer;

pub use state::TrainState;
pub use trainer::{Recipe, TrainReport, Trainer};
