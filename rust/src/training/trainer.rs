//! The training loop: dataset batches in, PJRT train-step executions out.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::state::TrainState;
use crate::data::{BatchIter, Dataset};
use crate::models::ModelMeta;
use crate::runtime::{Executable, Runtime, Tensor};

/// The full training recipe for a model — the step count the paper-scale
/// devices are modeled over, and the (smaller) number of *real* PJRT
/// steps the end-to-end examples execute.
#[derive(Debug, Clone, Copy)]
pub struct Recipe {
    /// optimizer steps of the full production training run
    pub full_steps: u64,
    /// real steps the e2e driver executes on this CPU
    pub real_steps: u64,
}

impl Recipe {
    /// Standard recipes backing the Table 1 calibration
    /// (`accel::devices`): BraggNN 76k steps, CookieNetAE 25k steps.
    pub fn standard(model: &str) -> Result<Recipe> {
        Ok(match model {
            "braggnn" => Recipe {
                full_steps: 76_000,
                real_steps: 200,
            },
            "cookienetae" => Recipe {
                full_steps: 25_000,
                real_steps: 12,
            },
            other => bail!("no standard recipe for `{other}`"),
        })
    }
}

/// Outcome of a (real) training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub model: String,
    pub steps: u64,
    /// (step, loss) samples
    pub losses: Vec<(u64, f32)>,
    pub first_loss: f32,
    pub final_loss: f32,
    /// wallclock of the whole loop
    pub real_secs: f64,
    /// wallclock spent inside PJRT execute
    pub exec_secs: f64,
}

/// Drives the AOT train-step executable.
pub struct Trainer {
    exe: Arc<Executable>,
    meta: ModelMeta,
}

impl Trainer {
    pub fn new(rt: &Runtime, meta: &ModelMeta) -> Result<Trainer> {
        let exe = rt.load_hlo(&meta.train_hlo_path())?;
        Ok(Trainer {
            exe,
            meta: meta.clone(),
        })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// One optimizer step on a prepared batch. Returns the loss.
    pub fn step(&self, state: &mut TrainState, x: &Tensor, y: &Tensor) -> Result<f32> {
        let want_x: Vec<usize> = std::iter::once(self.meta.train_batch)
            .chain(self.meta.input_shape.iter().copied())
            .collect();
        if x.shape() != want_x.as_slice() {
            bail!("batch x shape {:?} != {:?}", x.shape(), want_x);
        }
        let n = state.params.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(3 * n + 3);
        for t in state.params.iter().chain(&state.m).chain(&state.v) {
            args.push(t.to_literal()?);
        }
        args.push(Tensor::scalar(state.step).to_literal()?);
        args.push(x.to_literal()?);
        args.push(y.to_literal()?);
        let outputs = self.exe.run_literals(&args)?;
        state.absorb_outputs(outputs)
    }

    /// Run `steps` optimizer steps over the dataset (shuffled batches).
    pub fn train(
        &self,
        state: &mut TrainState,
        dataset: &Dataset,
        steps: u64,
        seed: u64,
        log_every: u64,
    ) -> Result<TrainReport> {
        if dataset.input_shape != self.meta.input_shape {
            bail!(
                "dataset input {:?} != model input {:?}",
                dataset.input_shape,
                self.meta.input_shape
            );
        }
        let started = Instant::now();
        let mut exec_secs = 0.0;
        let mut iter = BatchIter::new(dataset.n, self.meta.train_batch, seed);
        let mut losses = Vec::new();
        let mut first_loss = f32::NAN;
        let mut last_loss = f32::NAN;
        for s in 0..steps {
            let idx = iter.next_batch();
            let (x, y) = dataset.gather_batch(&idx)?;
            let t0 = Instant::now();
            let loss = self.step(state, &x, &y)?;
            exec_secs += t0.elapsed().as_secs_f64();
            if !loss.is_finite() {
                bail!("loss diverged at step {s}: {loss}");
            }
            if s == 0 {
                first_loss = loss;
            }
            last_loss = loss;
            if log_every > 0 && (s % log_every == 0 || s + 1 == steps) {
                losses.push((s, loss));
                log::debug!("{} step {s}: loss {loss:.6}", self.meta.name);
            }
        }
        Ok(TrainReport {
            model: self.meta.name.clone(),
            steps,
            losses,
            first_loss,
            final_loss: last_loss,
            real_secs: started.elapsed().as_secs_f64(),
            exec_secs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::BraggConfig;
    use crate::models::default_artifacts_dir;

    #[test]
    fn braggnn_real_training_reduces_loss() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let meta = ModelMeta::load(&dir, "braggnn").unwrap();
        let rt = Runtime::cpu().unwrap();
        let trainer = Trainer::new(&rt, &meta).unwrap();
        let dataset = crate::data::bragg::generate(&BraggConfig::default(), 512, 1).unwrap();
        let mut state = TrainState::init(&meta).unwrap();
        let report = trainer.train(&mut state, &dataset, 25, 7, 5).unwrap();
        assert_eq!(report.steps, 25);
        assert!(
            report.final_loss < report.first_loss * 0.8,
            "loss {} -> {}",
            report.first_loss,
            report.final_loss
        );
        assert!(report.exec_secs > 0.0 && report.exec_secs <= report.real_secs);
        assert!(state.step == 25.0);
    }

    #[test]
    fn rejects_wrong_batch_shape() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let meta = ModelMeta::load(&dir, "braggnn").unwrap();
        let rt = Runtime::cpu().unwrap();
        let trainer = Trainer::new(&rt, &meta).unwrap();
        let mut state = TrainState::init(&meta).unwrap();
        let x = Tensor::zeros(vec![3, 11, 11, 1]); // wrong batch
        let y = Tensor::zeros(vec![3, 2]);
        assert!(trainer.step(&mut state, &x, &y).is_err());
    }

    #[test]
    fn standard_recipes_exist_for_all_models() {
        assert!(Recipe::standard("braggnn").is_ok());
        assert!(Recipe::standard("cookienetae").is_ok());
        assert!(Recipe::standard("ghost").is_err());
    }
}
