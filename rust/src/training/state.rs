//! Mutable training state matching the flat train-step ABI.

use anyhow::{bail, Result};

use crate::models::ModelMeta;
use crate::runtime::Tensor;

/// Parameters + Adam moments + step counter.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: f32,
}

impl TrainState {
    /// Start from the artifact's He-init snapshot (same state pytest
    /// verified on the python side).
    pub fn init(meta: &ModelMeta) -> Result<TrainState> {
        let raw = meta.load_init_params()?;
        let params = meta
            .params
            .iter()
            .zip(raw)
            .map(|(spec, data)| Tensor::new(spec.shape.clone(), data))
            .collect::<Result<Vec<_>>>()?;
        let zeros: Vec<Tensor> = meta
            .params
            .iter()
            .map(|spec| Tensor::zeros(spec.shape.clone()))
            .collect();
        Ok(TrainState {
            m: zeros.clone(),
            v: zeros,
            params,
            step: 0.0,
        })
    }

    /// Build from externally supplied parameters (e.g. a deployed model).
    pub fn from_params(meta: &ModelMeta, params: Vec<Tensor>) -> Result<TrainState> {
        if params.len() != meta.params.len() {
            bail!(
                "expected {} parameter tensors, got {}",
                meta.params.len(),
                params.len()
            );
        }
        for (spec, t) in meta.params.iter().zip(&params) {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "param `{}`: shape {:?} != spec {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        let zeros: Vec<Tensor> = meta
            .params
            .iter()
            .map(|spec| Tensor::zeros(spec.shape.clone()))
            .collect();
        Ok(TrainState {
            m: zeros.clone(),
            v: zeros,
            params,
            step: 0.0,
        })
    }

    /// Update from the train-step outputs (params', m', v', step', loss).
    /// Returns the loss.
    pub fn absorb_outputs(&mut self, outputs: Vec<Tensor>) -> Result<f32> {
        let n = self.params.len();
        if outputs.len() != 3 * n + 2 {
            bail!("expected {} outputs, got {}", 3 * n + 2, outputs.len());
        }
        let mut it = outputs.into_iter();
        for i in 0..n {
            self.params[i] = it.next().unwrap();
        }
        for i in 0..n {
            self.m[i] = it.next().unwrap();
        }
        for i in 0..n {
            self.v[i] = it.next().unwrap();
        }
        self.step = it.next().unwrap().item()?;
        it.next().unwrap().item()
    }

    /// Total parameter elements (sanity checks, reports).
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|t| t.elems()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::default_artifacts_dir;
    use crate::models::ModelMeta;

    #[test]
    fn init_from_artifacts() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let meta = ModelMeta::load(&dir, "braggnn").unwrap();
        let state = TrainState::init(&meta).unwrap();
        assert_eq!(state.param_count(), meta.param_count);
        assert_eq!(state.step, 0.0);
        // moments start at zero
        assert!(state.m.iter().all(|t| t.data().iter().all(|&v| v == 0.0)));
        // weights are He-init, not all zero
        assert!(state.params[0].data().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn from_params_validates_shapes() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let meta = ModelMeta::load(&dir, "braggnn").unwrap();
        let good = TrainState::init(&meta).unwrap().params;
        assert!(TrainState::from_params(&meta, good.clone()).is_ok());
        let mut bad = good;
        bad[0] = Tensor::zeros(vec![1, 2, 3]);
        assert!(TrainState::from_params(&meta, bad).is_err());
    }
}
