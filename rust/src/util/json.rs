//! Minimal JSON parser/serializer.
//!
//! The offline crate cache has `serde_core`/`serde_derive` but not the
//! `serde` facade, so derived (De)Serialize cannot compile; this module is
//! the repo's JSON layer instead. It covers the full JSON grammar
//! (RFC 8259) minus exotic number forms beyond f64, which is all the
//! artifact metadata, flow definitions, and config files need.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable digests in tests and event logs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ----------------------------------------------------------- accessors

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for anything that is not there.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup with the same null-propagation convention.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // --------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // ------------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `}`"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `]`"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences byte-wise
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number `{s}`")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ------------------------------------------------------------ serializing

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert!(v.get("a").at(2).get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" é 😀"));
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "\"\\x\"", "{1:2}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn real_meta_file_shape() {
        let text = r#"{
            "name": "braggnn",
            "param_count": 36922,
            "params": [{"name": "conv1_w", "shape": [3,3,1,64], "init": "init/braggnn_p0.bin"}],
            "train": {"file": "braggnn_train.hlo.txt", "n_args": 45, "n_outputs": 44}
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("param_count").as_usize(), Some(36922));
        assert_eq!(
            v.get("params").at(0).get("shape").at(3).as_usize(),
            Some(64)
        );
        assert_eq!(v.get("train").get("n_args").as_usize(), Some(45));
    }

    #[test]
    fn number_formatting_integers() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }
}
