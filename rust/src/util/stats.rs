//! Small statistics helpers shared by metrics, benches, and reports.

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }
}

/// Percentile over a sample set (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    let rank = ((p / 100.0) * n).ceil() as isize - 1;
    sorted[rank.clamp(0, sorted.len() as isize - 1) as usize]
}

/// Jain's fairness index over a set of allocations/slowdowns:
/// `(Σx)² / (n · Σx²)`, 1.0 = perfectly fair, → 1/n as one element
/// dominates. Empty input returns 1.0 (nothing to be unfair about).
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sq)
}

/// Integrate a right-continuous step function over `[t0, t1]`.
///
/// The function holds `initial` from `t0` until the first change, then
/// each `(vt, value)` change takes effect at its instant. Changes must
/// be in non-decreasing `vt` order; changes outside `[t0, t1]` are
/// handled (before `t0`: the latest one replaces `initial`; after
/// `t1`: ignored). Used by the campaign cost accounting to turn an
/// autoscaler's `ScalingEvent` log into provisioned slot-seconds.
pub fn integrate_step(t0: f64, t1: f64, initial: f64, changes: &[(f64, f64)]) -> f64 {
    if t1 <= t0 {
        return 0.0;
    }
    let mut acc = 0.0;
    let mut cur_t = t0;
    let mut cur_v = initial;
    for &(vt, value) in changes {
        if vt <= t0 {
            cur_v = value;
            continue;
        }
        if vt >= t1 {
            break;
        }
        acc += cur_v * (vt - cur_t);
        cur_t = vt;
        cur_v = value;
    }
    acc + cur_v * (t1 - cur_t)
}

/// Human-readable byte count.
pub fn human_bytes(bytes: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{:.0} {}", v, UNITS[u])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Human-readable duration in seconds.
pub fn human_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else if s < 7200.0 {
        format!("{:.1} min", s / 60.0)
    } else {
        format!("{:.2} h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn jain_bounds_and_extremes() {
        // perfectly fair
        assert!((jain_index(&[2.0, 2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
        // one user hogging: index -> 1/n
        let skew = jain_index(&[10.0, 0.0, 0.0, 0.0]);
        assert!((skew - 0.25).abs() < 1e-12, "{skew}");
        // strictly between for mild skew
        let mild = jain_index(&[1.0, 2.0]);
        assert!(mild > 0.25 && mild < 1.0, "{mild}");
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn integrate_step_segments() {
        // constant over the window
        assert_eq!(integrate_step(0.0, 10.0, 2.0, &[]), 20.0);
        // one mid-window step: 2×4 + 5×6
        assert_eq!(integrate_step(0.0, 10.0, 2.0, &[(4.0, 5.0)]), 38.0);
        // change before the window replaces the initial value
        assert_eq!(integrate_step(10.0, 20.0, 1.0, &[(5.0, 3.0)]), 30.0);
        // change after the window is ignored
        assert_eq!(integrate_step(0.0, 10.0, 2.0, &[(15.0, 9.0)]), 20.0);
        // autoscale trace: up at 5 (cap 2), down at 8 (cap 1) over [0, 10]
        let trace = [(5.0, 2.0), (8.0, 1.0)];
        assert_eq!(integrate_step(0.0, 10.0, 1.0, &trace), 5.0 + 6.0 + 2.0);
        // empty/inverted window
        assert_eq!(integrate_step(3.0, 3.0, 7.0, &[]), 0.0);
        assert_eq!(integrate_step(5.0, 3.0, 7.0, &[]), 0.0);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(1536.0), "1.50 KiB");
        assert_eq!(human_secs(0.5), "500.0 ms");
        assert_eq!(human_secs(90.0), "90.00 s");
        assert_eq!(human_secs(300.0), "5.0 min");
    }
}
