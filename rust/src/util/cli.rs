//! Tiny CLI argument parser (no `clap` in the offline cache).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--switch` shapes the `xloop` binary and examples need, with generated
//! usage text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Declarative option set for one (sub)command.
#[derive(Debug, Default)]
pub struct Options {
    specs: Vec<ArgSpec>,
}

impl Options {
    pub fn new() -> Self {
        Options { specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self, cmd: &str) -> String {
        let mut out = format!("usage: {cmd} [options]\n");
        for s in &self.specs {
            let value = if s.is_flag { "" } else { " <value>" };
            let def = match s.default {
                Some(d) if !s.is_flag => format!(" (default: {d})"),
                _ => String::new(),
            };
            out.push_str(&format!("  --{}{}\t{}{}\n", s.name, value, s.help, def));
        }
        out
    }

    /// Parse an argv slice (without the program/subcommand names).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            let name = arg
                .strip_prefix("--")
                .ok_or_else(|| format!("unexpected positional argument `{arg}`"))?;
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            let spec = self
                .specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| format!("unknown option `--{name}`"))?;
            let value = if spec.is_flag {
                if inline.is_some() {
                    return Err(format!("flag `--{name}` takes no value"));
                }
                "true".to_string()
            } else if let Some(v) = inline {
                v
            } else {
                i += 1;
                args.get(i)
                    .cloned()
                    .ok_or_else(|| format!("option `--{name}` needs a value"))?
            };
            values.insert(name.to_string(), value);
            i += 1;
        }
        // defaults + required check
        for s in &self.specs {
            if !values.contains_key(s.name) {
                if let Some(d) = s.default {
                    values.insert(s.name.to_string(), d.to_string());
                } else if !s.is_flag {
                    return Err(format!("missing required option `--{}`", s.name));
                }
            }
        }
        Ok(Parsed { values })
    }
}

#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .unwrap_or_default()
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        self.get(name).parse().map_err(|_| {
            anyhow::anyhow!(
                "option `--{name}` expects an integer, got `{}`",
                self.get(name)
            )
        })
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        self.get(name).parse().map_err(|_| {
            anyhow::anyhow!(
                "option `--{name}` expects a number, got `{}`",
                self.get(name)
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> Options {
        Options::new()
            .opt("model", "braggnn", "model name")
            .req("mode", "execution mode")
            .flag("verbose", "chatty output")
            .opt("steps", "100", "train steps")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let p = opts()
            .parse(&argv(&["--mode=remote", "--steps", "25", "--verbose"]))
            .unwrap();
        assert_eq!(p.get("model"), "braggnn"); // default
        assert_eq!(p.get("mode"), "remote");
        assert_eq!(p.get_usize("steps").unwrap(), 25);
        assert!(p.get_bool("verbose"));
    }

    #[test]
    fn missing_required_fails() {
        let err = opts().parse(&argv(&["--steps", "1"])).unwrap_err();
        assert!(err.contains("--mode"), "{err}");
    }

    #[test]
    fn unknown_option_fails() {
        let err = opts()
            .parse(&argv(&["--mode", "x", "--nope", "1"]))
            .unwrap_err();
        assert!(err.contains("--nope"), "{err}");
    }

    #[test]
    fn flag_with_value_fails() {
        let err = opts()
            .parse(&argv(&["--mode", "x", "--verbose=yes"]))
            .unwrap_err();
        assert!(err.contains("verbose"), "{err}");
    }

    #[test]
    fn bad_number_reported() {
        let p = opts().parse(&argv(&["--mode", "x", "--steps", "ten"])).unwrap();
        assert!(p.get_usize("steps").is_err());
    }

    #[test]
    fn usage_mentions_everything() {
        let u = opts().usage("xloop run");
        for needle in ["--model", "--mode", "--verbose", "--steps", "default: 100"] {
            assert!(u.contains(needle), "{u}");
        }
    }
}
