//! stderr logger for the `log` facade (no env_logger offline).
//!
//! Level comes from `XLOOP_LOG` (error|warn|info|debug|trace), default
//! `info`. Install once with `logging::init()`; repeated calls are no-ops.

use std::io::Write;
use std::sync::Once;

struct StderrLogger {
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:<5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("XLOOP_LOG").as_deref() {
            Ok("error") => log::LevelFilter::Error,
            Ok("warn") => log::LevelFilter::Warn,
            Ok("debug") => log::LevelFilter::Debug,
            Ok("trace") => log::LevelFilter::Trace,
            Ok("off") => log::LevelFilter::Off,
            _ => log::LevelFilter::Info,
        };
        let logger = Box::new(StderrLogger { level });
        if log::set_boxed_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
