//! Self-contained utility layer standing in for crates absent from the
//! offline cache (serde_json, clap, rand, env_logger). See DESIGN.md.

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
