//! Deterministic PRNG + distributions (no `rand` crate offline).
//!
//! xoshiro256** core — fast, well-tested statistically, trivially seedable
//! — plus the distributions the data generators and fault injectors need:
//! uniform, normal (Ziggurat-free Box–Muller), Poisson (Knuth for small
//! lambda, PTRS-style normal approximation above), and exponential.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
            spare_normal: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded generation.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Poisson sample; exact (Knuth) below lambda=30, Gaussian approx above.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal();
            (lambda + z * lambda.sqrt()).round().max(0.0) as u64
        }
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(f64::MIN_POSITIVE).ln() / rate
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-component determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 20_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 700, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(4);
        for lambda in [0.5, 5.0, 80.0] {
            let n = 20_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += r.poisson(lambda) as f64;
            }
            let mean = sum / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork();
        let mut b = r.fork();
        let same = (0..50).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
