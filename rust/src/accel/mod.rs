//! Accelerator performance models for the DCAI systems of Table 1.
//!
//! We cannot run a Cerebras CS-2, a SambaNova RDU, or V100s here, so the
//! training durations the paper measured are *modeled*:
//!
//! ```text
//! per_step  = overhead + dp * flops_per_step / (peak * efficiency) + allreduce
//! steps_dp  = ceil(steps / dp)        (data parallelism keeps the epoch
//!                                      count: dp-times bigger batches,
//!                                      dp-times fewer steps)
//! T_train   = setup + steps_dp * per_step
//! ```
//!
//! with `allreduce` a ring model over the gradient tensors. Constants are
//! calibrated once against Table 1 (see `calibration` tests, and
//! EXPERIMENTS.md for paper-vs-model deltas):
//!
//! * V100:      15.7 TFLOP/s peak, 15 % achieved on these small models,
//!              14 ms/step framework overhead (BraggNN/CookieNetAE are
//!              latency-bound on GPUs — §5.3 says exactly this).
//! * Cerebras:  wafer-scale dataflow; compute is negligible for sub-1M
//!              parameter models, 0.22 ms/step pipeline overhead.
//! * SambaNova: 1 RDU, 300 TFLOP/s class, 1.75 ms/step overhead.
//! * 8x V100 + Horovod: V100 constants, dp=8, ring allreduce whose cost
//!              is latency-dominated for small gradient tensors (the
//!              paper's argument for why BraggNN multi-GPU is not worth
//!              it).
//!
//! The *numerics* of training always come from real PJRT executions; only
//! the virtual-time accounting flows through these models (DESIGN.md §7).

pub mod devices;
pub mod model;

pub use devices::{cerebras_wse, local_v100, multi_gpu_horovod, sambanova_rdu};
pub use model::{AcceleratorModel, AllreduceModel, TrainTime};
