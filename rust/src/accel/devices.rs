//! Concrete device configurations for the paper's Table 1 modes.
//!
//! Calibration targets (paper Table 1, end-to-end *training* column):
//!
//! | device               | BraggNN | CookieNetAE |
//! |----------------------|---------|-------------|
//! | local 1x V100        | 1102 s  | 517 s       |
//! | Cerebras (wafer)     | 19 s    | 6 s         |
//! | SambaNova (1 RDU)    | 139 s   | —           |
//! | 8x V100 Horovod      | —       | 88 s        |
//!
//! With the standard recipes (BraggNN: 76k steps @ batch 128 —
//! 7.9e8 FLOP/step; CookieNetAE: 25k steps @ batch 4 — 1.55e10
//! FLOP/step; see `models::recipes`), the constants below land within a
//! few percent of every target; the `calibration` tests pin them.

use super::model::{AcceleratorModel, AllreduceModel};

/// Single NVIDIA V100, deployable inside the experiment facility —
/// Table 1's "Local (one GPU)" mode.
pub fn local_v100() -> AcceleratorModel {
    AcceleratorModel {
        name: "local-v100".into(),
        peak_flops: 15.7e12,
        efficiency: 0.15,
        // small-model training on GPUs is latency-bound (paper §5.3)
        per_step_overhead_s: 14.0e-3,
        data_parallel: 1,
        allreduce: None,
        setup_s: 8.0,
    }
}

/// Cerebras CS-class wafer-scale engine, "entire wafer ... via model
/// replica" (paper §5.3). Dataflow execution removes per-step host
/// overhead almost entirely; compute is negligible for these models.
pub fn cerebras_wse() -> AcceleratorModel {
    AcceleratorModel {
        name: "cerebras-wse".into(),
        peak_flops: 1.0e15,
        efficiency: 0.45,
        per_step_overhead_s: 0.23e-3,
        data_parallel: 1,
        allreduce: None,
        setup_s: 0.5,
    }
}

/// SambaNova SN10, one of eight RDUs per node (as in the paper).
pub fn sambanova_rdu() -> AcceleratorModel {
    AcceleratorModel {
        name: "sambanova-1rdu".into(),
        peak_flops: 300.0e12,
        efficiency: 0.20,
        per_step_overhead_s: 1.80e-3,
        data_parallel: 1,
        allreduce: None,
        setup_s: 2.0,
    }
}

/// `n`-GPU V100 server with Horovod ring allreduce (same epochs: batch
/// grows n-fold, steps shrink n-fold, every step pays gradient sync).
pub fn multi_gpu_horovod(n: u32) -> AcceleratorModel {
    let base = local_v100();
    AcceleratorModel {
        name: format!("horovod-{n}xV100"),
        data_parallel: n,
        allreduce: Some(AllreduceModel {
            // NCCL over PCIe/NVLink; small per-layer tensors make the
            // sync latency-dominated, the paper's stated reason BraggNN
            // does not profit from data parallelism.
            bw_bps: 5.0e9,
            latency_s: 0.2e-3,
        }),
        setup_s: 15.0, // horovodrun worker spin-up
        ..base
    }
}

#[cfg(test)]
mod calibration {
    //! Pin the modeled training times to the paper's Table 1 within 15 %.
    use super::*;

    // standard recipes (see models::recipes): FLOP/step, grad bytes, steps
    const BRAGG_FLOPS: f64 = 7.93e8;
    const BRAGG_BYTES: f64 = 4.0 * 36_922.0;
    const BRAGG_STEPS: u64 = 76_000;
    const COOKIE_FLOPS: f64 = 1.55e10;
    const COOKIE_BYTES: f64 = 4.0 * 314_401.0;
    const COOKIE_STEPS: u64 = 25_000;

    fn assert_within(actual: f64, target: f64, tol: f64, what: &str) {
        let rel = (actual - target).abs() / target;
        assert!(
            rel < tol,
            "{what}: modeled {actual:.1}s vs paper {target}s ({:.0}% off)",
            rel * 100.0
        );
    }

    #[test]
    fn v100_matches_table1() {
        let m = local_v100();
        assert_within(
            m.train_time(BRAGG_FLOPS, BRAGG_BYTES, BRAGG_STEPS).total_s,
            1102.0,
            0.15,
            "BraggNN local V100",
        );
        assert_within(
            m.train_time(COOKIE_FLOPS, COOKIE_BYTES, COOKIE_STEPS).total_s,
            517.0,
            0.15,
            "CookieNetAE local V100",
        );
    }

    #[test]
    fn cerebras_matches_table1() {
        let m = cerebras_wse();
        assert_within(
            m.train_time(BRAGG_FLOPS, BRAGG_BYTES, BRAGG_STEPS).total_s,
            19.0,
            0.15,
            "BraggNN Cerebras",
        );
        assert_within(
            m.train_time(COOKIE_FLOPS, COOKIE_BYTES, COOKIE_STEPS).total_s,
            6.0,
            0.30, // 6 s leaves little room; the paper rounds to integers
            "CookieNetAE Cerebras",
        );
    }

    #[test]
    fn sambanova_matches_table1() {
        let m = sambanova_rdu();
        assert_within(
            m.train_time(BRAGG_FLOPS, BRAGG_BYTES, BRAGG_STEPS).total_s,
            139.0,
            0.15,
            "BraggNN SambaNova 1-RDU",
        );
    }

    #[test]
    fn horovod8_matches_table1() {
        let m = multi_gpu_horovod(8);
        assert_within(
            m.train_time(COOKIE_FLOPS, COOKIE_BYTES, COOKIE_STEPS).total_s,
            88.0,
            0.15,
            "CookieNetAE 8-GPU Horovod",
        );
    }

    #[test]
    fn remote_beats_local_by_over_30x_end_to_end_margin() {
        // the headline claim: remote training >= 30x faster than local,
        // leaving room for ~12 s of transfer overhead (Table 1)
        let local = local_v100()
            .train_time(BRAGG_FLOPS, BRAGG_BYTES, BRAGG_STEPS)
            .total_s;
        let remote = cerebras_wse()
            .train_time(BRAGG_FLOPS, BRAGG_BYTES, BRAGG_STEPS)
            .total_s;
        assert!(local / (remote + 12.0) > 30.0, "{local} vs {remote}");
    }

    #[test]
    fn braggnn_pays_more_for_gradient_sync_than_cookienetae() {
        // §5.3: BraggNN is latency-bound — "the speedup of computing
        // gaining from using multiple GPUs is less than the necessary
        // cost on gradients synchronization". In model terms: the
        // allreduce inflates BraggNN's step time by a larger factor than
        // CookieNetAE's (whose steps carry 20x the FLOPs).
        let single = local_v100();
        let multi = multi_gpu_horovod(8);
        let bragg_inflation = multi.step_time(BRAGG_FLOPS, BRAGG_BYTES)
            / single.step_time(BRAGG_FLOPS, BRAGG_BYTES);
        let cookie_inflation = multi.step_time(COOKIE_FLOPS, COOKIE_BYTES)
            / single.step_time(COOKIE_FLOPS, COOKIE_BYTES);
        assert!(
            bragg_inflation > cookie_inflation,
            "bragg {bragg_inflation:.3}x vs cookie {cookie_inflation:.3}x"
        );
    }
}
