//! The generic accelerator time model (see module docs in `mod.rs`).

/// Ring-allreduce cost model for data-parallel gradient sync.
#[derive(Debug, Clone, Copy)]
pub struct AllreduceModel {
    /// per-link bandwidth (bytes/s)
    pub bw_bps: f64,
    /// per-hop latency (s); small-tensor syncs are latency-dominated
    pub latency_s: f64,
}

impl AllreduceModel {
    /// Ring allreduce over `n` workers of `bytes` of gradients.
    /// 2(n-1)/n * bytes volume per worker + 2(n-1) latency hops.
    pub fn cost(&self, n: u32, bytes: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let n = n as f64;
        2.0 * (n - 1.0) / n * bytes / self.bw_bps + 2.0 * (n - 1.0) * self.latency_s
    }
}

/// One accelerator configuration (a Table 1 row's "mode").
#[derive(Debug, Clone)]
pub struct AcceleratorModel {
    pub name: String,
    /// peak throughput in FLOP/s
    pub peak_flops: f64,
    /// achieved fraction of peak on sub-1M-param models
    pub efficiency: f64,
    /// fixed per-step cost (framework, launch, host sync)
    pub per_step_overhead_s: f64,
    /// data-parallel width (replicas); 1 = single device
    pub data_parallel: u32,
    pub allreduce: Option<AllreduceModel>,
    /// one-time job setup (data load, graph load, worker spin-up)
    pub setup_s: f64,
}

/// Breakdown of a modeled training run.
#[derive(Debug, Clone, Copy)]
pub struct TrainTime {
    pub setup_s: f64,
    pub steps_executed: u64,
    pub per_step_s: f64,
    pub total_s: f64,
    /// fraction of per-step time spent on actual FLOPs
    pub compute_fraction: f64,
}

impl AcceleratorModel {
    /// Time for one synchronized optimizer step. Each of the `dp`
    /// replicas runs its own base batch (`flops_per_step`), so wall-clock
    /// compute equals the single-device value; data parallelism pays off
    /// by cutting the step *count* (see `train_time`).
    pub fn step_time(&self, flops_per_step: f64, grad_bytes: f64) -> f64 {
        let compute = flops_per_step / (self.peak_flops * self.efficiency);
        let sync = self
            .allreduce
            .map(|a| a.cost(self.data_parallel, grad_bytes))
            .unwrap_or(0.0);
        self.per_step_overhead_s + compute + sync
    }

    /// Full training-run model for a recipe of `steps` base-batch steps.
    pub fn train_time(&self, flops_per_step: f64, grad_bytes: f64, steps: u64) -> TrainTime {
        let dp = self.data_parallel.max(1) as u64;
        let steps_executed = steps.div_ceil(dp);
        let compute = flops_per_step / (self.peak_flops * self.efficiency);
        let sync = self
            .allreduce
            .map(|a| a.cost(self.data_parallel, grad_bytes))
            .unwrap_or(0.0);
        let per_step_s = self.per_step_overhead_s + compute + sync;
        TrainTime {
            setup_s: self.setup_s,
            steps_executed,
            per_step_s,
            total_s: self.setup_s + steps_executed as f64 * per_step_s,
            compute_fraction: compute / per_step_s,
        }
    }

    /// Batched-inference latency model (the paper's E operation).
    pub fn infer_time(&self, flops_per_batch: f64) -> f64 {
        self.per_step_overhead_s / 4.0 // no optimizer/sync work
            + flops_per_batch / (self.peak_flops * self.efficiency)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> AcceleratorModel {
        AcceleratorModel {
            name: "toy".into(),
            peak_flops: 1e12,
            efficiency: 0.5,
            per_step_overhead_s: 1e-3,
            data_parallel: 1,
            allreduce: None,
            setup_s: 10.0,
        }
    }

    #[test]
    fn single_device_accounting() {
        let m = toy();
        // 5e8 flops / 5e11 eff-flops = 1 ms compute + 1 ms overhead
        let t = m.train_time(5e8, 0.0, 1000);
        assert_eq!(t.steps_executed, 1000);
        assert!((t.per_step_s - 2e-3).abs() < 1e-12);
        assert!((t.total_s - 12.0).abs() < 1e-9);
        assert!((t.compute_fraction - 0.5).abs() < 1e-9);
    }

    #[test]
    fn data_parallel_divides_steps_adds_sync() {
        let mut m = toy();
        m.data_parallel = 8;
        m.allreduce = Some(AllreduceModel {
            bw_bps: 1e9,
            latency_s: 5e-4,
        });
        let t = m.train_time(5e8, 1e6, 1000);
        assert_eq!(t.steps_executed, 125);
        // sync = 2*7/8*1e6/1e9 + 14*5e-4 = 1.75e-3 + 7e-3 = 8.75e-3
        let sync = 2.0 * 7.0 / 8.0 * 1e6 / 1e9 + 14.0 * 5e-4;
        assert!((t.per_step_s - (2e-3 + sync)).abs() < 1e-12);
    }

    #[test]
    fn allreduce_zero_for_single_worker() {
        let a = AllreduceModel {
            bw_bps: 1e9,
            latency_s: 1e-3,
        };
        assert_eq!(a.cost(1, 1e9), 0.0);
        assert!(a.cost(2, 1e6) > 0.0);
    }

    #[test]
    fn allreduce_monotone_in_workers_and_bytes() {
        let a = AllreduceModel {
            bw_bps: 1e9,
            latency_s: 1e-4,
        };
        let mut last = 0.0;
        for n in 2..16 {
            let c = a.cost(n, 1e6);
            assert!(c > last);
            last = c;
        }
        assert!(a.cost(4, 2e6) > a.cost(4, 1e6));
    }

    #[test]
    fn inference_is_cheaper_than_training_step() {
        let m = toy();
        assert!(m.infer_time(5e8) < m.train_time(5e8, 0.0, 1).per_step_s);
    }
}
