//! Multi-site federation and brokered placement (DESIGN.md §15).
//!
//! The paper's evaluation is a binary: train at the remote DCAI
//! facility (ALCF) or on the locally deployable GPU. The
//! federated-ptychography and remote-operations lines of work
//! generalize that choice to K candidate sites behind a broker. This
//! module promotes sites to first-class objects: a [`Site`] bundles a
//! name, the access-link shape that joins it to the shared backbone,
//! the accelerator classes it hosts, a per-site [`PriceBook`] (egress
//! asymmetry rides here), and a residency set for the data-locality
//! credit. The [`Broker`] scores every live site per arriving campaign
//! task-group — by **predicted turnaround** (staging time from the
//! transfer fabric's predictive model + gang queue wait from the
//! scheduling estimate machinery) or **predicted dollars** (slot
//! dollars for the exact train estimate + egress dollars for the
//! staged bytes) — and places deterministically: sites are scanned in
//! name order and only a strictly better score moves the choice, so
//! equal scores tie-break to the lexicographically smaller name and
//! the decision is a pure function of (config, seed), invariant to
//! `XLOOP_THREADS`.
//!
//! With no `--sites` the campaign never constructs a broker and the
//! paper's fixed SLAC→ALCF path runs byte-identically.

use std::collections::BTreeSet;

use anyhow::{bail, Context, Result};

use crate::costmodel::PriceBook;
use crate::faas::FuncId;
use crate::simnet::{FaultPlan, Topology, GBPS};
use crate::transfer::{EndpointId, TransferRequest};
use crate::util::Json;

use super::world::World;

/// Accelerator classes a federated site may host — the train-capable
/// subset of `costmodel::KNOWN_CLASSES` (an endpoint without an
/// accelerator model can never run `train_model`, so `sim`/`cluster`
/// are not placeable).
pub const PLACEABLE_CLASSES: &[&str] = &["cerebras", "gpu8", "sambanova", "v100"];

/// File split the broker assumes when predicting staging time — the
/// campaign flow's `FlowShape::default().files`.
const BROKER_STAGE_FILES: usize = 16;

/// One federated DCAI site: an access link onto the shared backbone,
/// a set of accelerator endpoints, prices, and resident model families.
#[derive(Debug, Clone, PartialEq)]
pub struct Site {
    pub name: String,
    /// accelerator classes hosted; endpoint ids are `{name}#{class}`
    pub classes: Vec<String>,
    /// access-link (DTN NIC) capacity in Gbit/s
    pub gbps: f64,
    /// access-link one-way latency in milliseconds
    pub latency_ms: f64,
    /// per-site prices — `egress_per_gb` is where `--sites` egress
    /// asymmetry lives; class rates default to the paper book
    pub book: PriceBook,
    /// model families already resident at the site (locality credit:
    /// predicted staging is waived in the broker score)
    pub resident: BTreeSet<String>,
}

impl Site {
    /// The implicit home site: the paper's ALCF, reachable over its
    /// existing 10 Gbps DTN NIC, hosting the accelerator classes
    /// `World::paper` registers there, priced by the paper book.
    pub fn home() -> Site {
        Site {
            name: "alcf".into(),
            classes: vec!["cerebras".into(), "sambanova".into(), "gpu8".into()],
            gbps: 10.0,
            latency_ms: 0.5,
            book: PriceBook::paper(),
            resident: BTreeSet::new(),
        }
    }

    /// The site's staging endpoint (`{name}#dtn`).
    pub fn dtn(&self) -> String {
        format!("{}#dtn", self.name)
    }

    /// The site's faas endpoint for a class (`{name}#{class}`).
    pub fn endpoint(&self, class: &str) -> String {
        format!("{}#{class}", self.name)
    }

    /// All faas endpoints the site hosts, in declared class order.
    pub fn endpoints(&self) -> Vec<String> {
        self.classes.iter().map(|c| self.endpoint(c)).collect()
    }

    pub fn hosts(&self, class: &str) -> bool {
        self.classes.iter().any(|c| c == class)
    }

    /// Wire the site's access link and routes into a topology: a new
    /// facility, a `{name}-dtn-nic` link, and routes to every facility
    /// that already owns a `-dtn-nic` via the shared `esnet-backbone`.
    pub fn extend_topology(&self, topo: &mut Topology) -> Result<()> {
        let fac = topo.add_facility(&self.name)?;
        let backbone = topo.link_by_name("esnet-backbone")?;
        let nic = topo.add_link(
            &format!("{}-dtn-nic", self.name),
            self.gbps * GBPS,
            self.latency_ms / 1e3,
        )?;
        let peers: Vec<String> = topo
            .facilities
            .iter()
            .map(|f| f.name.clone())
            .filter(|n| *n != self.name)
            .collect();
        for peer in peers {
            let Ok(peer_nic) = topo.link_by_name(&format!("{peer}-dtn-nic")) else {
                continue;
            };
            let peer_id = topo.facility(&peer)?;
            topo.add_route(fac, peer_id, vec![nic, backbone, peer_nic])?;
            topo.add_route(peer_id, fac, vec![peer_nic, backbone, nic])?;
        }
        Ok(())
    }
}

/// Parse a `--sites` spec: semicolon-joined
/// `name:class1+class2:gbps:latency_ms:egress_per_gb[:model1+model2]`
/// entries, e.g.
/// `nersc:gpu8+v100:10:12:0.02;ornl:cerebras:25:18:0.09:braggnn`.
/// The trailing optional field lists resident model families (locality
/// credit). Site names must be unique and must not shadow the paper
/// facilities (`slac`, `alcf`); classes must be placeable and unique
/// per site; link and price numbers must be finite and sensible.
pub fn parse_sites(spec: &str) -> Result<Vec<Site>> {
    let mut sites: Vec<Site> = Vec::new();
    for entry in spec.split(';') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let fields: Vec<&str> = entry.split(':').collect();
        if !(5..=6).contains(&fields.len()) {
            bail!(
                "bad site entry `{entry}` \
                 (want name:classes:gbps:latency_ms:egress_per_gb[:resident])"
            );
        }
        let name = fields[0].trim();
        if name.is_empty() {
            bail!("site with empty name in `{entry}`");
        }
        if name == "slac" || name == "alcf" {
            bail!("site name `{name}` is reserved (paper facility)");
        }
        if sites.iter().any(|s| s.name == name) {
            bail!("duplicate site name `{name}`");
        }
        let mut classes: Vec<String> = Vec::new();
        for class in fields[1].split('+') {
            let class = class.trim();
            if class.is_empty() {
                continue;
            }
            if !PLACEABLE_CLASSES.contains(&class) {
                bail!(
                    "unknown endpoint class `{class}` for site `{name}` (placeable: {})",
                    PLACEABLE_CLASSES.join(", ")
                );
            }
            if classes.iter().any(|c| c == class) {
                bail!("duplicate class `{class}` for site `{name}`");
            }
            classes.push(class.to_string());
        }
        if classes.is_empty() {
            bail!("site `{name}` has an empty endpoint class list");
        }
        let num = |field: &str, what: &str| -> Result<f64> {
            field
                .trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad {what} `{field}` for site `{name}`"))
        };
        let gbps = num(fields[2], "gbps")?;
        if !gbps.is_finite() || gbps <= 0.0 {
            bail!("site `{name}` gbps must be finite and > 0, got {gbps}");
        }
        let latency_ms = num(fields[3], "latency_ms")?;
        if !latency_ms.is_finite() || latency_ms < 0.0 {
            bail!("site `{name}` latency_ms must be finite and >= 0, got {latency_ms}");
        }
        let egress = num(fields[4], "egress_per_gb")?;
        if !egress.is_finite() || egress < 0.0 {
            bail!("site `{name}` egress_per_gb must be finite and >= 0, got {egress}");
        }
        let resident: BTreeSet<String> = fields
            .get(5)
            .map(|f| {
                f.split('+')
                    .map(str::trim)
                    .filter(|m| !m.is_empty())
                    .map(str::to_string)
                    .collect()
            })
            .unwrap_or_default();
        sites.push(Site {
            name: name.to_string(),
            classes,
            gbps,
            latency_ms,
            book: PriceBook::paper().with_egress(egress),
            resident,
        });
    }
    Ok(sites)
}

/// Which score the broker minimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// predicted staging time + predicted gang queue wait (seconds)
    #[default]
    Turnaround,
    /// predicted slot dollars + predicted egress dollars
    Dollars,
}

impl Placement {
    pub fn parse(s: &str) -> Result<Placement> {
        match s.trim() {
            "turnaround" => Ok(Placement::Turnaround),
            "dollars" => Ok(Placement::Dollars),
            other => bail!("unknown placement policy `{other}` (turnaround, dollars)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Placement::Turnaround => "turnaround",
            Placement::Dollars => "dollars",
        }
    }
}

/// Per-site placement bookkeeping, reported in the enriched block.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteSummary {
    pub name: String,
    /// users the broker placed at this site
    pub placed: u32,
    /// placements that took the data-locality credit
    pub resident_hits: u32,
    pub egress_per_gb: f64,
}

/// The federation block of a campaign report.
#[derive(Debug, Clone, PartialEq)]
pub struct FederationSummary {
    pub placement: Placement,
    /// per-site stats, in site-name order (home site included)
    pub sites: Vec<SiteSummary>,
    /// gangs rerouted off dark sites by `SiteOutage` windows
    pub reroutes: u32,
    /// displaced gangs a site outage left with no live candidate
    pub stranded: u32,
}

/// The placement broker: home site + `--sites` extras in name order,
/// a down flag per site driven by `SiteOutage` windows, and running
/// stats. Scoring reads the live fabric (`World`) but never mutates
/// it, so placement stays a pure function of the shard's state.
#[derive(Debug, Clone)]
pub struct Broker {
    pub placement: Placement,
    sites: Vec<Site>,
    down: Vec<bool>,
    stats: Vec<SiteSummary>,
    reroutes: u32,
    stranded: u32,
}

impl Broker {
    /// Build a broker over the implicit home site plus `extra` sites,
    /// sorted by name for the stable tie-break.
    pub fn new(extra: &[Site], placement: Placement) -> Broker {
        let mut sites = vec![Site::home()];
        sites.extend(extra.iter().cloned());
        sites.sort_by(|a, b| a.name.cmp(&b.name));
        let stats = sites
            .iter()
            .map(|s| SiteSummary {
                name: s.name.clone(),
                placed: 0,
                resident_hits: 0,
                egress_per_gb: s.book.egress_per_gb,
            })
            .collect();
        let down = vec![false; sites.len()];
        Broker {
            placement,
            sites,
            down,
            stats,
            reroutes: 0,
            stranded: 0,
        }
    }

    pub fn sites(&self) -> &[Site] {
        &self.sites
    }

    fn index_of(&self, site: &str) -> Result<usize> {
        self.sites
            .iter()
            .position(|s| s.name == site)
            .with_context(|| format!("unknown federation site `{site}`"))
    }

    /// Check every `site=` window in a fault plan names a broker site.
    pub fn validate_plan(&self, plan: &FaultPlan) -> Result<()> {
        for s in &plan.sites {
            self.index_of(&s.site)
                .with_context(|| format!("site outage on unknown site `{}`", s.site))?;
        }
        Ok(())
    }

    /// Flip a site's outage state; returns the site's faas endpoints so
    /// the campaign driver can keep its per-endpoint `down_count`
    /// refcounts (and run the failover planner) in step.
    pub fn set_down(&mut self, site: &str, down: bool) -> Result<Vec<String>> {
        let i = self.index_of(site)?;
        self.down[i] = down;
        Ok(self.sites[i].endpoints())
    }

    /// Record the outcome of a site-outage failover wave.
    pub fn note_reroutes(&mut self, displaced: u32, stranded: u32) {
        self.reroutes += displaced.saturating_sub(stranded);
        self.stranded += stranded;
    }

    /// Predicted score for running a `width`-wide `model` task-group of
    /// `bytes` staged input on site `si`'s `class` endpoint at `now`.
    /// `f64::INFINITY` = infeasible (class not hosted, gang can never
    /// fit, or no WAN path).
    fn score(&self, world: &World, si: usize, class: &str, width: usize, bytes: u64, model: &str, now: f64) -> f64 {
        let site = &self.sites[si];
        if !site.hosts(class) {
            return f64::INFINITY;
        }
        let ep = site.endpoint(class);
        let Some(faas) = world.faas.as_ref() else {
            return f64::INFINITY;
        };
        let wait_s = faas.predicted_gang_wait(&ep, width, now);
        if !wait_s.is_finite() {
            return f64::INFINITY;
        }
        let resident = site.resident.contains(model);
        let stage_s = if resident {
            0.0
        } else {
            let req = TransferRequest::split_even(
                "broker-stage",
                EndpointId::from("slac#dtn"),
                EndpointId::from(site.dtn().as_str()),
                bytes.max(1),
                BROKER_STAGE_FILES,
            );
            match world.transfer.predict_linear(&req) {
                Ok(s) => s,
                Err(_) => return f64::INFINITY,
            }
        };
        match self.placement {
            Placement::Turnaround => stage_s + wait_s,
            Placement::Dollars => {
                let est_s = world
                    .estimate_task_secs(
                        &ep,
                        &FuncId("train_model".into()),
                        &Json::obj(vec![("model", Json::str(model))]),
                    )
                    .unwrap_or(0.0);
                let slot = site.book.slot_dollars(&ep, est_s * width as f64);
                let egress = if resident {
                    0.0
                } else {
                    site.book.egress_dollars(bytes as f64)
                };
                slot + egress
            }
        }
    }

    /// Place one arriving task-group: scan sites in name order, keep
    /// the first strictly best finite score among live sites hosting
    /// `class`. If an outage has every hosting site dark, the group
    /// parks on the first hosting site by name (it queues and runs at
    /// restore). Returns `(train_endpoint, stage_dtn)`.
    pub fn place(
        &mut self,
        world: &World,
        class: &str,
        width: usize,
        bytes: u64,
        model: &str,
        now: f64,
    ) -> Result<(String, String)> {
        let mut best: Option<(usize, f64)> = None;
        for si in 0..self.sites.len() {
            if self.down[si] || !self.sites[si].hosts(class) {
                continue;
            }
            let score = self.score(world, si, class, width, bytes, model, now);
            if !score.is_finite() {
                continue;
            }
            if best.map_or(true, |(_, b)| score < b) {
                best = Some((si, score));
            }
        }
        let si = match best {
            Some((si, _)) => si,
            // every hosting site is dark or infeasible: park on the
            // first hosting site so the work queues until restore
            None => self
                .sites
                .iter()
                .position(|s| s.hosts(class))
                .with_context(|| format!("no federation site hosts class `{class}`"))?,
        };
        self.stats[si].placed += 1;
        if self.sites[si].resident.contains(model) {
            self.stats[si].resident_hits += 1;
        }
        Ok((self.sites[si].endpoint(class), self.sites[si].dtn()))
    }

    pub fn summary(&self) -> FederationSummary {
        FederationSummary {
            placement: self.placement,
            sites: self.stats.clone(),
            reroutes: self.reroutes,
            stranded: self.stranded,
        }
    }
}

impl FederationSummary {
    /// Merge a shard's summary into this one (site lists are identical
    /// across shards — same config — so stats add elementwise).
    pub fn absorb(&mut self, other: &FederationSummary) {
        debug_assert_eq!(self.sites.len(), other.sites.len());
        for (a, b) in self.sites.iter_mut().zip(&other.sites) {
            debug_assert_eq!(a.name, b.name);
            a.placed += b.placed;
            a.resident_hits += b.resident_hits;
        }
        self.reroutes += other.reroutes;
        self.stranded += other.stranded;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sites_happy_path() {
        let sites =
            parse_sites("nersc:gpu8+v100:10:12:0.02;ornl:cerebras:25:18:0.09:braggnn+cookienetae")
                .unwrap();
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[0].name, "nersc");
        assert_eq!(sites[0].classes, vec!["gpu8", "v100"]);
        assert_eq!(sites[0].gbps, 10.0);
        assert_eq!(sites[0].latency_ms, 12.0);
        assert_eq!(sites[0].book.egress_per_gb, 0.02);
        assert!(sites[0].resident.is_empty());
        assert!(sites[1].resident.contains("braggnn"));
        assert!(sites[1].resident.contains("cookienetae"));
        assert_eq!(sites[1].endpoints(), vec!["ornl#cerebras"]);
        assert_eq!(sites[1].dtn(), "ornl#dtn");
        // class rates ride the paper book; only egress is per-site
        assert_eq!(sites[1].book.rate_per_slot_hour("ornl#cerebras"), 42.0);
        // empty spec = no extra sites
        assert!(parse_sites("").unwrap().is_empty());
        assert!(parse_sites(" ; ").unwrap().is_empty());
    }

    #[test]
    fn parse_sites_rejects_bad_specs() {
        // duplicate site names
        assert!(parse_sites("nersc:gpu8:10:12:0.02;nersc:v100:10:12:0.02")
            .unwrap_err()
            .to_string()
            .contains("duplicate site name"));
        // empty endpoint class list (explicitly empty field)
        assert!(parse_sites("nersc::10:12:0.02")
            .unwrap_err()
            .to_string()
            .contains("empty endpoint class list"));
        // negative egress rate
        assert!(parse_sites("nersc:gpu8:10:12:-0.02")
            .unwrap_err()
            .to_string()
            .contains("egress_per_gb"));
        // unknown price class (sim/cluster are known but not placeable)
        assert!(parse_sites("nersc:tpu:10:12:0.02")
            .unwrap_err()
            .to_string()
            .contains("unknown endpoint class"));
        assert!(parse_sites("nersc:sim:10:12:0.02").is_err());
        // duplicate classes within one site
        assert!(parse_sites("nersc:gpu8+gpu8:10:12:0.02")
            .unwrap_err()
            .to_string()
            .contains("duplicate class"));
        // reserved paper facility names
        assert!(parse_sites("alcf:gpu8:10:12:0.02")
            .unwrap_err()
            .to_string()
            .contains("reserved"));
        assert!(parse_sites("slac:gpu8:10:12:0.02").is_err());
        // malformed numbers and shapes
        assert!(parse_sites("nersc:gpu8:fast:12:0.02").is_err());
        assert!(parse_sites("nersc:gpu8:0:12:0.02").is_err()); // gbps 0
        assert!(parse_sites("nersc:gpu8:10:-1:0.02").is_err()); // latency < 0
        assert!(parse_sites("nersc:gpu8:10:12").is_err()); // too few fields
        assert!(parse_sites("nersc:gpu8:10:12:0.02:braggnn:extra").is_err());
        assert!(parse_sites(":gpu8:10:12:0.02").is_err()); // empty name
    }

    #[test]
    fn placement_parses() {
        assert_eq!(Placement::parse("turnaround").unwrap(), Placement::Turnaround);
        assert_eq!(Placement::parse("dollars").unwrap(), Placement::Dollars);
        assert!(Placement::parse("cheapest").is_err());
        assert_eq!(Placement::default(), Placement::Turnaround);
        assert_eq!(Placement::Dollars.as_str(), "dollars");
    }

    #[test]
    fn broker_orders_sites_by_name_with_home_included() {
        let extra = parse_sites("ornl:cerebras:25:18:0.09;nersc:gpu8:10:12:0.02").unwrap();
        let b = Broker::new(&extra, Placement::Turnaround);
        let names: Vec<&str> = b.sites().iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["alcf", "nersc", "ornl"]);
        // the summary mirrors that order with zeroed stats
        let s = b.summary();
        assert_eq!(s.sites.len(), 3);
        assert!(s.sites.iter().all(|x| x.placed == 0));
        assert_eq!(s.reroutes, 0);
    }

    #[test]
    fn site_outage_plans_validate_against_broker_sites() {
        let extra = parse_sites("nersc:cerebras:10:12:0.02").unwrap();
        let b = Broker::new(&extra, Placement::Turnaround);
        assert!(b.validate_plan(&FaultPlan::parse("site=nersc@0..10").unwrap()).is_ok());
        assert!(b.validate_plan(&FaultPlan::parse("site=alcf@0..10").unwrap()).is_ok());
        assert!(b
            .validate_plan(&FaultPlan::parse("site=ornl@0..10").unwrap())
            .unwrap_err()
            .to_string()
            .contains("unknown site"));
    }

    #[test]
    fn topology_extension_routes_through_the_backbone() {
        let mut topo = Topology::paper();
        let site = &parse_sites("nersc:gpu8:20:10:0.02").unwrap()[0];
        site.extend_topology(&mut topo).unwrap();
        let slac = topo.facility("slac").unwrap();
        let nersc = topo.facility("nersc").unwrap();
        let alcf = topo.facility("alcf").unwrap();
        // 0.5ms slac nic + 23ms backbone + 10ms nersc nic, both ways
        let rtt = topo.rtt(slac, nersc).unwrap();
        assert!((rtt - 2.0 * (0.5e-3 + 23.0e-3 + 10.0e-3)).abs() < 1e-12, "{rtt}");
        // narrowest hop to nersc is its own 20 Gbps NIC vs slac's 10
        let min_cap = topo
            .route(slac, nersc)
            .unwrap()
            .iter()
            .map(|&l| topo.link(l).capacity_bps)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(min_cap, 10.0 * GBPS);
        let min_cap_back = topo
            .route(nersc, alcf)
            .unwrap()
            .iter()
            .map(|&l| topo.link(l).capacity_bps)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(min_cap_back, 10.0 * GBPS); // alcf's NIC
        // a second site also routes to the first (site<->site paths)
        let site2 = &parse_sites("ornl:cerebras:25:18:0.09").unwrap()[0];
        site2.extend_topology(&mut topo).unwrap();
        let ornl = topo.facility("ornl").unwrap();
        assert!(topo.route(ornl, nersc).is_ok());
        assert!(topo.route(nersc, ornl).is_ok());
    }
}
