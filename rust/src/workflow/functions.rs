//! The faas functions of the DNNTrainerFlow: the paper's operations
//! **S**imulate/collect, **A**nalyze (labeling), and **T**rain, each
//! registered once on the funcX fabric (§3: "build our computation
//! actions, including simulation, data annotation and model training,
//! using funcX").

use anyhow::{bail, Context, Result};

use super::world::{TrainedModel, TrainingMode, World};
use crate::data::{bragg, cookiebox, BraggConfig, CookieConfig};
use crate::simnet::VClock;
use crate::training::{Recipe, TrainState, Trainer};
use crate::util::Json;

/// Detector/simulation sample rates for virtual-time accounting of **S**.
/// `pub(crate)` so `World::estimate_task_secs` predicts from the same
/// constants the bodies charge — scheduler estimates stay exact.
pub(crate) fn generation_rate(model: &str) -> f64 {
    match model {
        "braggnn" => 100_000.0,   // peaks/s out of the HEDM pipeline
        "cookienetae" => 5_000.0, // shots/s of eToF simulation
        _ => 10_000.0,
    }
}

/// Paper §4.2: the DC cluster labels at 2.44 µs/peak (1024 cores).
pub(crate) const CLUSTER_LABEL_S_PER_SAMPLE: f64 = 2.44e-6;

pub fn register_all(faas: &mut crate::faas::FaasService<World>) -> Result<()> {
    faas.register_function("generate_data", generate_data)?;
    faas.register_function("label_data", label_data)?;
    faas.register_function("train_model", train_model)?;
    faas.register_function("resume_train", resume_train)?;
    faas.register_function("evaluate_model", evaluate_model)?;
    Ok(())
}

/// **S**: synthesize a training set near the experiment.
/// args: {model, n, seed, name?, facility?}
fn generate_data(world: &mut World, clock: &mut VClock, args: &Json) -> Result<Json> {
    let model = args.get("model").as_str().context("args.model")?;
    let n = args.get("n").as_usize().context("args.n")?;
    let seed = args.get("seed").as_u64().unwrap_or(1234);
    let name = args
        .get("name")
        .as_str()
        .map(str::to_string)
        .unwrap_or_else(|| format!("{model}-train"));
    let facility = args.get("facility").as_str().unwrap_or("slac");

    let dataset = match model {
        "braggnn" => bragg::generate(&BraggConfig::default(), n, seed)?,
        "cookienetae" => cookiebox::generate(&CookieConfig::default(), n, seed)?,
        other => bail!("no generator for model `{other}`"),
    };
    clock.advance(n as f64 / generation_rate(model));
    let bytes = dataset.wire_bytes();
    world.put_file(facility, &name, bytes);
    world.datasets.insert(name.clone(), dataset);
    Ok(Json::obj(vec![
        ("dataset", Json::str(name)),
        ("n", Json::num(n as f64)),
        ("wire_bytes", Json::num(bytes as f64)),
    ]))
}

/// **A**: label a staged dataset with the conventional analyzer.
///
/// BraggNN datasets are *really* labeled: the Levenberg–Marquardt
/// pseudo-Voigt fitter runs on up to `real_cap` patches (replacing their
/// targets with fitted centers) and its measured per-peak *CPU* cost —
/// worker busy time, independent of the pool's thread count — is
/// recorded as C(A); virtual time is charged at the paper's 1024-core
/// cluster rate for the full set. CookieNetAE targets come from simulation, so
/// labeling is a pass-through (the paper notes simulation provides the
/// ground truth for single-particle-imaging-like cases).
/// args: {dataset, real_cap?}
fn label_data(world: &mut World, clock: &mut VClock, args: &Json) -> Result<Json> {
    let name = args.get("dataset").as_str().context("args.dataset")?;
    let real_cap = args.get("real_cap").as_usize().unwrap_or(512);
    let ds = world.dataset(name)?;
    let n = ds.n;
    let is_bragg = ds.input_shape == vec![11, 11, 1];

    let mut real_per_peak = 0.0;
    let mut real_per_peak_wall = 0.0;
    if is_bragg {
        let k = real_cap.min(n);
        let px = 11 * 11;
        let patches: Vec<f32> = world.dataset(name)?.x[..k * px].to_vec();
        // Routed through `pool::scope` stage fan-out (the entry point the
        // flows/faas layers expose), so faas-side labeling shares the one
        // `XLOOP_THREADS` knob; fits stay bit-identical to the serial
        // path in any thread count.
        let (fits, timing) = crate::analysis::label_patches_scoped(&patches, k, 11, 11)?;
        // C(A) is the per-*core* analyzer cost, so record the summed
        // worker busy time per peak (thread-count independent); the
        // delivered wallclock rides along for the latency view
        real_per_peak = timing.per_peak_cpu_s();
        real_per_peak_wall = timing.per_peak_wall_s();
        let ds = world.datasets.get_mut(name).unwrap();
        for (i, fit) in fits.iter().enumerate() {
            let (x, y) = fit.center();
            ds.y[2 * i] = (x / 10.0) as f32;
            ds.y[2 * i + 1] = (y / 10.0) as f32;
        }
        world.last_label_cost_s = Some(real_per_peak);
    }
    clock.advance(n as f64 * CLUSTER_LABEL_S_PER_SAMPLE);
    Ok(Json::obj(vec![
        ("dataset", Json::str(name)),
        ("n", Json::num(n as f64)),
        ("real_labeled", Json::num(if is_bragg { real_cap.min(n) } else { 0 } as f64)),
        ("real_s_per_peak", Json::num(real_per_peak)),
        ("real_s_per_peak_wall", Json::num(real_per_peak_wall)),
    ]))
}

/// Fine-tuning needs fewer steps than from-scratch training; the paper's
/// §7(1) motivation. Fraction calibrated from the warm-start ablation
/// test below (loss parity at ~1/4 the steps).
pub(crate) const FINETUNE_STEP_FRACTION: f64 = 0.25;

/// **T**: (re)train a model on a DCAI endpoint.
///
/// Virtual time comes from the endpoint's accelerator model over the full
/// production recipe; real PJRT steps run when the world is in
/// `TrainingMode::Real`, producing the actual trained weights and loss
/// curve. With `warm_start: true` (paper §7 future work 1) the model
/// repository supplies the closest prior checkpoint as a foundation and
/// the step budget shrinks to a fine-tuning run.
/// args: {model, dataset, endpoint, seed?, warm_start?, sample?, setting?}
fn train_model(world: &mut World, clock: &mut VClock, args: &Json) -> Result<Json> {
    let model = args.get("model").as_str().context("args.model")?;
    let dataset_name = args.get("dataset").as_str().context("args.dataset")?;
    let endpoint = args.get("endpoint").as_str().context("args.endpoint")?;
    let seed = args.get("seed").as_u64().unwrap_or(7);
    let tag = crate::models::ExperimentTag {
        sample: args.get("sample").as_str().unwrap_or("default").to_string(),
        setting: args.get("setting").as_f64().unwrap_or(0.0),
    };

    // warm start from the repository when asked and available
    let foundation: Option<Vec<crate::runtime::Tensor>> =
        if args.get("warm_start").as_bool().unwrap_or(false) {
            world
                .repository
                .select_foundation(model, &tag)
                .map(|c| c.params.clone())
        } else {
            None
        };
    let warm = foundation.is_some();

    let meta = world.registry.get(model)?.clone();
    let accel = world.accel(endpoint)?.clone();
    let recipe = Recipe::standard(model)?;
    let full_steps = if warm {
        ((recipe.full_steps as f64 * FINETUNE_STEP_FRACTION) as u64).max(1)
    } else {
        recipe.full_steps
    };
    let modeled = accel.train_time(
        meta.train_flops_per_step,
        meta.param_bytes() as f64,
        full_steps,
    );
    clock.advance(modeled.total_s);

    let (params, report, final_loss) = match world.training_mode {
        TrainingMode::Real { steps_override } => {
            let base = steps_override.unwrap_or(recipe.real_steps);
            let steps = if warm {
                ((base as f64 * FINETUNE_STEP_FRACTION) as u64).max(1)
            } else {
                base
            };
            let dataset = world.dataset(dataset_name)?;
            let trainer = Trainer::new(&world.rt, &meta)?;
            let mut state = match &foundation {
                Some(p) => TrainState::from_params(&meta, p.clone())?,
                None => TrainState::init(&meta)?,
            };
            let report = trainer.train(&mut state, dataset, steps, seed, (steps / 20).max(1))?;
            let loss = report.final_loss;
            (state.params, Some(report), Some(loss))
        }
        TrainingMode::VirtualOnly => {
            let params = match foundation {
                Some(p) => p,
                None => TrainState::init(&meta)?.params,
            };
            (params, None, None)
        }
    };

    // publish into the repository (val loss = final train loss here; the
    // evaluate_model function refines it for callers that need held-out)
    let version = world.repository.publish(
        model,
        params.clone(),
        final_loss.unwrap_or(f32::MAX.min(1e30)),
        tag,
        modeled.total_s,
    )?;

    let real_steps = report.as_ref().map(|r| r.steps).unwrap_or(0);
    world.trained.insert(
        model.to_string(),
        TrainedModel {
            model: model.to_string(),
            params,
            final_loss,
            report,
            virtual_train_s: modeled.total_s,
            trained_on: endpoint.to_string(),
        },
    );
    Ok(Json::obj(vec![
        ("model", Json::str(model)),
        ("endpoint", Json::str(endpoint)),
        ("virtual_train_s", Json::num(modeled.total_s)),
        ("per_step_s", Json::num(modeled.per_step_s)),
        ("full_steps", Json::num(full_steps as f64)),
        ("real_steps", Json::num(real_steps as f64)),
        ("warm_start", Json::Bool(warm)),
        ("repo_version", Json::num(version as f64)),
        (
            "final_loss",
            final_loss.map(|l| Json::num(l as f64)).unwrap_or(Json::Null),
        ),
    ]))
}

/// **T** (resumed): replay the tail of a spot-preempted training run
/// from its last checkpoint (DESIGN.md §12).
///
/// Under the run-at-start execution model the original `train_model`
/// body already did its side effects (repository publish, `trained`
/// insert) when the task started — the preemption only invalidated the
/// *time* the fabric had scheduled past the reclaim instant. The resume
/// therefore charges exactly the remaining body seconds (full duration
/// minus the checkpointed prefix) on the failover endpoint and re-emits
/// the original output, so the flow layer observes a normal `train`
/// completion. args: {remaining_s, output}
fn resume_train(_world: &mut World, clock: &mut VClock, args: &Json) -> Result<Json> {
    let remaining_s = args
        .get("remaining_s")
        .as_f64()
        .context("args.remaining_s")?;
    if !remaining_s.is_finite() || remaining_s < 0.0 {
        bail!("bad resume remaining_s {remaining_s}");
    }
    clock.advance(remaining_s);
    Ok(args.get("output").clone())
}

/// Validation inference on a trained model (used by tests/examples to
/// close the loop without deploying). args: {model, dataset, batches?}
fn evaluate_model(world: &mut World, clock: &mut VClock, args: &Json) -> Result<Json> {
    let model = args.get("model").as_str().context("args.model")?;
    let dataset_name = args.get("dataset").as_str().context("args.dataset")?;
    let batches = args.get("batches").as_u64().unwrap_or(2);

    let meta = world.registry.get(model)?.clone();
    let trained = world.trained(model)?;
    let exe = world.rt.load_hlo(&meta.infer_hlo_path())?;
    let dataset = world.dataset(dataset_name)?;

    let b = meta.infer_batch;
    let mut mse_sum = 0.0f64;
    let mut count = 0usize;
    for i in 0..batches {
        let idx: Vec<usize> = (0..b).map(|k| (i as usize * b + k) % dataset.n).collect();
        let (x, y) = dataset.gather_batch(&idx)?;
        let mut args_l: Vec<xla::Literal> = trained
            .params
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        args_l.push(x.to_literal()?);
        let out = exe.run_literals(&args_l)?;
        let pred = &out[0];
        for (p, t) in pred.data().iter().zip(y.data()) {
            mse_sum += ((p - t) as f64).powi(2);
            count += 1;
        }
    }
    let mse = mse_sum / count.max(1) as f64;
    clock.advance(0.5); // validation bookkeeping
    Ok(Json::obj(vec![
        ("model", Json::str(model)),
        ("val_mse", Json::num(mse)),
        ("samples", Json::num((batches * b as u64) as f64)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::FaasService;

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    fn world_and_faas() -> (World, FaasService<World>) {
        let mut w = World::paper(3).unwrap();
        let faas = w.faas.take().unwrap();
        (w, faas)
    }

    #[test]
    fn generate_then_label_braggnn() {
        if !artifacts_present() {
            return;
        }
        let (mut w, mut faas) = world_and_faas();
        let mut clock = VClock::new();
        let gen = crate::faas::FuncId("generate_data".into());
        let args = Json::parse(r#"{"model": "braggnn", "n": 256, "seed": 5}"#).unwrap();
        let t = faas
            .submit(&mut w, &mut clock, "slac#sim", &gen, &args)
            .unwrap();
        let out = faas.result(t).unwrap();
        assert_eq!(out.get("dataset").as_str(), Some("braggnn-train"));
        assert!(w.datasets.contains_key("braggnn-train"));
        assert!(clock.now() > 0.0);

        let before: Vec<f32> = w.dataset("braggnn-train").unwrap().y[..8].to_vec();
        let label = crate::faas::FuncId("label_data".into());
        let args =
            Json::parse(r#"{"dataset": "braggnn-train", "real_cap": 32}"#).unwrap();
        let t = faas
            .submit(&mut w, &mut clock, "alcf#cluster", &label, &args)
            .unwrap();
        let out = faas.result(t).unwrap().clone();
        assert_eq!(out.get("real_labeled").as_usize(), Some(32));
        assert!(out.get("real_s_per_peak").as_f64().unwrap() > 0.0);
        // labels actually re-written by the fitter (subpixel shifts)
        let after: Vec<f32> = w.dataset("braggnn-train").unwrap().y[..8].to_vec();
        assert_ne!(before, after);
        // ...but close to the ground truth
        for (a, b) in before.iter().zip(&after) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn train_model_virtual_only_charges_modeled_time() {
        if !artifacts_present() {
            return;
        }
        let (mut w, mut faas) = world_and_faas();
        w.training_mode = TrainingMode::VirtualOnly;
        let mut clock = VClock::new();
        let gen = crate::faas::FuncId("generate_data".into());
        faas.submit(
            &mut w,
            &mut clock,
            "slac#sim",
            &gen,
            &Json::parse(r#"{"model": "braggnn", "n": 64}"#).unwrap(),
        )
        .unwrap();
        let before = clock.now();
        let train = crate::faas::FuncId("train_model".into());
        let args = Json::parse(
            r#"{"model": "braggnn", "dataset": "braggnn-train", "endpoint": "alcf#cerebras"}"#,
        )
        .unwrap();
        let t = faas
            .submit(&mut w, &mut clock, "alcf#cerebras", &train, &args)
            .unwrap();
        let out = faas.result(t).unwrap();
        let virt = out.get("virtual_train_s").as_f64().unwrap();
        // Cerebras BraggNN: ~18 s modeled (Table 1: 19 s)
        assert!((15.0..22.0).contains(&virt), "{virt}");
        assert!(clock.now() - before >= virt);
        assert!(w.trained("braggnn").is_ok());
    }

    #[test]
    fn train_model_real_runs_pjrt_and_evaluates() {
        if !artifacts_present() {
            return;
        }
        let (mut w, mut faas) = world_and_faas();
        w.training_mode = TrainingMode::Real {
            steps_override: Some(12),
        };
        let mut clock = VClock::new();
        let gen = crate::faas::FuncId("generate_data".into());
        faas.submit(
            &mut w,
            &mut clock,
            "slac#sim",
            &gen,
            &Json::parse(r#"{"model": "braggnn", "n": 256, "seed": 2}"#).unwrap(),
        )
        .unwrap();
        let train = crate::faas::FuncId("train_model".into());
        let t = faas
            .submit(
                &mut w,
                &mut clock,
                "alcf#cerebras",
                &train,
                &Json::parse(
                    r#"{"model": "braggnn", "dataset": "braggnn-train", "endpoint": "alcf#cerebras"}"#,
                )
                .unwrap(),
            )
            .unwrap();
        let out = faas.result(t).unwrap();
        assert_eq!(out.get("real_steps").as_u64(), Some(12));
        let loss = out.get("final_loss").as_f64().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        let trained = w.trained("braggnn").unwrap();
        assert!(trained.report.is_some());

        // evaluate on the same data
        let eval = crate::faas::FuncId("evaluate_model".into());
        let t = faas
            .submit(
                &mut w,
                &mut clock,
                "alcf#cerebras",
                &eval,
                &Json::parse(r#"{"model": "braggnn", "dataset": "braggnn-train"}"#).unwrap(),
            )
            .unwrap();
        let out = faas.result(t).unwrap();
        assert!(out.get("val_mse").as_f64().unwrap().is_finite());
    }

    #[test]
    fn warm_start_finetunes_from_repository() {
        if !artifacts_present() {
            return;
        }
        let (mut w, mut faas) = world_and_faas();
        w.training_mode = TrainingMode::Real {
            steps_override: Some(40),
        };
        let mut clock = VClock::new();
        let gen = crate::faas::FuncId("generate_data".into());
        faas.submit(
            &mut w,
            &mut clock,
            "slac#sim",
            &gen,
            &Json::parse(r#"{"model": "braggnn", "n": 512, "seed": 21}"#).unwrap(),
        )
        .unwrap();
        let train = crate::faas::FuncId("train_model".into());
        let base_args = r#"{"model": "braggnn", "dataset": "braggnn-train",
                            "endpoint": "alcf#cerebras", "sample": "Ti64", "setting": 1.0}"#;
        // cold start: full step budget, published to the repo
        let t = faas
            .submit(&mut w, &mut clock, "alcf#cerebras", &train,
                    &Json::parse(base_args).unwrap())
            .unwrap();
        let cold = faas.result(t).unwrap().clone();
        assert_eq!(cold.get("warm_start").as_bool(), Some(false));
        assert_eq!(cold.get("repo_version").as_usize(), Some(1));
        let cold_virtual = cold.get("virtual_train_s").as_f64().unwrap();
        let cold_loss = cold.get("final_loss").as_f64().unwrap();

        // warm start: quarter budget, starts from the checkpoint, and
        // still reaches at least comparable loss
        let warm_args = base_args.replace(r#""setting": 1.0}"#,
                                          r#""setting": 1.1, "warm_start": true}"#);
        let t = faas
            .submit(&mut w, &mut clock, "alcf#cerebras", &train,
                    &Json::parse(&warm_args).unwrap())
            .unwrap();
        let warm = faas.result(t).unwrap().clone();
        assert_eq!(warm.get("warm_start").as_bool(), Some(true));
        assert_eq!(warm.get("real_steps").as_u64(), Some(10));
        let warm_virtual = warm.get("virtual_train_s").as_f64().unwrap();
        assert!(
            warm_virtual < cold_virtual * 0.35,
            "fine-tune {warm_virtual}s not ~4x cheaper than {cold_virtual}s"
        );
        // the fine-tune *starts* from the checkpoint: its first loss must
        // already be in the converged regime (a cold start begins ~0.9)
        let warm_report = w.trained("braggnn").unwrap().report.as_ref().unwrap().clone();
        assert!(
            (warm_report.first_loss as f64) < cold_loss * 10.0
                && warm_report.first_loss < 0.1,
            "warm start began at {} — not from the checkpoint (cold final {cold_loss})",
            warm_report.first_loss
        );
        assert_eq!(w.repository.versions("braggnn"), 2);
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        if !artifacts_present() {
            return;
        }
        let (mut w, mut faas) = world_and_faas();
        let mut clock = VClock::new();
        let gen = crate::faas::FuncId("generate_data".into());
        let t = faas
            .submit(
                &mut w,
                &mut clock,
                "slac#sim",
                &gen,
                &Json::parse(r#"{"model": "resnet", "n": 4}"#).unwrap(),
            )
            .unwrap();
        assert!(faas.result(t).is_err());
    }
}
