//! Multi-tenant retraining campaigns: N users' DNNTrainerFlows
//! interleaved over the shared DCAI + WAN fabric (DESIGN.md §3).
//!
//! The paper measures a *single* user's turnaround; a facility serves
//! many beamlines at once, where DCAI queue wait and shared ESnet
//! bandwidth dominate. This layer launches N copies of the retraining
//! scenario with Poisson arrivals and drives them through one
//! discrete-event loop: flow runs park on fabric tickets, faas endpoints
//! queue on capacity slots, and concurrent transfers share bandwidth
//! max-min fairly. The N=1 campaign reproduces `xloop table1`'s
//! per-phase breakdown bit for bit; at higher loads it answers the
//! question Table 1 cannot: at what load does the local V100 beat the
//! remote DCAI?

use anyhow::{Context, Result};

use super::coordinator::{extract_breakdown, RetrainBreakdown};
use super::flow::{dnn_trainer_flow, FlowShape};
use super::scenario::Scenario;
use super::world::{TrainingMode, World};
use crate::flows::{FabricHost, FlowEngine, FlowRun, RunPoll, RunReport, Ticket};
use crate::simnet::{Scheduler, VClock};
use crate::util::{Json, Rng};

/// One campaign: N users retraining the same scenario on one fabric.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub users: usize,
    pub scenario: Scenario,
    /// mean seconds between user arrivals (Poisson process; the first
    /// user arrives at t=0). `<= 0` launches everyone at once.
    pub mean_interarrival_s: f64,
    /// seed for the arrival process (the fabric uses `scenario.seed`)
    pub seed: u64,
}

/// Outcome for one user's retraining.
#[derive(Debug, Clone)]
pub struct UserOutcome {
    pub user: usize,
    pub arrival_vt: f64,
    /// when the user's flow (including deploy) finished
    pub finished_vt: f64,
    /// arrival to deployed model, the loaded-facility turnaround
    pub turnaround_s: f64,
    /// the Table 1 per-phase breakdown of this user's flow
    pub breakdown: RetrainBreakdown,
}

/// Aggregate faas load on one endpoint over the campaign.
#[derive(Debug, Clone)]
pub struct EndpointLoad {
    pub endpoint: String,
    pub tasks: u64,
    pub total_queue_wait_s: f64,
    pub max_queue_wait_s: f64,
}

impl EndpointLoad {
    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.tasks == 0 {
            0.0
        } else {
            self.total_queue_wait_s / self.tasks as f64
        }
    }
}

/// Full campaign outcome.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub config_users: usize,
    pub mean_interarrival_s: f64,
    pub users: Vec<UserOutcome>,
    pub endpoint_loads: Vec<EndpointLoad>,
    /// mean per-task goodput over every WAN transfer in the campaign
    pub mean_task_throughput_bps: f64,
    /// first arrival to last deployment
    pub makespan_s: f64,
}

impl CampaignReport {
    /// Nearest-rank percentile of user turnaround (q in [0, 100]).
    pub fn turnaround_percentile(&self, q: f64) -> f64 {
        let mut ts: Vec<f64> = self.users.iter().map(|u| u.turnaround_s).collect();
        if ts.is_empty() {
            return 0.0;
        }
        ts.sort_by(f64::total_cmp);
        let idx = ((q / 100.0) * (ts.len() - 1) as f64).round() as usize;
        ts[idx.min(ts.len() - 1)]
    }

    pub fn max_turnaround_s(&self) -> f64 {
        self.users
            .iter()
            .map(|u| u.turnaround_s)
            .fold(0.0, f64::max)
    }

    pub fn load(&self, endpoint: &str) -> Option<&EndpointLoad> {
        self.endpoint_loads.iter().find(|l| l.endpoint == endpoint)
    }
}

/// Per-user progress through the campaign.
enum UserState {
    /// not yet arrived
    Waiting,
    /// dataset generation queued on `slac#sim`
    Preparing(Ticket),
    /// flow in progress
    Running(FlowRun),
    Done(RunReport),
}

/// Events on the campaign's scheduler: user arrivals are static and live
/// in the heap; `Scan` wake-ups are scheduled each round for the
/// earliest *dynamic* source (a flow's scheduled completion or a fabric
/// state change, whose times shift with contention). Spurious or stale
/// wake-ups are harmless — every firing just re-scans at `now`.
enum Wake {
    Arrival,
    Scan,
}

/// Run a campaign to completion on a fresh paper fabric.
///
/// Every user runs the same scenario (per-user dataset names keep their
/// data disjoint); training is virtual-only — the campaign is a capacity
/// study, not a weights producer.
pub fn run_campaign(cfg: &CampaignConfig) -> Result<CampaignReport> {
    anyhow::ensure!(cfg.users > 0, "campaign needs at least one user");
    let mut world = World::paper(cfg.scenario.seed)?;
    world.training_mode = TrainingMode::VirtualOnly;
    let mut engine = FlowEngine::<World>::new();
    super::providers::register_all(&mut engine)?;
    let clock0 = VClock::new();
    let token = engine
        .auth
        .issue(
            &clock0,
            "beamline-scientist",
            &["transfer:use", "compute:use", "deploy:use", "rollback:use"],
            30.0 * 24.0 * 3600.0,
        )
        .id;

    // Poisson arrivals: exponential inter-arrival gaps, first user at 0
    let mut arrivals = vec![0.0f64];
    let mut rng = Rng::new(cfg.seed);
    for i in 1..cfg.users {
        let gap = if cfg.mean_interarrival_s > 0.0 {
            rng.exponential(1.0 / cfg.mean_interarrival_s)
        } else {
            0.0
        };
        arrivals.push(arrivals[i - 1] + gap);
    }

    let shape = FlowShape {
        remote: cfg.scenario.mode.is_remote(),
        ..Default::default()
    };
    let def = dnn_trainer_flow(&shape)?;
    let datasets: Vec<String> = (0..cfg.users)
        .map(|i| format!("{}-train-u{}", cfg.scenario.model, i + 1))
        .collect();

    let mut states: Vec<UserState> = (0..cfg.users).map(|_| UserState::Waiting).collect();
    let gen = crate::faas::FuncId("generate_data".into());

    // The event-queue scheduler owns the campaign's virtual clock
    // (single writer): arrivals are scheduled up front, dynamic wake-ups
    // (flow completions, fabric events) are fed in each round, and every
    // time step is a deterministic heap pop.
    let mut sched = Scheduler::<Wake>::new();
    for &a in &arrivals {
        sched.schedule_at(a, Wake::Arrival);
    }

    loop {
        let now = sched.now();
        // settle everything possible at the current instant (poll order =
        // user index order: the deterministic tie-break)
        loop {
            let mut progressed = false;
            for i in 0..cfg.users {
                match &mut states[i] {
                    UserState::Waiting => {
                        if arrivals[i] <= now {
                            let args = Json::obj(vec![
                                ("model", Json::str(cfg.scenario.model.clone())),
                                ("n", Json::num(cfg.scenario.real_samples as f64)),
                                ("seed", Json::num(cfg.scenario.seed as f64)),
                                ("name", Json::str(datasets[i].clone())),
                            ]);
                            let ticket = world
                                .submit_compute_ticket(now, "slac#sim", &gen, &args)
                                .with_context(|| format!("user {i} dataset generation"))?;
                            states[i] = UserState::Preparing(ticket);
                            progressed = true;
                        }
                    }
                    UserState::Preparing(ticket) => {
                        if let Some((tf, res)) = world.take_ready(*ticket) {
                            res.with_context(|| format!("user {i} dataset generation"))?;
                            let input = Json::obj(vec![
                                ("model", Json::str(cfg.scenario.model.clone())),
                                ("dataset", Json::str(datasets[i].clone())),
                                (
                                    "dataset_bytes",
                                    Json::num(cfg.scenario.staged_bytes as f64),
                                ),
                                (
                                    "train_endpoint",
                                    Json::str(cfg.scenario.mode.train_endpoint()),
                                ),
                            ]);
                            let run = engine.begin(&def, &input, &token, tf)?;
                            states[i] = UserState::Running(run);
                            progressed = true;
                        }
                    }
                    UserState::Running(run) => {
                        if engine.poll(run, &mut world, now)? == RunPoll::Finished {
                            let prev = std::mem::replace(&mut states[i], UserState::Waiting);
                            let UserState::Running(run) = prev else { unreachable!() };
                            states[i] = UserState::Done(run.into_report());
                            progressed = true;
                        }
                    }
                    UserState::Done(_) => {}
                }
            }
            if !progressed {
                break;
            }
        }
        if states.iter().all(|s| matches!(s, UserState::Done(_))) {
            break;
        }

        // earliest *dynamic* source: a scheduled flow completion or a
        // fabric event (queue start/completion, transfer
        // re-allocation/delivery); arrivals already live in the heap
        let mut dyn_t = f64::INFINITY;
        for s in states.iter_mut() {
            if let UserState::Running(run) = s {
                if let RunPoll::WaitUntil(t) = engine.poll(run, &mut world, now)? {
                    dyn_t = dyn_t.min(t);
                }
            }
        }
        if let Some(t) = world.next_fabric_event() {
            dyn_t = dyn_t.min(t);
        }
        if dyn_t.is_finite() {
            sched.schedule_at(dyn_t.max(now), Wake::Scan);
        }
        let Some((t, _wake)) = sched.pop() else {
            anyhow::bail!(
                "campaign stalled at vt {now:.3} ({} users incomplete)",
                states
                    .iter()
                    .filter(|s| !matches!(s, UserState::Done(_)))
                    .count()
            );
        };
        world.advance_fabrics(t);
    }

    // per-user outcomes
    let mut users = Vec::with_capacity(cfg.users);
    for (i, s) in states.into_iter().enumerate() {
        let UserState::Done(report) = s else { unreachable!() };
        anyhow::ensure!(
            report.succeeded,
            "user {i} flow failed: {:?}",
            report
                .records
                .iter()
                .map(|r| format!("{}:{:?}", r.id, r.status))
                .collect::<Vec<_>>()
        );
        let breakdown = extract_breakdown(&report, &cfg.scenario, report.start_vt)?;
        users.push(UserOutcome {
            user: i + 1,
            arrival_vt: arrivals[i],
            finished_vt: report.end_vt,
            turnaround_s: report.end_vt - arrivals[i],
            breakdown,
        });
    }

    // endpoint queue statistics from the faas records
    let mut loads: std::collections::BTreeMap<String, EndpointLoad> =
        std::collections::BTreeMap::new();
    if let Some(faas) = world.faas.as_ref() {
        for rec in faas.records() {
            if !rec.status.is_complete() {
                continue;
            }
            let wait = rec.queue_wait_secs();
            let entry = loads
                .entry(rec.endpoint.clone())
                .or_insert_with(|| EndpointLoad {
                    endpoint: rec.endpoint.clone(),
                    tasks: 0,
                    total_queue_wait_s: 0.0,
                    max_queue_wait_s: 0.0,
                });
            entry.tasks += 1;
            entry.total_queue_wait_s += wait;
            entry.max_queue_wait_s = entry.max_queue_wait_s.max(wait);
        }
    }

    let mean_task_throughput_bps = if world.transfer_log.is_empty() {
        0.0
    } else {
        world
            .transfer_log
            .iter()
            .map(|r| r.throughput_bps())
            .sum::<f64>()
            / world.transfer_log.len() as f64
    };
    let makespan_s = users.iter().map(|u| u.finished_vt).fold(0.0, f64::max);

    Ok(CampaignReport {
        config_users: cfg.users,
        mean_interarrival_s: cfg.mean_interarrival_s,
        users,
        endpoint_loads: loads.into_values().collect(),
        mean_task_throughput_bps,
        makespan_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::scenario::Mode;
    use crate::workflow::{Coordinator, TrainingMode};

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    /// Acceptance: the N=1 campaign is the degenerate case of the DES
    /// machinery and must reproduce the synchronous table1 path's
    /// per-phase breakdown with bit-identical virtual times.
    #[test]
    fn single_user_campaign_matches_table1_bit_for_bit() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();

        let mut c = Coordinator::paper(scenario.seed).unwrap();
        c.set_training_mode(TrainingMode::VirtualOnly);
        let table1 = c.run_retraining(&scenario, None).unwrap().breakdown;

        let report = run_campaign(&CampaignConfig {
            users: 1,
            scenario,
            mean_interarrival_s: 60.0,
            seed: 42,
        })
        .unwrap();
        let b = &report.users[0].breakdown;

        assert_eq!(b.data_transfer_s, table1.data_transfer_s);
        assert_eq!(b.training_s, table1.training_s);
        assert_eq!(b.model_transfer_s, table1.model_transfer_s);
        assert_eq!(b.end_to_end_s, table1.end_to_end_s);
        // uncontended: no queue wait anywhere
        for load in &report.endpoint_loads {
            assert_eq!(load.total_queue_wait_s, 0.0, "{load:?}");
        }
    }

    /// Contended campaign: simultaneous users queue on the capacity-1
    /// DCAI trainer and share WAN bandwidth, so tail turnaround grows
    /// and per-task transfer throughput drops below the solo value.
    #[test]
    fn contention_creates_queue_wait_and_slower_transfers() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let solo = run_campaign(&CampaignConfig {
            users: 1,
            scenario: scenario.clone(),
            mean_interarrival_s: 1.0,
            seed: 7,
        })
        .unwrap();

        let loaded = run_campaign(&CampaignConfig {
            users: 4,
            scenario,
            mean_interarrival_s: 1.0, // near-simultaneous arrivals
            seed: 7,
        })
        .unwrap();

        // DCAI queue wait appears on the trainer
        let train_load = loaded.load("alcf#cerebras").expect("trainer used");
        assert!(
            train_load.total_queue_wait_s > 0.0,
            "no queue wait under contention: {train_load:?}"
        );
        // the tail is strictly worse than the uncontended turnaround
        assert!(
            loaded.max_turnaround_s() > solo.users[0].turnaround_s,
            "tail {} not above solo {}",
            loaded.max_turnaround_s(),
            solo.users[0].turnaround_s
        );
        // concurrent staging shares the WAN: per-task goodput drops
        assert!(
            loaded.mean_task_throughput_bps < solo.mean_task_throughput_bps,
            "transfer throughput did not degrade: {} vs {}",
            loaded.mean_task_throughput_bps,
            solo.mean_task_throughput_bps
        );
        // percentiles are ordered
        assert!(
            loaded.turnaround_percentile(95.0) >= loaded.turnaround_percentile(50.0)
        );
        assert!((loaded.makespan_s) >= loaded.users[0].turnaround_s);
    }

    /// The arrival process and the full DES replay are deterministic for
    /// a given seed.
    #[test]
    fn campaign_is_deterministic_for_seed() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("cookienetae", Mode::RemoteCerebras).unwrap();
        let cfg = CampaignConfig {
            users: 3,
            scenario,
            mean_interarrival_s: 10.0,
            seed: 11,
        };
        let a = run_campaign(&cfg).unwrap();
        let b = run_campaign(&cfg).unwrap();
        for (ua, ub) in a.users.iter().zip(&b.users) {
            assert_eq!(ua.arrival_vt, ub.arrival_vt);
            assert_eq!(ua.turnaround_s, ub.turnaround_s);
            assert_eq!(ua.finished_vt, ub.finished_vt);
        }
    }

    /// Local-mode campaigns run with no transfers but still queue on the
    /// single V100.
    #[test]
    fn local_mode_campaign_queues_on_v100() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::LocalV100).unwrap();
        let rep = run_campaign(&CampaignConfig {
            users: 2,
            scenario,
            mean_interarrival_s: 1.0,
            seed: 3,
        })
        .unwrap();
        assert_eq!(rep.mean_task_throughput_bps, 0.0); // no WAN transfers
        let v100 = rep.load("slac#v100").expect("v100 used");
        // local training is ~30x slower; the second user queues behind it
        assert!(v100.total_queue_wait_s > 0.0, "{v100:?}");
        for u in &rep.users {
            assert!(u.breakdown.data_transfer_s.is_none());
        }
    }
}
