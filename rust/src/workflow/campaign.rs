//! Multi-tenant retraining campaigns: N users' DNNTrainerFlows
//! interleaved over the shared DCAI + WAN fabric (DESIGN.md §3, §9).
//!
//! The paper measures a *single* user's turnaround; a facility serves
//! many beamlines at once, where DCAI queue wait and shared ESnet
//! bandwidth dominate. This layer launches N copies of the retraining
//! scenario with Poisson arrivals and drives them through one
//! discrete-event loop: flow runs park on fabric tickets, faas endpoints
//! queue on capacity slots, and concurrent transfers share bandwidth
//! max-min fairly. The N=1 campaign reproduces `xloop table1`'s
//! per-phase breakdown bit for bit; at higher loads it answers the
//! question Table 1 cannot: at what load does the local V100 beat the
//! remote DCAI?
//!
//! On top of the queueing core the campaign threads the DESIGN.md §9
//! knobs: a scheduling [`PolicyKind`] for the faas fabric, per-endpoint
//! [`Autoscaler`]s, a scheduled [`FaultPlan`] (endpoint outages and WAN
//! brownouts, each window edge a `des` event), per-user priority
//! classes, and per-user fairness metrics (queueing slowdown
//! percentiles, Jain's index) in the report; plus the DESIGN.md §10
//! knobs: a heterogeneous tenant [`MixEntry`] mix (per-class model and
//! training gang width sharing one trainer) and slot-hour
//! [`CostSummary`] accounting; plus the DESIGN.md §11 knobs: per-class
//! arrival processes (each mix entry may carry its own mean
//! inter-arrival `rate_s` and an optional Markov-modulated [`Burst`]
//! mode, each class's Poisson stream seeded deterministically from the
//! root seed) and dollar pricing — [`CostSummary::dollars`] converts
//! slot-time and WAN egress into provisioned/used/waste dollars with a
//! per-tenant bill that provably sums to the fabric total. All knobs
//! default off, and the default-knob campaign is bit-identical to the
//! pre-policy one (test-pinned, and byte-diffed by the
//! `campaign-golden` CI job).
//!
//! Multi-site federation (DESIGN.md §15) adds brokered placement on
//! top: `--sites` promotes candidate DCAI facilities to first-class
//! [`Site`]s behind a [`Broker`] that scores every live site per
//! arriving task-group — predicted turnaround or predicted dollars —
//! applies the data-locality credit, and places deterministically.
//! `site=` fault windows take whole sites dark; running gangs are
//! checkpoint-migrated off them in one failover wave and queued work
//! parks until restore. Without `--sites` no broker is constructed and
//! the paper's fixed SLAC→ALCF path runs byte-identically.
//!
//! Sharded campaigns (DESIGN.md §13) split the user population across
//! independent fabric replicas; `sync_wan` (DESIGN.md §14) upgrades
//! that to conservative bounded-lag execution: shards advance in
//! lock-step virtual-time windows sized from the WAN topology, publish
//! their per-window WAN byte demand to a shared ledger, and a global
//! water-fill converts aggregate over-subscription into per-shard WAN
//! slowdown factors for the next window — so cross-shard transfers
//! contend for the physical links instead of each replica claiming the
//! full pipe.

use anyhow::{Context, Result};

use super::closedloop::{ClosedLoopLedger, ClosedLoopSpec, DriftStream, ServeOutcome};
use super::coordinator::{extract_breakdown, RetrainBreakdown};
use super::federation::{Broker, FederationSummary, Placement, Site};
use super::flow::{dnn_trainer_flow, FlowShape};
use super::scenario::Scenario;
use super::world::{SpotLedger, Tenant, TrainingMode, World};
use crate::auth::TokenId;
use crate::costmodel::PriceBook;
use crate::faas::{Autoscaler, FuncId, PolicyKind, ScalingEvent};
use crate::flows::{
    FabricHost, FlowDefinition, FlowEngine, FlowRun, RunPoll, RunReport, Ticket,
};
use crate::pool::{Pool, ScopeTask};
use crate::simnet::{FaultPlan, Scheduler, Topology, VClock};
use crate::util::stats::{integrate_step, jain_index, percentile};
use crate::util::{Json, Rng};

/// Markov-modulated (bursty) arrival mode for one tenant class
/// (DESIGN.md §11): the class's Poisson stream alternates
/// exponentially-distributed *calm* and *burst* phases. During a burst
/// the arrival rate is multiplied by `factor`; `duty` is the stationary
/// fraction of time spent bursting. The mean phase cycle is
/// [`BURST_CYCLE_MEANS`] mean inter-arrival gaps, so bursts are long
/// enough to pile users onto the trainer but short against a campaign.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Burst {
    /// arrival-rate multiplier inside burst phases (must be > 1)
    pub factor: f64,
    /// stationary fraction of time in burst phases (0 < duty < 1)
    pub duty: f64,
}

/// Mean calm+burst phase cycle, in units of the class's mean
/// inter-arrival gap (mean burst phase = `duty × cycle`, mean calm
/// phase = `(1 − duty) × cycle`).
pub const BURST_CYCLE_MEANS: f64 = 10.0;

/// One tenant class of a heterogeneous campaign: which model its users
/// retrain, what share of the user population it gets, how many trainer
/// capacity slots its training jobs gang over (DESIGN.md §10), and —
/// optionally — its own arrival process (DESIGN.md §11).
#[derive(Debug, Clone, PartialEq)]
pub struct MixEntry {
    pub model: String,
    /// target share of the user population (weights are normalized;
    /// users are apportioned deterministically by largest remainder,
    /// so a 0.7/0.3 mix of 10 users is exactly 7/3 — no sampling noise
    /// between policy-sweep rows)
    pub weight: f64,
    /// gang width of this class's `train_model` jobs
    pub slots: usize,
    /// mean inter-arrival seconds for this class's own Poisson stream
    /// (`None` = the campaign-wide `mean_interarrival_s`). Setting a
    /// rate (or a burst) on *any* entry switches the whole campaign to
    /// per-class arrival streams.
    pub rate_s: Option<f64>,
    /// optional Markov-modulated burst mode for this class's stream
    pub burst: Option<Burst>,
}

impl MixEntry {
    /// A plain entry (no per-class arrival process) — the DESIGN.md §10
    /// shape.
    pub fn new(model: impl Into<String>, weight: f64, slots: usize) -> MixEntry {
        MixEntry {
            model: model.into(),
            weight,
            slots,
            rate_s: None,
            burst: None,
        }
    }
}

/// Parse a burst token: `burst=FACTOR@DUTY`, e.g. `burst=4@0.25`.
fn parse_burst(tok: &str) -> Result<Burst> {
    let spec = tok
        .strip_prefix("burst=")
        .with_context(|| format!("bad burst spec `{tok}` (want burst=factor@duty)"))?;
    let (factor, duty) = spec
        .split_once('@')
        .with_context(|| format!("bad burst spec `{tok}` (want burst=factor@duty)"))?;
    let factor: f64 = factor
        .parse()
        .map_err(|_| anyhow::anyhow!("bad burst factor `{factor}` in `{tok}`"))?;
    let duty: f64 = duty
        .parse()
        .map_err(|_| anyhow::anyhow!("bad burst duty `{duty}` in `{tok}`"))?;
    anyhow::ensure!(
        factor.is_finite() && factor > 1.0,
        "burst factor must be > 1 in `{tok}`"
    );
    anyhow::ensure!(
        duty.is_finite() && duty > 0.0 && duty < 1.0,
        "burst duty must be in (0, 1) in `{tok}`"
    );
    Ok(Burst { factor, duty })
}

/// Parse a `--mix` spec: `model:weight[:slots[:rate_s[:burst=F@D]]]`
/// entries joined by commas — e.g. `braggnn:0.7:1,cookienetae:0.3:4`
/// (DESIGN.md §10 shape) or `braggnn:0.7:1:30,cookienetae:0.3:4:120:burst=4@0.25`
/// (per-class arrivals, DESIGN.md §11).
pub fn parse_mix(spec: &str) -> Result<Vec<MixEntry>> {
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let parts: Vec<&str> = tok.split(':').collect();
        anyhow::ensure!(
            (2..=5).contains(&parts.len()),
            "bad mix entry `{tok}` (want model:weight[:slots[:rate_s[:burst=F@D]]])"
        );
        let weight: f64 = parts[1]
            .parse()
            .map_err(|_| anyhow::anyhow!("bad mix weight `{}` in `{tok}`", parts[1]))?;
        anyhow::ensure!(
            weight.is_finite() && weight > 0.0,
            "mix weight must be positive in `{tok}`"
        );
        let slots: usize = if parts.len() >= 3 {
            parts[2]
                .parse()
                .map_err(|_| anyhow::anyhow!("bad mix slots `{}` in `{tok}`", parts[2]))?
        } else {
            1
        };
        anyhow::ensure!(slots >= 1, "mix slots must be >= 1 in `{tok}`");
        let rate_s: Option<f64> = if parts.len() >= 4 {
            let r: f64 = parts[3]
                .parse()
                .map_err(|_| anyhow::anyhow!("bad mix rate `{}` in `{tok}`", parts[3]))?;
            anyhow::ensure!(
                r.is_finite() && r >= 0.0,
                "mix rate must be finite and >= 0 in `{tok}` (0 = all at once)"
            );
            Some(r)
        } else {
            None
        };
        let burst = if parts.len() == 5 {
            Some(parse_burst(parts[4])?)
        } else {
            None
        };
        out.push(MixEntry {
            model: parts[0].to_string(),
            weight,
            slots,
            rate_s,
            burst,
        });
    }
    Ok(out)
}

/// Generate `n` arrival instants for one tenant class (DESIGN.md §11).
///
/// Plain mode is a Poisson process: i.i.d. exponential gaps with mean
/// `mean_gap_s` (unlike the shared default stream, no user is pinned
/// to t = 0 — each class's first arrival is one drawn gap in). Burst
/// mode is an exact two-state Markov-modulated Poisson process:
/// exponential phase lengths, and because the exponential is
/// memoryless, re-drawing the arrival gap at each phase boundary
/// samples the MMPP exactly. `mean_gap_s <= 0` launches the whole
/// class at t = 0.
fn class_arrivals(n: usize, mean_gap_s: f64, burst: Option<Burst>, rng: &mut Rng) -> Vec<f64> {
    if mean_gap_s <= 0.0 {
        return vec![0.0; n];
    }
    let base_rate = 1.0 / mean_gap_s;
    let mut out = Vec::with_capacity(n);
    match burst {
        None => {
            let mut t = 0.0;
            for _ in 0..n {
                t += rng.exponential(base_rate);
                out.push(t);
            }
        }
        Some(b) => {
            let cycle = BURST_CYCLE_MEANS * mean_gap_s;
            let mean_phase = |in_burst: bool| {
                if in_burst {
                    b.duty * cycle
                } else {
                    (1.0 - b.duty) * cycle
                }
            };
            let mut t = 0.0;
            let mut in_burst = false;
            let mut phase_end = rng.exponential(1.0 / mean_phase(false));
            for _ in 0..n {
                loop {
                    let rate = if in_burst { base_rate * b.factor } else { base_rate };
                    let gap = rng.exponential(rate);
                    if t + gap <= phase_end {
                        t += gap;
                        break;
                    }
                    t = phase_end;
                    in_burst = !in_burst;
                    phase_end = t + rng.exponential(1.0 / mean_phase(in_burst));
                }
                out.push(t);
            }
        }
    }
    out
}

/// Deterministic largest-remainder apportionment of users to mix
/// entries: user `i` goes to the entry with the largest unmet quota
/// `weight_e · (i+1) − assigned_e` (ties to the earlier entry). Exact
/// shares, no sampling noise — a policy sweep compares policies, not
/// assignment draws.
fn apportion_mix(mix: &[MixEntry], users: usize) -> Vec<usize> {
    let total: f64 = mix.iter().map(|e| e.weight).sum();
    let mut assigned = vec![0usize; mix.len()];
    let mut out = Vec::with_capacity(users);
    for i in 0..users {
        let mut best = 0usize;
        let mut best_deficit = f64::NEG_INFINITY;
        for (e, entry) in mix.iter().enumerate() {
            let deficit = entry.weight / total * (i + 1) as f64 - assigned[e] as f64;
            if deficit > best_deficit + 1e-12 {
                best = e;
                best_deficit = deficit;
            }
        }
        assigned[best] += 1;
        out.push(best);
    }
    out
}

/// One spot-tier (preemptible) endpoint of a campaign (DESIGN.md §12).
///
/// Preemptions arrive as a Poisson process with mean inter-preemption
/// gap `preempt_rate_s`; each is announced `grace_s` seconds before the
/// slots disappear — the drain window in which running gangs fall back
/// to their last checkpoint boundary and short tasks finish normally.
#[derive(Debug, Clone, PartialEq)]
pub struct SpotSpec {
    pub endpoint: String,
    /// mean seconds between preemptions (exponential gaps)
    pub preempt_rate_s: f64,
    /// seconds between the reclaim warning and the slots disappearing
    pub grace_s: f64,
}

/// Parse a `--spot` spec: comma-joined `endpoint:mean_gap_s:grace_s`
/// entries, e.g. `alcf#cerebras:900:30`. Non-positive mean gaps,
/// negative graces, and duplicate endpoints are rejected.
pub fn parse_spot(spec: &str) -> Result<Vec<SpotSpec>> {
    let mut out: Vec<SpotSpec> = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let parts: Vec<&str> = tok.split(':').collect();
        anyhow::ensure!(
            parts.len() == 3,
            "bad spot entry `{tok}` (want endpoint:mean_gap_s:grace_s)"
        );
        let rate: f64 = parts[1]
            .parse()
            .map_err(|_| anyhow::anyhow!("bad spot mean gap `{}` in `{tok}`", parts[1]))?;
        let grace: f64 = parts[2]
            .parse()
            .map_err(|_| anyhow::anyhow!("bad spot grace `{}` in `{tok}`", parts[2]))?;
        anyhow::ensure!(
            rate.is_finite() && rate > 0.0,
            "spot mean gap must be finite and > 0 in `{tok}`"
        );
        anyhow::ensure!(
            grace.is_finite() && grace >= 0.0,
            "spot grace must be finite and >= 0 in `{tok}`"
        );
        anyhow::ensure!(
            out.iter().all(|s| s.endpoint != parts[0]),
            "duplicate spot entry for `{}`",
            parts[0]
        );
        out.push(SpotSpec {
            endpoint: parts[0].to_string(),
            preempt_rate_s: rate,
            grace_s: grace,
        });
    }
    Ok(out)
}

/// Salt folded into the root seed for each spot endpoint's preemption
/// stream, so spot draws never perturb the arrival streams.
const SPOT_SALT: u64 = 0x5B07_71E2_D15C_0A11;

/// Salt folded into the root seed for each shard's derived seed
/// (DESIGN.md §13), so a shard's arrival/spot streams never collide
/// with the unsharded streams or with another shard's.
const SHARD_SALT: u64 = 0x51A2_D0E5_7AC7_1C33;

/// Users per shard when `shards == 0` auto-sizes the partition. The
/// shard count is a pure function of the user count — **never** of the
/// thread count — so reports are identical under any `XLOOP_THREADS`.
/// Campaigns at or below this size stay on the serial path.
pub const AUTO_SHARD_USERS: usize = 4096;

/// The seed a shard's campaign runs under: a SplitMix-style derivation
/// from the root seed and the shard index (the PR 1 chunked-RNG trick).
fn shard_seed(root: u64, shard: usize) -> u64 {
    root ^ SHARD_SALT ^ (shard as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
}

/// The accelerator class of a fabric endpoint id
/// (`alcf#cerebras` → `cerebras`) — what the broker places by.
fn endpoint_class(endpoint: &str) -> &str {
    endpoint.split_once('#').map(|(_, c)| c).unwrap_or(endpoint)
}

/// Salt folded into the root seed for each user's serving-drift
/// stream (DESIGN.md §16), so drift draws never perturb the arrival
/// or spot streams; per-user decorrelation reuses the golden-ratio
/// multiplier via [`super::closedloop::per_user_seed`].
const DRIFT_SALT: u64 = 0xD21F_7A11_0C10_5EDB;

/// Mean spot restore delay as a fraction of the mean preemption gap:
/// reclaimed pools come back an order of magnitude faster than they are
/// taken (≈91% stationary availability), matching the short reclaim
/// windows preemptible cloud pools exhibit.
pub const SPOT_RESTORE_FRACTION: f64 = 0.1;

/// One campaign: N users retraining on one shared fabric — the same
/// scenario for everyone by default, or a heterogeneous tenant `mix`.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub users: usize,
    pub scenario: Scenario,
    /// mean seconds between user arrivals (Poisson process; the first
    /// user arrives at t=0). `<= 0` launches everyone at once.
    pub mean_interarrival_s: f64,
    /// seed for the arrival process (the fabric uses `scenario.seed`)
    pub seed: u64,
    /// faas scheduling policy (default FIFO — bit-identical to PR 2)
    pub policy: PolicyKind,
    /// per-user priority classes, cycled over the user index (empty =
    /// every user priority 0); only `PolicyKind::Priority` orders by it
    pub priorities: Vec<i64>,
    /// autoscalers to attach, by endpoint id (empty = fixed capacity)
    pub autoscale: Vec<(String, Autoscaler)>,
    /// scheduled endpoint outages / WAN brownouts (empty = fault-free).
    /// With a non-empty plan, users whose flows exhaust their retries
    /// are reported as failed instead of aborting the campaign.
    pub faults: FaultPlan,
    /// heterogeneous tenant mix (empty = every user runs `scenario`).
    /// Entries apportion the user population by weight; each user
    /// retrains their entry's model (same training mode/endpoint as
    /// `scenario` — the classes *share* the trainer, which is the whole
    /// point) with their entry's gang width. When the widest gang
    /// exceeds the trainer's capacity, the campaign sizes the trainer
    /// up to it (or validates an attached autoscaler covers it).
    pub mix: Vec<MixEntry>,
    /// spot-tier endpoints (empty = everything on-demand). Each runs a
    /// deterministically seeded Poisson preemption process: a warning
    /// opens the `grace_s` drain window, then the slots vanish and the
    /// failover planner migrates the displaced gangs (DESIGN.md §12).
    /// As with fault plans, users whose flows exhaust their retries are
    /// reported as failed instead of aborting the campaign.
    pub spot: Vec<SpotSpec>,
    /// checkpoint cadence for training gangs, in virtual seconds of
    /// training progress (`None` = no checkpoints: a preempted gang
    /// loses everything since its start)
    pub checkpoint_every_s: Option<f64>,
    /// shard count for parallel execution (DESIGN.md §13). `0` = auto:
    /// serial up to [`AUTO_SHARD_USERS`] users, then one shard per
    /// `AUTO_SHARD_USERS` — a pure function of the user count, never of
    /// the thread count, so reports are `XLOOP_THREADS`-invariant.
    /// `1` forces the serial path. Each shard is an **independent
    /// fabric replica** serving a contiguous slice of the user
    /// population with its own derived arrival/spot streams; the merge
    /// is deterministic in shard order.
    pub shards: usize,
    /// users per shard for the `shards == 0` auto-split (`0` = the
    /// built-in [`AUTO_SHARD_USERS`], overridable by the
    /// `XLOOP_SHARD_USERS` environment variable). Ignored when
    /// `shards` is explicit. Like the shard count itself, this is a
    /// pure function of the config and environment — never of the
    /// thread count.
    pub shard_users: usize,
    /// conservative bounded-lag window synchronization across shards
    /// (DESIGN.md §14): shards advance in lock-step virtual-time
    /// windows and share the physical WAN through a per-window demand
    /// ledger and global water-fill, instead of each replica claiming
    /// the full pipe. `false` (the default) keeps the independent
    /// fabric-replica semantics, byte-identical to PR 6/7; at an
    /// effective shard count of 1 the flag is a no-op — the serial
    /// path never contends with itself.
    pub sync_wan: bool,
    /// extra federation sites behind the placement broker (DESIGN.md
    /// §15; empty = no broker, the paper's fixed SLAC→ALCF path,
    /// byte-identical to every earlier PR). Build with
    /// [`super::federation::parse_sites`].
    pub sites: Vec<Site>,
    /// which score the broker minimizes when `sites` is non-empty
    /// (ignored otherwise)
    pub placement: Placement,
    /// closed-loop serving drift (DESIGN.md §16; `None` = the
    /// exogenous-arrival semantics of every earlier PR, byte-identical
    /// output). `Some(spec)` replaces the Poisson arrival plan with
    /// per-user drift streams: each user serves batches on the edge
    /// device until their fit-residual EWMA trips the trigger, which
    /// *admits* their retraining flow into the fabric; the completed
    /// retrain hot-swaps the served model and resets the drift clock.
    pub closed_loop: Option<ClosedLoopSpec>,
}

impl Default for CampaignConfig {
    /// One user of the default scenario with every knob at its
    /// disabled default — the root of the `with_*` builder chain.
    fn default() -> CampaignConfig {
        CampaignConfig {
            users: 1,
            scenario: Scenario::default(),
            mean_interarrival_s: 60.0,
            seed: 42,
            policy: PolicyKind::Fifo,
            priorities: Vec::new(),
            autoscale: Vec::new(),
            faults: FaultPlan::default(),
            mix: Vec::new(),
            spot: Vec::new(),
            checkpoint_every_s: None,
            shards: 0,
            shard_users: 0,
            sync_wan: false,
            sites: Vec::new(),
            placement: Placement::Turnaround,
            closed_loop: None,
        }
    }
}

impl CampaignConfig {
    /// A campaign with every DESIGN.md §9 knob at its default (FIFO,
    /// no autoscaling, no faults, uniform priorities). A thin shim
    /// over the [`CampaignConfig::default`] builder chain, kept for
    /// the positional callers of earlier PRs.
    pub fn new(
        users: usize,
        scenario: Scenario,
        mean_interarrival_s: f64,
        seed: u64,
    ) -> CampaignConfig {
        CampaignConfig::default()
            .with_users(users)
            .with_scenario(scenario)
            .with_interarrival_s(mean_interarrival_s)
            .with_seed(seed)
    }

    pub fn with_users(mut self, users: usize) -> CampaignConfig {
        self.users = users;
        self
    }

    pub fn with_scenario(mut self, scenario: Scenario) -> CampaignConfig {
        self.scenario = scenario;
        self
    }

    pub fn with_interarrival_s(mut self, mean_interarrival_s: f64) -> CampaignConfig {
        self.mean_interarrival_s = mean_interarrival_s;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> CampaignConfig {
        self.seed = seed;
        self
    }

    pub fn with_policy(mut self, policy: PolicyKind) -> CampaignConfig {
        self.policy = policy;
        self
    }

    pub fn with_priorities(mut self, priorities: Vec<i64>) -> CampaignConfig {
        self.priorities = priorities;
        self
    }

    pub fn with_autoscale(mut self, autoscale: Vec<(String, Autoscaler)>) -> CampaignConfig {
        self.autoscale = autoscale;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> CampaignConfig {
        self.faults = faults;
        self
    }

    pub fn with_mix(mut self, mix: Vec<MixEntry>) -> CampaignConfig {
        self.mix = mix;
        self
    }

    pub fn with_spot(mut self, spot: Vec<SpotSpec>) -> CampaignConfig {
        self.spot = spot;
        self
    }

    pub fn with_checkpoint_every_s(mut self, cadence: Option<f64>) -> CampaignConfig {
        self.checkpoint_every_s = cadence;
        self
    }

    pub fn with_shards(mut self, shards: usize) -> CampaignConfig {
        self.shards = shards;
        self
    }

    pub fn with_shard_users(mut self, shard_users: usize) -> CampaignConfig {
        self.shard_users = shard_users;
        self
    }

    pub fn with_sync_wan(mut self, sync_wan: bool) -> CampaignConfig {
        self.sync_wan = sync_wan;
        self
    }

    pub fn with_sites(mut self, sites: Vec<Site>) -> CampaignConfig {
        self.sites = sites;
        self
    }

    pub fn with_placement(mut self, placement: Placement) -> CampaignConfig {
        self.placement = placement;
        self
    }

    pub fn with_closed_loop(mut self, closed_loop: Option<ClosedLoopSpec>) -> CampaignConfig {
        self.closed_loop = closed_loop;
        self
    }

    fn user_priority(&self, i: usize) -> i64 {
        if self.priorities.is_empty() {
            0
        } else {
            self.priorities[i % self.priorities.len()]
        }
    }
}

/// Outcome for one user's retraining.
#[derive(Debug, Clone)]
pub struct UserOutcome {
    pub user: usize,
    /// the model this user retrained (differs across users only under
    /// a heterogeneous mix)
    pub model: String,
    /// gang width of this user's training job
    pub gang_slots: usize,
    pub arrival_vt: f64,
    /// when the user's flow (including deploy) finished
    pub finished_vt: f64,
    /// arrival to deployed model, the loaded-facility turnaround
    pub turnaround_s: f64,
    /// whether the flow succeeded (false only possible under a
    /// `FaultPlan` or spot preemption process that exhausted an
    /// action's retries)
    pub succeeded: bool,
    /// the Table 1 per-phase breakdown of this user's flow (`None` for
    /// failed users)
    pub breakdown: Option<RetrainBreakdown>,
    /// total faas capacity-slot queue wait across this user's tasks
    pub queue_wait_s: f64,
    /// queueing slowdown: `turnaround / (turnaround - queue_wait)` —
    /// 1.0 means the user never waited for a slot
    pub slowdown: f64,
}

/// Per-user fairness across the campaign (DESIGN.md §9): slowdown
/// moments/percentiles and Jain's index over per-user slowdowns.
#[derive(Debug, Clone)]
pub struct FairnessSummary {
    pub mean_slowdown: f64,
    pub max_slowdown: f64,
    pub p50_slowdown: f64,
    pub p95_slowdown: f64,
    /// Jain's fairness index over per-user slowdowns (1.0 = every user
    /// slowed equally; → 1/N as one user absorbs all the queueing)
    pub jain: f64,
}

/// Slot-time cost of one endpoint over the campaign (DESIGN.md §10).
///
/// "Provisioned" integrates the endpoint's capacity over the campaign
/// window `[0, makespan]` — every slot-second the facility had to keep
/// powered, used or not — with autoscaler capacity changes applied at
/// their `ScalingEvent` instants. "Used" sums each task's execution
/// time weighted by its gang width. The difference is idle cost; the
/// share of it attributable to autoscaling is the scale-up waste.
#[derive(Debug, Clone)]
pub struct EndpointCost {
    pub endpoint: String,
    /// capacity at campaign start (after any mix-driven sizing)
    pub base_capacity: usize,
    /// highest capacity the endpoint reached
    pub peak_capacity: usize,
    /// ∫ capacity dt over the campaign window, in slot-seconds
    pub provisioned_slot_s: f64,
    /// Σ execution seconds × gang width over completed tasks
    pub used_slot_s: f64,
    /// ∫ max(capacity − base, 0) dt — slot-seconds added by scale-ups
    pub scaleup_slot_s: f64,
}

impl EndpointCost {
    /// Provisioned-but-unused slot-seconds.
    pub fn idle_slot_s(&self) -> f64 {
        (self.provisioned_slot_s - self.used_slot_s).max(0.0)
    }

    /// Fraction of provisioned slot-time that ran work.
    pub fn utilization(&self) -> f64 {
        if self.provisioned_slot_s <= 0.0 {
            0.0
        } else {
            (self.used_slot_s / self.provisioned_slot_s).min(1.0)
        }
    }

    /// Idle slot-seconds attributable to autoscaling, under the
    /// convention that base slots absorb work first: the scaled-up
    /// slot-time that cannot be covered by actual usage beyond what
    /// the base capacity could have served.
    pub fn scaleup_waste_slot_s(&self) -> f64 {
        self.scaleup_slot_s.min(self.idle_slot_s())
    }
}

/// Campaign-wide cost accounting: per-endpoint slot-time economics
/// plus per-tenant attributed usage — the dollars-proxy that lets
/// autoscaler policies be compared on cost as well as slowdown/Jain.
/// [`CostSummary::dollars`] turns it into real dollars under a
/// `PriceBook` (DESIGN.md §11).
#[derive(Debug, Clone)]
pub struct CostSummary {
    /// every endpoint of the fabric, in id order (idle endpoints still
    /// accrue provisioned cost — that is the point)
    pub endpoints: Vec<EndpointCost>,
    /// used slot-seconds attributed to each user (index = user − 1)
    /// via task metadata
    pub per_user_slot_s: Vec<f64>,
    /// used slot-seconds per user *per endpoint* (index = user − 1) —
    /// the resolution dollarization needs, since rates differ per
    /// endpoint class
    pub per_user_endpoint_slot_s: Vec<std::collections::BTreeMap<String, f64>>,
    /// scale-up waste slot-seconds per user per endpoint (index =
    /// user − 1), attributed to the tenant whose demand fired each
    /// `ScalingEvent` (its `trigger_user`) via a LIFO above-base slot
    /// ledger, then scaled so the per-endpoint sums equal that
    /// endpoint's `scaleup_waste_slot_s()` exactly
    pub per_user_scaleup_waste: Vec<std::collections::BTreeMap<String, f64>>,
    /// total bytes that crossed the WAN over the campaign,
    /// retransmissions included (the wire does not refund retries)
    pub egress_bytes: f64,
    /// WAN bytes attributed to each user (index = user − 1) via the
    /// transfer log's tenant tags
    pub per_user_egress_bytes: Vec<f64>,
    /// endpoints that ran as spot capacity — billed at the `class:spot`
    /// rate by [`CostSummary::dollars`] (DESIGN.md §12)
    pub spot_endpoints: std::collections::BTreeSet<String>,
}

impl CostSummary {
    pub fn endpoint(&self, id: &str) -> Option<&EndpointCost> {
        self.endpoints.iter().find(|e| e.endpoint == id)
    }

    pub fn total_provisioned_slot_s(&self) -> f64 {
        self.endpoints.iter().map(|e| e.provisioned_slot_s).sum()
    }

    pub fn total_used_slot_s(&self) -> f64 {
        self.endpoints.iter().map(|e| e.used_slot_s).sum()
    }

    pub fn total_scaleup_waste_slot_s(&self) -> f64 {
        self.endpoints.iter().map(|e| e.scaleup_waste_slot_s()).sum()
    }

    /// Scale-up waste slot-seconds attributed to one user (index =
    /// user − 1), summed across endpoints.
    pub fn user_scaleup_waste_slot_s(&self, user_idx: usize) -> f64 {
        self.per_user_scaleup_waste
            .get(user_idx)
            .map(|m| m.values().sum())
            .unwrap_or(0.0)
    }

    /// Price the campaign in dollars under `book` (DESIGN.md §11).
    ///
    /// Per endpoint: provisioned/used/waste slot-seconds × the class's
    /// $/slot-hour. The **fabric total** is every provisioned
    /// slot-dollar plus egress dollars — what the facility actually
    /// paid, idle capacity included. The per-tenant bill partitions
    /// that total exactly: each endpoint's provisioned dollars are
    /// split by the tenants' shares of its *used* slot-time (an
    /// endpoint nobody used is facility overhead, split evenly), and
    /// egress dollars follow the transfer log's tenant tags (untagged
    /// bytes, absent in campaigns, split evenly). The shares are a
    /// partition of unity per endpoint, so
    /// `Σ per_tenant[i].total_usd() == total_usd()` holds by
    /// construction — the invariant the cost tests pin. Endpoints in
    /// `spot_endpoints` are billed at the discounted `class:spot` rate
    /// (DESIGN.md §12); one rate per endpoint, so the partition is
    /// untouched by the tier split.
    pub fn dollars(&self, book: &PriceBook) -> DollarSummary {
        let users = self.per_user_slot_s.len();
        let mut per_tenant: Vec<TenantDollars> = (1..=users)
            .map(|user| TenantDollars {
                user,
                used_usd: 0.0,
                idle_share_usd: 0.0,
                scaleup_waste_usd: 0.0,
                egress_usd: 0.0,
            })
            .collect();
        let mut endpoints = Vec::with_capacity(self.endpoints.len());
        for e in &self.endpoints {
            let spot = self.spot_endpoints.contains(&e.endpoint);
            let prov_usd = book.slot_dollars_tiered(&e.endpoint, e.provisioned_slot_s, spot);
            let used_by_user: Vec<f64> = (0..users)
                .map(|u| {
                    self.per_user_endpoint_slot_s[u]
                        .get(&e.endpoint)
                        .copied()
                        .unwrap_or(0.0)
                })
                .collect();
            let used_total: f64 = used_by_user.iter().sum();
            for u in 0..users {
                let share = if used_total > 0.0 {
                    used_by_user[u] / used_total
                } else {
                    1.0 / users as f64
                };
                let used_usd = book.slot_dollars_tiered(&e.endpoint, used_by_user[u], spot);
                per_tenant[u].used_usd += used_usd;
                per_tenant[u].idle_share_usd += share * prov_usd - used_usd;
                per_tenant[u].scaleup_waste_usd += book.slot_dollars_tiered(
                    &e.endpoint,
                    self.per_user_scaleup_waste[u]
                        .get(&e.endpoint)
                        .copied()
                        .unwrap_or(0.0),
                    spot,
                );
            }
            endpoints.push(EndpointDollars {
                endpoint: e.endpoint.clone(),
                rate_per_slot_hour: book.rate_per_slot_hour_tiered(&e.endpoint, spot),
                provisioned_usd: prov_usd,
                used_usd: book.slot_dollars_tiered(&e.endpoint, e.used_slot_s, spot),
                scaleup_waste_usd: book.slot_dollars_tiered(
                    &e.endpoint,
                    e.scaleup_waste_slot_s(),
                    spot,
                ),
            });
        }
        let tagged: f64 = self.per_user_egress_bytes.iter().sum();
        let untagged = (self.egress_bytes - tagged).max(0.0);
        for u in 0..users {
            per_tenant[u].egress_usd =
                book.egress_dollars(self.per_user_egress_bytes[u] + untagged / users as f64);
        }
        DollarSummary {
            endpoints,
            egress_bytes: self.egress_bytes,
            egress_usd: book.egress_dollars(self.egress_bytes),
            per_tenant,
        }
    }
}

/// One endpoint's slot-time economics in dollars (DESIGN.md §11).
#[derive(Debug, Clone)]
pub struct EndpointDollars {
    pub endpoint: String,
    /// the `PriceBook` rate applied (0.0 = unpriced class; spot
    /// endpoints carry their discounted `class:spot` rate)
    pub rate_per_slot_hour: f64,
    pub provisioned_usd: f64,
    pub used_usd: f64,
    pub scaleup_waste_usd: f64,
}

/// One tenant's bill (DESIGN.md §11). `used + idle share + egress` is
/// the tenant's total; the scale-up waste line is a *memo* — the part
/// of the fabric's waste traceable to scale-ups this tenant's demand
/// triggered — not an additional charge.
#[derive(Debug, Clone)]
pub struct TenantDollars {
    /// 1-based campaign user index
    pub user: usize,
    /// slot-dollars for work this tenant actually ran
    pub used_usd: f64,
    /// this tenant's share of provisioned-but-unused capacity dollars
    /// (split by used-slot-time share per endpoint)
    pub idle_share_usd: f64,
    /// memo: waste dollars from scale-ups this tenant triggered
    pub scaleup_waste_usd: f64,
    /// WAN egress dollars for this tenant's transfers
    pub egress_usd: f64,
}

impl TenantDollars {
    /// The tenant's bill: used + idle share + egress.
    pub fn total_usd(&self) -> f64 {
        self.used_usd + self.idle_share_usd + self.egress_usd
    }
}

/// The campaign priced in dollars (DESIGN.md §11): per-endpoint lines,
/// egress, and the per-tenant bills that partition the fabric total.
#[derive(Debug, Clone)]
pub struct DollarSummary {
    pub endpoints: Vec<EndpointDollars>,
    pub egress_bytes: f64,
    pub egress_usd: f64,
    pub per_tenant: Vec<TenantDollars>,
}

impl DollarSummary {
    pub fn provisioned_usd(&self) -> f64 {
        self.endpoints.iter().map(|e| e.provisioned_usd).sum()
    }

    pub fn used_usd(&self) -> f64 {
        self.endpoints.iter().map(|e| e.used_usd).sum()
    }

    pub fn scaleup_waste_usd(&self) -> f64 {
        self.endpoints.iter().map(|e| e.scaleup_waste_usd).sum()
    }

    /// The fabric total: every provisioned slot-dollar plus egress —
    /// exactly what the per-tenant bills sum to (test-pinned).
    pub fn total_usd(&self) -> f64 {
        self.provisioned_usd() + self.egress_usd
    }
}

/// Aggregate faas load on one endpoint over the campaign.
#[derive(Debug, Clone)]
pub struct EndpointLoad {
    pub endpoint: String,
    pub tasks: u64,
    pub total_queue_wait_s: f64,
    pub max_queue_wait_s: f64,
}

impl EndpointLoad {
    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.tasks == 0 {
            0.0
        } else {
            self.total_queue_wait_s / self.tasks as f64
        }
    }
}

/// Full campaign outcome.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub config_users: usize,
    pub mean_interarrival_s: f64,
    pub users: Vec<UserOutcome>,
    pub endpoint_loads: Vec<EndpointLoad>,
    /// mean per-task goodput over every WAN transfer in the campaign
    pub mean_task_throughput_bps: f64,
    /// number of WAN transfers behind that mean — the weight a
    /// deterministic shard merge needs to keep the mean exact
    pub wan_transfers: u64,
    /// first arrival to last deployment
    pub makespan_s: f64,
    /// the scheduling policy the faas fabric ran under
    pub policy: PolicyKind,
    /// per-user fairness metrics (over all users, failed included —
    /// their queueing was real)
    pub fairness: FairnessSummary,
    /// autoscaler capacity changes, in virtual-time order
    pub scaling: Vec<ScalingEvent>,
    /// 1-based indices of users whose flows failed under the fault plan
    /// or the spot preemption process
    pub failed_users: Vec<usize>,
    /// slot-time cost accounting (DESIGN.md §10)
    pub cost: CostSummary,
    /// spot-tier activity — preemptions, migrations, checkpoint/loss
    /// accounting (DESIGN.md §12); `None` when no endpoint ran as spot
    pub spot: Option<SpotLedger>,
    /// federation placement stats — per-site placements, locality
    /// hits, outage reroutes (DESIGN.md §15); `None` without `--sites`
    pub federation: Option<FederationSummary>,
    /// how many shards the campaign actually ran across (1 = serial)
    pub shards: usize,
    /// the per-shard user width the partition was carved with (for a
    /// serial run this is just the user count)
    pub shard_users: usize,
    /// bounded-lag windows executed under `sync_wan` (DESIGN.md §14);
    /// `0` in replica mode and on the serial path
    pub sync_wan_windows: u64,
    /// closed-loop serving/drift integrals — batches served, triggers,
    /// hot-swaps, staleness and accuracy-loss seconds (DESIGN.md §16);
    /// `None` without `--closed-loop`
    pub closed_loop: Option<ClosedLoopLedger>,
}

impl CampaignReport {
    /// Nearest-rank percentile of user turnaround (q in [0, 100]).
    pub fn turnaround_percentile(&self, q: f64) -> f64 {
        let mut ts: Vec<f64> = self.users.iter().map(|u| u.turnaround_s).collect();
        if ts.is_empty() {
            return 0.0;
        }
        ts.sort_by(f64::total_cmp);
        let idx = ((q / 100.0) * (ts.len() - 1) as f64).round() as usize;
        ts[idx.min(ts.len() - 1)]
    }

    pub fn max_turnaround_s(&self) -> f64 {
        self.users
            .iter()
            .map(|u| u.turnaround_s)
            .fold(0.0, f64::max)
    }

    pub fn load(&self, endpoint: &str) -> Option<&EndpointLoad> {
        self.endpoint_loads.iter().find(|l| l.endpoint == endpoint)
    }
}

/// Per-user progress through the campaign.
enum UserState {
    /// not yet arrived
    Waiting,
    /// dataset generation queued on `slac#sim`
    Preparing(Ticket),
    /// flow in progress
    Running(FlowRun),
    Done(RunReport),
}

/// Events on the campaign's scheduler: user arrivals and fault-plan
/// window edges are static and live in the heap; `Scan` wake-ups are
/// scheduled each round for the earliest *dynamic* source (a flow's
/// scheduled completion or a fabric state change, whose times shift
/// with contention). Spurious or stale wake-ups are harmless — every
/// firing just re-scans at `now`.
enum Wake {
    Arrival,
    Scan,
    /// apply the indexed [`FaultChange`] at its window edge
    Fault(usize),
    /// spot preemption announced on spec `i`: open the grace window
    /// (DESIGN.md §12)
    SpotWarn(usize),
    /// spec `i`'s grace window expired: reclaim the slots and run the
    /// failover migration planner
    SpotReclaim(usize),
    /// spec `i`'s pool restored: the endpoint takes starts again
    SpotRestore(usize),
    /// user `i` serves their next drift batch on the edge device
    /// (DESIGN.md §16): update the fit-residual EWMA, maybe fire the
    /// trigger (admitting the user's retraining flow), reschedule one
    /// batch gap later
    Drift(usize),
}

/// One scheduled fault-plan transition (a window edge turned into a
/// `des` event).
enum FaultChange {
    OutageStart(String),
    OutageEnd(String),
    /// index into the plan's `wan` list — activates its factor
    WanStart(usize),
    WanEnd(usize),
    /// index into the plan's `sites` list — the whole site goes dark:
    /// the broker stops placing there, running gangs are checkpoint-
    /// migrated off in one failover wave (DESIGN.md §15)
    SiteDown(usize),
    /// the site's endpoints take starts again (refcounted, like
    /// endpoint outages)
    SiteUp(usize),
}

/// Recompute and apply the effective WAN factor: the most severe
/// (smallest) factor among active degradation windows, 1.0 when none,
/// composed with the shard's bounded-lag `sync_factor` (DESIGN.md §14;
/// 1.0 outside `sync_wan` mode — and `x * 1.0` is IEEE-exact, so the
/// composition leaves the serial path bit-identical).
fn apply_wan_factor(world: &mut World, plan: &FaultPlan, active: &[bool], sync_factor: f64) {
    let factor = plan
        .wan
        .iter()
        .zip(active)
        .filter(|(_, &a)| a)
        .map(|(w, _)| w.factor)
        .fold(1.0f64, f64::min);
    world.transfer.set_wan_factor(factor * sync_factor);
}

/// Run a campaign to completion on a fresh paper fabric.
///
/// Every user runs the base scenario — or, under a heterogeneous
/// `mix`, their tenant class's model and gang width on the *same*
/// trainer (DESIGN.md §10). Per-user dataset names keep their data
/// disjoint; training is virtual-only — the campaign is a capacity
/// study, not a weights producer.
///
/// With an effective shard count above 1 (an explicit `cfg.shards` or
/// the `AUTO_SHARD_USERS` auto-split at scale) the user population is
/// partitioned across [`crate::pool::scope`] workers, each shard an
/// independent fabric replica, and the reports merged deterministically
/// (DESIGN.md §13). At an effective count of 1 this *is* the serial
/// path — byte-identical to every earlier PR. With `sync_wan` set the
/// shards instead advance in bounded-lag lock-step and share the
/// physical WAN through a windowed demand ledger (DESIGN.md §14).
///
/// A thin shim over [`CampaignRunner`] — identical to
/// `CampaignRunner::new(cfg).run()`, kept for the callers of earlier
/// PRs.
pub fn run_campaign(cfg: &CampaignConfig) -> Result<CampaignReport> {
    CampaignRunner::new(cfg).run()
}

/// Builder collapsing the campaign entry points behind one seam: the
/// config is mandatory, the pool optional (defaulting to the global
/// `XLOOP_THREADS` pool), and `run()` picks the serial, replica, or
/// bounded-lag executor exactly as the free functions did —
/// `CampaignRunner::new(cfg).pool(&p).run()` is byte-identical to
/// `run_campaign_with_pool(cfg, &p)`.
pub struct CampaignRunner<'p> {
    cfg: CampaignConfig,
    pool: Option<&'p Pool>,
}

impl<'p> CampaignRunner<'p> {
    pub fn new(cfg: &CampaignConfig) -> CampaignRunner<'p> {
        CampaignRunner {
            cfg: cfg.clone(),
            pool: None,
        }
    }

    /// Run shard tasks on an explicit pool instead of the global one —
    /// the seam the thread-count invariance tests drive.
    pub fn pool(mut self, pool: &'p Pool) -> CampaignRunner<'p> {
        self.pool = Some(pool);
        self
    }

    pub fn run(self) -> Result<CampaignReport> {
        run_campaign_impl(&self.cfg, self.pool.unwrap_or_else(Pool::global))
    }
}

/// The per-shard user width the `shards == 0` auto-split divides by:
/// an explicit `cfg.shard_users` wins, else the `XLOOP_SHARD_USERS`
/// environment override, else the built-in [`AUTO_SHARD_USERS`].
/// Unparsable or zero values fall through to the next tier.
fn auto_shard_users(cfg: &CampaignConfig) -> usize {
    if cfg.shard_users > 0 {
        return cfg.shard_users;
    }
    if let Ok(v) = std::env::var("XLOOP_SHARD_USERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    AUTO_SHARD_USERS
}

/// The effective shard count: explicit `shards` wins, else the
/// auto-split — both clamped to the user count so no shard is empty.
fn effective_shards(cfg: &CampaignConfig) -> usize {
    let s = if cfg.shards > 0 {
        cfg.shards
    } else {
        cfg.users.div_ceil(auto_shard_users(cfg).max(1))
    };
    s.clamp(1, cfg.users.max(1))
}

/// [`run_campaign`] on an explicit pool — the seam the thread-count
/// invariance test drives (the global pool reads `XLOOP_THREADS` once
/// per process, so a test cannot vary it). A thin shim over
/// [`CampaignRunner`], kept for the callers of earlier PRs.
pub fn run_campaign_with_pool(cfg: &CampaignConfig, pool: &Pool) -> Result<CampaignReport> {
    CampaignRunner::new(cfg).pool(pool).run()
}

/// The dispatch body behind [`CampaignRunner::run`] and both shims:
/// serial at an effective shard count of 1, else the replica carve —
/// handed to the bounded-lag executor under `sync_wan`.
fn run_campaign_impl(cfg: &CampaignConfig, pool: &Pool) -> Result<CampaignReport> {
    let shards = effective_shards(cfg);
    if shards <= 1 {
        return run_campaign_serial(cfg);
    }
    // contiguous balanced split, earlier shards take the remainder —
    // the same carve as `pool::split_ranges`, but recomputed here as a
    // pure function of (users, shards) so the partition can never
    // depend on worker count
    let base = cfg.users / shards;
    let rem = cfg.users % shards;
    let mut offsets = Vec::with_capacity(shards);
    let mut shard_cfgs = Vec::with_capacity(shards);
    let mut offset = 0usize;
    for s in 0..shards {
        let len = base + usize::from(s < rem);
        let mut sc = cfg.clone();
        sc.users = len;
        sc.shards = 1; // shard sub-runs are serial by construction
        sc.seed = shard_seed(cfg.seed, s);
        // priority classes cycle over the *global* user index: rotate
        // the cycle to this shard's offset so user `offset + k` keeps
        // the class the unsharded campaign would give it
        if !cfg.priorities.is_empty() {
            let n = cfg.priorities.len();
            sc.priorities = (0..n).map(|k| cfg.priorities[(offset + k) % n]).collect();
        }
        offsets.push(offset);
        offset += len;
        shard_cfgs.push(sc);
    }
    if cfg.sync_wan {
        return run_campaign_sync(cfg, pool, &offsets, &shard_cfgs);
    }
    let tasks: Vec<ScopeTask<Result<CampaignReport>>> = shard_cfgs
        .iter()
        .map(|sc| Box::new(move || run_campaign_serial(sc)) as ScopeTask<Result<CampaignReport>>)
        .collect();
    let mut reports = Vec::with_capacity(shards);
    for r in pool.scope(tasks) {
        reports.push(r?);
    }
    Ok(merge_shard_reports(cfg, &offsets, reports, 0))
}

/// Floor for a shard's bounded-lag WAN slowdown factor. Water-fill
/// ratios below this would stall a shard's transfers near-completely
/// and with them the window progress; the floor keeps every shard
/// moving while still modeling severe contention.
const MIN_SYNC_FACTOR: f64 = 1e-3;

/// Transfer quantum used to size the sync window: the window must be
/// wide enough that draining one quantum through the narrowest link is
/// observable within it, or the demand ledger would alias.
const SYNC_QUANTUM_BYTES: f64 = 16.0 * 1024.0 * 1024.0;

/// Bounded-lag window width for a WAN topology (DESIGN.md §14): the
/// topology round-trip time (information cannot cross the fabric
/// faster, so a narrower window buys no fidelity) or the time to drain
/// one transfer quantum through the narrowest link, whichever is
/// larger, floored at 1 ms. For the paper topology this is the 48 ms
/// RTT.
pub fn sync_window_s(topo: &Topology) -> f64 {
    let rtt: f64 = 2.0 * topo.links.iter().map(|l| l.latency_s).sum::<f64>();
    let min_cap = topo
        .links
        .iter()
        .map(|l| l.capacity_bps)
        .fold(f64::INFINITY, f64::min);
    let drain = if min_cap.is_finite() && min_cap > 0.0 {
        SYNC_QUANTUM_BYTES / min_cap
    } else {
        0.0
    };
    rtt.max(drain).max(1e-3)
}

/// Progressive-filling max-min fair allocation of `cap` across the
/// demands: ascending demand order, each claimant takes
/// `min(demand, remaining / claimants_left)`. Identical in spirit to
/// the transfer solver's per-link fill, but over *shards* instead of
/// streams. Public so the metamorphic invariant suite can fuzz its
/// max-min fairness directly (`rust/tests/invariants.rs`).
pub fn water_fill(demands: &[f64], cap: f64) -> Vec<f64> {
    let mut order: Vec<usize> = (0..demands.len()).collect();
    order.sort_by(|&a, &b| demands[a].total_cmp(&demands[b]).then(a.cmp(&b)));
    let mut alloc = vec![0.0f64; demands.len()];
    let mut remaining = cap;
    let mut left = demands.len();
    for &i in &order {
        let share = remaining / left as f64;
        let a = demands[i].min(share);
        alloc[i] = a;
        remaining = (remaining - a).max(0.0);
        left -= 1;
    }
    alloc
}

/// The conservative bounded-lag executor (DESIGN.md §14). Each round:
///
/// 1. `window_end = t_min + W`, where `t_min` is the earliest pending
///    event across unfinished shards and `W` = [`sync_window_s`] —
///    every event at or before the barrier is safe to execute because
///    cross-shard influence (the WAN factor) only changes *at*
///    barriers.
/// 2. Unfinished shards run their windows in parallel on the pool
///    (deterministic regardless of worker count: shards don't share
///    mutable state mid-window).
/// 3. Serially, in shard order: drain each shard's per-link WAN byte
///    ledger, un-throttle the observed rates by the factor that was in
///    force (so an already-slowed shard's *latent* demand is what
///    enters the fill — otherwise the factor oscillates), water-fill
///    each contended link, and set every shard's factor for the next
///    window to its worst per-link allocation ratio.
///
/// Windows advance strictly monotonically: all events `<= window_end`
/// were consumed, so the next `t_min` exceeds the previous barrier.
fn run_campaign_sync(
    cfg: &CampaignConfig,
    pool: &Pool,
    offsets: &[usize],
    shard_cfgs: &[CampaignConfig],
) -> Result<CampaignReport> {
    // mirror each shard's fabric: with federation sites the window
    // width and link capacities come from the *extended* topology,
    // wired in broker (name) order — the same order `ShardRun::new`
    // registers them, so link indices agree with the demand ledgers
    let mut topo = Topology::paper();
    if !cfg.sites.is_empty() {
        for site in Broker::new(&cfg.sites, cfg.placement).sites() {
            if site.name != "alcf" {
                site.extend_topology(&mut topo)?;
            }
        }
    }
    let window = sync_window_s(&topo);
    let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_bps).collect();
    let mut runs = Vec::with_capacity(shard_cfgs.len());
    for sc in shard_cfgs {
        runs.push(ShardRun::new(sc)?);
    }
    let mut windows: u64 = 0;
    let mut window_start = 0.0f64;
    while !runs.iter().all(|r| r.finished) {
        let t_min = runs
            .iter_mut()
            .filter(|r| !r.finished)
            .filter_map(|r| r.next_time())
            .fold(f64::INFINITY, f64::min);
        // an unfinished shard with an empty scheduler either settles to
        // completion inside its window or reports its own stall — an
        // unbounded window covers both
        let window_end = if t_min.is_finite() {
            t_min + window
        } else {
            f64::INFINITY
        };
        let tasks: Vec<ScopeTask<Result<bool>>> = runs
            .iter_mut()
            .filter(|r| !r.finished)
            .map(|r| Box::new(move || r.run_window(window_end)) as ScopeTask<Result<bool>>)
            .collect();
        for done in pool.scope(tasks) {
            done?;
        }
        windows += 1;
        if !window_end.is_finite() {
            break; // the unbounded window ran everything to completion
        }
        // serial post-barrier exchange, deterministic in shard order
        let span = (window_end - window_start).max(window);
        let mut demand: std::collections::BTreeMap<usize, Vec<(usize, f64)>> =
            std::collections::BTreeMap::new();
        for (ri, r) in runs.iter_mut().enumerate() {
            let drained = r.world.transfer.take_wan_window_bytes();
            if r.finished {
                continue; // past demand with no future: never throttles others
            }
            for (link, bytes) in drained {
                if bytes > 0.0 {
                    // un-throttle: the demand a factor-1.0 shard would
                    // have presented over this window
                    let rate = bytes / span / r.sync_factor;
                    demand.entry(link).or_default().push((ri, rate));
                }
            }
        }
        let mut factors = vec![1.0f64; runs.len()];
        for (link, shares) in &demand {
            if shares.len() < 2 {
                continue; // a link only one shard uses cannot contend
            }
            let cap = caps.get(*link).copied().unwrap_or(f64::INFINITY);
            let rates: Vec<f64> = shares.iter().map(|&(_, rate)| rate).collect();
            if !cap.is_finite() || rates.iter().sum::<f64>() <= cap {
                continue; // under-subscribed: everyone keeps factor 1.0
            }
            let alloc = water_fill(&rates, cap);
            for (&(ri, rate), &a) in shares.iter().zip(&alloc) {
                if rate > 0.0 {
                    factors[ri] = factors[ri].min((a / rate).clamp(MIN_SYNC_FACTOR, 1.0));
                }
            }
        }
        for (ri, r) in runs.iter_mut().enumerate() {
            if !r.finished {
                r.set_sync_factor(factors[ri]);
            }
        }
        window_start = window_end;
    }
    let mut reports = Vec::with_capacity(runs.len());
    for r in runs {
        reports.push(r.finish()?);
    }
    Ok(merge_shard_reports(cfg, offsets, reports, windows))
}

/// Merge per-shard reports into one campaign report, deterministically
/// in shard order (DESIGN.md §13): users renumbered to their global
/// 1-based indices, ledgers summed, the throughput mean re-weighted by
/// per-shard transfer counts, fairness recomputed over the full
/// population, and the scaling log stably re-sorted by virtual time.
fn merge_shard_reports(
    cfg: &CampaignConfig,
    offsets: &[usize],
    reports: Vec<CampaignReport>,
    sync_wan_windows: u64,
) -> CampaignReport {
    let mut users = Vec::with_capacity(cfg.users);
    let mut failed_users = Vec::new();
    let mut scaling: Vec<ScalingEvent> = Vec::new();
    let mut loads: std::collections::BTreeMap<String, EndpointLoad> =
        std::collections::BTreeMap::new();
    let mut cost_eps: std::collections::BTreeMap<String, EndpointCost> =
        std::collections::BTreeMap::new();
    let mut per_user_slot_s = Vec::with_capacity(cfg.users);
    let mut per_user_endpoint_slot_s = Vec::with_capacity(cfg.users);
    let mut per_user_scaleup_waste = Vec::with_capacity(cfg.users);
    let mut per_user_egress_bytes = Vec::with_capacity(cfg.users);
    let mut spot_endpoints = std::collections::BTreeSet::new();
    let mut egress_bytes = 0.0f64;
    let mut makespan_s = 0.0f64;
    let mut bps_weighted = 0.0f64;
    let mut wan_transfers = 0u64;
    let mut spot: Option<SpotLedger> = None;
    let mut federation: Option<FederationSummary> = None;
    let mut closed_loop: Option<ClosedLoopLedger> = None;
    for (rep, &off) in reports.into_iter().zip(offsets) {
        for mut u in rep.users {
            u.user += off;
            users.push(u);
        }
        failed_users.extend(rep.failed_users.iter().map(|u| u + off));
        for mut e in rep.scaling {
            if e.trigger_user > 0 {
                e.trigger_user += off as u32;
            }
            scaling.push(e);
        }
        for l in rep.endpoint_loads {
            let entry = loads
                .entry(l.endpoint.clone())
                .or_insert_with(|| EndpointLoad {
                    endpoint: l.endpoint.clone(),
                    tasks: 0,
                    total_queue_wait_s: 0.0,
                    max_queue_wait_s: 0.0,
                });
            entry.tasks += l.tasks;
            entry.total_queue_wait_s += l.total_queue_wait_s;
            entry.max_queue_wait_s = entry.max_queue_wait_s.max(l.max_queue_wait_s);
        }
        // shards are fabric replicas: capacities agree, slot-time adds
        for c in rep.cost.endpoints {
            let entry = cost_eps
                .entry(c.endpoint.clone())
                .or_insert_with(|| EndpointCost {
                    endpoint: c.endpoint.clone(),
                    base_capacity: c.base_capacity,
                    peak_capacity: 0,
                    provisioned_slot_s: 0.0,
                    used_slot_s: 0.0,
                    scaleup_slot_s: 0.0,
                });
            entry.base_capacity = entry.base_capacity.max(c.base_capacity);
            entry.peak_capacity = entry.peak_capacity.max(c.peak_capacity);
            entry.provisioned_slot_s += c.provisioned_slot_s;
            entry.used_slot_s += c.used_slot_s;
            entry.scaleup_slot_s += c.scaleup_slot_s;
        }
        per_user_slot_s.extend(rep.cost.per_user_slot_s);
        per_user_endpoint_slot_s.extend(rep.cost.per_user_endpoint_slot_s);
        per_user_scaleup_waste.extend(rep.cost.per_user_scaleup_waste);
        per_user_egress_bytes.extend(rep.cost.per_user_egress_bytes);
        spot_endpoints.extend(rep.cost.spot_endpoints);
        egress_bytes += rep.cost.egress_bytes;
        makespan_s = makespan_s.max(rep.makespan_s);
        bps_weighted += rep.mean_task_throughput_bps * rep.wan_transfers as f64;
        wan_transfers += rep.wan_transfers;
        if let Some(s) = rep.spot {
            let acc = spot.get_or_insert_with(SpotLedger::default);
            acc.preemptions += s.preemptions;
            acc.displaced += s.displaced;
            acc.wan_migrations += s.wan_migrations;
            acc.local_migrations += s.local_migrations;
            acc.migration_bytes += s.migration_bytes;
            acc.checkpointed_s += s.checkpointed_s;
            acc.lost_s += s.lost_s;
            acc.stranded += s.stranded;
        }
        if let Some(f) = rep.federation {
            match federation.as_mut() {
                None => federation = Some(f),
                Some(acc) => acc.absorb(&f),
            }
        }
        if let Some(c) = rep.closed_loop {
            let acc = closed_loop.get_or_insert_with(ClosedLoopLedger::default);
            acc.batches_served += c.batches_served;
            acc.triggers += c.triggers;
            acc.forced_triggers += c.forced_triggers;
            acc.suppressed += c.suppressed;
            acc.retrains_admitted += c.retrains_admitted;
            acc.hot_swaps += c.hot_swaps;
            acc.staleness_s += c.staleness_s;
            acc.accuracy_loss += c.accuracy_loss;
            acc.edge_busy_s += c.edge_busy_s;
            acc.drift_slot_s += c.drift_slot_s;
        }
    }
    // a stable sort keeps shard order as the same-instant tie-break
    scaling.sort_by(|a, b| a.vt.total_cmp(&b.vt));
    let slowdowns: Vec<f64> = users.iter().map(|u| u.slowdown).collect();
    let fairness = FairnessSummary {
        mean_slowdown: slowdowns.iter().sum::<f64>() / slowdowns.len() as f64,
        max_slowdown: slowdowns.iter().cloned().fold(0.0, f64::max),
        p50_slowdown: percentile(&slowdowns, 50.0),
        p95_slowdown: percentile(&slowdowns, 95.0),
        jain: jain_index(&slowdowns),
    };
    CampaignReport {
        config_users: cfg.users,
        mean_interarrival_s: cfg.mean_interarrival_s,
        users,
        endpoint_loads: loads.into_values().collect(),
        mean_task_throughput_bps: if wan_transfers > 0 {
            bps_weighted / wan_transfers as f64
        } else {
            0.0
        },
        wan_transfers,
        makespan_s,
        policy: cfg.policy,
        fairness,
        scaling,
        failed_users,
        cost: CostSummary {
            endpoints: cost_eps.into_values().collect(),
            per_user_slot_s,
            per_user_endpoint_slot_s,
            per_user_scaleup_waste,
            egress_bytes,
            per_user_egress_bytes,
            spot_endpoints,
        },
        spot,
        federation,
        shards: offsets.len(),
        shard_users: cfg.users.div_ceil(offsets.len().max(1)),
        sync_wan_windows,
        closed_loop,
    }
}

/// The serial campaign: one fabric, one DES, every user on it — the
/// exact path of every earlier PR, and the body each shard runs: one
/// unbounded window *is* that path, since `run_until(∞)` degenerates
/// to the old pop-until-empty loop instruction for instruction.
fn run_campaign_serial(cfg: &CampaignConfig) -> Result<CampaignReport> {
    let mut run = ShardRun::new(cfg)?;
    let done = run.run_window(f64::INFINITY)?;
    debug_assert!(done, "an unbounded window runs to completion");
    run.finish()
}

/// One shard's in-flight campaign: the full serial-campaign state —
/// fabric, flow engine, per-user FSM, event queue — packaged so the
/// bounded-lag executor (DESIGN.md §14) can drive it window by window,
/// pausing at virtual-time barriers and resuming after the cross-shard
/// WAN exchange. `Send` (pinned by a test) because a window barrier
/// may migrate a shard between pool workers.
struct ShardRun {
    cfg: CampaignConfig,
    scen: Vec<Scenario>,
    widths: Vec<usize>,
    arrivals: Vec<f64>,
    datasets: Vec<String>,
    spot_eps: std::collections::BTreeSet<String>,
    world: World,
    base_capacities: Vec<(String, usize)>,
    engine: FlowEngine<World>,
    def: FlowDefinition,
    token: TokenId,
    states: Vec<UserState>,
    gen: FuncId,
    sched: Scheduler<Wake>,
    fault_changes: Vec<FaultChange>,
    wan_active: Vec<bool>,
    down_count: std::collections::BTreeMap<String, usize>,
    spot_rngs: Vec<Rng>,
    /// the placement broker (DESIGN.md §15); `None` without `--sites`
    /// — the no-broker path is byte-identical to every earlier PR
    broker: Option<Broker>,
    /// WAN slowdown factor imposed by the sync executor for the
    /// current window (1.0 = unthrottled; always 1.0 serially)
    sync_factor: f64,
    /// per-user serving-drift streams (DESIGN.md §16); empty without
    /// `--closed-loop` — the default path allocates no drift objects
    drift: Vec<DriftStream>,
    /// closed-loop integrals accumulated as batches serve and swaps
    /// land (merged into `CampaignReport.closed_loop` at `finish()`)
    cl_ledger: ClosedLoopLedger,
    /// FLOPs per served inference batch, per user (precomputed from
    /// the registry; empty without `--closed-loop`)
    serve_flops: Vec<f64>,
    /// every user reached `Done`: the run is ready to `finish()`
    finished: bool,
}

impl ShardRun {
    /// Validate the config and stand the shard's fabric up —
    /// everything the serial campaign did before its event loop.
    fn new(cfg: &CampaignConfig) -> Result<ShardRun> {
        anyhow::ensure!(cfg.users > 0, "campaign needs at least one user");
        cfg.faults.validate()?;
        // a programmatically built mix bypasses parse_mix: re-validate so
        // degenerate weights fail loudly instead of silently apportioning
        // every user to the first entry
        for e in &cfg.mix {
            anyhow::ensure!(
                e.weight.is_finite() && e.weight > 0.0 && e.slots >= 1,
                "bad mix entry `{}`: weight must be finite and positive, slots >= 1",
                e.model
            );
            if let Some(r) = e.rate_s {
                anyhow::ensure!(
                    r.is_finite() && r >= 0.0,
                    "bad mix entry `{}`: rate must be finite and >= 0",
                    e.model
                );
            }
            if let Some(b) = e.burst {
                anyhow::ensure!(
                    b.factor.is_finite() && b.factor > 1.0 && b.duty > 0.0 && b.duty < 1.0,
                    "bad mix entry `{}`: burst factor must be > 1 and duty in (0, 1)",
                    e.model
                );
            }
        }
        // a programmatically built spot plan bypasses parse_spot: re-check
        let mut spot_eps: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        for s in &cfg.spot {
            anyhow::ensure!(
                s.preempt_rate_s.is_finite() && s.preempt_rate_s > 0.0,
                "bad spot spec `{}`: mean preemption gap must be finite and > 0",
                s.endpoint
            );
            anyhow::ensure!(
                s.grace_s.is_finite() && s.grace_s >= 0.0,
                "bad spot spec `{}`: grace must be finite and >= 0",
                s.endpoint
            );
            anyhow::ensure!(
                spot_eps.insert(s.endpoint.clone()),
                "duplicate spot spec for `{}`",
                s.endpoint
            );
        }
        if let Some(c) = cfg.checkpoint_every_s {
            anyhow::ensure!(
                c.is_finite() && c > 0.0,
                "checkpoint cadence must be finite and > 0 (got {c})"
            );
        }
        // a programmatically built closed-loop spec bypasses the CLI
        // parser: re-validate so degenerate thresholds/rates fail
        // before any fabric state exists (DESIGN.md §16)
        if let Some(spec) = &cfg.closed_loop {
            spec.validate()?;
        }

        // heterogeneous mix: apportion users to entries and build each
        // user's scenario (same mode — the classes share the trainer — but
        // their own model, staged payload, and gang width). An empty mix
        // degenerates to clones of `cfg.scenario` and width 1: the default
        // campaign path, bit-identical to the homogeneous one.
        let assignment: Vec<Option<usize>> = if cfg.mix.is_empty() {
            vec![None; cfg.users]
        } else {
            apportion_mix(&cfg.mix, cfg.users).into_iter().map(Some).collect()
        };
        let scen: Vec<Scenario> = assignment
            .iter()
            .map(|a| match a {
                None => Ok(cfg.scenario.clone()),
                Some(e) => {
                    let mut s = Scenario::table1(&cfg.mix[*e].model, cfg.scenario.mode)
                        .with_context(|| format!("mix entry `{}`", cfg.mix[*e].model))?;
                    s.seed = cfg.scenario.seed;
                    Ok(s)
                }
            })
            .collect::<Result<_>>()?;
        let widths: Vec<usize> = assignment
            .iter()
            .map(|a| a.map(|e| cfg.mix[e].slots.max(1)).unwrap_or(1))
            .collect();
        let max_width = widths.iter().copied().max().unwrap_or(1);

        let mut world = World::paper(cfg.scenario.seed)?;
        world.training_mode = TrainingMode::VirtualOnly;
        world.checkpoint_every_s = cfg.checkpoint_every_s;

        // Federation (DESIGN.md §15): stand the extra sites up on the
        // shared fabric — topology, DTN, accelerator endpoints — in
        // broker (name) order so registration is deterministic, and
        // validate any `site=` fault windows against the broker.
        let broker = if cfg.sites.is_empty() {
            anyhow::ensure!(
                cfg.faults.sites.is_empty(),
                "fault plan has `site=` outage windows but no federation sites \
                 were configured (--sites)"
            );
            None
        } else {
            anyhow::ensure!(
                cfg.scenario.mode.is_remote(),
                "--sites needs a remote training mode (the local V100 never \
                 crosses the WAN, so there is nothing to broker)"
            );
            let b = Broker::new(&cfg.sites, cfg.placement);
            b.validate_plan(&cfg.faults)?;
            for site in b.sites() {
                if site.name != "alcf" {
                    // the home site *is* `World::paper`
                    world.add_site(site)?;
                }
            }
            Some(b)
        };

        let base_capacities: Vec<(String, usize)> = {
            let faas = world.faas.as_mut().expect("fresh world has faas");
            faas.set_policy(cfg.policy.build())?;
            for (ep, auto) in &cfg.autoscale {
                faas.set_autoscaler(ep, auto.clone())?;
            }
            // size the trainer to the widest gang in the mix: a fixed
            // endpoint grows its base capacity, an autoscaled one must be
            // able to reach the width on its own
            if max_width > 1 {
                let trainer = cfg.scenario.mode.train_endpoint();
                match cfg.autoscale.iter().find(|(ep, _)| ep.as_str() == trainer) {
                    Some((_, auto)) => {
                        anyhow::ensure!(
                            auto.max_capacity >= max_width,
                            "mix has a width-{max_width} gang but the `{trainer}` autoscaler \
                             tops out at {} slot(s)",
                            auto.max_capacity
                        );
                    }
                    None => {
                        let current = faas.endpoint_mut(trainer)?.capacity;
                        if current < max_width {
                            faas.set_capacity(trainer, max_width)?;
                        }
                    }
                }
                // federated replicas of the trainer class must fit the
                // widest gang too, or a brokered placement could park a
                // gang on a site that can never start it
                if let Some(b) = &broker {
                    let class = endpoint_class(trainer);
                    for site in b.sites() {
                        if site.name == "alcf" || !site.hosts(class) {
                            continue;
                        }
                        let ep = site.endpoint(class);
                        if faas.endpoint_mut(&ep)?.capacity < max_width {
                            faas.set_capacity(&ep, max_width)?;
                        }
                    }
                }
            }
            // fail on unknown outage endpoints up front, not mid-campaign
            for o in &cfg.faults.outages {
                faas.endpoint_mut(&o.endpoint)
                    .with_context(|| format!("fault plan outage `{}`", o.endpoint))?;
            }
            // mark spot tiers (and fail on unknown endpoints) up front
            for s in &cfg.spot {
                faas.endpoint_mut(&s.endpoint)
                    .with_context(|| format!("spot spec `{}`", s.endpoint))?
                    .tier = crate::faas::CapacityTier::Spot {
                    preempt_rate_s: s.preempt_rate_s,
                    grace_s: s.grace_s,
                };
            }
            // capacities at campaign start: the cost accounting baseline
            faas.endpoints().map(|e| (e.id.clone(), e.capacity)).collect()
        };
        let mut engine = FlowEngine::<World>::new();
        super::providers::register_all(&mut engine)?;
        let clock0 = VClock::new();
        let token = engine
            .auth
            .issue(
                &clock0,
                "beamline-scientist",
                &["transfer:use", "compute:use", "deploy:use", "rollback:use"],
                30.0 * 24.0 * 3600.0,
            )
            .id;

        // Arrival processes. Default: one shared Poisson stream, first
        // user at t = 0 — byte-identical to every earlier PR. When any mix
        // entry carries its own `rate_s` or a `burst` mode, each class
        // gets its own stream (DESIGN.md §11), seeded deterministically
        // from the root seed and the class index, so sweep rows that vary
        // only a policy or a price replay identical arrivals — zero
        // sampling noise between rows. Class arrivals are handed to that
        // class's users in apportionment order.
        let per_class = cfg.mix.iter().any(|e| e.rate_s.is_some() || e.burst.is_some());
        let arrivals: Vec<f64> = if cfg.closed_loop.is_some() {
            // closed loop (DESIGN.md §16): no exogenous arrival plan.
            // Every user's retraining flow is *admitted* by their drift
            // trigger — the arrival slot is set to the trigger's virtual
            // time when it fires. Until then it is ∞ (never scheduled,
            // never eligible). The Poisson/per-class `Rng`s are never
            // constructed, so toggling the knob cannot shift any other
            // stream's draws.
            vec![f64::INFINITY; cfg.users]
        } else if per_class {
            let mut streams: Vec<std::vec::IntoIter<f64>> = cfg
                .mix
                .iter()
                .enumerate()
                .map(|(e, entry)| {
                    let n = assignment.iter().filter(|a| **a == Some(e)).count();
                    // SplitMix-style derivation: independent per-class
                    // streams, each a pure function of (root seed, class)
                    let mut rng =
                        Rng::new(cfg.seed ^ (e as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
                    class_arrivals(
                        n,
                        entry.rate_s.unwrap_or(cfg.mean_interarrival_s),
                        entry.burst,
                        &mut rng,
                    )
                    .into_iter()
                })
                .collect();
            assignment
                .iter()
                .map(|a| {
                    streams[a.expect("per-class arrivals imply a mix")]
                        .next()
                        .expect("one arrival per apportioned user")
                })
                .collect()
        } else {
            // shared Poisson stream: exponential gaps, first user at 0
            let mut arrivals = vec![0.0f64];
            let mut rng = Rng::new(cfg.seed);
            for i in 1..cfg.users {
                let gap = if cfg.mean_interarrival_s > 0.0 {
                    rng.exponential(1.0 / cfg.mean_interarrival_s)
                } else {
                    0.0
                };
                arrivals.push(arrivals[i - 1] + gap);
            }
            arrivals
        };

        let shape = FlowShape {
            remote: cfg.scenario.mode.is_remote(),
            // with a broker each user's staging destination (and the
            // symmetric model-return source) is the placed site's DTN,
            // resolved per flow from the input; the `None` default
            // keeps the paper's fixed `alcf#dtn` byte-identically
            stage_dst: broker.as_ref().map(|_| "${input.stage_dst}".to_string()),
            ..Default::default()
        };
        let def = dnn_trainer_flow(&shape)?;
        let datasets: Vec<String> = (0..cfg.users)
            .map(|i| format!("{}-train-u{}", scen[i].model, i + 1))
            .collect();

        let states: Vec<UserState> = (0..cfg.users).map(|_| UserState::Waiting).collect();
        let gen = crate::faas::FuncId("generate_data".into());

        // The event-queue scheduler owns the campaign's virtual clock
        // (single writer): arrivals and fault-window edges are scheduled up
        // front, dynamic wake-ups (flow completions, fabric events) are fed
        // in each round, and every time step is a deterministic pop.
        // `for_load` sizes the backend to the expected event volume — one
        // arrival plus a handful of scan/fault wake-ups per user — picking
        // the §13 calendar queue at scale (`XLOOP_DES` overrides); both
        // backends pop the identical (time, seq) order, so the choice never
        // changes a byte of output.
        let mut sched = Scheduler::<Wake>::for_load(cfg.users.saturating_mul(8));
        for &a in &arrivals {
            // closed-loop users start at ∞ (admitted by their drift
            // trigger later); an infinite timestamp never enters the
            // queue. Exogenous plans are always finite, so this guard
            // is a no-op on the default path.
            if a.is_finite() {
                sched.schedule_at(a, Wake::Arrival);
            }
        }
        let mut fault_changes: Vec<FaultChange> = Vec::new();
        for o in &cfg.faults.outages {
            fault_changes.push(FaultChange::OutageStart(o.endpoint.clone()));
            sched.schedule_at(o.from_vt, Wake::Fault(fault_changes.len() - 1));
            fault_changes.push(FaultChange::OutageEnd(o.endpoint.clone()));
            sched.schedule_at(o.until_vt, Wake::Fault(fault_changes.len() - 1));
        }
        for (wi, w) in cfg.faults.wan.iter().enumerate() {
            fault_changes.push(FaultChange::WanStart(wi));
            sched.schedule_at(w.from_vt, Wake::Fault(fault_changes.len() - 1));
            fault_changes.push(FaultChange::WanEnd(wi));
            sched.schedule_at(w.until_vt, Wake::Fault(fault_changes.len() - 1));
        }
        for (si, s) in cfg.faults.sites.iter().enumerate() {
            fault_changes.push(FaultChange::SiteDown(si));
            sched.schedule_at(s.from_vt, Wake::Fault(fault_changes.len() - 1));
            fault_changes.push(FaultChange::SiteUp(si));
            sched.schedule_at(s.until_vt, Wake::Fault(fault_changes.len() - 1));
        }
        let wan_active = vec![false; cfg.faults.wan.len()];
        // outage windows are refcounted per endpoint so same-instant edges
        // (a window ending exactly where the next begins) compose correctly
        // in either firing order
        let down_count: std::collections::BTreeMap<String, usize> =
            std::collections::BTreeMap::new();
        // spot preemption processes (DESIGN.md §12): one deterministic
        // stream per spec, seeded from the root seed and the spec index so
        // spot draws never perturb the arrival streams. Each cycles
        // warn → (grace) → reclaim → (restore) → next warn; the shared
        // down-refcount makes a scheduled outage on a spot endpoint and its
        // preemption windows compose instead of double-toggling the status.
        let mut spot_rngs: Vec<Rng> = (0..cfg.spot.len())
            .map(|i| {
                Rng::new(cfg.seed ^ SPOT_SALT ^ (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15))
            })
            .collect();
        for (i, s) in cfg.spot.iter().enumerate() {
            let first = spot_rngs[i].exponential(1.0 / s.preempt_rate_s);
            sched.schedule_at(first, Wake::SpotWarn(i));
        }

        // Closed-loop drift streams (DESIGN.md §16): one seeded
        // residual process per user, salted so drift draws never
        // perturb arrival/spot streams; the first batch of every user
        // serves one gap in, in user order (the scheduler's sequence
        // tie-break keeps same-instant batches deterministic). The
        // provenance stamp makes every fabric task this shard submits
        // drift-attributed for the cost ledger.
        let (drift, serve_flops) = match &cfg.closed_loop {
            None => (Vec::new(), Vec::new()),
            Some(spec) => {
                world.task_origin = crate::faas::TaskOrigin::Drift;
                let streams: Vec<DriftStream> = (0..cfg.users)
                    .map(|i| {
                        let seed = super::closedloop::per_user_seed(cfg.seed ^ DRIFT_SALT, i);
                        DriftStream::new(*spec, seed)
                    })
                    .collect();
                let flops: Vec<f64> = scen
                    .iter()
                    .map(|s| s.serve_flops_per_batch(&world.registry))
                    .collect::<Result<_>>()?;
                for i in 0..cfg.users {
                    sched.schedule_at(spec.gap_s(), Wake::Drift(i));
                }
                (streams, flops)
            }
        };

        Ok(ShardRun {
            cfg: cfg.clone(),
            scen,
            widths,
            arrivals,
            datasets,
            spot_eps,
            world,
            base_capacities,
            engine,
            def,
            token,
            states,
            gen,
            sched,
            fault_changes,
            wan_active,
            down_count,
            spot_rngs,
            broker,
            sync_factor: 1.0,
            drift,
            cl_ledger: ClosedLoopLedger::default(),
            serve_flops,
            finished: false,
        })
    }

    /// Virtual time of the shard's earliest pending event — what the
    /// sync executor derives the next window barrier from.
    fn next_time(&mut self) -> Option<f64> {
        self.sched.peek_time()
    }

    /// Install the next window's WAN slowdown factor (called serially
    /// by the sync executor between windows, in shard order). The
    /// composed fault × sync factor applies immediately, so transfers
    /// re-solve from the barrier on; a no-op when unchanged.
    fn set_sync_factor(&mut self, factor: f64) {
        if factor == self.sync_factor {
            return;
        }
        self.sync_factor = factor;
        apply_wan_factor(&mut self.world, &self.cfg.faults, &self.wan_active, factor);
    }

    /// Drive the shard until every user is `Done` (returns `true`) or
    /// the next event lies beyond `window_end` (returns `false`, with
    /// the fabrics streamed up to the barrier so the WAN demand ledger
    /// covers the whole window). `window_end = ∞` is exactly the old
    /// serial event loop.
    fn run_window(&mut self, window_end: f64) -> Result<bool> {
        let ShardRun {
            cfg,
            scen,
            widths,
            arrivals,
            datasets,
            world,
            engine,
            def,
            token,
            states,
            gen,
            sched,
            fault_changes,
            wan_active,
            down_count,
            spot_rngs,
            broker,
            sync_factor,
            drift,
            cl_ledger,
            serve_flops,
            finished,
            ..
        } = self;
        loop {
            let now = sched.now();
            // settle everything possible at the current instant (poll order =
            // user index order: the deterministic tie-break)
            loop {
                let mut progressed = false;
                for i in 0..cfg.users {
                    world.tenant = Tenant {
                        user: (i + 1) as u32,
                        priority: cfg.user_priority(i),
                        train_slots: widths[i],
                    };
                    match &mut states[i] {
                        UserState::Waiting => {
                            if arrivals[i] <= now {
                                let args = Json::obj(vec![
                                    ("model", Json::str(scen[i].model.clone())),
                                    ("n", Json::num(scen[i].real_samples as f64)),
                                    ("seed", Json::num(scen[i].seed as f64)),
                                    ("name", Json::str(datasets[i].clone())),
                                ]);
                                let ticket = world
                                    .submit_compute_ticket(now, "slac#sim", &gen, &args)
                                    .with_context(|| format!("user {i} dataset generation"))?;
                                states[i] = UserState::Preparing(ticket);
                                progressed = true;
                            }
                        }
                        UserState::Preparing(ticket) => {
                            if let Some((tf, res)) = world.take_ready(*ticket) {
                                res.with_context(|| format!("user {i} dataset generation"))?;
                                let input = match broker.as_mut() {
                                    None => Json::obj(vec![
                                        ("model", Json::str(scen[i].model.clone())),
                                        ("dataset", Json::str(datasets[i].clone())),
                                        (
                                            "dataset_bytes",
                                            Json::num(scen[i].staged_bytes as f64),
                                        ),
                                        (
                                            "train_endpoint",
                                            Json::str(scen[i].mode.train_endpoint()),
                                        ),
                                    ]),
                                    // brokered placement (DESIGN.md §15):
                                    // score every live site for this
                                    // task-group *now* — the flow then
                                    // stages to the placed site's DTN and
                                    // trains on its endpoint
                                    Some(b) => {
                                        let (train_ep, stage_dtn) = b.place(
                                            world,
                                            endpoint_class(scen[i].mode.train_endpoint()),
                                            widths[i],
                                            scen[i].staged_bytes,
                                            &scen[i].model,
                                            now,
                                        )?;
                                        Json::obj(vec![
                                            ("model", Json::str(scen[i].model.clone())),
                                            ("dataset", Json::str(datasets[i].clone())),
                                            (
                                                "dataset_bytes",
                                                Json::num(scen[i].staged_bytes as f64),
                                            ),
                                            ("train_endpoint", Json::str(train_ep)),
                                            ("stage_dst", Json::str(stage_dtn)),
                                        ])
                                    }
                                };
                                let run = engine.begin(&def, &input, &token, tf)?;
                                states[i] = UserState::Running(run);
                                progressed = true;
                            }
                        }
                        UserState::Running(run) => {
                            if engine.poll(run, &mut world, now)? == RunPoll::Finished {
                                let prev = std::mem::replace(&mut states[i], UserState::Waiting);
                                let UserState::Running(run) = prev else { unreachable!() };
                                let rep = run.into_report();
                                // closed-loop hot-swap (DESIGN.md §16):
                                // the retrained model replaces the served
                                // version at the flow's virtual completion
                                // time. Staleness = swap vt - trigger vt;
                                // `arrivals[i]` IS the trigger time (that's
                                // how the retrain was admitted), the same
                                // subtraction `finish()` uses for
                                // turnaround — the integrals agree
                                // bit-exactly.
                                if !drift.is_empty() && rep.succeeded {
                                    cl_ledger.hot_swaps += 1;
                                    cl_ledger.staleness_s += rep.end_vt - arrivals[i];
                                    drift[i].hot_swap(rep.end_vt);
                                    world.edge.note_swap(rep.end_vt, &scen[i].model);
                                }
                                states[i] = UserState::Done(rep);
                                progressed = true;
                            }
                        }
                        UserState::Done(_) => {}
                    }
                }
                if !progressed {
                    break;
                }
            }
            if states.iter().all(|s| matches!(s, UserState::Done(_))) {
                *finished = true;
                return Ok(true);
            }

            // earliest *dynamic* source: a scheduled flow completion or a
            // fabric event (queue start/completion, autoscaler transition,
            // transfer re-allocation/delivery); arrivals and fault-window
            // edges already live in the heap
            let mut dyn_t = f64::INFINITY;
            for (i, s) in states.iter_mut().enumerate() {
                if let UserState::Running(run) = s {
                    world.tenant = Tenant {
                        user: (i + 1) as u32,
                        priority: cfg.user_priority(i),
                        train_slots: widths[i],
                    };
                    if let RunPoll::WaitUntil(t) = engine.poll(run, &mut world, now)? {
                        dyn_t = dyn_t.min(t);
                    }
                }
            }
            if let Some(t) = world.next_fabric_event() {
                dyn_t = dyn_t.min(t);
            }
            if dyn_t.is_finite() {
                sched.schedule_at(dyn_t.max(now), Wake::Scan);
            }
            let Some((t, wake)) = sched.run_until(window_end) else {
                if sched.is_empty() {
                    anyhow::bail!(
                        "campaign stalled at vt {now:.3} ({} users incomplete)",
                        states
                            .iter()
                            .filter(|s| !matches!(s, UserState::Done(_)))
                            .count()
                    );
                }
                // bounded-lag pause: the next event lies beyond the window
                // barrier. No event at or before `window_end` exists, so
                // streaming the fabrics to the barrier completes nothing —
                // it only moves partial transfer bytes into the WAN demand
                // ledger, so the window's demand is fully accounted before
                // the cross-shard exchange.
                world.advance_fabrics(window_end);
                return Ok(false);
            };
            world.advance_fabrics(t);
            // fault-window and spot edges apply after the fabrics settle at
            // t, so a task finishing exactly at the edge instant still
            // finished
            match wake {
                Wake::Fault(i) => match &fault_changes[i] {
                    FaultChange::OutageStart(ep) => {
                        let c = down_count.entry(ep.clone()).or_insert(0);
                        *c += 1;
                        if *c == 1 {
                            world.begin_endpoint_outage(ep, t)?;
                        }
                    }
                    FaultChange::OutageEnd(ep) => {
                        let c = down_count.entry(ep.clone()).or_insert(1);
                        *c = c.saturating_sub(1);
                        if *c == 0 {
                            world.end_endpoint_outage(ep, t)?;
                        }
                    }
                    FaultChange::WanStart(wi) => {
                        wan_active[*wi] = true;
                        apply_wan_factor(world, &cfg.faults, wan_active, *sync_factor);
                    }
                    FaultChange::WanEnd(wi) => {
                        wan_active[*wi] = false;
                        apply_wan_factor(world, &cfg.faults, wan_active, *sync_factor);
                    }
                    FaultChange::SiteDown(si) => {
                        let b = broker.as_mut().expect("site windows imply a broker");
                        let eps = b.set_down(&cfg.faults.sites[*si].site, true)?;
                        // refcount every site endpoint down; only the
                        // newly-dark ones enter the failover wave (an
                        // overlapping outage already reclaimed the rest)
                        let mut newly_dark: Vec<String> = Vec::new();
                        for ep in &eps {
                            let c = down_count.entry(ep.clone()).or_insert(0);
                            *c += 1;
                            if *c == 1 {
                                newly_dark.push(ep.clone());
                            }
                        }
                        // checkpoint-migrate the running gangs off the
                        // dark site in one assignment wave (the broker
                        // skips it for new placements from here on);
                        // queued work parks until restore. The wave's
                        // bookkeeping lands on a fresh ledger so site
                        // reroutes report separately from spot activity.
                        let mut ledger = SpotLedger::default();
                        let displaced =
                            world.fail_over_endpoints(&newly_dark, t, &mut ledger)?;
                        b.note_reroutes(displaced as u32, ledger.stranded);
                    }
                    FaultChange::SiteUp(si) => {
                        let b = broker.as_mut().expect("site windows imply a broker");
                        let eps = b.set_down(&cfg.faults.sites[*si].site, false)?;
                        for ep in &eps {
                            let c = down_count.entry(ep.clone()).or_insert(1);
                            *c = c.saturating_sub(1);
                            if *c == 0 {
                                world.end_endpoint_outage(ep, t)?;
                            }
                        }
                    }
                },
                Wake::SpotWarn(i) => {
                    let s = &cfg.spot[i];
                    if down_count.get(&s.endpoint).copied().unwrap_or(0) > 0 {
                        // the endpoint is already dark (scheduled outage or
                        // an unresolved spot window): this preemption
                        // dissolves into the existing downtime — redraw
                        let gap = spot_rngs[i].exponential(1.0 / s.preempt_rate_s);
                        sched.schedule_at(t + gap, Wake::SpotWarn(i));
                    } else {
                        *down_count.entry(s.endpoint.clone()).or_insert(0) += 1;
                        world.spot_warn_endpoint(&s.endpoint, t)?;
                        sched.schedule_at(t + s.grace_s, Wake::SpotReclaim(i));
                    }
                }
                Wake::SpotReclaim(i) => {
                    let s = &cfg.spot[i];
                    world.preempt_spot_endpoint(&s.endpoint, t)?;
                    let gap = spot_rngs[i]
                        .exponential(1.0 / (SPOT_RESTORE_FRACTION * s.preempt_rate_s));
                    sched.schedule_at(t + gap, Wake::SpotRestore(i));
                }
                Wake::SpotRestore(i) => {
                    let s = &cfg.spot[i];
                    let c = down_count.entry(s.endpoint.clone()).or_insert(1);
                    *c = c.saturating_sub(1);
                    if *c == 0 {
                        world.end_endpoint_outage(&s.endpoint, t)?;
                    }
                    let gap = spot_rngs[i].exponential(1.0 / s.preempt_rate_s);
                    sched.schedule_at(t + gap, Wake::SpotWarn(i));
                }
                Wake::Drift(i) => {
                    // a Done user's stream is retired: no serve, no
                    // reschedule — the drained events are what lets the
                    // campaign terminate
                    if !matches!(states[i], UserState::Done(_)) {
                        let out = drift[i].serve(t);
                        cl_ledger.batches_served += 1;
                        cl_ledger.edge_busy_s += world.edge.device.infer_time(serve_flops[i]);
                        // accuracy-loss integral: excess residual over
                        // the acceptable threshold, held for one batch
                        // gap (rectangle rule on the batch grid)
                        cl_ledger.accuracy_loss += (drift[i].ewma
                            - drift[i].spec().threshold)
                            .max(0.0)
                            * drift[i].spec().gap_s();
                        match out {
                            ServeOutcome::Fired | ServeOutcome::ForcedFire => {
                                cl_ledger.triggers += 1;
                                if out == ServeOutcome::ForcedFire {
                                    cl_ledger.forced_triggers += 1;
                                }
                                // admit the retraining flow *unless* one
                                // is already in flight for this user —
                                // the trigger time becomes the arrival
                                // the settle loop acts on
                                if matches!(states[i], UserState::Waiting)
                                    && arrivals[i].is_infinite()
                                {
                                    arrivals[i] = t;
                                    cl_ledger.retrains_admitted += 1;
                                }
                            }
                            ServeOutcome::Suppressed => cl_ledger.suppressed += 1,
                            ServeOutcome::Quiet => {}
                        }
                        sched.schedule_at(t + drift[i].spec().gap_s(), Wake::Drift(i));
                    }
                }
                Wake::Arrival | Wake::Scan => {}
            }
        }
    }

    /// Assemble the shard's campaign report — everything the serial
    /// campaign did after its event loop.
    fn finish(self) -> Result<CampaignReport> {
        debug_assert!(self.finished, "finish() before the last window");
        let ShardRun {
            cfg,
            scen,
            widths,
            arrivals,
            spot_eps,
            world,
            base_capacities,
            states,
            broker,
            mut cl_ledger,
            ..
        } = self;
        // per-user capacity-slot queue wait, attributed via task metadata
        let mut per_user_wait = vec![0.0f64; cfg.users];
        if let Some(faas) = world.faas.as_ref() {
            for rec in faas.records() {
                if !rec.status.is_complete() {
                    continue;
                }
                let u = rec.meta.user as usize;
                if (1..=cfg.users).contains(&u) {
                    per_user_wait[u - 1] += rec.queue_wait_secs();
                }
            }
        }

        // per-user outcomes. Flow failures are terminal campaign errors on
        // a fault-free fabric (they would mean a broken flow, not a studied
        // condition); under a fault plan they become reported outcomes.
        let mut users = Vec::with_capacity(cfg.users);
        let mut failed_users = Vec::new();
        for (i, s) in states.into_iter().enumerate() {
            let UserState::Done(report) = s else { unreachable!() };
            if !report.succeeded && cfg.faults.is_empty() && cfg.spot.is_empty() {
                anyhow::bail!(
                    "user {i} flow failed: {:?}",
                    report
                        .records
                        .iter()
                        .map(|r| format!("{}:{:?}", r.id, r.status))
                        .collect::<Vec<_>>()
                );
            }
            let breakdown = if report.succeeded {
                Some(extract_breakdown(&report, &scen[i], report.start_vt)?)
            } else {
                failed_users.push(i + 1);
                None
            };
            let turnaround_s = report.end_vt - arrivals[i];
            let queue_wait_s = per_user_wait[i];
            let slowdown = turnaround_s / (turnaround_s - queue_wait_s).max(1e-9);
            users.push(UserOutcome {
                user: i + 1,
                model: scen[i].model.clone(),
                gang_slots: widths[i],
                arrival_vt: arrivals[i],
                finished_vt: report.end_vt,
                turnaround_s,
                succeeded: report.succeeded,
                breakdown,
                queue_wait_s,
                slowdown,
            });
        }

        let slowdowns: Vec<f64> = users.iter().map(|u| u.slowdown).collect();
        let fairness = FairnessSummary {
            mean_slowdown: slowdowns.iter().sum::<f64>() / slowdowns.len() as f64,
            max_slowdown: slowdowns.iter().cloned().fold(0.0, f64::max),
            p50_slowdown: percentile(&slowdowns, 50.0),
            p95_slowdown: percentile(&slowdowns, 95.0),
            jain: jain_index(&slowdowns),
        };

        // endpoint queue statistics from the faas records
        let mut loads: std::collections::BTreeMap<String, EndpointLoad> =
            std::collections::BTreeMap::new();
        if let Some(faas) = world.faas.as_ref() {
            for rec in faas.records() {
                if !rec.status.is_complete() {
                    continue;
                }
                let wait = rec.queue_wait_secs();
                let entry = loads
                    .entry(rec.endpoint.clone())
                    .or_insert_with(|| EndpointLoad {
                        endpoint: rec.endpoint.clone(),
                        tasks: 0,
                        total_queue_wait_s: 0.0,
                        max_queue_wait_s: 0.0,
                    });
                entry.tasks += 1;
                entry.total_queue_wait_s += wait;
                entry.max_queue_wait_s = entry.max_queue_wait_s.max(wait);
            }
        }

        let mean_task_throughput_bps = if world.transfer_log.is_empty() {
            0.0
        } else {
            world
                .transfer_log
                .iter()
                .map(|r| r.throughput_bps())
                .sum::<f64>()
                / world.transfer_log.len() as f64
        };
        let makespan_s = users.iter().map(|u| u.finished_vt).fold(0.0, f64::max);
        let scaling = world
            .faas
            .as_ref()
            .map(|f| f.scaling_log().to_vec())
            .unwrap_or_default();

        // slot-time cost accounting (DESIGN.md §10): provisioned capacity
        // integrated over [0, makespan] per endpoint (scaling events
        // applied at their instants), usage summed as exec × gang width,
        // and the used share attributed per tenant via task metadata —
        // both in total and per endpoint (dollarization needs the
        // per-endpoint resolution, DESIGN.md §11)
        let mut per_user_slot_s = vec![0.0f64; cfg.users];
        let mut per_user_endpoint_slot_s: Vec<std::collections::BTreeMap<String, f64>> =
            vec![std::collections::BTreeMap::new(); cfg.users];
        let mut used_by_ep: std::collections::BTreeMap<String, f64> =
            std::collections::BTreeMap::new();
        if let Some(faas) = world.faas.as_ref() {
            for rec in faas.records() {
                if !rec.status.is_complete() || !rec.exec_secs().is_finite() {
                    continue;
                }
                let slot_s = rec.exec_secs().max(0.0) * rec.meta.width() as f64;
                *used_by_ep.entry(rec.endpoint.clone()).or_insert(0.0) += slot_s;
                let u = rec.meta.user as usize;
                if (1..=cfg.users).contains(&u) {
                    per_user_slot_s[u - 1] += slot_s;
                    *per_user_endpoint_slot_s[u - 1]
                        .entry(rec.endpoint.clone())
                        .or_insert(0.0) += slot_s;
                }
            }
        }
        let endpoints_cost: Vec<EndpointCost> = base_capacities
            .iter()
            .map(|(ep, base)| {
                let changes: Vec<(f64, f64)> = scaling
                    .iter()
                    .filter(|e| &e.endpoint == ep)
                    .map(|e| (e.vt, e.capacity as f64))
                    .collect();
                let peak = changes
                    .iter()
                    .map(|&(_, c)| c as usize)
                    .max()
                    .unwrap_or(0)
                    .max(*base);
                let scaleup_changes: Vec<(f64, f64)> = changes
                    .iter()
                    .map(|&(vt, c)| (vt, (c - *base as f64).max(0.0)))
                    .collect();
                EndpointCost {
                    endpoint: ep.clone(),
                    base_capacity: *base,
                    peak_capacity: peak,
                    provisioned_slot_s: integrate_step(0.0, makespan_s, *base as f64, &changes),
                    used_slot_s: used_by_ep.get(ep).copied().unwrap_or(0.0),
                    scaleup_slot_s: integrate_step(0.0, makespan_s, 0.0, &scaleup_changes),
                }
            })
            .collect();
        // per-tenant scale-up waste (DESIGN.md §11): replay each
        // endpoint's scaling log as a LIFO ledger of above-base slots, each
        // tagged with its `ScalingEvent` trigger tenant; integrate every
        // tagged slot's active lifetime over [0, makespan]; then scale the
        // per-tenant shares so they sum to the endpoint's waste =
        // min(scale-up, idle) exactly. (All campaign work is tenant-tagged,
        // so no scale-up trigger is anonymous here; untagged triggers would
        // leave their share out of the per-tenant view.)
        let mut per_user_scaleup_waste: Vec<std::collections::BTreeMap<String, f64>> =
            vec![std::collections::BTreeMap::new(); cfg.users];
        for ec in &endpoints_cost {
            let waste = ec.scaleup_waste_slot_s();
            if waste <= 0.0 {
                continue;
            }
            let mut above: Vec<(u32, f64)> = Vec::new(); // (trigger user, active since)
            let mut slot_s_by_user: std::collections::BTreeMap<u32, f64> =
                std::collections::BTreeMap::new();
            let mut prev = ec.base_capacity;
            for e in scaling.iter().filter(|e| e.endpoint == ec.endpoint) {
                let vt = e.vt.min(makespan_s);
                if e.capacity > prev {
                    // only the above-base portion enters the ledger: a
                    // refill from below base (autoscaler floor < base) is
                    // not scale-up and must not siphon waste shares
                    for _ in prev.max(ec.base_capacity)..e.capacity {
                        above.push((e.trigger_user, vt));
                    }
                } else {
                    for _ in 0..(prev - e.capacity) {
                        // pops below base are no-ops: the ledger only
                        // tracks above-base slots
                        if let Some((u, since)) = above.pop() {
                            *slot_s_by_user.entry(u).or_insert(0.0) += (vt - since).max(0.0);
                        }
                    }
                }
                prev = e.capacity;
            }
            for (u, since) in above {
                *slot_s_by_user.entry(u).or_insert(0.0) += (makespan_s - since).max(0.0);
            }
            let total: f64 = slot_s_by_user.values().sum();
            if total <= 0.0 {
                continue;
            }
            for (u, s) in slot_s_by_user {
                let u = u as usize;
                if (1..=cfg.users).contains(&u) {
                    *per_user_scaleup_waste[u - 1]
                        .entry(ec.endpoint.clone())
                        .or_insert(0.0) += waste * s / total;
                }
            }
        }

        // WAN egress (DESIGN.md §11): every logged transfer crossed the
        // wide-area fabric; bill the bytes on the wire, retries included
        let egress_bytes: f64 = world
            .transfer_log
            .iter()
            .map(|r| (r.bytes + r.retried_bytes) as f64)
            .sum();
        let mut per_user_egress_bytes = vec![0.0f64; cfg.users];
        for (rep, &u) in world.transfer_log.iter().zip(&world.transfer_log_users) {
            let u = u as usize;
            if (1..=cfg.users).contains(&u) {
                per_user_egress_bytes[u - 1] += (rep.bytes + rep.retried_bytes) as f64;
            }
        }

        let cost = CostSummary {
            endpoints: endpoints_cost,
            per_user_slot_s,
            per_user_endpoint_slot_s,
            per_user_scaleup_waste,
            egress_bytes,
            per_user_egress_bytes,
            spot_endpoints: spot_eps,
        };

        // drift-attributed fabric slot-seconds (DESIGN.md §16): every
        // task the closed loop caused carries `TaskOrigin::Drift`
        // provenance — summed here so the report separates what the
        // trigger *cost the fabric* from what the edge served
        let closed_loop = if cfg.closed_loop.is_some() {
            if let Some(faas) = world.faas.as_ref() {
                for rec in faas.records() {
                    if rec.status.is_complete()
                        && rec.exec_secs().is_finite()
                        && rec.meta.origin == crate::faas::TaskOrigin::Drift
                    {
                        cl_ledger.drift_slot_s +=
                            rec.exec_secs().max(0.0) * rec.meta.width() as f64;
                    }
                }
            }
            Some(cl_ledger)
        } else {
            None
        };

        Ok(CampaignReport {
            config_users: cfg.users,
            mean_interarrival_s: cfg.mean_interarrival_s,
            users,
            endpoint_loads: loads.into_values().collect(),
            mean_task_throughput_bps,
            wan_transfers: world.transfer_log.len() as u64,
            makespan_s,
            policy: cfg.policy,
            fairness,
            scaling,
            failed_users,
            cost,
            spot: if cfg.spot.is_empty() { None } else { Some(world.spot) },
            federation: broker.map(|b| b.summary()),
            shards: 1,
            shard_users: cfg.users,
            sync_wan_windows: 0,
            closed_loop,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfer::{EndpointId, TransferRequest};
    use crate::workflow::federation::parse_sites;
    use crate::workflow::scenario::Mode;
    use crate::workflow::{Coordinator, TrainingMode};

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    /// Acceptance: the N=1 campaign is the degenerate case of the DES
    /// machinery and must reproduce the synchronous table1 path's
    /// per-phase breakdown with bit-identical virtual times.
    #[test]
    fn single_user_campaign_matches_table1_bit_for_bit() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();

        let mut c = Coordinator::paper(scenario.seed).unwrap();
        c.set_training_mode(TrainingMode::VirtualOnly);
        let table1 = c.run_retraining(&scenario, None).unwrap().breakdown;

        let report = run_campaign(&CampaignConfig::new(1, scenario, 60.0, 42)).unwrap();
        let b = report.users[0].breakdown.as_ref().unwrap();

        assert_eq!(b.data_transfer_s, table1.data_transfer_s);
        assert_eq!(b.training_s, table1.training_s);
        assert_eq!(b.model_transfer_s, table1.model_transfer_s);
        assert_eq!(b.end_to_end_s, table1.end_to_end_s);
        // uncontended: no queue wait anywhere, slowdown exactly 1
        for load in &report.endpoint_loads {
            assert_eq!(load.total_queue_wait_s, 0.0, "{load:?}");
        }
        assert_eq!(report.users[0].queue_wait_s, 0.0);
        assert_eq!(report.users[0].slowdown, 1.0);
        assert_eq!(report.fairness.jain, 1.0);
        assert!(report.failed_users.is_empty());
        assert!(report.scaling.is_empty());
    }

    /// Contended campaign: simultaneous users queue on the capacity-1
    /// DCAI trainer and share WAN bandwidth, so tail turnaround grows
    /// and per-task transfer throughput drops below the solo value.
    #[test]
    fn contention_creates_queue_wait_and_slower_transfers() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let solo = run_campaign(&CampaignConfig::new(1, scenario.clone(), 1.0, 7)).unwrap();

        // near-simultaneous arrivals
        let loaded = run_campaign(&CampaignConfig::new(4, scenario, 1.0, 7)).unwrap();

        // DCAI queue wait appears on the trainer
        let train_load = loaded.load("alcf#cerebras").expect("trainer used");
        assert!(
            train_load.total_queue_wait_s > 0.0,
            "no queue wait under contention: {train_load:?}"
        );
        // the tail is strictly worse than the uncontended turnaround
        assert!(
            loaded.max_turnaround_s() > solo.users[0].turnaround_s,
            "tail {} not above solo {}",
            loaded.max_turnaround_s(),
            solo.users[0].turnaround_s
        );
        // concurrent staging shares the WAN: per-task goodput drops
        assert!(
            loaded.mean_task_throughput_bps < solo.mean_task_throughput_bps,
            "transfer throughput did not degrade: {} vs {}",
            loaded.mean_task_throughput_bps,
            solo.mean_task_throughput_bps
        );
        // percentiles are ordered
        assert!(
            loaded.turnaround_percentile(95.0) >= loaded.turnaround_percentile(50.0)
        );
        assert!((loaded.makespan_s) >= loaded.users[0].turnaround_s);
        // queueing shows up in the fairness metrics: someone was slowed,
        // slowdowns are >= 1, and Jain stays in (0, 1]
        assert!(loaded.fairness.max_slowdown > 1.0, "{:?}", loaded.fairness);
        for u in &loaded.users {
            assert!(u.slowdown >= 1.0, "{u:?}");
        }
        assert!(
            loaded.fairness.jain > 0.0 && loaded.fairness.jain <= 1.0,
            "{:?}",
            loaded.fairness
        );
        // per-user waits attribute the endpoint totals: sums must agree
        // on the contended trainer (every train task is user-tagged)
        let total_wait: f64 = loaded.users.iter().map(|u| u.queue_wait_s).sum();
        let ep_wait: f64 = loaded
            .endpoint_loads
            .iter()
            .map(|l| l.total_queue_wait_s)
            .sum();
        assert!(
            (total_wait - ep_wait).abs() < 1e-6,
            "user-attributed {total_wait} vs endpoint {ep_wait}"
        );
    }

    /// The arrival process and the full DES replay are deterministic for
    /// a given seed.
    #[test]
    fn campaign_is_deterministic_for_seed() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("cookienetae", Mode::RemoteCerebras).unwrap();
        let cfg = CampaignConfig::new(3, scenario, 10.0, 11);
        let a = run_campaign(&cfg).unwrap();
        let b = run_campaign(&cfg).unwrap();
        for (ua, ub) in a.users.iter().zip(&b.users) {
            assert_eq!(ua.arrival_vt, ub.arrival_vt);
            assert_eq!(ua.turnaround_s, ub.turnaround_s);
            assert_eq!(ua.finished_vt, ub.finished_vt);
        }
    }

    /// Satellite pin: a multi-tenant campaign whose config spells every
    /// DESIGN.md §9 knob out at its disabled default (Fifo policy, no
    /// autoscaling, no faults, uniform priorities) reproduces the
    /// default-config report *exactly* — the knob path introduces zero
    /// perturbation into the PR 2 queueing core, whose absolute numbers
    /// the table1/contention tests above pin.
    #[test]
    fn fifo_with_knobs_disabled_matches_default_campaign() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let default_cfg = CampaignConfig::new(3, scenario.clone(), 5.0, 13);
        let explicit = CampaignConfig {
            users: 3,
            scenario,
            mean_interarrival_s: 5.0,
            seed: 13,
            policy: PolicyKind::Fifo,
            priorities: vec![0, 0, 0],
            autoscale: Vec::new(),
            faults: crate::simnet::FaultPlan::default(),
            mix: Vec::new(),
            spot: Vec::new(),
            checkpoint_every_s: None,
            shards: 0,
            shard_users: 0,
            sync_wan: false,
            sites: Vec::new(),
            placement: Placement::Turnaround,
            closed_loop: None,
        };
        let a = run_campaign(&default_cfg).unwrap();
        let b = run_campaign(&explicit).unwrap();
        for (ua, ub) in a.users.iter().zip(&b.users) {
            assert_eq!(ua.arrival_vt, ub.arrival_vt);
            assert_eq!(ua.finished_vt, ub.finished_vt);
            assert_eq!(ua.turnaround_s, ub.turnaround_s);
            assert_eq!(ua.queue_wait_s, ub.queue_wait_s);
            assert_eq!(ua.slowdown, ub.slowdown);
        }
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.mean_task_throughput_bps, b.mean_task_throughput_bps);
        assert!(b.scaling.is_empty() && b.failed_users.is_empty());
    }

    /// Priority classes reorder contended users: with all-at-once
    /// arrivals on the capacity-1 trainer, the high-priority class is
    /// collectively served sooner than the low class.
    #[test]
    fn priority_classes_reorder_contended_users() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(4, scenario, 0.0, 9);
        cfg.policy = PolicyKind::Priority { aging_s: 300.0 };
        cfg.priorities = vec![0, 3]; // users 1,3 low; users 2,4 high
        let rep = run_campaign(&cfg).unwrap();
        assert_eq!(rep.policy.label(), "priority");
        let turn = |i: usize| rep.users[i].turnaround_s;
        let high = turn(1) + turn(3);
        let low = turn(0) + turn(2);
        assert!(
            high < low,
            "high-priority users not served sooner: high {high} vs low {low}"
        );
    }

    /// A mid-campaign trainer outage fails the running training task;
    /// the flow's retry re-queues it, the surviving queue re-dispatches
    /// at recovery, and every user still completes — just later. A WAN
    /// brownout over the staging window likewise stretches turnaround.
    #[test]
    fn fault_windows_stretch_but_do_not_break_campaigns() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let clean =
            run_campaign(&CampaignConfig::new(2, scenario.clone(), 1.0, 21)).unwrap();

        // trainer down across the first training window
        let mut cfg = CampaignConfig::new(2, scenario.clone(), 1.0, 21);
        cfg.faults = crate::simnet::FaultPlan::parse("outage=alcf#cerebras@25..200").unwrap();
        let outage = run_campaign(&cfg).unwrap();
        assert!(
            outage.makespan_s > clean.makespan_s,
            "outage did not stretch the campaign: {} vs {}",
            outage.makespan_s,
            clean.makespan_s
        );
        for u in &outage.users {
            assert!(u.succeeded, "flow retries should absorb the outage: {u:?}");
        }

        // WAN brownout while the datasets stage
        let mut cfg = CampaignConfig::new(2, scenario, 1.0, 21);
        cfg.faults = crate::simnet::FaultPlan::parse("wan=0.3@0..60").unwrap();
        let brown = run_campaign(&cfg).unwrap();
        assert!(
            brown.makespan_s > clean.makespan_s,
            "brownout did not stretch the campaign: {} vs {}",
            brown.makespan_s,
            clean.makespan_s
        );
        assert!(brown.mean_task_throughput_bps < clean.mean_task_throughput_bps);

        // unknown outage endpoint is rejected up front
        let mut cfg = CampaignConfig::new(1, clean_scenario(), 1.0, 21);
        cfg.faults = crate::simnet::FaultPlan::parse("outage=alcf#ghost@0..10").unwrap();
        assert!(run_campaign(&cfg).is_err());
    }

    fn clean_scenario() -> Scenario {
        Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap()
    }

    /// An autoscaled trainer absorbs a burst: the tail turnaround drops
    /// below the fixed-capacity campaign's and the report logs the
    /// capacity changes.
    #[test]
    fn autoscaled_trainer_cuts_tail_turnaround() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let fixed = run_campaign(&CampaignConfig::new(6, scenario.clone(), 1.0, 17)).unwrap();

        let mut cfg = CampaignConfig::new(6, scenario, 1.0, 17);
        cfg.autoscale = vec![(
            "alcf#cerebras".to_string(),
            Autoscaler {
                min_capacity: 1,
                max_capacity: 3,
                scale_up_waiting: 2,
                provision_delay_s: 10.0,
                scale_down_idle_s: 120.0,
                cooldown_s: 5.0,
            },
        )];
        let scaled = run_campaign(&cfg).unwrap();
        assert!(
            !scaled.scaling.is_empty(),
            "no scaling events under a 6-user burst"
        );
        assert!(scaled.scaling.iter().any(|e| e.capacity > 1));
        assert!(
            scaled.max_turnaround_s() < fixed.max_turnaround_s(),
            "autoscaling did not cut the tail: {} vs {}",
            scaled.max_turnaround_s(),
            fixed.max_turnaround_s()
        );
    }

    // ---- gang scheduling, heterogeneous mixes, cost accounting ----

    #[test]
    fn mix_spec_parses_and_apportions() {
        let mix = parse_mix("braggnn:0.7:1,cookienetae:0.3:4").unwrap();
        assert_eq!(mix.len(), 2);
        assert_eq!(mix[0], MixEntry::new("braggnn", 0.7, 1));
        assert_eq!(mix[1].slots, 4);
        // slots default to 1
        assert_eq!(parse_mix("braggnn:1").unwrap()[0].slots, 1);
        assert!(parse_mix("braggnn").is_err());
        assert!(parse_mix("braggnn:0").is_err());
        assert!(parse_mix("braggnn:1:0").is_err());
        assert!(parse_mix("braggnn:x:1").is_err());
        assert!(parse_mix("").unwrap().is_empty());

        // degenerate weights built programmatically (bypassing
        // parse_mix) are rejected by run_campaign itself
        let mut cfg = CampaignConfig::new(
            2,
            Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap(),
            1.0,
            1,
        );
        cfg.mix = vec![MixEntry::new("braggnn", 0.0, 1)];
        assert!(run_campaign(&cfg).unwrap_err().to_string().contains("bad mix entry"));

        // largest-remainder apportionment is exact and deterministic:
        // a 0.7/0.3 split of 10 users is 7/3, interleaved
        let a = apportion_mix(&mix, 10);
        assert_eq!(a.iter().filter(|&&e| e == 0).count(), 7);
        assert_eq!(a.iter().filter(|&&e| e == 1).count(), 3);
        assert_eq!(a[0], 0, "heavier class seeds the sequence");
        // 50/50 alternates starting from the earlier entry
        let even = parse_mix("braggnn:0.5:1,cookienetae:0.5:2").unwrap();
        assert_eq!(apportion_mix(&even, 4), vec![0, 1, 0, 1]);
    }

    /// Tentpole pin (named in the issue): a single-entry mix with gang
    /// width 1 routes through the whole mix/gang machinery — per-user
    /// scenarios, tenant widths, trainer sizing, cost accounting — and
    /// reproduces the default campaign bit for bit.
    #[test]
    fn gang_width_one_is_bit_identical() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let default_cfg = CampaignConfig::new(3, scenario.clone(), 5.0, 13);
        let mut mixed = default_cfg.clone();
        mixed.mix = vec![MixEntry::new("braggnn", 1.0, 1)];
        let a = run_campaign(&default_cfg).unwrap();
        let b = run_campaign(&mixed).unwrap();
        for (ua, ub) in a.users.iter().zip(&b.users) {
            assert_eq!(ua.arrival_vt, ub.arrival_vt);
            assert_eq!(ua.finished_vt, ub.finished_vt);
            assert_eq!(ua.turnaround_s, ub.turnaround_s);
            assert_eq!(ua.queue_wait_s, ub.queue_wait_s);
            assert_eq!(ua.slowdown, ub.slowdown);
            assert_eq!(ub.model, "braggnn");
            assert_eq!(ub.gang_slots, 1);
        }
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.mean_task_throughput_bps, b.mean_task_throughput_bps);
        // cost accounting agrees too — same fabric, same usage
        assert_eq!(
            a.cost.total_used_slot_s(),
            b.cost.total_used_slot_s()
        );
        assert_eq!(
            a.cost.total_provisioned_slot_s(),
            b.cost.total_provisioned_slot_s()
        );
    }

    /// Satellite: a heterogeneous mix makes the policies genuinely
    /// separate — braggnn singles and width-2 cookienetae gangs share
    /// the trainer, and FIFO/SJF/backfill produce different outcomes
    /// (the separation ROADMAP predicts), with backfill never
    /// pessimizing mean slowdown beyond noise.
    #[test]
    fn mixed_campaign_policies_separate() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        // staggered arrivals + sustained trainer backlog: queues hold
        // braggnn singles (longer estimate) and cookienetae gangs
        // (shorter estimate, double width) at the same decision points,
        // which is where the policies diverge
        let run = |kind: PolicyKind| {
            let mut cfg = CampaignConfig::new(8, scenario.clone(), 10.0, 19);
            cfg.policy = kind;
            cfg.mix = parse_mix("braggnn:0.5:1,cookienetae:0.5:2").unwrap();
            run_campaign(&cfg).unwrap()
        };
        let fifo = run(PolicyKind::Fifo);
        // deterministic apportionment: braggnn, cookienetae, ...
        assert_eq!(fifo.users[0].model, "braggnn");
        assert_eq!(fifo.users[1].model, "cookienetae");
        assert_eq!(fifo.users[1].gang_slots, 2);
        // the trainer was sized to the widest gang
        let trainer = fifo.cost.endpoint("alcf#cerebras").expect("trainer cost");
        assert_eq!(trainer.base_capacity, 2);
        assert!(trainer.used_slot_s > 0.0);
        assert!(trainer.provisioned_slot_s >= trainer.used_slot_s - 1e-6);
        // simultaneous arrivals on a shared trainer: someone queued
        assert!(fifo.fairness.max_slowdown > 1.0, "{:?}", fifo.fairness);
        // per-tenant attribution covers all tagged work
        let attributed: f64 = fifo.cost.per_user_slot_s.iter().sum();
        assert!(
            (attributed - fifo.cost.total_used_slot_s()).abs() < 1e-6,
            "attributed {attributed} vs used {}",
            fifo.cost.total_used_slot_s()
        );

        let sjf = run(PolicyKind::Sjf);
        let backfill = run(PolicyKind::Backfill);
        let trace = |r: &CampaignReport| -> Vec<(f64, f64)> {
            r.users
                .iter()
                .map(|u| (u.turnaround_s, u.queue_wait_s))
                .collect()
        };
        // the policies actually reorder the mixed workload
        assert!(
            trace(&fifo) != trace(&sjf) || trace(&fifo) != trace(&backfill),
            "mixed workload did not separate the policies: {:?}",
            trace(&fifo)
        );
        // backfill only moves work into holes the FIFO head leaves
        // open; it must not pessimize mean slowdown beyond noise
        assert!(
            backfill.fairness.mean_slowdown
                <= fifo.fairness.mean_slowdown + 0.25,
            "backfill {} vs fifo {}",
            backfill.fairness.mean_slowdown,
            fifo.fairness.mean_slowdown
        );
    }

    /// A width-2 gang needs the autoscaler to reach its width when the
    /// trainer is elastic; an autoscaler that cannot cover the widest
    /// gang is rejected up front.
    #[test]
    fn mixed_gang_respects_autoscaler_ceiling() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(2, scenario, 0.0, 5);
        cfg.mix = parse_mix("cookienetae:1.0:3").unwrap();
        cfg.autoscale = vec![("alcf#cerebras".to_string(), Autoscaler::up_to(2))];
        let err = run_campaign(&cfg).unwrap_err();
        assert!(err.to_string().contains("tops out"), "{err}");
    }

    /// Cost accounting under autoscaling: provisioned slot-time covers
    /// usage, the scale-up share is integrated from the scaling log,
    /// and waste is bounded by both the scale-up and the idle time.
    #[test]
    fn cost_summary_accounts_autoscaled_slot_time() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(6, scenario, 1.0, 17);
        cfg.autoscale = vec![(
            "alcf#cerebras".to_string(),
            Autoscaler {
                min_capacity: 1,
                max_capacity: 3,
                scale_up_waiting: 2,
                provision_delay_s: 10.0,
                scale_down_idle_s: 120.0,
                cooldown_s: 5.0,
            },
        )];
        let rep = run_campaign(&cfg).unwrap();
        assert!(!rep.scaling.is_empty());
        let trainer = rep.cost.endpoint("alcf#cerebras").expect("trainer cost");
        assert_eq!(trainer.base_capacity, 1);
        assert!(trainer.peak_capacity > 1);
        assert!(trainer.scaleup_slot_s > 0.0, "{trainer:?}");
        assert!(trainer.provisioned_slot_s >= trainer.used_slot_s - 1e-6);
        assert!(trainer.scaleup_waste_slot_s() <= trainer.scaleup_slot_s + 1e-9);
        assert!(trainer.scaleup_waste_slot_s() <= trainer.idle_slot_s() + 1e-9);
        assert!(trainer.utilization() > 0.0 && trainer.utilization() <= 1.0);
        // every endpoint accrues provisioned cost for the whole window,
        // even the ones the flow never touched
        for ep in &rep.cost.endpoints {
            assert!(
                ep.provisioned_slot_s >= ep.base_capacity as f64 * rep.makespan_s - 1e-6
                    || ep.peak_capacity > ep.base_capacity,
                "{ep:?}"
            );
        }
    }

    // ---- pricing, per-class arrivals, dollar attribution (§11) ----

    #[test]
    fn mix_spec_parses_rates_and_bursts() {
        let mix =
            parse_mix("braggnn:0.7:1:30,cookienetae:0.3:4:120:burst=4@0.25").unwrap();
        assert_eq!(mix[0].rate_s, Some(30.0));
        assert_eq!(mix[0].burst, None);
        assert_eq!(mix[1].rate_s, Some(120.0));
        assert_eq!(
            mix[1].burst,
            Some(Burst {
                factor: 4.0,
                duty: 0.25
            })
        );
        // the §10 two/three-part shapes still parse with no arrival
        // process attached
        let plain = parse_mix("braggnn:0.7:1,cookienetae:0.3:4").unwrap();
        assert!(plain.iter().all(|e| e.rate_s.is_none() && e.burst.is_none()));
        // bad rates and bursts are rejected
        assert!(parse_mix("braggnn:1:1:abc").is_err());
        assert!(parse_mix("braggnn:1:1:-5").is_err());
        assert!(parse_mix("braggnn:1:1:30:burst=1@0.5").is_err()); // factor <= 1
        assert!(parse_mix("braggnn:1:1:30:burst=4@1.5").is_err()); // duty out of range
        assert!(parse_mix("braggnn:1:1:30:spike=4@0.5").is_err()); // not a burst token
        assert!(parse_mix("braggnn:1:1:30:burst=4@0.5:extra").is_err()); // too many parts
    }

    /// Per-class arrival streams (DESIGN.md §11): deterministic in the
    /// root seed, and each class's arrival tempo follows its own rate
    /// instead of the shared campaign stream.
    #[test]
    fn per_class_arrivals_are_deterministic_and_rate_driven() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        // braggnn users arrive ~100x faster than cookienetae users
        let mut cfg = CampaignConfig::new(6, scenario.clone(), 60.0, 23);
        cfg.mix = parse_mix("braggnn:0.5:1:5,cookienetae:0.5:1:500").unwrap();
        let a = run_campaign(&cfg).unwrap();
        let b = run_campaign(&cfg).unwrap();
        for (ua, ub) in a.users.iter().zip(&b.users) {
            assert_eq!(ua.arrival_vt, ub.arrival_vt);
            assert_eq!(ua.finished_vt, ub.finished_vt);
        }
        // per-class streams do not pin anyone to t = 0
        assert!(a.users.iter().all(|u| u.arrival_vt > 0.0));
        // the fast class's mean arrival is far earlier than the slow
        // class's (means 5 s vs 500 s over 3 users each)
        let mean = |model: &str| {
            let xs: Vec<f64> = a
                .users
                .iter()
                .filter(|u| u.model == model)
                .map(|u| u.arrival_vt)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            mean("braggnn") < mean("cookienetae"),
            "rates not honored: braggnn mean {} vs cookienetae mean {}",
            mean("braggnn"),
            mean("cookienetae")
        );

        // burst mode replays deterministically too
        let mut bursty = CampaignConfig::new(4, scenario, 60.0, 23);
        bursty.mix = parse_mix("braggnn:1.0:1:60:burst=4@0.25").unwrap();
        let x = run_campaign(&bursty).unwrap();
        let y = run_campaign(&bursty).unwrap();
        for (ux, uy) in x.users.iter().zip(&y.users) {
            assert_eq!(ux.arrival_vt, uy.arrival_vt);
            assert_eq!(ux.turnaround_s, uy.turnaround_s);
        }
    }

    /// Tentpole pin (named in the issue): the per-tenant dollar bill
    /// partitions the fabric total — used + idle-share + egress summed
    /// over tenants equals provisioned $ + egress $ — and the scale-up
    /// waste memo (attributed via `ScalingEvent::trigger_user`) sums to
    /// the fabric's waste dollars.
    #[test]
    fn dollar_attribution_sums_to_fabric_total() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(6, scenario, 1.0, 17);
        cfg.autoscale = vec![(
            "alcf#cerebras".to_string(),
            Autoscaler {
                min_capacity: 1,
                max_capacity: 3,
                scale_up_waiting: 2,
                provision_delay_s: 10.0,
                scale_down_idle_s: 120.0,
                cooldown_s: 5.0,
            },
        )];
        let rep = run_campaign(&cfg).unwrap();

        // every scale-up in a campaign is tenant-attributed
        let mut prev: std::collections::BTreeMap<&str, usize> =
            rep.cost.endpoints.iter().map(|e| (e.endpoint.as_str(), e.base_capacity)).collect();
        for e in &rep.scaling {
            let p = prev.get_mut(e.endpoint.as_str()).expect("known endpoint");
            if e.capacity > *p {
                assert!(
                    (1..=cfg.users).contains(&(e.trigger_user as usize)),
                    "anonymous scale-up: {e:?}"
                );
            } else {
                assert_eq!(e.trigger_user, 0, "attributed scale-down: {e:?}");
            }
            *p = e.capacity;
        }
        // per-tenant waste slot-seconds sum to the fabric's waste
        let waste_attr: f64 = (0..cfg.users)
            .map(|u| rep.cost.user_scaleup_waste_slot_s(u))
            .sum();
        assert!(
            (waste_attr - rep.cost.total_scaleup_waste_slot_s()).abs() < 1e-6,
            "waste attribution {waste_attr} vs total {}",
            rep.cost.total_scaleup_waste_slot_s()
        );
        // remote campaigns move data: egress observed and fully tagged
        assert!(rep.cost.egress_bytes > 0.0);
        let tagged: f64 = rep.cost.per_user_egress_bytes.iter().sum();
        assert!(
            (tagged - rep.cost.egress_bytes).abs() < 1e-6,
            "untagged egress: {tagged} of {}",
            rep.cost.egress_bytes
        );

        // the invariant: Σ per-tenant bills == fabric total
        let book = PriceBook::paper();
        let d = rep.cost.dollars(&book);
        let billed: f64 = d.per_tenant.iter().map(|t| t.total_usd()).sum();
        assert!(
            (billed - d.total_usd()).abs() < 1e-6 * d.total_usd().max(1.0),
            "bills {billed} vs fabric total {}",
            d.total_usd()
        );
        assert!(d.total_usd() > 0.0);
        assert!(d.egress_usd > 0.0);
        assert!(d.provisioned_usd() >= d.used_usd() - 1e-9);
        // the waste memo dollarizes the attributed slot-seconds
        let memo: f64 = d.per_tenant.iter().map(|t| t.scaleup_waste_usd).sum();
        assert!(
            (memo - d.scaleup_waste_usd()).abs() < 1e-6 * d.scaleup_waste_usd().max(1.0),
            "waste memo {memo} vs {}",
            d.scaleup_waste_usd()
        );
        // the trainer is priced at the premium Cerebras rate
        let trainer = d
            .endpoints
            .iter()
            .find(|e| e.endpoint == "alcf#cerebras")
            .expect("trainer priced");
        assert_eq!(trainer.rate_per_slot_hour, 42.0);
        assert!(trainer.provisioned_usd > 0.0);
        // an empty book prices everything at zero
        let zero = rep.cost.dollars(&PriceBook::new());
        assert_eq!(zero.total_usd(), 0.0);
        assert!(zero.per_tenant.iter().all(|t| t.total_usd() == 0.0));
    }

    // ---- spot capacity, checkpoints, failover migration (§12) ----

    #[test]
    fn spot_spec_parses_and_rejects() {
        let s = parse_spot("alcf#cerebras:900:30").unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].endpoint, "alcf#cerebras");
        assert_eq!(s[0].preempt_rate_s, 900.0);
        assert_eq!(s[0].grace_s, 30.0);
        assert!(parse_spot("").unwrap().is_empty());
        assert_eq!(parse_spot("a#b:10:0, c#d:5:1").unwrap().len(), 2);
        assert!(parse_spot("a#b:10").is_err()); // missing grace
        assert!(parse_spot("a#b:10:1:2").is_err()); // too many parts
        assert!(parse_spot("a#b:0:1").is_err()); // gap must be > 0
        assert!(parse_spot("a#b:-1:1").is_err());
        assert!(parse_spot("a#b:10:-1").is_err()); // negative grace
        assert!(parse_spot("a#b:x:1").is_err());
        assert!(parse_spot("a#b:10:1,a#b:20:2")
            .unwrap_err()
            .to_string()
            .contains("duplicate"));

        // degenerate programmatic specs are re-validated by run_campaign
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(1, scenario.clone(), 1.0, 1);
        cfg.spot = vec![SpotSpec {
            endpoint: "alcf#cerebras".into(),
            preempt_rate_s: f64::NAN,
            grace_s: 1.0,
        }];
        assert!(run_campaign(&cfg).unwrap_err().to_string().contains("spot spec"));
        let mut cfg = CampaignConfig::new(1, scenario.clone(), 1.0, 1);
        cfg.checkpoint_every_s = Some(0.0);
        assert!(run_campaign(&cfg).unwrap_err().to_string().contains("checkpoint"));
        // unknown spot endpoint is rejected up front (needs the fabric)
        if artifacts_present() {
            let mut cfg = CampaignConfig::new(1, scenario, 1.0, 1);
            cfg.spot = parse_spot("alcf#ghost:100:5").unwrap();
            assert!(run_campaign(&cfg).unwrap_err().to_string().contains("spot spec"));
        }
    }

    /// Tentpole pin: an aggressive preemption process on the spot
    /// trainer displaces running gangs, the failover planner migrates
    /// them, every displaced gang is accounted for, and the whole
    /// campaign replays bit-identically — the spot stream is a pure
    /// function of the root seed. Because resumes replay only the
    /// remaining work past the last checkpoint, total used slot-time
    /// stays well under the 2× full-restart blowup (the issue's
    /// acceptance bound).
    #[test]
    fn spot_campaign_preempts_migrates_and_stays_deterministic() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let base = run_campaign(&CampaignConfig::new(4, scenario.clone(), 0.0, 31)).unwrap();
        assert!(base.spot.is_none(), "on-demand campaign carries no spot ledger");

        let mut cfg = CampaignConfig::new(4, scenario, 0.0, 31);
        // mean gap 6 s against ~18 s trains: displacement is near-certain
        cfg.spot = parse_spot("alcf#cerebras:6:2").unwrap();
        cfg.checkpoint_every_s = Some(5.0);
        let a = run_campaign(&cfg).unwrap();
        let b = run_campaign(&cfg).unwrap();
        for (ua, ub) in a.users.iter().zip(&b.users) {
            assert_eq!(ua.arrival_vt, ub.arrival_vt);
            assert_eq!(ua.finished_vt, ub.finished_vt);
            assert_eq!(ua.turnaround_s, ub.turnaround_s);
        }
        assert_eq!(a.makespan_s, b.makespan_s);

        let s = a.spot.expect("spot campaign reports a ledger");
        assert_eq!(b.spot, Some(s), "spot ledger replays bit-identically");
        // 4 users × ~18 s of serialized training against a mean-25 s
        // preemption gap: displacement is effectively certain
        assert!(s.preemptions >= 1, "{s:?}");
        assert!(s.displaced >= 1, "{s:?}");
        // every displaced gang is migrated or stranded — none vanish
        assert_eq!(
            s.displaced,
            s.local_migrations + s.wan_migrations + s.stranded,
            "{s:?}"
        );
        // alcf#sambanova / alcf#gpu8 stay online: nobody strands, and
        // with live local candidates the planner never pays for the WAN
        assert_eq!(s.stranded, 0, "{s:?}");
        assert!(s.local_migrations >= 1, "{s:?}");
        assert!(a.failed_users.is_empty(), "{:?}", a.failed_users);
        // displaced progress splits into kept + lost checkpoint time
        assert!(s.checkpointed_s + s.lost_s > 0.0, "{s:?}");
        assert!(s.checkpointed_s >= 0.0 && s.lost_s >= 0.0, "{s:?}");
        // the acceptance bound: resumes replay remaining work only, so
        // the preempted campaign burns < 2× the on-demand slot-time
        assert!(
            a.cost.total_used_slot_s() < 2.0 * base.cost.total_used_slot_s(),
            "spot used {} vs on-demand {}",
            a.cost.total_used_slot_s(),
            base.cost.total_used_slot_s()
        );
        // a resumed gang re-enters the queue with its *remaining* work
        // as the estimate, so attributed slot-time still covers all
        // completed records
        let attributed: f64 = a.cost.per_user_slot_s.iter().sum();
        assert!(
            (attributed - a.cost.total_used_slot_s()).abs() < 1e-6,
            "attributed {attributed} vs used {}",
            a.cost.total_used_slot_s()
        );
    }

    /// Tentpole pin (named in the issue): per-tenant bills partition
    /// the fabric total exactly on a mixed spot/on-demand fabric, with
    /// the spot trainer billed at the discounted `class:spot` rate and
    /// migration egress folded into the preempted tenant's bill.
    #[test]
    fn spot_bills_partition_and_discount() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(4, scenario, 0.0, 31);
        cfg.spot = parse_spot("alcf#cerebras:6:2").unwrap();
        cfg.checkpoint_every_s = Some(5.0);
        let rep = run_campaign(&cfg).unwrap();
        assert!(rep.cost.spot_endpoints.contains("alcf#cerebras"));

        let book = PriceBook::paper();
        let d = rep.cost.dollars(&book);
        // the spot trainer carries the 30% spot rate; on-demand
        // endpoints keep list price
        let trainer = d
            .endpoints
            .iter()
            .find(|e| e.endpoint == "alcf#cerebras")
            .expect("trainer priced");
        assert!((trainer.rate_per_slot_hour - 42.0 * 0.3).abs() < 1e-12);
        let sim = d
            .endpoints
            .iter()
            .find(|e| e.endpoint == "slac#sim")
            .expect("sim priced");
        assert_eq!(sim.rate_per_slot_hour, 0.4);
        // the partition of unity survives the mixed-tier fabric
        let billed: f64 = d.per_tenant.iter().map(|t| t.total_usd()).sum();
        assert!(
            (billed - d.total_usd()).abs() < 1e-6 * d.total_usd().max(1.0),
            "bills {billed} vs fabric total {}",
            d.total_usd()
        );
        // all egress — staging, model return, and any checkpoint
        // migrations — is tenant-tagged
        let tagged: f64 = rep.cost.per_user_egress_bytes.iter().sum();
        assert!(
            (tagged - rep.cost.egress_bytes).abs() < 1e-6,
            "untagged egress: {tagged} of {}",
            rep.cost.egress_bytes
        );
        if let Some(s) = rep.spot {
            if s.wan_migrations > 0 {
                assert!(rep.cost.egress_bytes >= s.migration_bytes as f64);
            }
        }
        // discounting the spot tier can only cut the fabric total
        let mut on_demand = rep.cost.clone();
        on_demand.spot_endpoints.clear();
        let d2 = on_demand.dollars(&book);
        assert!(
            d2.total_usd() >= d.total_usd(),
            "spot discount raised the bill: {} vs {}",
            d.total_usd(),
            d2.total_usd()
        );
    }

    // ---- sharded execution (§13) ----

    /// The shard count is a pure function of the config — never of the
    /// thread count. That is the whole determinism argument, so pin it.
    #[test]
    fn shard_count_is_a_pure_function_of_the_config() {
        if std::env::var_os("XLOOP_SHARD_USERS").is_some() {
            return; // the env override legitimately changes the auto-split
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(8, scenario, 1.0, 1);
        assert_eq!(effective_shards(&cfg), 1, "small campaigns stay serial");
        cfg.users = AUTO_SHARD_USERS;
        assert_eq!(effective_shards(&cfg), 1, "threshold itself stays serial");
        cfg.users = AUTO_SHARD_USERS * 3 + 1;
        assert_eq!(effective_shards(&cfg), 4);
        cfg.users = 1_000_000;
        assert_eq!(effective_shards(&cfg), 1_000_000usize.div_ceil(AUTO_SHARD_USERS));
        // an explicit per-shard width retunes the auto-split
        cfg.users = 1000;
        cfg.shard_users = 100;
        assert_eq!(effective_shards(&cfg), 10);
        cfg.shard_users = 1;
        assert_eq!(effective_shards(&cfg), 1000, "width 1 = one user per shard");
        cfg.shard_users = 0;
        // explicit shards win, clamped so no shard is empty
        cfg.shards = 3;
        cfg.users = 10;
        assert_eq!(effective_shards(&cfg), 3);
        cfg.shards = 64;
        assert_eq!(effective_shards(&cfg), 10);
        // the explicit count also beats the width knob
        cfg.shard_users = 5;
        assert_eq!(effective_shards(&cfg), 10);
        // derived shard seeds are distinct from the root and each other
        let seeds: std::collections::BTreeSet<u64> =
            (0..8).map(|s| shard_seed(42, s)).collect();
        assert_eq!(seeds.len(), 8);
        assert!(!seeds.contains(&42));
    }

    /// Degenerate configs die cleanly: zero users is an error on every
    /// path (serial, replica, sync), and an explicit shard count above
    /// the user count is clamped so no empty shard ever reaches the
    /// merge.
    #[test]
    fn zero_users_errors_on_every_path() {
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(0, scenario, 1.0, 5);
        assert!(run_campaign(&cfg).is_err());
        cfg.shards = 4; // explicit shards never manufacture an empty merge
        assert!(run_campaign(&cfg).is_err());
        cfg.shards = 0;
        cfg.sync_wan = true;
        assert!(run_campaign(&cfg).is_err());
    }

    #[test]
    fn more_shards_than_users_never_yields_an_empty_shard() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(3, scenario, 1.0, 19);
        cfg.shards = 10; // clamped to the user count
        let rep = run_campaign_with_pool(&cfg, &Pool::new(4)).unwrap();
        assert_eq!(rep.shards, 3);
        assert_eq!(rep.users.len(), 3);
        for (i, u) in rep.users.iter().enumerate() {
            assert_eq!(u.user, i + 1);
            assert!(u.succeeded);
        }
    }

    // ---- bounded-lag window synchronization (§14) ----

    /// A shard must be able to migrate between pool workers at window
    /// barriers — pin the auto-trait so a future `Rc`/raw-pointer
    /// regression fails here instead of deep inside `pool::scope`.
    #[test]
    fn shard_run_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ShardRun>();
    }

    /// The sync window is the paper topology's 48 ms RTT (the RTT term
    /// dominates the 16 MiB drain time on a 10 Gbps NIC).
    #[test]
    fn sync_window_tracks_the_paper_topology_rtt() {
        let w = sync_window_s(&Topology::paper());
        assert!((w - 0.048).abs() < 1e-9, "window {w}");
    }

    /// Hand-computable water-fill: ascending fill order, bottlenecked
    /// claimants split the residue equally, and allocations never
    /// exceed demand or capacity.
    #[test]
    fn water_fill_is_max_min_fair() {
        assert_eq!(water_fill(&[5.0, 1.0, 10.0], 9.0), vec![4.0, 1.0, 4.0]);
        // under capacity: everyone gets their whole demand
        assert_eq!(water_fill(&[2.0, 2.0], 10.0), vec![2.0, 2.0]);
        // uniform oversubscription: equal shares, capacity exhausted
        assert_eq!(water_fill(&[8.0, 8.0, 8.0], 6.0), vec![2.0, 2.0, 2.0]);
        assert!(water_fill(&[], 5.0).is_empty());
    }

    /// `--sync-wan --shards 1` routes through the serial path: there is
    /// nothing to contend with, and the report must be byte-identical
    /// (full `Debug` form) to the plain serial campaign.
    #[test]
    fn sync_wan_at_one_shard_is_the_serial_path_bit_for_bit() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(2, scenario, 1.0, 23);
        let serial = run_campaign(&cfg).unwrap();
        cfg.sync_wan = true;
        cfg.shards = 1;
        let sync = run_campaign_with_pool(&cfg, &Pool::new(8)).unwrap();
        assert_eq!(format!("{serial:?}"), format!("{sync:?}"));
        assert_eq!(sync.shards, 1);
        assert_eq!(sync.sync_wan_windows, 0);
    }

    /// The §14 acceptance fixture: two single-user shards staging the
    /// same 3.6 GB dataset, launched together. In replica mode each
    /// replica claims the full 10 Gbps DTN NIC — physically 2×
    /// oversubscribed. The bounded-lag ledger detects the overlap and
    /// water-fills the bottleneck, so both stagings run at half rate
    /// and every turnaround is strictly slower.
    #[test]
    fn sync_wan_contention_is_strictly_slower_than_replica_mode() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(2, scenario, 0.0, 29);
        cfg.shards = 2;
        let replica = run_campaign_with_pool(&cfg, &Pool::new(2)).unwrap();
        cfg.sync_wan = true;
        let sync = run_campaign_with_pool(&cfg, &Pool::new(2)).unwrap();
        assert_eq!(replica.sync_wan_windows, 0);
        assert!(sync.sync_wan_windows > 0, "no windows executed");
        for (r, s) in replica.users.iter().zip(&sync.users) {
            assert!(
                s.turnaround_s > r.turnaround_s,
                "user {} not slowed by cross-shard contention: sync {} vs replica {}",
                r.user,
                s.turnaround_s,
                r.turnaround_s
            );
        }
        assert!(
            sync.mean_task_throughput_bps < replica.mean_task_throughput_bps,
            "shared WAN did not lower goodput: {} vs {}",
            sync.mean_task_throughput_bps,
            replica.mean_task_throughput_bps
        );
        assert!(sync.makespan_s > replica.makespan_s);
    }

    /// The §14 determinism pin: the windowed report is byte-equal (full
    /// `Debug` form) across worker counts, exactly like replica mode —
    /// windows are derived from virtual time and the exchange runs
    /// serially in shard order, so the thread count can never leak in.
    #[test]
    fn sync_wan_campaign_is_thread_count_invariant() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(6, scenario, 1.0, 37);
        cfg.shards = 3;
        cfg.sync_wan = true;
        let a = run_campaign_with_pool(&cfg, &Pool::new(1)).unwrap();
        let b = run_campaign_with_pool(&cfg, &Pool::new(8)).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert!(a.sync_wan_windows > 0);
        assert_eq!(a.shards, 3);
        assert_eq!(a.shard_users, 2);
    }

    /// Tentpole pin (named in the issue): the sharded report is
    /// byte-equal (full `Debug` form) across worker counts — with the
    /// knobs off, and with spot preemption and a WAN fault plan riding
    /// along. `run_campaign_with_pool` is the seam because the global
    /// pool reads `XLOOP_THREADS` once per process.
    #[test]
    fn sharded_campaign_is_thread_count_invariant() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(6, scenario.clone(), 1.0, 37);
        cfg.shards = 3;
        let a = run_campaign_with_pool(&cfg, &Pool::new(1)).unwrap();
        let b = run_campaign_with_pool(&cfg, &Pool::new(8)).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));

        let mut cfg = CampaignConfig::new(6, scenario, 0.0, 37);
        cfg.shards = 3;
        cfg.spot = parse_spot("alcf#cerebras:6:2").unwrap();
        cfg.checkpoint_every_s = Some(5.0);
        cfg.faults = crate::simnet::FaultPlan::parse("wan=0.5@0..60").unwrap();
        let a = run_campaign_with_pool(&cfg, &Pool::new(1)).unwrap();
        let b = run_campaign_with_pool(&cfg, &Pool::new(8)).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert!(a.spot.is_some(), "spot ledger survives the merge");
    }

    /// The deterministic merge keeps the bookkeeping exact: users come
    /// back renumbered 1..=N in shard order, the per-user cost vectors
    /// cover the population, attribution sums still match the fabric
    /// totals, and the re-weighted throughput mean sits inside the
    /// per-shard range.
    #[test]
    fn sharded_merge_renumbers_and_balances() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(5, scenario, 1.0, 41);
        cfg.shards = 2;
        cfg.priorities = vec![0, 2, 5];
        let rep = run_campaign_with_pool(&cfg, &Pool::new(2)).unwrap();
        assert_eq!(rep.config_users, 5);
        assert_eq!(rep.users.len(), 5);
        for (i, u) in rep.users.iter().enumerate() {
            assert_eq!(u.user, i + 1, "global renumbering in shard order");
            assert!(u.succeeded);
        }
        assert_eq!(rep.cost.per_user_slot_s.len(), 5);
        assert_eq!(rep.cost.per_user_egress_bytes.len(), 5);
        assert!(rep.failed_users.is_empty());
        // attribution still covers the merged totals exactly
        let attributed: f64 = rep.cost.per_user_slot_s.iter().sum();
        assert!(
            (attributed - rep.cost.total_used_slot_s()).abs() < 1e-6,
            "attributed {attributed} vs used {}",
            rep.cost.total_used_slot_s()
        );
        let tagged: f64 = rep.cost.per_user_egress_bytes.iter().sum();
        assert!(
            (tagged - rep.cost.egress_bytes).abs() < 1e-6,
            "untagged egress after merge: {tagged} of {}",
            rep.cost.egress_bytes
        );
        // the makespan is the max over shards, so no user outruns it
        for u in &rep.users {
            assert!(u.finished_vt <= rep.makespan_s + 1e-9);
        }
        assert!(rep.wan_transfers > 0);
        assert!(rep.mean_task_throughput_bps > 0.0);
        assert!(rep.fairness.jain > 0.0 && rep.fairness.jain <= 1.0);
        // per-tenant dollar partition survives the merge
        let d = rep.cost.dollars(&PriceBook::paper());
        let billed: f64 = d.per_tenant.iter().map(|t| t.total_usd()).sum();
        assert!(
            (billed - d.total_usd()).abs() < 1e-6 * d.total_usd().max(1.0),
            "bills {billed} vs fabric total {}",
            d.total_usd()
        );
    }

    /// Local-mode campaigns run with no transfers but still queue on the
    /// single V100.
    #[test]
    fn local_mode_campaign_queues_on_v100() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::LocalV100).unwrap();
        let rep = run_campaign(&CampaignConfig::new(2, scenario, 1.0, 3)).unwrap();
        assert_eq!(rep.mean_task_throughput_bps, 0.0); // no WAN transfers
        let v100 = rep.load("slac#v100").expect("v100 used");
        // local training is ~30x slower; the second user queues behind it
        assert!(v100.total_queue_wait_s > 0.0, "{v100:?}");
        for u in &rep.users {
            assert!(u.breakdown.as_ref().unwrap().data_transfer_s.is_none());
        }
    }

    /// Satellite pin: `CampaignConfig::default()` has every knob at its
    /// disabled default, and the positional `new` constructor is a thin
    /// shim over the `with_*` chain — identical field for field.
    #[test]
    fn default_config_pins_every_knob_off() {
        let d = CampaignConfig::default();
        assert_eq!(d.users, 1);
        assert_eq!(d.scenario.model, "braggnn");
        assert_eq!(d.scenario.mode, Mode::RemoteCerebras);
        assert_eq!(d.mean_interarrival_s, 60.0);
        assert_eq!(d.seed, 42);
        assert!(matches!(d.policy, PolicyKind::Fifo));
        assert!(d.priorities.is_empty());
        assert!(d.autoscale.is_empty());
        assert!(d.faults.is_empty());
        assert!(d.mix.is_empty());
        assert!(d.spot.is_empty());
        assert_eq!(d.checkpoint_every_s, None);
        assert_eq!((d.shards, d.shard_users), (0, 0));
        assert!(!d.sync_wan);
        assert!(d.sites.is_empty());
        assert_eq!(d.placement, Placement::Turnaround);
        assert_eq!(d.closed_loop, None);
        let scenario = Scenario::table1("cookienetae", Mode::RemoteMultiGpu).unwrap();
        let positional = CampaignConfig::new(3, scenario.clone(), 5.0, 13);
        let chained = CampaignConfig::default()
            .with_users(3)
            .with_scenario(scenario)
            .with_interarrival_s(5.0)
            .with_seed(13);
        assert_eq!(format!("{positional:?}"), format!("{chained:?}"));
    }

    /// Satellite pin: the old free functions are shims over
    /// [`CampaignRunner`] — all entry points produce byte-identical
    /// reports on both the serial and the pooled sharded path.
    #[test]
    fn runner_builder_matches_free_function_shims() {
        if !artifacts_present() {
            return;
        }
        let cfg = CampaignConfig::new(3, clean_scenario(), 2.0, 17);
        let free = run_campaign(&cfg).unwrap();
        let built = CampaignRunner::new(&cfg).run().unwrap();
        assert_eq!(format!("{free:?}"), format!("{built:?}"));
        let sharded = cfg.with_shards(2);
        let pool = Pool::new(2);
        let free = run_campaign_with_pool(&sharded, &pool).unwrap();
        let built = CampaignRunner::new(&sharded).pool(&pool).run().unwrap();
        assert_eq!(format!("{free:?}"), format!("{built:?}"));
    }

    /// Broker determinism (satellite): bit-equal scores tie-break to
    /// the lexicographically smaller site name (sites are scanned in
    /// name order and only a *strictly* better score moves the
    /// choice), and the data-locality credit waives predicted staging
    /// for resident models only.
    #[test]
    fn broker_tie_breaks_on_name_and_credits_residency() {
        if !artifacts_present() {
            return;
        }
        // two identically-shaped sites hosting a class the home site
        // lacks (v100): their scores are bit-equal, so the name decides
        let spec = "ornl:v100:10:12:0.02;nersc:v100:10:12:0.02";
        let mut world = World::paper(42).unwrap();
        let mut broker = Broker::new(&parse_sites(spec).unwrap(), Placement::Turnaround);
        for site in broker.sites().to_vec() {
            if site.name != "alcf" {
                world.add_site(&site).unwrap();
            }
        }
        let bytes = 3_600_000_000;
        let (ep, dtn) = broker.place(&world, "v100", 1, bytes, "braggnn", 0.0).unwrap();
        assert_eq!((ep.as_str(), dtn.as_str()), ("nersc#v100", "nersc#dtn"));
        // residency flips it: `ornl` resident for braggnn scores 0
        let spec = "ornl:v100:10:12:0.02:braggnn;nersc:v100:10:12:0.02";
        let mut world = World::paper(42).unwrap();
        let mut broker = Broker::new(&parse_sites(spec).unwrap(), Placement::Turnaround);
        for site in broker.sites().to_vec() {
            if site.name != "alcf" {
                world.add_site(&site).unwrap();
            }
        }
        let (ep, _) = broker.place(&world, "v100", 1, bytes, "braggnn", 0.0).unwrap();
        assert_eq!(ep, "ornl#v100");
        // ...but only for the resident model — anything else re-ties
        let (ep, _) = broker.place(&world, "v100", 1, bytes, "cookienetae", 0.0).unwrap();
        assert_eq!(ep, "nersc#v100");
        let summary = broker.summary();
        assert_eq!(summary.sites.iter().map(|s| s.placed).sum::<u32>(), 2);
        let ornl = summary.sites.iter().find(|s| s.name == "ornl").unwrap();
        assert_eq!((ornl.placed, ornl.resident_hits), (1, 1));
    }

    /// Acceptance pin (world level, exact arithmetic): with idle queues
    /// the broker's turnaround score *is* the transfer model's staging
    /// prediction (predicted gang wait is exactly 0), two sites with
    /// the same NIC capacity differ by exactly `handshake_rtts × ΔRTT`
    /// (the `x/v` throughput term cancels), and a `SiteOutage` moves
    /// placement to the *next-best* site by that arithmetic — not
    /// merely to "some" live site.
    #[test]
    fn site_outage_reroutes_to_next_best_site_by_exact_turnaround() {
        if !artifacts_present() {
            return;
        }
        // same 10 Gb/s shape as the home DTN path, higher latency;
        // ornl strictly worse than nersc, both worse than home
        let spec = "nersc:cerebras:10:12:0.02;ornl:cerebras:10:40:0.02";
        let mut world = World::paper(42).unwrap();
        let mut broker = Broker::new(&parse_sites(spec).unwrap(), Placement::Turnaround);
        for site in broker.sites().to_vec() {
            if site.name != "alcf" {
                world.add_site(&site).unwrap();
            }
        }
        let bytes = 3_600_000_000u64;
        let stage = |dst: &str| {
            world
                .transfer
                .predict_linear(&TransferRequest::split_even(
                    "broker-stage",
                    EndpointId::from("slac#dtn"),
                    EndpointId::from(dst),
                    bytes,
                    16,
                ))
                .unwrap()
        };
        // idle fabric: the gang-wait term of every candidate is exactly 0
        let faas = world.faas.as_ref().unwrap();
        for ep in ["alcf#cerebras", "nersc#cerebras", "ornl#cerebras"] {
            assert_eq!(faas.predicted_gang_wait(ep, 1, 0.0), 0.0, "{ep}");
        }
        // equal-capacity paths differ by exactly handshake_rtts × ΔRTT
        let topo = &world.transfer.topo;
        let slac = topo.facility("slac").unwrap();
        let rtt = |name: &str| topo.rtt(slac, topo.facility(name).unwrap()).unwrap();
        let handshakes = world.transfer.params.handshake_rtts;
        let d_nersc = stage("nersc#dtn") - stage("alcf#dtn");
        assert!(
            (d_nersc - handshakes * (rtt("nersc") - rtt("alcf"))).abs() < 1e-9,
            "{d_nersc}"
        );
        let d_ornl = stage("ornl#dtn") - stage("nersc#dtn");
        assert!(
            (d_ornl - handshakes * (rtt("ornl") - rtt("nersc"))).abs() < 1e-9,
            "{d_ornl}"
        );
        assert!(stage("alcf#dtn") < stage("nersc#dtn"));
        assert!(stage("nersc#dtn") < stage("ornl#dtn"));
        // all up: home wins on the pinned ordering
        let (ep, dtn) = broker.place(&world, "cerebras", 1, bytes, "braggnn", 0.0).unwrap();
        assert_eq!((ep.as_str(), dtn.as_str()), ("alcf#cerebras", "alcf#dtn"));
        // home dark: the next-best by the exact arithmetic is nersc
        broker.set_down("alcf", true).unwrap();
        let (ep, dtn) = broker.place(&world, "cerebras", 1, bytes, "braggnn", 0.0).unwrap();
        assert_eq!((ep.as_str(), dtn.as_str()), ("nersc#cerebras", "nersc#dtn"));
        // nersc dark too: ornl is the only live candidate left
        broker.set_down("nersc", true).unwrap();
        let (ep, _) = broker.place(&world, "cerebras", 1, bytes, "braggnn", 0.0).unwrap();
        assert_eq!(ep, "ornl#cerebras");
        // everything dark: park on the first hosting site by name — the
        // group queues there and runs at restore
        broker.set_down("ornl", true).unwrap();
        let (ep, _) = broker.place(&world, "cerebras", 1, bytes, "braggnn", 0.0).unwrap();
        assert_eq!(ep, "alcf#cerebras");
        // restore flips placement back deterministically
        broker.set_down("alcf", false).unwrap();
        let (ep, _) = broker.place(&world, "cerebras", 1, bytes, "braggnn", 0.0).unwrap();
        assert_eq!(ep, "alcf#cerebras");
        assert_eq!(broker.summary().sites.iter().map(|s| s.placed).sum::<u32>(), 5);
    }

    /// Acceptance (named in the issue): a `SiteOutage` opening mid-train
    /// reroutes the in-flight user off the dark site — the failover wave
    /// checkpoint-migrates the running gang and the federation block
    /// counts the reroute — and the whole run replays byte-identically.
    #[test]
    fn site_outage_reroutes_in_flight_users() {
        if !artifacts_present() {
            return;
        }
        // braggnn resident at nersc: the locality credit wins placement
        // outright (score 0 vs the home site's ~7 s predicted stage), so
        // the single user provably trains there. Generation is ~0.02 s
        // and staging ~7 s, so the 18 s-scale train is running when the
        // outage opens at t=10; it stays dark past any plausible finish,
        // forcing a migration rather than an in-place wait.
        let cfg = CampaignConfig::default()
            .with_scenario(Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap())
            .with_interarrival_s(1.0)
            .with_seed(31)
            .with_sites(parse_sites("nersc:cerebras:10:12:0.02:braggnn").unwrap())
            .with_checkpoint_every_s(Some(5.0))
            .with_faults(FaultPlan::parse("site=nersc@10..4000").unwrap());
        let rep = run_campaign(&cfg).unwrap();
        let fed = rep.federation.as_ref().expect("sites imply a federation block");
        let nersc = fed.sites.iter().find(|s| s.name == "nersc").unwrap();
        assert_eq!((nersc.placed, nersc.resident_hits), (1, 1), "{fed:?}");
        assert_eq!(fed.reroutes, 1, "{fed:?}");
        assert_eq!(fed.stranded, 0, "{fed:?}");
        assert!(rep.users[0].succeeded);
        let again = run_campaign(&cfg).unwrap();
        assert_eq!(format!("{rep:?}"), format!("{again:?}"));
    }

    /// Tentpole pin (named in the issue): the federated report — with a
    /// site-outage window taking the extra site dark mid-campaign — is
    /// byte-equal in full `Debug` form across worker counts.
    #[test]
    fn federated_campaign_is_thread_count_invariant() {
        if !artifacts_present() {
            return;
        }
        let cfg = CampaignConfig::default()
            .with_users(6)
            .with_scenario(Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap())
            .with_interarrival_s(1.0)
            .with_seed(37)
            .with_shards(3)
            .with_sites(parse_sites("nersc:cerebras:25:5:0.02").unwrap())
            .with_faults(FaultPlan::parse("site=nersc@40..400").unwrap());
        let one = run_campaign_with_pool(&cfg, &Pool::new(1)).unwrap();
        let eight = run_campaign_with_pool(&cfg, &Pool::new(8)).unwrap();
        assert_eq!(format!("{one:?}"), format!("{eight:?}"));
        let fed = one.federation.expect("sites imply a federation block");
        assert_eq!(fed.sites.len(), 2); // home + nersc, name order
        assert_eq!(fed.sites[0].name, "alcf");
        assert_eq!(fed.sites[1].name, "nersc");
        assert_eq!(fed.sites.iter().map(|s| s.placed).sum::<u32>(), 6);
        assert!(one.users.iter().all(|u| u.succeeded));
        // the same campaign without sites carries no federation block
        let plain = CampaignConfig::default()
            .with_users(6)
            .with_scenario(Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap())
            .with_interarrival_s(1.0)
            .with_seed(37);
        assert!(run_campaign(&plain).unwrap().federation.is_none());
    }

    /// Degenerate federation configs fail fast with pointed messages:
    /// `site=` windows without a broker, local mode behind a broker,
    /// and outage windows naming a site the broker does not know.
    #[test]
    fn federation_config_validation_rejects_degenerate_combos() {
        if !artifacts_present() {
            return;
        }
        let remote = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let err = run_campaign(
            &CampaignConfig::new(1, remote.clone(), 1.0, 1)
                .with_faults(FaultPlan::parse("site=nersc@0..10").unwrap()),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("no federation sites"), "{err:#}");
        let local = Scenario::table1("braggnn", Mode::LocalV100).unwrap();
        let err = run_campaign(
            &CampaignConfig::new(1, local, 1.0, 1)
                .with_sites(parse_sites("nersc:v100:10:12:0.02").unwrap()),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("remote training mode"), "{err:#}");
        let err = run_campaign(
            &CampaignConfig::new(1, remote, 1.0, 1)
                .with_sites(parse_sites("nersc:cerebras:10:12:0.02").unwrap())
                .with_faults(FaultPlan::parse("site=ornl@0..10").unwrap()),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("unknown site"), "{err:#}");
    }

    /// A noise-free, unsmoothed drift spec whose every trigger time is
    /// hand-computable: ewma = 0.01 × model age, batch every 2 s, so
    /// the threshold 0.1 is first exceeded at t = 12 for every user.
    fn traced_loop() -> ClosedLoopSpec {
        ClosedLoopSpec {
            serve_rate: 0.5,
            threshold: 0.1,
            hysteresis: 0.5,
            cooldown_s: 0.0,
            ewma_alpha: 1.0,
            drift_rate: 0.01,
            noise: 0.0,
            max_batches: 10_000,
        }
    }

    /// Tentpole acceptance (named in the issue): with `--closed-loop`
    /// the drift trigger *admits* every retraining flow — no user
    /// arrives at the Poisson stream's t = 0; the hand-traced spec
    /// pins the admission instant — and the staleness integral equals
    /// the turnaround sum bit-exactly, because the hot-swap records
    /// `end_vt - trigger_vt` with the same subtraction `finish()`
    /// uses for turnaround.
    #[test]
    fn closed_loop_admits_retrains_and_staleness_is_exact() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let cfg = CampaignConfig::new(2, scenario, 5.0, 42)
            .with_closed_loop(Some(traced_loop()));
        let rep = run_campaign(&cfg).unwrap();
        let cl = rep.closed_loop.expect("knob on implies the ledger");
        // drift replaced the Poisson plan: both users admitted at the
        // hand-traced trigger instant, not at the Poisson t = 0
        assert_eq!(cl.retrains_admitted, 2, "{cl:?}");
        for u in &rep.users {
            assert_eq!(u.arrival_vt, 12.0, "user {} not drift-admitted", u.user);
            assert!(u.succeeded);
        }
        assert_eq!(cl.hot_swaps, 2);
        assert!(cl.triggers >= 2);
        assert_eq!(cl.forced_triggers, 0);
        // two-term sums are order-insensitive in IEEE arithmetic, so
        // the identity holds to the last bit
        let turnaround_sum: f64 = rep.users.iter().map(|u| u.turnaround_s).sum();
        assert_eq!(cl.staleness_s, turnaround_sum, "{cl:?}");
        assert!(cl.batches_served > 0);
        assert!(cl.edge_busy_s > 0.0);
        // batches served above threshold while the retrains were in
        // flight: the accuracy-loss integral is strictly positive
        assert!(cl.accuracy_loss > 0.0, "{cl:?}");
        // every fabric task the loop admitted carries Drift provenance
        assert!(cl.drift_slot_s > 0.0, "{cl:?}");
        // and the whole thing replays byte-identically
        let again = run_campaign(&cfg).unwrap();
        assert_eq!(format!("{rep:?}"), format!("{again:?}"));
    }

    /// Tentpole pin (named in the issue): the closed-loop report — with
    /// shards and a spot trainer riding along — is byte-equal in full
    /// `Debug` form across worker counts.
    #[test]
    fn closed_loop_campaign_is_thread_count_invariant() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let mut cfg = CampaignConfig::new(4, scenario, 1.0, 37);
        cfg.shards = 2;
        cfg.spot = parse_spot("alcf#cerebras:60:2").unwrap();
        cfg.checkpoint_every_s = Some(5.0);
        cfg.closed_loop = Some(traced_loop());
        let one = run_campaign_with_pool(&cfg, &Pool::new(1)).unwrap();
        let eight = run_campaign_with_pool(&cfg, &Pool::new(8)).unwrap();
        assert_eq!(format!("{one:?}"), format!("{eight:?}"));
        let cl = one.closed_loop.expect("ledger survives the merge");
        assert_eq!(cl.retrains_admitted, 4);
        assert!(one.spot.is_some());
    }

    /// Knob off ⇒ no drift objects, no report field: the default
    /// campaign carries `closed_loop: None` and is untouched by the
    /// subsystem existing (the byte-identity is pinned end-to-end by
    /// `rust/tests/invariants.rs` and the CI golden).
    #[test]
    fn closed_loop_off_leaves_no_trace_in_the_report() {
        if !artifacts_present() {
            return;
        }
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let rep = run_campaign(&CampaignConfig::new(2, scenario, 5.0, 42)).unwrap();
        assert!(rep.closed_loop.is_none());
        assert_eq!(rep.users[0].arrival_vt, 0.0, "Poisson first user at 0");
    }

    /// Degenerate closed-loop configs fail fast with pointed messages
    /// (mirrors the PR 8 spot/checkpoint guards): zero / negative /
    /// NaN thresholds, a degenerate serve rate, and `--users 0` are
    /// all rejected before any fabric state exists — no artifacts
    /// needed, validation precedes the world build.
    #[test]
    fn closed_loop_config_validation_rejects_degenerate_specs() {
        let base = CampaignConfig::default();
        for threshold in [0.0, -0.5, f64::NAN] {
            let cfg = base.clone().with_closed_loop(Some(ClosedLoopSpec {
                threshold,
                ..ClosedLoopSpec::default()
            }));
            let err = run_campaign(&cfg).unwrap_err();
            assert!(
                format!("{err:#}").contains("drift threshold"),
                "threshold {threshold}: {err:#}"
            );
        }
        let cfg = base.clone().with_closed_loop(Some(ClosedLoopSpec {
            serve_rate: f64::INFINITY,
            ..ClosedLoopSpec::default()
        }));
        let err = run_campaign(&cfg).unwrap_err();
        assert!(format!("{err:#}").contains("serve rate"), "{err:#}");
        let cfg = base
            .with_users(0)
            .with_closed_loop(Some(ClosedLoopSpec::default()));
        let err = run_campaign(&cfg).unwrap_err();
        assert!(
            format!("{err:#}").contains("at least one user"),
            "{err:#}"
        );
    }
}
