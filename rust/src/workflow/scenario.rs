//! Table 1 scenarios: which model retrains where, with what staged
//! payload.

use anyhow::{bail, Result};

/// The four training modes of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    LocalV100,
    RemoteCerebras,
    RemoteSambaNova,
    RemoteMultiGpu,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode> {
        Ok(match s {
            "local" | "local-v100" => Mode::LocalV100,
            "remote-cerebras" | "cerebras" => Mode::RemoteCerebras,
            "remote-sambanova" | "sambanova" => Mode::RemoteSambaNova,
            "remote-multigpu" | "multigpu" | "gpu8" => Mode::RemoteMultiGpu,
            other => bail!(
                "unknown mode `{other}` (local, remote-cerebras, remote-sambanova, remote-multigpu)"
            ),
        })
    }

    pub fn is_remote(&self) -> bool {
        !matches!(self, Mode::LocalV100)
    }

    /// The faas endpoint that trains in this mode.
    pub fn train_endpoint(&self) -> &'static str {
        match self {
            Mode::LocalV100 => "slac#v100",
            Mode::RemoteCerebras => "alcf#cerebras",
            Mode::RemoteSambaNova => "alcf#sambanova",
            Mode::RemoteMultiGpu => "alcf#gpu8",
        }
    }

    /// Table 1 row label.
    pub fn label(&self) -> &'static str {
        match self {
            Mode::LocalV100 => "Local (one GPU)",
            Mode::RemoteCerebras => "Remote (Cerebras, Entire Wafer)",
            Mode::RemoteSambaNova => "Remote (SambaNova 1-RDU)",
            Mode::RemoteMultiGpu => "Remote (multi-GPU server)",
        }
    }
}

/// One retraining scenario (a Table 1 cell pair).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub model: String,
    pub mode: Mode,
    /// bytes staged to the DCAI (the paper moved full training sets; the
    /// in-memory dataset used for *real* steps is much smaller)
    pub staged_bytes: u64,
    /// samples generated for real training
    pub real_samples: usize,
    pub seed: u64,
}

impl Default for Scenario {
    /// The lead Table 1 cell (BraggNN on the remote Cerebras) — the
    /// scenario `CampaignConfig::default()` starts from. `table1` is
    /// infallible for this pair, so the builder root never errors.
    fn default() -> Scenario {
        Scenario::table1("braggnn", Mode::RemoteCerebras).expect("built-in table1 scenario")
    }
}

impl Scenario {
    /// Defaults reproducing the Table 1 magnitudes: staged payloads sized
    /// so the paper-calibrated fabric yields ~7 s (BraggNN) and ~5 s
    /// (CookieNetAE) data-transfer times.
    pub fn table1(model: &str, mode: Mode) -> Result<Scenario> {
        let staged_bytes = match model {
            "braggnn" => 3_600_000_000,
            "cookienetae" => 1_200_000_000,
            other => bail!("no table1 scenario for `{other}`"),
        };
        let real_samples = match model {
            "braggnn" => 2048,
            _ => 64,
        };
        Ok(Scenario {
            model: model.to_string(),
            mode,
            staged_bytes,
            real_samples,
            seed: 42,
        })
    }

    /// FLOPs per served inference batch on this scenario's model
    /// (forward-pass FLOPs x the model's inference batch size) — what
    /// the closed-loop campaign charges the edge device per drift
    /// batch (DESIGN.md §16).
    pub fn serve_flops_per_batch(
        &self,
        registry: &crate::models::ModelRegistry,
    ) -> Result<f64> {
        let meta = registry.get(&self.model)?;
        Ok(meta.fwd_flops_per_sample * meta.infer_batch as f64)
    }

    /// The paper's Table 1 grid (modes measured per model).
    pub fn table1_grid() -> Vec<Scenario> {
        let mut rows = Vec::new();
        for mode in [Mode::LocalV100, Mode::RemoteCerebras, Mode::RemoteSambaNova] {
            rows.push(Scenario::table1("braggnn", mode).unwrap());
        }
        for mode in [Mode::LocalV100, Mode::RemoteCerebras, Mode::RemoteMultiGpu] {
            rows.push(Scenario::table1("cookienetae", mode).unwrap());
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing() {
        assert_eq!(Mode::parse("local").unwrap(), Mode::LocalV100);
        assert_eq!(Mode::parse("cerebras").unwrap(), Mode::RemoteCerebras);
        assert!(Mode::parse("quantum").is_err());
        assert!(!Mode::LocalV100.is_remote());
        assert!(Mode::RemoteCerebras.is_remote());
    }

    #[test]
    fn grid_matches_paper_rows() {
        let grid = Scenario::table1_grid();
        assert_eq!(grid.len(), 6);
        assert_eq!(grid.iter().filter(|s| s.model == "braggnn").count(), 3);
        assert!(grid
            .iter()
            .any(|s| s.model == "cookienetae" && s.mode == Mode::RemoteMultiGpu));
        assert!(Scenario::table1("resnet", Mode::LocalV100).is_err());
    }
}
