//! The paper's DNNTrainerFlow as a declarative flow definition (§3,
//! github.com/AISDC/DNNTrainerFlow): stage data → (label) → train on a
//! DCAI endpoint → return the model → deploy to the edge.
//!
//! Built as JSON so it round-trips through `FlowDefinition::from_json` —
//! the same path a user-authored flow file takes.

use anyhow::Result;

use crate::flows::FlowDefinition;
use crate::util::Json;

/// Options shaping the generated definition.
#[derive(Debug, Clone)]
pub struct FlowShape {
    /// include WAN transfers (false = the paper's "local" mode)
    pub remote: bool,
    /// include the labeling action (operation A) before training
    pub with_labeling: bool,
    /// roll the edge back to pristine weights if deployment fails
    pub rollback_on_failure: bool,
    /// transfer file split + pinned concurrency
    pub files: usize,
    pub concurrency: Option<usize>,
    /// override the remote staging destination (and the symmetric
    /// trained-model return source) — `None` keeps the paper's fixed
    /// `alcf#dtn`, the federation broker passes `"${input.stage_dst}"`
    /// so each user's placed site picks the DTN pair
    pub stage_dst: Option<String>,
}

impl Default for FlowShape {
    fn default() -> Self {
        FlowShape {
            remote: true,
            with_labeling: false,
            rollback_on_failure: true,
            files: 16,
            concurrency: None,
            stage_dst: None,
        }
    }
}

/// Build the DNNTrainerFlow definition.
///
/// Flow input schema (referenced via `${input...}`):
/// `{model, dataset, dataset_bytes, train_endpoint}`.
pub fn dnn_trainer_flow(shape: &FlowShape) -> Result<FlowDefinition> {
    let mut actions = Vec::new();
    let mut train_dep = Vec::new();

    let remote_dtn = shape.stage_dst.as_deref().unwrap_or("alcf#dtn");

    if shape.remote {
        let mut stage = format!(
            r#"{{"id": "stage_data", "provider": "transfer", "retries": 2,
                 "params": {{"label": "train-data", "src": "slac#dtn", "dst": "{remote_dtn}",
                             "bytes": "${{input.dataset_bytes}}", "files": {}"#,
            shape.files
        );
        if let Some(k) = shape.concurrency {
            stage.push_str(&format!(r#", "concurrency": {k}"#));
        }
        stage.push_str("}}");
        actions.push(stage);
        train_dep.push("stage_data");
    }

    if shape.with_labeling {
        let dep = if shape.remote {
            r#", "depends_on": ["stage_data"]"#
        } else {
            ""
        };
        actions.push(format!(
            r#"{{"id": "label", "provider": "compute"{dep},
                 "params": {{"endpoint": "alcf#cluster", "function": "label_data",
                             "args": {{"dataset": "${{input.dataset}}"}}}}}}"#
        ));
        train_dep = vec!["label"];
    }

    let deps_json = if train_dep.is_empty() {
        String::new()
    } else {
        format!(
            r#", "depends_on": [{}]"#,
            train_dep
                .iter()
                .map(|d| format!("\"{d}\""))
                .collect::<Vec<_>>()
                .join(", ")
        )
    };
    actions.push(format!(
        r#"{{"id": "train", "provider": "compute"{deps_json}, "retries": 1,
             "params": {{"endpoint": "${{input.train_endpoint}}", "function": "train_model",
                         "args": {{"model": "${{input.model}}", "dataset": "${{input.dataset}}",
                                   "endpoint": "${{input.train_endpoint}}"}}}}}}"#
    ));

    let deploy_dep = if shape.remote {
        actions.push(format!(
            r#"{{"id": "return_model", "provider": "transfer", "retries": 2, "depends_on": ["train"],
                "params": {{"label": "trained-model", "src": "{remote_dtn}", "dst": "slac#dtn",
                           "model": "${{input.model}}", "files": 1}}}}"#
        ));
        "return_model"
    } else {
        "train"
    };

    let failure = if shape.rollback_on_failure {
        r#", "on_failure": {"catch": "rollback_edge"}"#
    } else {
        ""
    };
    actions.push(format!(
        r#"{{"id": "deploy", "provider": "deploy", "depends_on": ["{deploy_dep}"]{failure},
             "params": {{"model": "${{input.model}}"}}}}"#
    ));
    if shape.rollback_on_failure {
        actions.push(
            r#"{"id": "rollback_edge", "provider": "rollback", "handler": true,
                "params": {"model": "${input.model}"}}"#
                .to_string(),
        );
    }

    let name = if shape.remote {
        "dnn-trainer-flow-remote"
    } else {
        "dnn-trainer-flow-local"
    };
    let text = format!(
        r#"{{"name": "{name}", "actions": [{}]}}"#,
        actions.join(", ")
    );
    FlowDefinition::from_json(&Json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remote_flow_has_expected_chain() {
        let def = dnn_trainer_flow(&FlowShape::default()).unwrap();
        let ids: Vec<&str> = def
            .order()
            .iter()
            .map(|&i| def.actions[i].id.as_str())
            .collect();
        assert_eq!(ids, vec!["stage_data", "train", "return_model", "deploy"]);
        // handler exists but is excluded from the normal order
        assert!(def.action("rollback_edge").unwrap().is_handler);
    }

    #[test]
    fn local_flow_skips_transfers() {
        let def = dnn_trainer_flow(&FlowShape {
            remote: false,
            rollback_on_failure: false,
            ..Default::default()
        })
        .unwrap();
        let ids: Vec<&str> = def
            .order()
            .iter()
            .map(|&i| def.actions[i].id.as_str())
            .collect();
        assert_eq!(ids, vec!["train", "deploy"]);
    }

    #[test]
    fn labeling_variant_inserts_label_before_train() {
        let def = dnn_trainer_flow(&FlowShape {
            with_labeling: true,
            ..Default::default()
        })
        .unwrap();
        let ids: Vec<&str> = def
            .order()
            .iter()
            .map(|&i| def.actions[i].id.as_str())
            .collect();
        assert_eq!(
            ids,
            vec!["stage_data", "label", "train", "return_model", "deploy"]
        );
    }

    #[test]
    fn stage_dst_override_rewires_both_transfers() {
        let def = dnn_trainer_flow(&FlowShape {
            stage_dst: Some("${input.stage_dst}".into()),
            ..Default::default()
        })
        .unwrap();
        let stage = def.action("stage_data").unwrap();
        assert_eq!(stage.params.get("dst").as_str(), Some("${input.stage_dst}"));
        let ret = def.action("return_model").unwrap();
        assert_eq!(ret.params.get("src").as_str(), Some("${input.stage_dst}"));
        assert_eq!(ret.params.get("dst").as_str(), Some("slac#dtn"));
        // the default shape keeps the paper's fixed DTN pair
        let def = dnn_trainer_flow(&FlowShape::default()).unwrap();
        let stage = def.action("stage_data").unwrap();
        assert_eq!(stage.params.get("dst").as_str(), Some("alcf#dtn"));
    }

    #[test]
    fn concurrency_pin_lands_in_params() {
        let def = dnn_trainer_flow(&FlowShape {
            concurrency: Some(4),
            ..Default::default()
        })
        .unwrap();
        let stage = def.action("stage_data").unwrap();
        assert_eq!(stage.params.get("concurrency").as_usize(), Some(4));
    }
}
