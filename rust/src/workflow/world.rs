//! `World`: the mutable state the flow engine's actions operate on —
//! facility storage, datasets, trained models, the transfer fabric, the
//! FaaS fabric, the PJRT runtime, accelerator models, and the edge host.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::accel::{cerebras_wse, local_v100, multi_gpu_horovod, sambanova_rdu, AcceleratorModel};
use crate::data::Dataset;
use crate::edge::EdgeHost;
use crate::faas::{FaasEndpoint, FaasService};
use crate::models::ModelRegistry;
use crate::runtime::{Runtime, Tensor};
use crate::training::TrainReport;
use crate::transfer::TransferService;

/// A model trained somewhere in the fabric, awaiting deployment.
pub struct TrainedModel {
    pub model: String,
    pub params: Vec<Tensor>,
    pub final_loss: Option<f32>,
    /// real-execution report when real training ran
    pub report: Option<TrainReport>,
    /// virtual seconds the DCAI device spent
    pub virtual_train_s: f64,
    pub trained_on: String,
}

/// Controls whether `train_model` runs real PJRT steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TrainingMode {
    /// execute `Recipe::real_steps` (or the override) real PJRT steps
    Real { steps_override: Option<u64> },
    /// virtual-time only (Table 1 benches): params stay at init
    VirtualOnly,
}

/// The execution context threaded through flows and faas functions.
pub struct World {
    pub rt: Arc<Runtime>,
    pub registry: ModelRegistry,
    pub transfer: TransferService,
    /// taken out (`Option`) during submission so faas bodies can borrow
    /// the rest of the world mutably — see `providers::ComputeProvider`
    pub faas: Option<FaasService<World>>,
    /// facility storage: facility -> logical file -> bytes
    pub storage: BTreeMap<String, BTreeMap<String, u64>>,
    /// in-memory dataset payloads by name
    pub datasets: BTreeMap<String, Dataset>,
    /// trained models by model name
    pub trained: BTreeMap<String, TrainedModel>,
    /// accelerator model per faas endpoint id
    pub accels: BTreeMap<String, AcceleratorModel>,
    pub edge: EdgeHost,
    pub training_mode: TrainingMode,
    /// per-peak wallclock of the last real labeling run (C(A) measured)
    pub last_label_cost_s: Option<f64>,
    /// versioned checkpoint store (paper §7 future work 1): publishes
    /// every trained model, serves warm starts for fine-tuning
    pub repository: crate::models::ModelRepository,
}

impl World {
    /// The paper's fabric: SLAC (experiment + edge + local V100) and ALCF
    /// (Cerebras, SambaNova, 8-GPU server, labeling cluster).
    pub fn paper(seed: u64) -> Result<World> {
        let rt = Runtime::cpu()?;
        let registry = ModelRegistry::load(&crate::models::default_artifacts_dir())?;
        let transfer = TransferService::paper(seed);
        let slac = transfer.topo.facility("slac")?;
        let alcf = transfer.topo.facility("alcf")?;

        let mut faas = FaasService::<World>::new();
        for (id, fac) in [
            ("slac#v100", slac),
            ("slac#sim", slac),
            ("alcf#cerebras", alcf),
            ("alcf#sambanova", alcf),
            ("alcf#gpu8", alcf),
            ("alcf#cluster", alcf),
        ] {
            faas.register_endpoint(FaasEndpoint::new(id, fac))?;
        }
        super::functions::register_all(&mut faas)?;

        let mut accels = BTreeMap::new();
        accels.insert("slac#v100".to_string(), local_v100());
        accels.insert("alcf#cerebras".to_string(), cerebras_wse());
        accels.insert("alcf#sambanova".to_string(), sambanova_rdu());
        accels.insert("alcf#gpu8".to_string(), multi_gpu_horovod(8));

        let edge = EdgeHost::new("slac-edge", rt.clone());

        Ok(World {
            rt,
            registry,
            transfer,
            faas: Some(faas),
            storage: BTreeMap::new(),
            datasets: BTreeMap::new(),
            trained: BTreeMap::new(),
            accels,
            edge,
            training_mode: TrainingMode::Real {
                steps_override: None,
            },
            last_label_cost_s: None,
            repository: crate::models::ModelRepository::new(),
        })
    }

    pub fn dataset(&self, name: &str) -> Result<&Dataset> {
        self.datasets
            .get(name)
            .with_context(|| format!("unknown dataset `{name}`"))
    }

    pub fn trained(&self, model: &str) -> Result<&TrainedModel> {
        self.trained
            .get(model)
            .with_context(|| format!("model `{model}` has not been trained"))
    }

    pub fn accel(&self, endpoint: &str) -> Result<&AcceleratorModel> {
        self.accels
            .get(endpoint)
            .with_context(|| format!("no accelerator model for endpoint `{endpoint}`"))
    }

    /// Record a logical file at a facility's storage.
    pub fn put_file(&mut self, facility: &str, name: &str, bytes: u64) {
        self.storage
            .entry(facility.to_string())
            .or_default()
            .insert(name.to_string(), bytes);
    }

    pub fn file_bytes(&self, facility: &str, name: &str) -> Result<u64> {
        self.storage
            .get(facility)
            .and_then(|m| m.get(name))
            .copied()
            .with_context(|| format!("no file `{name}` at `{facility}`"))
    }

    /// Resolve the transfer payload size for a provider parameter set:
    /// explicit `bytes`, a dataset's wire size, or a model's param bytes.
    pub fn payload_bytes(&self, params: &crate::util::Json) -> Result<u64> {
        if let Some(b) = params.get("bytes").as_u64() {
            return Ok(b);
        }
        if let Some(ds) = params.get("dataset").as_str() {
            return Ok(self.dataset(ds)?.wire_bytes());
        }
        if let Some(m) = params.get("model").as_str() {
            return Ok(self.registry.get(m)?.param_bytes());
        }
        bail!("transfer params need `bytes`, `dataset`, or `model`")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    #[test]
    fn paper_world_wires_up() {
        if !artifacts_present() {
            return;
        }
        let w = World::paper(1).unwrap();
        assert!(w.faas.is_some());
        assert_eq!(w.accels.len(), 4);
        assert!(w.accel("alcf#cerebras").is_ok());
        assert!(w.accel("alcf#ghost").is_err());
        assert!(w.dataset("nope").is_err());
        assert!(w.trained("braggnn").is_err());
    }

    #[test]
    fn storage_and_payload_resolution() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(2).unwrap();
        w.put_file("slac", "scan-42.h5", 1000);
        assert_eq!(w.file_bytes("slac", "scan-42.h5").unwrap(), 1000);
        assert!(w.file_bytes("alcf", "scan-42.h5").is_err());

        let p = crate::util::Json::parse(r#"{"bytes": 77}"#).unwrap();
        assert_eq!(w.payload_bytes(&p).unwrap(), 77);
        let p = crate::util::Json::parse(r#"{"model": "braggnn"}"#).unwrap();
        assert_eq!(w.payload_bytes(&p).unwrap(), 4 * 36_922);
        let p = crate::util::Json::parse(r#"{"nothing": 1}"#).unwrap();
        assert!(w.payload_bytes(&p).is_err());
    }
}
