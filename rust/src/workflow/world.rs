//! `World`: the mutable state the flow engine's actions operate on —
//! facility storage, datasets, trained models, the transfer fabric, the
//! FaaS fabric, the PJRT runtime, accelerator models, and the edge host.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::accel::{cerebras_wse, local_v100, multi_gpu_horovod, sambanova_rdu, AcceleratorModel};
use crate::data::Dataset;
use crate::edge::EdgeHost;
use crate::faas::{FaasEndpoint, FaasService, FuncId, TaskId, TaskMeta, TaskStatus};
use crate::flows::{FabricHost, Ticket};
use crate::models::ModelRegistry;
use crate::runtime::{Runtime, Tensor};
use crate::training::TrainReport;
use crate::transfer::{TransferHandle, TransferReport, TransferRequest, TransferService};
use crate::util::Json;

/// A model trained somewhere in the fabric, awaiting deployment.
pub struct TrainedModel {
    pub model: String,
    pub params: Vec<Tensor>,
    pub final_loss: Option<f32>,
    /// real-execution report when real training ran
    pub report: Option<TrainReport>,
    /// virtual seconds the DCAI device spent
    pub virtual_train_s: f64,
    pub trained_on: String,
}

/// Controls whether `train_model` runs real PJRT steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TrainingMode {
    /// execute `Recipe::real_steps` (or the override) real PJRT steps
    Real { steps_override: Option<u64> },
    /// virtual-time only (Table 1 benches): params stay at init
    VirtualOnly,
}

/// Who is submitting fabric work right now. The campaign layer sets
/// this before driving each user's flow so every faas task carries the
/// tenant, priority class, and gang width the scheduling policy needs
/// (DESIGN.md §9, §10); single-tenant paths leave the untagged default.
#[derive(Debug, Clone, Copy)]
pub struct Tenant {
    /// 1-based campaign user index (0 = untagged)
    pub user: u32,
    /// static priority class; larger = more urgent
    pub priority: i64,
    /// gang width of this tenant's *training* jobs: `train_model`
    /// tasks occupy this many capacity slots atomically (a multi-node
    /// or multi-wafer-section allocation). All other functions stay
    /// single-slot — dataset generation and labeling model as ordinary
    /// tasks.
    pub train_slots: usize,
}

impl Default for Tenant {
    fn default() -> Self {
        Tenant {
            user: 0,
            priority: 0,
            train_slots: 1,
        }
    }
}

/// Work submitted to a shared fabric, awaiting completion. The ticket
/// registry is what lets `ActionProvider::start` return immediately
/// while the transfer/faas fabrics advance under the DES scheduler.
enum PendingOp {
    Transfer {
        handle: TransferHandle,
        /// post-completion bookkeeping: the payload materializes at the
        /// destination facility's storage
        dst_facility: String,
        dataset: Option<String>,
        model: Option<String>,
        /// submitting tenant at submit time (0 = untagged) — the egress
        /// dollar attribution key (DESIGN.md §11)
        user: u32,
    },
    Faas {
        task: TaskId,
    },
}

/// The execution context threaded through flows and faas functions.
pub struct World {
    pub rt: Arc<Runtime>,
    pub registry: ModelRegistry,
    pub transfer: TransferService,
    /// taken out (`Option`) while fabrics advance so faas bodies can
    /// borrow the rest of the world mutably — see `advance_fabrics`
    pub faas: Option<FaasService<World>>,
    /// facility storage: facility -> logical file -> bytes
    pub storage: BTreeMap<String, BTreeMap<String, u64>>,
    /// in-memory dataset payloads by name
    pub datasets: BTreeMap<String, Dataset>,
    /// trained models by model name
    pub trained: BTreeMap<String, TrainedModel>,
    /// accelerator model per faas endpoint id
    pub accels: BTreeMap<String, AcceleratorModel>,
    pub edge: EdgeHost,
    pub training_mode: TrainingMode,
    /// per-peak wallclock of the last real labeling run (C(A) measured)
    pub last_label_cost_s: Option<f64>,
    /// versioned checkpoint store (paper §7 future work 1): publishes
    /// every trained model, serves warm starts for fine-tuning
    pub repository: crate::models::ModelRepository,
    /// every transfer completed through the fabric (campaign statistics)
    pub transfer_log: Vec<TransferReport>,
    /// submitting tenant of each `transfer_log` entry, in lockstep
    /// (0 = untagged single-tenant work) — what the campaign's egress
    /// dollar accounting bills per user (DESIGN.md §11)
    pub transfer_log_users: Vec<u32>,
    /// submitting tenant for fabric work (campaign layer sets per user)
    pub tenant: Tenant,
    /// fabric work awaiting completion, by ticket id
    pending: BTreeMap<u64, PendingOp>,
    /// resolved tickets: (finish virtual time, outcome)
    ready: BTreeMap<u64, (f64, Result<Json>)>,
    next_ticket: u64,
}

impl World {
    /// The paper's fabric: SLAC (experiment + edge + local V100) and ALCF
    /// (Cerebras, SambaNova, 8-GPU server, labeling cluster).
    pub fn paper(seed: u64) -> Result<World> {
        let rt = Runtime::cpu()?;
        let registry = ModelRegistry::load(&crate::models::default_artifacts_dir())?;
        let transfer = TransferService::paper(seed);
        let slac = transfer.topo.facility("slac")?;
        let alcf = transfer.topo.facility("alcf")?;

        let mut faas = FaasService::<World>::new();
        // DCAI training systems serve one job at a time (capacity 1 —
        // the contended resources of the campaign study); the simulation
        // host and the 1024-core labeling cluster admit several.
        for (id, fac, capacity) in [
            ("slac#v100", slac, 1),
            ("slac#sim", slac, 4),
            ("alcf#cerebras", alcf, 1),
            ("alcf#sambanova", alcf, 1),
            ("alcf#gpu8", alcf, 1),
            ("alcf#cluster", alcf, 8),
        ] {
            faas.register_endpoint(FaasEndpoint::new(id, fac).with_capacity(capacity))?;
        }
        super::functions::register_all(&mut faas)?;

        let mut accels = BTreeMap::new();
        accels.insert("slac#v100".to_string(), local_v100());
        accels.insert("alcf#cerebras".to_string(), cerebras_wse());
        accels.insert("alcf#sambanova".to_string(), sambanova_rdu());
        accels.insert("alcf#gpu8".to_string(), multi_gpu_horovod(8));

        let edge = EdgeHost::new("slac-edge", rt.clone());

        Ok(World {
            rt,
            registry,
            transfer,
            faas: Some(faas),
            storage: BTreeMap::new(),
            datasets: BTreeMap::new(),
            trained: BTreeMap::new(),
            accels,
            edge,
            training_mode: TrainingMode::Real {
                steps_override: None,
            },
            last_label_cost_s: None,
            repository: crate::models::ModelRepository::new(),
            transfer_log: Vec::new(),
            transfer_log_users: Vec::new(),
            tenant: Tenant::default(),
            pending: BTreeMap::new(),
            ready: BTreeMap::new(),
            next_ticket: 1,
        })
    }

    fn alloc_ticket(&mut self) -> Ticket {
        let t = Ticket(self.next_ticket);
        self.next_ticket += 1;
        t
    }

    /// Submit a WAN transfer to the shared fabric; the returned ticket
    /// resolves (via `advance_fabrics`/`take_ready`) when the task is
    /// delivered, at which point the payload appears at `dst_facility`.
    pub fn submit_transfer_ticket(
        &mut self,
        now: f64,
        req: &TransferRequest,
        dst_facility: String,
        dataset: Option<String>,
        model: Option<String>,
    ) -> Result<Ticket> {
        let handle = self.transfer.submit_task(now, req)?;
        let ticket = self.alloc_ticket();
        let user = self.tenant.user;
        self.pending.insert(
            ticket.0,
            PendingOp::Transfer {
                handle,
                dst_facility,
                dataset,
                model,
                user,
            },
        );
        Ok(ticket)
    }

    /// Queue a faas task on an endpoint; the ticket resolves when the
    /// task completes (queue wait included). Offline endpoints resolve
    /// immediately with the recorded failure. The task carries the
    /// current [`Tenant`] plus a cost-model duration estimate so
    /// SJF/backfill policies can order it (DESIGN.md §9).
    pub fn submit_compute_ticket(
        &mut self,
        now: f64,
        endpoint: &str,
        func: &FuncId,
        args: &Json,
    ) -> Result<Ticket> {
        let meta = TaskMeta {
            user: self.tenant.user,
            priority: self.tenant.priority,
            est_duration_s: self.estimate_task_secs(endpoint, func, args),
            // only training jobs gang up (multi-node allocations);
            // generation/labeling/evaluation stay single-slot
            slots: if func.0 == "train_model" {
                self.tenant.train_slots.max(1)
            } else {
                1
            },
        };
        let faas = self
            .faas
            .as_mut()
            .context("faas service missing (reentrant compute?)")?;
        let task = faas.enqueue_with_meta(now, endpoint, func, args, meta)?;
        let status = faas.record(task)?.status.clone();
        let ticket = self.alloc_ticket();
        match status {
            // offline endpoint: failed at enqueue, no fabric event coming
            TaskStatus::Failed(m) => {
                self.ready
                    .insert(ticket.0, (now, Err(anyhow::anyhow!("task {task:?} failed: {m}"))));
            }
            _ => {
                self.pending.insert(ticket.0, PendingOp::Faas { task });
            }
        }
        Ok(ticket)
    }

    pub fn dataset(&self, name: &str) -> Result<&Dataset> {
        self.datasets
            .get(name)
            .with_context(|| format!("unknown dataset `{name}`"))
    }

    pub fn trained(&self, model: &str) -> Result<&TrainedModel> {
        self.trained
            .get(model)
            .with_context(|| format!("model `{model}` has not been trained"))
    }

    pub fn accel(&self, endpoint: &str) -> Result<&AcceleratorModel> {
        self.accels
            .get(endpoint)
            .with_context(|| format!("no accelerator model for endpoint `{endpoint}`"))
    }

    /// Record a logical file at a facility's storage.
    pub fn put_file(&mut self, facility: &str, name: &str, bytes: u64) {
        self.storage
            .entry(facility.to_string())
            .or_default()
            .insert(name.to_string(), bytes);
    }

    pub fn file_bytes(&self, facility: &str, name: &str) -> Result<u64> {
        self.storage
            .get(facility)
            .and_then(|m| m.get(name))
            .copied()
            .with_context(|| format!("no file `{name}` at `{facility}`"))
    }

    /// Predict a faas body's virtual duration from the same cost models
    /// the bodies charge: accelerator models for training, the paper's
    /// cluster labeling rate for **A**, the detector/simulation rates
    /// for **S**. Exact for every registered function (the bodies
    /// advance their scratch clocks by precisely these amounts), which
    /// is what lets `EasyBackfill` promise it never delays the head of
    /// line. `None` for unknown functions — SJF runs those last and
    /// backfill will not gamble on them.
    pub fn estimate_task_secs(&self, endpoint: &str, func: &FuncId, args: &Json) -> Option<f64> {
        match func.0.as_str() {
            "generate_data" => {
                let model = args.get("model").as_str()?;
                let n = args.get("n").as_usize()? as f64;
                Some(n / super::functions::generation_rate(model))
            }
            "label_data" => {
                let ds = args.get("dataset").as_str()?;
                let n = self.datasets.get(ds)?.n as f64;
                Some(n * super::functions::CLUSTER_LABEL_S_PER_SAMPLE)
            }
            "train_model" => {
                let model = args.get("model").as_str()?;
                let meta = self.registry.get(model).ok()?;
                let accel = self.accels.get(endpoint)?;
                let recipe = crate::training::Recipe::standard(model).ok()?;
                // mirror the body exactly: the step budget shrinks only
                // when a warm start is requested AND a foundation
                // checkpoint exists right now. (A checkpoint published
                // between enqueue and start makes the estimate
                // conservative — backfill stays safe, it never promises
                // a job is *shorter* than it runs.)
                let tag = crate::models::ExperimentTag {
                    sample: args.get("sample").as_str().unwrap_or("default").to_string(),
                    setting: args.get("setting").as_f64().unwrap_or(0.0),
                };
                let warm = args.get("warm_start").as_bool().unwrap_or(false)
                    && self.repository.select_foundation(model, &tag).is_some();
                let steps = if warm {
                    ((recipe.full_steps as f64 * super::functions::FINETUNE_STEP_FRACTION)
                        as u64)
                        .max(1)
                } else {
                    recipe.full_steps
                };
                Some(
                    accel
                        .train_time(meta.train_flops_per_step, meta.param_bytes() as f64, steps)
                        .total_s,
                )
            }
            "evaluate_model" => Some(0.5),
            _ => None,
        }
    }

    /// Apply a `FaultPlan` window edge to the fabrics (campaign layer;
    /// DESIGN.md §9).
    pub fn begin_endpoint_outage(&mut self, endpoint: &str, now: f64) -> Result<()> {
        self.faas
            .as_mut()
            .context("faas service missing")?
            .begin_outage(endpoint, now)
    }

    pub fn end_endpoint_outage(&mut self, endpoint: &str, now: f64) -> Result<()> {
        self.faas
            .as_mut()
            .context("faas service missing")?
            .end_outage(endpoint, now)
    }

    /// Resolve the transfer payload size for a provider parameter set:
    /// explicit `bytes`, a dataset's wire size, or a model's param bytes.
    pub fn payload_bytes(&self, params: &crate::util::Json) -> Result<u64> {
        if let Some(b) = params.get("bytes").as_u64() {
            return Ok(b);
        }
        if let Some(ds) = params.get("dataset").as_str() {
            return Ok(self.dataset(ds)?.wire_bytes());
        }
        if let Some(m) = params.get("model").as_str() {
            return Ok(self.registry.get(m)?.param_bytes());
        }
        bail!("transfer params need `bytes`, `dataset`, or `model`")
    }
}

impl FabricHost for World {
    fn next_fabric_event(&mut self) -> Option<f64> {
        let t1 = self.transfer.next_event_time();
        let t2 = self.faas.as_ref().and_then(|f| f.next_event_time());
        match (t1, t2) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    fn advance_fabrics(&mut self, t: f64) {
        // WAN transfers: deliveries resolve tickets and materialize the
        // payload at the destination facility
        for (handle, res) in self.transfer.advance_to(t) {
            let ticket = self.pending.iter().find_map(|(id, op)| match op {
                PendingOp::Transfer { handle: h, .. } if *h == handle => Some(*id),
                _ => None,
            });
            let Some(tid) = ticket else { continue };
            let Some(PendingOp::Transfer {
                dst_facility,
                dataset,
                model,
                user,
                ..
            }) = self.pending.remove(&tid)
            else {
                continue;
            };
            let resolved = match res {
                Ok(rep) => {
                    if let Some(ds) = &dataset {
                        self.put_file(&dst_facility, ds, rep.bytes);
                    }
                    if let Some(m) = &model {
                        self.put_file(&dst_facility, &format!("{m}.weights"), rep.bytes);
                    }
                    let out = Json::obj(vec![
                        ("bytes", Json::num(rep.bytes as f64)),
                        ("seconds", Json::num(rep.duration())),
                        ("data_seconds", Json::num(rep.data_secs())),
                        ("throughput_bps", Json::num(rep.throughput_bps())),
                        ("concurrency", Json::num(rep.concurrency as f64)),
                        ("attempts", Json::num(rep.total_attempts() as f64)),
                    ]);
                    let finish = rep.finish_vt;
                    self.transfer_log.push(rep);
                    self.transfer_log_users.push(user);
                    (finish, Ok(out))
                }
                Err(e) => (t, Err(e)),
            };
            self.ready.insert(tid, resolved);
        }

        // faas: queue starts run function bodies against this world, so
        // the service is taken out for the duration (same Option dance
        // the providers used pre-DES)
        if let Some(mut faas) = self.faas.take() {
            let completed = faas.advance_to(self, t);
            for task in completed {
                let ticket = self.pending.iter().find_map(|(id, op)| match op {
                    PendingOp::Faas { task: tk } if *tk == task => Some(*id),
                    _ => None,
                });
                let Some(tid) = ticket else { continue };
                self.pending.remove(&tid);
                let rec = faas.record(task).expect("completed task recorded");
                let resolved = match &rec.status {
                    TaskStatus::Success(v) => (
                        rec.finished_vt,
                        Ok(Json::obj(vec![
                            ("endpoint", Json::str(rec.endpoint.clone())),
                            ("exec_seconds", Json::num(rec.exec_secs())),
                            ("dispatch_seconds", Json::num(rec.overhead_secs())),
                            ("queue_wait_seconds", Json::num(rec.queue_wait_secs())),
                            ("output", v.clone()),
                        ])),
                    ),
                    TaskStatus::Failed(m) => (
                        rec.finished_vt,
                        Err(anyhow::anyhow!("task {task:?} failed: {m}")),
                    ),
                    _ => (
                        t,
                        Err(anyhow::anyhow!(
                            "task {task:?} incomplete after completion event"
                        )),
                    ),
                };
                self.ready.insert(tid, resolved);
            }
            self.faas = Some(faas);
        }
    }

    fn take_ready(&mut self, ticket: Ticket) -> Option<(f64, Result<Json>)> {
        self.ready.remove(&ticket.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    #[test]
    fn paper_world_wires_up() {
        if !artifacts_present() {
            return;
        }
        let w = World::paper(1).unwrap();
        assert!(w.faas.is_some());
        assert_eq!(w.accels.len(), 4);
        assert!(w.accel("alcf#cerebras").is_ok());
        assert!(w.accel("alcf#ghost").is_err());
        assert!(w.dataset("nope").is_err());
        assert!(w.trained("braggnn").is_err());
    }

    /// The scheduler's duration estimates come from the same cost
    /// models the bodies charge, so for registered functions they are
    /// *exact* — the property `EasyBackfill`'s no-delay guarantee
    /// rests on.
    #[test]
    fn duration_estimates_are_exact_for_known_functions() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(8).unwrap();
        w.training_mode = TrainingMode::VirtualOnly;
        let gen = FuncId("generate_data".into());
        let args = crate::util::Json::parse(
            r#"{"model": "braggnn", "n": 64, "seed": 5, "name": "est-d"}"#,
        )
        .unwrap();
        let est = w.estimate_task_secs("slac#sim", &gen, &args).unwrap();
        let ticket = w.submit_compute_ticket(0.0, "slac#sim", &gen, &args).unwrap();
        loop {
            if w.take_ready(ticket).is_some() {
                break;
            }
            let t = w.next_fabric_event().expect("generation pending");
            w.advance_fabrics(t);
        }
        let faas = w.faas.as_ref().unwrap();
        let rec = faas.records().last().unwrap();
        assert_eq!(rec.exec_secs(), est, "estimate not exact");
        assert_eq!(rec.meta.est_duration_s, Some(est));

        let train = FuncId("train_model".into());
        let targs = crate::util::Json::parse(
            r#"{"model": "braggnn", "dataset": "est-d", "endpoint": "alcf#cerebras"}"#,
        )
        .unwrap();
        let est = w.estimate_task_secs("alcf#cerebras", &train, &targs).unwrap();
        // Cerebras BraggNN: ~18 s modeled (Table 1: 19 s)
        assert!((15.0..22.0).contains(&est), "{est}");
        // unknown functions carry no estimate
        assert!(w
            .estimate_task_secs("slac#sim", &FuncId("ghost".into()), &crate::util::Json::Null)
            .is_none());
    }

    #[test]
    fn storage_and_payload_resolution() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(2).unwrap();
        w.put_file("slac", "scan-42.h5", 1000);
        assert_eq!(w.file_bytes("slac", "scan-42.h5").unwrap(), 1000);
        assert!(w.file_bytes("alcf", "scan-42.h5").is_err());

        let p = crate::util::Json::parse(r#"{"bytes": 77}"#).unwrap();
        assert_eq!(w.payload_bytes(&p).unwrap(), 77);
        let p = crate::util::Json::parse(r#"{"model": "braggnn"}"#).unwrap();
        assert_eq!(w.payload_bytes(&p).unwrap(), 4 * 36_922);
        let p = crate::util::Json::parse(r#"{"nothing": 1}"#).unwrap();
        assert!(w.payload_bytes(&p).is_err());
    }
}
