//! `World`: the mutable state the flow engine's actions operate on —
//! facility storage, datasets, trained models, the transfer fabric, the
//! FaaS fabric, the PJRT runtime, accelerator models, and the edge host.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::accel::{cerebras_wse, local_v100, multi_gpu_horovod, sambanova_rdu, AcceleratorModel};
use crate::data::Dataset;
use crate::edge::EdgeHost;
use crate::faas::{FaasEndpoint, FaasService, FuncId, TaskId, TaskMeta, TaskStatus};
use crate::flows::{FabricHost, Ticket};
use crate::models::ModelRegistry;
use crate::runtime::{Runtime, Tensor};
use crate::training::TrainReport;
use crate::transfer::{
    EndpointId, TransferHandle, TransferReport, TransferRequest, TransferService,
};
use crate::util::Json;

/// A model trained somewhere in the fabric, awaiting deployment.
pub struct TrainedModel {
    pub model: String,
    pub params: Vec<Tensor>,
    pub final_loss: Option<f32>,
    /// real-execution report when real training ran
    pub report: Option<TrainReport>,
    /// virtual seconds the DCAI device spent
    pub virtual_train_s: f64,
    pub trained_on: String,
}

/// Controls whether `train_model` runs real PJRT steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TrainingMode {
    /// execute `Recipe::real_steps` (or the override) real PJRT steps
    Real { steps_override: Option<u64> },
    /// virtual-time only (Table 1 benches): params stay at init
    VirtualOnly,
}

/// Who is submitting fabric work right now. The campaign layer sets
/// this before driving each user's flow so every faas task carries the
/// tenant, priority class, and gang width the scheduling policy needs
/// (DESIGN.md §9, §10); single-tenant paths leave the untagged default.
#[derive(Debug, Clone, Copy)]
pub struct Tenant {
    /// 1-based campaign user index (0 = untagged)
    pub user: u32,
    /// static priority class; larger = more urgent
    pub priority: i64,
    /// gang width of this tenant's *training* jobs: `train_model`
    /// tasks occupy this many capacity slots atomically (a multi-node
    /// or multi-wafer-section allocation). All other functions stay
    /// single-slot — dataset generation and labeling model as ordinary
    /// tasks.
    pub train_slots: usize,
}

impl Default for Tenant {
    fn default() -> Self {
        Tenant {
            user: 0,
            priority: 0,
            train_slots: 1,
        }
    }
}

/// Cumulative spot preemption / failover-migration bookkeeping
/// (DESIGN.md §12). The campaign layer reads this into its report.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpotLedger {
    /// reclaim events that found at least one gang running
    pub preemptions: u32,
    /// gangs displaced mid-run across all reclaims
    pub displaced: u32,
    /// failover migrations whose checkpoint crossed the WAN
    pub wan_migrations: u32,
    /// failover migrations within the source facility (no WAN hop)
    pub local_migrations: u32,
    /// checkpoint bytes shipped over the WAN for migrations
    pub migration_bytes: u64,
    /// body seconds preserved in checkpoints across all preemptions
    pub checkpointed_s: f64,
    /// body seconds executed past the last checkpoint boundary and lost
    pub lost_s: f64,
    /// displaced gangs with no live failover candidate: the failure was
    /// delivered to the flow layer's retry machinery instead
    pub stranded: u32,
}

/// Work submitted to a shared fabric, awaiting completion. The ticket
/// registry is what lets `ActionProvider::start` return immediately
/// while the transfer/faas fabrics advance under the DES scheduler.
enum PendingOp {
    Transfer {
        handle: TransferHandle,
        /// post-completion bookkeeping: the payload materializes at the
        /// destination facility's storage
        dst_facility: String,
        dataset: Option<String>,
        model: Option<String>,
        /// submitting tenant at submit time (0 = untagged) — the egress
        /// dollar attribution key (DESIGN.md §11)
        user: u32,
    },
    Faas {
        task: TaskId,
    },
    /// A spot-preempted gang's checkpoint in flight to its failover
    /// endpoint (DESIGN.md §12). When the transfer delivers, the resume
    /// task is enqueued on `endpoint` and the ticket is rewired to it;
    /// the egress is billed to the preempted tenant.
    Migration {
        handle: TransferHandle,
        /// failover endpoint the planner chose
        endpoint: String,
        /// `resume_train` args ({remaining_s, output})
        args: Json,
        /// scheduler metadata for the resumed gang (same tenant /
        /// priority / width; estimate = remaining work)
        meta: TaskMeta,
        /// preempted tenant (egress attribution)
        user: u32,
    },
}

/// The execution context threaded through flows and faas functions.
pub struct World {
    pub rt: Arc<Runtime>,
    pub registry: ModelRegistry,
    pub transfer: TransferService,
    /// taken out (`Option`) while fabrics advance so faas bodies can
    /// borrow the rest of the world mutably — see `advance_fabrics`
    pub faas: Option<FaasService<World>>,
    /// facility storage: facility -> logical file -> bytes
    pub storage: BTreeMap<String, BTreeMap<String, u64>>,
    /// in-memory dataset payloads by name
    pub datasets: BTreeMap<String, Dataset>,
    /// trained models by model name
    pub trained: BTreeMap<String, TrainedModel>,
    /// accelerator model per faas endpoint id
    pub accels: BTreeMap<String, AcceleratorModel>,
    pub edge: EdgeHost,
    pub training_mode: TrainingMode,
    /// per-peak wallclock of the last real labeling run (C(A) measured)
    pub last_label_cost_s: Option<f64>,
    /// versioned checkpoint store (paper §7 future work 1): publishes
    /// every trained model, serves warm starts for fine-tuning
    pub repository: crate::models::ModelRepository,
    /// every transfer completed through the fabric (campaign statistics)
    pub transfer_log: Vec<TransferReport>,
    /// submitting tenant of each `transfer_log` entry, in lockstep
    /// (0 = untagged single-tenant work) — what the campaign's egress
    /// dollar accounting bills per user (DESIGN.md §11)
    pub transfer_log_users: Vec<u32>,
    /// submitting tenant for fabric work (campaign layer sets per user)
    pub tenant: Tenant,
    /// checkpoint cadence attached to `train_model` tasks (body
    /// seconds between resumable checkpoints). `None` = training is
    /// not checkpointable: a spot preemption loses all progress.
    pub checkpoint_every_s: Option<f64>,
    /// cumulative spot preemption / migration bookkeeping
    pub spot: SpotLedger,
    /// provenance stamped on every fabric submission (DESIGN.md §16):
    /// the campaign layer sets `Drift` when retraining flows are
    /// admitted by the closed-loop trigger instead of the arrival plan,
    /// so cost accounting can attribute drift-caused slot-seconds.
    pub task_origin: crate::faas::TaskOrigin,
    /// fabric work awaiting completion, by ticket id
    pending: BTreeMap<u64, PendingOp>,
    /// resolved tickets: (finish virtual time, outcome)
    ready: BTreeMap<u64, (f64, Result<Json>)>,
    next_ticket: u64,
}

impl World {
    /// The paper's fabric: SLAC (experiment + edge + local V100) and ALCF
    /// (Cerebras, SambaNova, 8-GPU server, labeling cluster).
    pub fn paper(seed: u64) -> Result<World> {
        let rt = Runtime::cpu()?;
        let registry = ModelRegistry::load(&crate::models::default_artifacts_dir())?;
        let transfer = TransferService::paper(seed);
        let slac = transfer.topo.facility("slac")?;
        let alcf = transfer.topo.facility("alcf")?;

        let mut faas = FaasService::<World>::new();
        // DCAI training systems serve one job at a time (capacity 1 —
        // the contended resources of the campaign study); the simulation
        // host and the 1024-core labeling cluster admit several.
        for (id, fac, capacity) in [
            ("slac#v100", slac, 1),
            ("slac#sim", slac, 4),
            ("alcf#cerebras", alcf, 1),
            ("alcf#sambanova", alcf, 1),
            ("alcf#gpu8", alcf, 1),
            ("alcf#cluster", alcf, 8),
        ] {
            faas.register_endpoint(FaasEndpoint::new(id, fac).with_capacity(capacity))?;
        }
        super::functions::register_all(&mut faas)?;

        let mut accels = BTreeMap::new();
        accels.insert("slac#v100".to_string(), local_v100());
        accels.insert("alcf#cerebras".to_string(), cerebras_wse());
        accels.insert("alcf#sambanova".to_string(), sambanova_rdu());
        accels.insert("alcf#gpu8".to_string(), multi_gpu_horovod(8));

        let edge = EdgeHost::new("slac-edge", rt.clone());

        Ok(World {
            rt,
            registry,
            transfer,
            faas: Some(faas),
            storage: BTreeMap::new(),
            datasets: BTreeMap::new(),
            trained: BTreeMap::new(),
            accels,
            edge,
            training_mode: TrainingMode::Real {
                steps_override: None,
            },
            last_label_cost_s: None,
            repository: crate::models::ModelRepository::new(),
            transfer_log: Vec::new(),
            transfer_log_users: Vec::new(),
            tenant: Tenant::default(),
            checkpoint_every_s: None,
            spot: SpotLedger::default(),
            task_origin: crate::faas::TaskOrigin::default(),
            pending: BTreeMap::new(),
            ready: BTreeMap::new(),
            next_ticket: 1,
        })
    }

    /// Register a federated site on this world (DESIGN.md §15): wire
    /// its access link into the transfer topology, register its
    /// `{name}#dtn` staging endpoint (ALCF-class DTN disks), and add
    /// one faas endpoint + accelerator model per hosted class. Sites
    /// never touch the paper endpoints, so a world with no sites added
    /// is exactly `World::paper`.
    pub fn add_site(&mut self, site: &super::federation::Site) -> Result<()> {
        site.extend_topology(&mut self.transfer.topo)?;
        let fac = self.transfer.topo.facility(&site.name)?;
        self.transfer.endpoints.register(crate::transfer::Endpoint {
            id: EndpointId::from(site.dtn().as_str()),
            facility: fac,
            read_bps: 1.45e9,
            write_bps: 1.25e9,
        })?;
        let faas = self.faas.as_mut().context("faas service missing")?;
        for class in &site.classes {
            let id = site.endpoint(class);
            let accel = match class.as_str() {
                "cerebras" => cerebras_wse(),
                "sambanova" => sambanova_rdu(),
                "gpu8" => multi_gpu_horovod(8),
                "v100" => local_v100(),
                other => bail!("class `{other}` is not placeable at a federated site"),
            };
            faas.register_endpoint(FaasEndpoint::new(id.as_str(), fac).with_capacity(1))?;
            self.accels.insert(id, accel);
        }
        Ok(())
    }

    fn alloc_ticket(&mut self) -> Ticket {
        let t = Ticket(self.next_ticket);
        self.next_ticket += 1;
        t
    }

    /// Submit a WAN transfer to the shared fabric; the returned ticket
    /// resolves (via `advance_fabrics`/`take_ready`) when the task is
    /// delivered, at which point the payload appears at `dst_facility`.
    pub fn submit_transfer_ticket(
        &mut self,
        now: f64,
        req: &TransferRequest,
        dst_facility: String,
        dataset: Option<String>,
        model: Option<String>,
    ) -> Result<Ticket> {
        let handle = self.transfer.submit_task(now, req)?;
        let ticket = self.alloc_ticket();
        let user = self.tenant.user;
        self.pending.insert(
            ticket.0,
            PendingOp::Transfer {
                handle,
                dst_facility,
                dataset,
                model,
                user,
            },
        );
        Ok(ticket)
    }

    /// Queue a faas task on an endpoint; the ticket resolves when the
    /// task completes (queue wait included). Offline endpoints resolve
    /// immediately with the recorded failure. The task carries the
    /// current [`Tenant`] plus a cost-model duration estimate so
    /// SJF/backfill policies can order it (DESIGN.md §9).
    pub fn submit_compute_ticket(
        &mut self,
        now: f64,
        endpoint: &str,
        func: &FuncId,
        args: &Json,
    ) -> Result<Ticket> {
        let meta = TaskMeta {
            user: self.tenant.user,
            priority: self.tenant.priority,
            est_duration_s: self.estimate_task_secs(endpoint, func, args),
            // only training jobs gang up (multi-node allocations);
            // generation/labeling/evaluation stay single-slot
            slots: if func.0 == "train_model" {
                self.tenant.train_slots.max(1)
            } else {
                1
            },
            // only training persists resumable checkpoints; everything
            // else restarts from scratch on preemption
            checkpoint_every_s: if func.0 == "train_model" {
                self.checkpoint_every_s
            } else {
                None
            },
            origin: self.task_origin,
        };
        let faas = self
            .faas
            .as_mut()
            .context("faas service missing (reentrant compute?)")?;
        let task = faas.enqueue_with_meta(now, endpoint, func, args, meta)?;
        let status = faas.record(task)?.status.clone();
        let ticket = self.alloc_ticket();
        match status {
            // offline endpoint: failed at enqueue, no fabric event coming
            TaskStatus::Failed(m) => {
                self.ready
                    .insert(ticket.0, (now, Err(anyhow::anyhow!("task {task:?} failed: {m}"))));
            }
            _ => {
                self.pending.insert(ticket.0, PendingOp::Faas { task });
            }
        }
        Ok(ticket)
    }

    pub fn dataset(&self, name: &str) -> Result<&Dataset> {
        self.datasets
            .get(name)
            .with_context(|| format!("unknown dataset `{name}`"))
    }

    pub fn trained(&self, model: &str) -> Result<&TrainedModel> {
        self.trained
            .get(model)
            .with_context(|| format!("model `{model}` has not been trained"))
    }

    pub fn accel(&self, endpoint: &str) -> Result<&AcceleratorModel> {
        self.accels
            .get(endpoint)
            .with_context(|| format!("no accelerator model for endpoint `{endpoint}`"))
    }

    /// Record a logical file at a facility's storage.
    pub fn put_file(&mut self, facility: &str, name: &str, bytes: u64) {
        self.storage
            .entry(facility.to_string())
            .or_default()
            .insert(name.to_string(), bytes);
    }

    pub fn file_bytes(&self, facility: &str, name: &str) -> Result<u64> {
        self.storage
            .get(facility)
            .and_then(|m| m.get(name))
            .copied()
            .with_context(|| format!("no file `{name}` at `{facility}`"))
    }

    /// Predict a faas body's virtual duration from the same cost models
    /// the bodies charge: accelerator models for training, the paper's
    /// cluster labeling rate for **A**, the detector/simulation rates
    /// for **S**. Exact for every registered function (the bodies
    /// advance their scratch clocks by precisely these amounts), which
    /// is what lets `EasyBackfill` promise it never delays the head of
    /// line. `None` for unknown functions — SJF runs those last and
    /// backfill will not gamble on them.
    pub fn estimate_task_secs(&self, endpoint: &str, func: &FuncId, args: &Json) -> Option<f64> {
        match func.0.as_str() {
            "generate_data" => {
                let model = args.get("model").as_str()?;
                let n = args.get("n").as_usize()? as f64;
                Some(n / super::functions::generation_rate(model))
            }
            "label_data" => {
                let ds = args.get("dataset").as_str()?;
                let n = self.datasets.get(ds)?.n as f64;
                Some(n * super::functions::CLUSTER_LABEL_S_PER_SAMPLE)
            }
            "train_model" => {
                let model = args.get("model").as_str()?;
                let meta = self.registry.get(model).ok()?;
                let accel = self.accels.get(endpoint)?;
                let recipe = crate::training::Recipe::standard(model).ok()?;
                // mirror the body exactly: the step budget shrinks only
                // when a warm start is requested AND a foundation
                // checkpoint exists right now. (A checkpoint published
                // between enqueue and start makes the estimate
                // conservative — backfill stays safe, it never promises
                // a job is *shorter* than it runs.)
                let tag = crate::models::ExperimentTag {
                    sample: args.get("sample").as_str().unwrap_or("default").to_string(),
                    setting: args.get("setting").as_f64().unwrap_or(0.0),
                };
                let warm = args.get("warm_start").as_bool().unwrap_or(false)
                    && self.repository.select_foundation(model, &tag).is_some();
                let steps = if warm {
                    ((recipe.full_steps as f64 * super::functions::FINETUNE_STEP_FRACTION)
                        as u64)
                        .max(1)
                } else {
                    recipe.full_steps
                };
                Some(
                    accel
                        .train_time(meta.train_flops_per_step, meta.param_bytes() as f64, steps)
                        .total_s,
                )
            }
            "evaluate_model" => Some(0.5),
            // a resumed training run replays exactly its remaining body
            // seconds — the estimate the failover queue orders it by
            "resume_train" => args.get("remaining_s").as_f64(),
            _ => None,
        }
    }

    /// Apply a `FaultPlan` window edge to the fabrics (campaign layer;
    /// DESIGN.md §9).
    pub fn begin_endpoint_outage(&mut self, endpoint: &str, now: f64) -> Result<()> {
        self.faas
            .as_mut()
            .context("faas service missing")?
            .begin_outage(endpoint, now)
    }

    pub fn end_endpoint_outage(&mut self, endpoint: &str, now: f64) -> Result<()> {
        self.faas
            .as_mut()
            .context("faas service missing")?
            .end_outage(endpoint, now)
    }

    /// A spot preemption was announced on `endpoint` at `now`: the
    /// grace window opens — no new starts, running gangs keep draining
    /// toward their checkpoint boundaries (DESIGN.md §12).
    pub fn spot_warn_endpoint(&mut self, endpoint: &str, now: f64) -> Result<()> {
        self.faas
            .as_mut()
            .context("faas service missing")?
            .spot_warn(endpoint, now)
    }

    /// The facility of a fabric endpoint id (`alcf#cerebras` → `alcf`).
    fn facility_of(endpoint: &str) -> &str {
        endpoint.split_once('#').map(|(f, _)| f).unwrap_or(endpoint)
    }

    /// The grace window on `endpoint` expired at `now`: reclaim the
    /// spot slots and run the failover migration planner over the
    /// displaced gangs (DESIGN.md §12).
    ///
    /// Candidates are training-capable endpoints (those carrying an
    /// accelerator model) currently accepting starts. The cost of
    /// moving a gang to a candidate is the predicted WAN time for its
    /// checkpoint bytes through the *shared* transfer fabric (zero
    /// within the source facility) plus the candidate's predicted
    /// queue wait; gangs are placed by minimum-cost one-to-one
    /// assignment (the Kuhn–Munkres optimum — with a handful of
    /// candidates an exact bitmask DP over candidate subsets is
    /// trivial), one-to-one so a burst of displaced gangs cannot
    /// dogpile the single cheapest endpoint; waves handle more gangs
    /// than candidates. A cross-facility move ships the checkpoint as
    /// a real transfer task — it contends with campaign transfers and
    /// its egress is billed to the preempted tenant on delivery. A
    /// gang with no live candidate is stranded: its failure is
    /// delivered so the flow layer's retry machinery resubmits it
    /// (the resubmission queues on the Down endpoint and runs at
    /// restore).
    pub fn preempt_spot_endpoint(&mut self, endpoint: &str, now: f64) -> Result<()> {
        // Accumulate onto a copy of the live spot ledger and write it
        // back, so the f64 running sums add in exactly the order the
        // pre-federation single-endpoint planner used (bit-identical
        // spot reports).
        let eps = [endpoint.to_string()];
        let mut ledger = self.spot;
        let res = self.fail_over_endpoints(&eps, now, &mut ledger);
        self.spot = ledger;
        res.map(|_| ())
    }

    /// The generalized failover core: reclaim every endpoint in
    /// `endpoints` (a single spot reclaim, or a whole site going dark —
    /// DESIGN.md §15) and replan all displaced gangs in one assignment
    /// wave. Bookkeeping lands on `ledger` — the spot path passes the
    /// live `self.spot` (by copy, written back), the site-outage path a
    /// fresh ledger so reroutes are reported separately. Returns the
    /// number of gangs displaced.
    pub fn fail_over_endpoints(
        &mut self,
        endpoints: &[String],
        now: f64,
        ledger: &mut SpotLedger,
    ) -> Result<usize> {
        let mut faas = self.faas.take().context("faas service missing")?;
        // (source endpoint, displaced gang) pairs, in reclaim order
        let mut displaced: Vec<(String, crate::faas::Displaced)> = Vec::new();
        for endpoint in endpoints {
            let batch = match faas.reclaim_spot(endpoint, now) {
                Ok(d) => d,
                Err(e) => {
                    self.faas = Some(faas);
                    return Err(e);
                }
            };
            if !batch.is_empty() {
                ledger.preemptions += 1;
            }
            displaced.extend(batch.into_iter().map(|d| (endpoint.clone(), d)));
        }
        if displaced.is_empty() {
            self.faas = Some(faas);
            return Ok(0);
        }

        let candidates: Vec<String> = faas
            .endpoints()
            .filter(|ep| {
                !endpoints.contains(&ep.id)
                    && ep.status == crate::faas::EndpointStatus::Online
                    && self.accels.contains_key(&ep.id)
            })
            .map(|ep| ep.id.clone())
            .collect();
        let src_facs: Vec<String> = displaced
            .iter()
            .map(|(src, _)| Self::facility_of(src).to_string())
            .collect();

        // checkpoint artifact size per gang: the published model's
        // parameter bytes (`models::repository::Checkpoint` stores the
        // params the original start already published)
        let ckpt_bytes: Vec<u64> = displaced
            .iter()
            .map(|(_, d)| {
                d.output
                    .get("model")
                    .as_str()
                    .and_then(|m| self.registry.get(m).ok())
                    .map(|meta| meta.param_bytes())
                    .unwrap_or(0)
            })
            .collect();

        // cost matrix: WAN ship time + predicted queue wait (infinite =
        // infeasible: gang can never fit, or no WAN path)
        let mut costs = vec![vec![f64::INFINITY; candidates.len()]; displaced.len()];
        for (gi, (_, d)) in displaced.iter().enumerate() {
            let src_fac = &src_facs[gi];
            for (ci, cand) in candidates.iter().enumerate() {
                let wait = faas.predicted_gang_wait(cand, d.meta.width(), now);
                if !wait.is_finite() {
                    continue;
                }
                let cand_fac = Self::facility_of(cand);
                let wan = if cand_fac == src_fac.as_str() {
                    0.0
                } else {
                    let req = TransferRequest::split_even(
                        "spot-migrate",
                        EndpointId::from(format!("{src_fac}#dtn").as_str()),
                        EndpointId::from(format!("{cand_fac}#dtn").as_str()),
                        ckpt_bytes[gi].max(1),
                        1,
                    );
                    match self.transfer.predict_linear(&req) {
                        Ok(s) => s,
                        Err(_) => continue,
                    }
                };
                costs[gi][ci] = wan + wait;
            }
        }

        // exact minimum-cost assignment per wave via bitmask DP; a
        // stranding penalty far above any real cost means a gang goes
        // unassigned only when it has no feasible candidate at all
        const STRAND: f64 = 1e18;
        let n = candidates.len();
        let mut assignment: Vec<Option<usize>> = vec![None; displaced.len()];
        if n > 0 {
            let gangs: Vec<usize> = (0..displaced.len()).collect();
            for wave in gangs.chunks(n) {
                let k = wave.len();
                let full = 1usize << n;
                let mut dp = vec![vec![f64::INFINITY; full]; k + 1];
                // (chosen candidate or n = stranded, predecessor mask)
                let mut from = vec![vec![(usize::MAX, 0usize); full]; k + 1];
                dp[0][0] = 0.0;
                for i in 0..k {
                    let gi = wave[i];
                    for mask in 0..full {
                        let base = dp[i][mask];
                        if !base.is_finite() {
                            continue;
                        }
                        if base + STRAND < dp[i + 1][mask] {
                            dp[i + 1][mask] = base + STRAND;
                            from[i + 1][mask] = (n, mask);
                        }
                        for ci in 0..n {
                            if mask & (1 << ci) != 0 || !costs[gi][ci].is_finite() {
                                continue;
                            }
                            let nm = mask | (1 << ci);
                            if base + costs[gi][ci] < dp[i + 1][nm] {
                                dp[i + 1][nm] = base + costs[gi][ci];
                                from[i + 1][nm] = (ci, mask);
                            }
                        }
                    }
                }
                let mut best = (f64::INFINITY, 0usize);
                for mask in 0..full {
                    if dp[k][mask] < best.0 {
                        best = (dp[k][mask], mask);
                    }
                }
                let mut mask = best.1;
                for i in (0..k).rev() {
                    let (ci, prev) = from[i + 1][mask];
                    if ci < n {
                        assignment[wave[i]] = Some(ci);
                    }
                    mask = prev;
                }
            }
        }

        for (gi, (src_ep, d)) in displaced.iter().enumerate() {
            let src_fac = &src_facs[gi];
            ledger.displaced += 1;
            ledger.checkpointed_s += d.checkpointed_s;
            ledger.lost_s += (d.elapsed_s - d.checkpointed_s).max(0.0);
            // the displaced task's compute ticket; a gang driven outside
            // the ticket machinery has nobody to deliver a resume to
            let ticket = self.pending.iter().find_map(|(id, op)| match op {
                PendingOp::Faas { task } if *task == d.task => Some(*id),
                _ => None,
            });
            let Some(tid) = ticket else {
                ledger.stranded += 1;
                continue;
            };
            let Some(target) = assignment[gi].map(|ci| candidates[ci].clone()) else {
                ledger.stranded += 1;
                self.pending.remove(&tid);
                self.ready.insert(
                    tid,
                    (
                        now,
                        Err(anyhow::anyhow!(
                            "task {:?} preempted on `{src_ep}`: no failover candidate",
                            d.task
                        )),
                    ),
                );
                continue;
            };
            let args = Json::obj(vec![
                ("remaining_s", Json::num(d.remaining_s())),
                ("output", d.output.clone()),
            ]);
            let meta = TaskMeta {
                user: d.meta.user,
                priority: d.meta.priority,
                // the failover queue orders the gang by its REMAINING
                // work, not the full estimate
                est_duration_s: Some(d.remaining_s()),
                slots: d.meta.width(),
                checkpoint_every_s: d.meta.checkpoint_every_s,
                // provenance survives the migration: drift-triggered
                // work stays drift-attributed after a failover resume
                origin: d.meta.origin,
            };
            if Self::facility_of(&target) == src_fac.as_str() {
                // same facility: the checkpoint moves over local
                // staging — the resume enqueues immediately
                let fid = FuncId("resume_train".into());
                match faas.enqueue_with_meta(now, &target, &fid, &args, meta) {
                    Ok(task) => {
                        ledger.local_migrations += 1;
                        self.pending.insert(tid, PendingOp::Faas { task });
                    }
                    Err(e) => {
                        ledger.stranded += 1;
                        self.pending.remove(&tid);
                        self.ready.insert(tid, (now, Err(e)));
                    }
                }
            } else {
                let bytes = ckpt_bytes[gi].max(1);
                let req = TransferRequest::split_even(
                    format!("spot-migrate-{}", d.task.0),
                    EndpointId::from(format!("{src_fac}#dtn").as_str()),
                    EndpointId::from(
                        format!("{}#dtn", Self::facility_of(&target)).as_str(),
                    ),
                    bytes,
                    1,
                );
                match self.transfer.submit_task(now, &req) {
                    Ok(handle) => {
                        ledger.wan_migrations += 1;
                        ledger.migration_bytes += bytes;
                        self.pending.insert(
                            tid,
                            PendingOp::Migration {
                                handle,
                                endpoint: target,
                                args,
                                meta,
                                user: d.meta.user,
                            },
                        );
                    }
                    Err(e) => {
                        ledger.stranded += 1;
                        self.pending.remove(&tid);
                        self.ready.insert(tid, (now, Err(e)));
                    }
                }
            }
        }
        self.faas = Some(faas);
        Ok(displaced.len())
    }

    /// Resolve the transfer payload size for a provider parameter set:
    /// explicit `bytes`, a dataset's wire size, or a model's param bytes.
    pub fn payload_bytes(&self, params: &crate::util::Json) -> Result<u64> {
        if let Some(b) = params.get("bytes").as_u64() {
            return Ok(b);
        }
        if let Some(ds) = params.get("dataset").as_str() {
            return Ok(self.dataset(ds)?.wire_bytes());
        }
        if let Some(m) = params.get("model").as_str() {
            return Ok(self.registry.get(m)?.param_bytes());
        }
        bail!("transfer params need `bytes`, `dataset`, or `model`")
    }
}

impl FabricHost for World {
    fn next_fabric_event(&mut self) -> Option<f64> {
        let t1 = self.transfer.next_event_time();
        let t2 = self.faas.as_ref().and_then(|f| f.next_event_time());
        match (t1, t2) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    fn advance_fabrics(&mut self, t: f64) {
        // WAN transfers: deliveries resolve tickets and materialize the
        // payload at the destination facility
        for (handle, res) in self.transfer.advance_to(t) {
            let ticket = self.pending.iter().find_map(|(id, op)| match op {
                PendingOp::Transfer { handle: h, .. } if *h == handle => Some(*id),
                PendingOp::Migration { handle: h, .. } if *h == handle => Some(*id),
                _ => None,
            });
            let Some(tid) = ticket else { continue };
            match self.pending.remove(&tid) {
                Some(PendingOp::Transfer {
                    dst_facility,
                    dataset,
                    model,
                    user,
                    ..
                }) => {
                    let resolved = match res {
                        Ok(rep) => {
                            if let Some(ds) = &dataset {
                                self.put_file(&dst_facility, ds, rep.bytes);
                            }
                            if let Some(m) = &model {
                                self.put_file(&dst_facility, &format!("{m}.weights"), rep.bytes);
                            }
                            let out = Json::obj(vec![
                                ("bytes", Json::num(rep.bytes as f64)),
                                ("seconds", Json::num(rep.duration())),
                                ("data_seconds", Json::num(rep.data_secs())),
                                ("throughput_bps", Json::num(rep.throughput_bps())),
                                ("concurrency", Json::num(rep.concurrency as f64)),
                                ("attempts", Json::num(rep.total_attempts() as f64)),
                            ]);
                            let finish = rep.finish_vt;
                            self.transfer_log.push(rep);
                            self.transfer_log_users.push(user);
                            (finish, Ok(out))
                        }
                        Err(e) => (t, Err(e)),
                    };
                    self.ready.insert(tid, resolved);
                }
                Some(PendingOp::Migration {
                    endpoint,
                    args,
                    meta,
                    user,
                    ..
                }) => {
                    // a preempted gang's checkpoint arriving at its
                    // failover facility: bill the egress to the
                    // preempted tenant and enter the target's queue at
                    // the delivery instant — the same advance picks the
                    // resume up below if a slot is free by `t`
                    let resolved = match res {
                        Ok(rep) => {
                            let finish = rep.finish_vt;
                            self.transfer_log.push(rep);
                            self.transfer_log_users.push(user);
                            let fid = FuncId("resume_train".into());
                            let faas =
                                self.faas.as_mut().expect("faas present before advance");
                            match faas.enqueue_with_meta(finish, &endpoint, &fid, &args, meta)
                            {
                                Ok(task) => {
                                    match &faas.record(task).expect("enqueued").status {
                                        // offline failover target: failed
                                        // at enqueue, no event coming
                                        TaskStatus::Failed(m) => Some((
                                            finish,
                                            Err(anyhow::anyhow!(
                                                "resume on `{endpoint}` failed: {m}"
                                            )),
                                        )),
                                        _ => {
                                            self.pending
                                                .insert(tid, PendingOp::Faas { task });
                                            None
                                        }
                                    }
                                }
                                Err(e) => Some((finish, Err(e))),
                            }
                        }
                        Err(e) => Some((t, Err(e))),
                    };
                    if let Some(r) = resolved {
                        self.ready.insert(tid, r);
                    }
                }
                _ => continue,
            }
        }

        // faas: queue starts run function bodies against this world, so
        // the service is taken out for the duration (same Option dance
        // the providers used pre-DES)
        if let Some(mut faas) = self.faas.take() {
            let completed = faas.advance_to(self, t);
            for task in completed {
                let ticket = self.pending.iter().find_map(|(id, op)| match op {
                    PendingOp::Faas { task: tk } if *tk == task => Some(*id),
                    _ => None,
                });
                let Some(tid) = ticket else { continue };
                self.pending.remove(&tid);
                let rec = faas.record(task).expect("completed task recorded");
                let resolved = match &rec.status {
                    TaskStatus::Success(v) => (
                        rec.finished_vt,
                        Ok(Json::obj(vec![
                            ("endpoint", Json::str(rec.endpoint.clone())),
                            ("exec_seconds", Json::num(rec.exec_secs())),
                            ("dispatch_seconds", Json::num(rec.overhead_secs())),
                            ("queue_wait_seconds", Json::num(rec.queue_wait_secs())),
                            ("output", v.clone()),
                        ])),
                    ),
                    TaskStatus::Failed(m) => (
                        rec.finished_vt,
                        Err(anyhow::anyhow!("task {task:?} failed: {m}")),
                    ),
                    _ => (
                        t,
                        Err(anyhow::anyhow!(
                            "task {task:?} incomplete after completion event"
                        )),
                    ),
                };
                self.ready.insert(tid, resolved);
            }
            self.faas = Some(faas);
        }
    }

    fn take_ready(&mut self, ticket: Ticket) -> Option<(f64, Result<Json>)> {
        self.ready.remove(&ticket.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    #[test]
    fn paper_world_wires_up() {
        if !artifacts_present() {
            return;
        }
        let w = World::paper(1).unwrap();
        assert!(w.faas.is_some());
        assert_eq!(w.accels.len(), 4);
        assert!(w.accel("alcf#cerebras").is_ok());
        assert!(w.accel("alcf#ghost").is_err());
        assert!(w.dataset("nope").is_err());
        assert!(w.trained("braggnn").is_err());
    }

    /// The scheduler's duration estimates come from the same cost
    /// models the bodies charge, so for registered functions they are
    /// *exact* — the property `EasyBackfill`'s no-delay guarantee
    /// rests on.
    #[test]
    fn duration_estimates_are_exact_for_known_functions() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(8).unwrap();
        w.training_mode = TrainingMode::VirtualOnly;
        let gen = FuncId("generate_data".into());
        let args = crate::util::Json::parse(
            r#"{"model": "braggnn", "n": 64, "seed": 5, "name": "est-d"}"#,
        )
        .unwrap();
        let est = w.estimate_task_secs("slac#sim", &gen, &args).unwrap();
        let ticket = w.submit_compute_ticket(0.0, "slac#sim", &gen, &args).unwrap();
        loop {
            if w.take_ready(ticket).is_some() {
                break;
            }
            let t = w.next_fabric_event().expect("generation pending");
            w.advance_fabrics(t);
        }
        let faas = w.faas.as_ref().unwrap();
        let rec = faas.records().last().unwrap();
        assert_eq!(rec.exec_secs(), est, "estimate not exact");
        assert_eq!(rec.meta.est_duration_s, Some(est));

        let train = FuncId("train_model".into());
        let targs = crate::util::Json::parse(
            r#"{"model": "braggnn", "dataset": "est-d", "endpoint": "alcf#cerebras"}"#,
        )
        .unwrap();
        let est = w.estimate_task_secs("alcf#cerebras", &train, &targs).unwrap();
        // Cerebras BraggNN: ~18 s modeled (Table 1: 19 s)
        assert!((15.0..22.0).contains(&est), "{est}");
        // unknown functions carry no estimate
        assert!(w
            .estimate_task_secs("slac#sim", &FuncId("ghost".into()), &crate::util::Json::Null)
            .is_none());
    }

    #[test]
    fn storage_and_payload_resolution() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(2).unwrap();
        w.put_file("slac", "scan-42.h5", 1000);
        assert_eq!(w.file_bytes("slac", "scan-42.h5").unwrap(), 1000);
        assert!(w.file_bytes("alcf", "scan-42.h5").is_err());

        let p = crate::util::Json::parse(r#"{"bytes": 77}"#).unwrap();
        assert_eq!(w.payload_bytes(&p).unwrap(), 77);
        let p = crate::util::Json::parse(r#"{"model": "braggnn"}"#).unwrap();
        assert_eq!(w.payload_bytes(&p).unwrap(), 4 * 36_922);
        let p = crate::util::Json::parse(r#"{"nothing": 1}"#).unwrap();
        assert!(w.payload_bytes(&p).is_err());
    }

    /// End-to-end spot failover across the WAN (DESIGN.md §12): with
    /// both local failover candidates down, a preempted Cerebras gang
    /// must ship its checkpoint to `slac#v100`, wait out the transfer,
    /// and replay exactly the remaining work — the ticket resolves once,
    /// from the failover endpoint, with checkpointed progress preserved.
    #[test]
    fn spot_preemption_migrates_over_wan_and_resumes() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(3).unwrap();
        w.training_mode = TrainingMode::VirtualOnly;
        w.checkpoint_every_s = Some(4.0);
        w.tenant = Tenant { user: 1, priority: 0, train_slots: 1 };
        // only the WAN candidate survives
        w.begin_endpoint_outage("alcf#sambanova", 0.0).unwrap();
        w.begin_endpoint_outage("alcf#gpu8", 0.0).unwrap();

        let train = FuncId("train_model".into());
        let args = crate::util::Json::parse(
            r#"{"model": "braggnn", "dataset": "virtual-d", "endpoint": "alcf#cerebras"}"#,
        )
        .unwrap();
        let ticket = w
            .submit_compute_ticket(0.0, "alcf#cerebras", &train, &args)
            .unwrap();
        // run past dispatch overhead so the gang is mid-flight
        w.advance_fabrics(5.0);
        let (started, full) = {
            let rec = w
                .faas
                .as_ref()
                .unwrap()
                .records()
                .iter()
                .find(|r| r.endpoint == "alcf#cerebras")
                .expect("train dispatched");
            (rec.started_vt, rec.exec_secs())
        };
        assert!(started.is_finite() && started < 5.0, "started {started}");
        assert!(full > 7.0, "cerebras braggnn train modeled at {full} s");

        // grace opens 5 s into the run; capacity reclaimed 2 s later.
        // 7 s of progress against a 4 s cadence: one checkpoint kept
        // (4 s), 3 s lost.
        w.spot_warn_endpoint("alcf#cerebras", started + 5.0).unwrap();
        w.preempt_spot_endpoint("alcf#cerebras", started + 7.0).unwrap();
        assert_eq!(w.spot.preemptions, 1);
        assert_eq!(w.spot.displaced, 1);
        assert_eq!(w.spot.wan_migrations, 1, "{:?}", w.spot);
        assert_eq!(w.spot.local_migrations, 0);
        assert_eq!(w.spot.stranded, 0);
        assert_eq!(w.spot.checkpointed_s, 4.0);
        assert!((w.spot.lost_s - 3.0).abs() < 1e-6, "{:?}", w.spot);
        assert_eq!(w.spot.migration_bytes, 4 * 36_922);

        // drive the WAN transfer and the replay to completion
        let (finish, res) = loop {
            if let Some(r) = w.take_ready(ticket) {
                break r;
            }
            let t = w.next_fabric_event().expect("migration pending");
            w.advance_fabrics(t);
        };
        let out = res.expect("resumed train succeeds");
        assert_eq!(out.get("endpoint").as_str(), Some("slac#v100"));
        // the failover replays only the remaining work past the
        // checkpoint
        let exec = out.get("exec_seconds").as_f64().unwrap();
        assert!((exec - (full - 4.0)).abs() < 1e-6, "exec {exec} vs full {full}");
        // checkpoint shipping is real WAN time, billed to the tenant
        assert!(finish > started + 7.0);
        let rep = w.transfer_log.last().expect("migration transfer logged");
        assert_eq!(rep.bytes, 4 * 36_922);
        assert_eq!(w.transfer_log_users.last(), Some(&1));

        // the fabric records tell the same story: the preempted run
        // failed at +7 s, the resume succeeded elsewhere, and total
        // slot-time stays well under a full restart's 2× blowup
        let faas = w.faas.as_ref().unwrap();
        let cer = faas
            .records()
            .iter()
            .find(|r| r.endpoint == "alcf#cerebras")
            .unwrap();
        assert!(matches!(cer.status, TaskStatus::Failed(_)));
        assert!((cer.exec_secs() - 7.0).abs() < 1e-6);
        let v100 = faas
            .records()
            .iter()
            .find(|r| r.endpoint == "slac#v100")
            .expect("failover record");
        assert!(matches!(v100.status, TaskStatus::Success(_)));
        let total: f64 = faas
            .records()
            .iter()
            .filter(|r| r.status.is_complete() && r.exec_secs().is_finite())
            .map(|r| r.exec_secs().max(0.0))
            .sum();
        assert!((total - (full + 3.0)).abs() < 1e-6, "total {total} vs full {full}");
        assert!(total < 2.0 * full);
    }
}
