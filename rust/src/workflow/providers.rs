//! Concrete action providers binding the flows engine to the `World`:
//! Transfer (Globus Transfer), Compute (funcX), Deploy (edge), Simulate.

use anyhow::{Context, Result};

use super::world::World;
use crate::flows::ActionProvider;
use crate::simnet::VClock;
use crate::training::TrainState;
use crate::transfer::TransferRequest;
use crate::util::Json;

/// Wrap a multi-file WAN transfer as a flow action.
/// params: {label?, src, dst, files?, concurrency?, verify_checksum?}
/// plus one payload selector: bytes | dataset | model.
pub struct TransferProvider;

impl ActionProvider<World> for TransferProvider {
    fn name(&self) -> &'static str {
        "transfer"
    }

    fn execute(&self, world: &mut World, clock: &mut VClock, params: &Json) -> Result<Json> {
        let src = params.get("src").as_str().context("transfer params.src")?;
        let dst = params.get("dst").as_str().context("transfer params.dst")?;
        let bytes = world.payload_bytes(params)?;
        let files = params.get("files").as_usize().unwrap_or(16).max(1);
        let label = params
            .get("label")
            .as_str()
            .unwrap_or("transfer")
            .to_string();
        let mut req = TransferRequest::split_even(label, src.into(), dst.into(), bytes, files);
        if let Some(k) = params.get("concurrency").as_usize() {
            req.concurrency = Some(k);
        }
        if let Some(v) = params.get("verify_checksum").as_bool() {
            req.verify_checksum = v;
        }
        let rep = world.transfer.execute(clock, &req)?;

        // the payload now exists at the destination facility's storage
        let dst_facility = dst.split('#').next().unwrap_or(dst).to_string();
        if let Some(ds) = params.get("dataset").as_str() {
            world.put_file(&dst_facility, ds, bytes);
        }
        if let Some(m) = params.get("model").as_str() {
            world.put_file(&dst_facility, &format!("{m}.weights"), bytes);
        }

        Ok(Json::obj(vec![
            ("bytes", Json::num(rep.bytes as f64)),
            ("seconds", Json::num(rep.duration())),
            ("data_seconds", Json::num(rep.data_secs())),
            ("throughput_bps", Json::num(rep.throughput_bps())),
            ("concurrency", Json::num(rep.concurrency as f64)),
            ("attempts", Json::num(rep.total_attempts() as f64)),
        ]))
    }
}

/// Wrap a funcX submission as a flow action.
/// params: {endpoint, function, args}
pub struct ComputeProvider;

impl ActionProvider<World> for ComputeProvider {
    fn name(&self) -> &'static str {
        "compute"
    }

    fn execute(&self, world: &mut World, clock: &mut VClock, params: &Json) -> Result<Json> {
        let endpoint = params
            .get("endpoint")
            .as_str()
            .context("compute params.endpoint")?
            .to_string();
        let func = crate::faas::FuncId(
            params
                .get("function")
                .as_str()
                .context("compute params.function")?
                .to_string(),
        );
        let args = params.get("args").clone();

        // Take the faas service out of the world so the function body can
        // borrow the rest of the world mutably (see World::faas docs).
        let mut faas = world
            .faas
            .take()
            .context("faas service missing (reentrant compute?)")?;
        let submitted = faas.submit(world, clock, &endpoint, &func, &args);
        let result = submitted.and_then(|task| {
            let record = faas.record(task)?;
            let exec_secs = record.exec_secs();
            let overhead = record.overhead_secs();
            let output = faas.result(task)?.clone();
            Ok(Json::obj(vec![
                ("endpoint", Json::str(endpoint.clone())),
                ("exec_seconds", Json::num(exec_secs)),
                ("dispatch_seconds", Json::num(overhead)),
                ("output", output),
            ]))
        });
        world.faas = Some(faas);
        result
    }
}

/// Deploy a trained model onto the edge host (operation **D**).
/// params: {model}
pub struct DeployProvider;

impl ActionProvider<World> for DeployProvider {
    fn name(&self) -> &'static str {
        "deploy"
    }

    fn execute(&self, world: &mut World, clock: &mut VClock, params: &Json) -> Result<Json> {
        let model = params.get("model").as_str().context("deploy params.model")?;
        let meta = world.registry.get(model)?.clone();
        let params_copy = world.trained(model)?.params.clone();
        let version = world.edge.deploy(&meta, params_copy)?;

        // smoke inference proves the deployment serves
        let x = crate::runtime::Tensor::zeros(
            std::iter::once(meta.infer_batch)
                .chain(meta.input_shape.iter().copied())
                .collect(),
        );
        let out = world.edge.infer_batch(&x)?;
        anyhow::ensure!(out.is_finite(), "deployed model produced non-finite output");

        // model load + runtime warm-up on the edge box
        clock.advance(1.0 + meta.param_bytes() as f64 / 200e6);
        Ok(Json::obj(vec![
            ("model", Json::str(model)),
            ("version", Json::num(version as f64)),
        ]))
    }
}

/// Re-deploy the *initial* weights (used by ablations / catch handlers to
/// roll the edge back to a known-good model). params: {model}
pub struct RollbackProvider;

impl ActionProvider<World> for RollbackProvider {
    fn name(&self) -> &'static str {
        "rollback"
    }

    fn execute(&self, world: &mut World, clock: &mut VClock, params: &Json) -> Result<Json> {
        let model = params.get("model").as_str().context("rollback params.model")?;
        let meta = world.registry.get(model)?.clone();
        let params_init = TrainState::init(&meta)?.params;
        let version = world.edge.deploy(&meta, params_init)?;
        clock.advance(1.0);
        log::warn!("edge rolled back to pristine `{model}` (v{version})");
        Ok(Json::obj(vec![
            ("model", Json::str(model)),
            ("version", Json::num(version as f64)),
            ("rolled_back", Json::Bool(true)),
        ]))
    }
}

/// Register every provider on an engine.
pub fn register_all(engine: &mut crate::flows::FlowEngine<World>) -> Result<()> {
    engine.register_provider(Box::new(TransferProvider))?;
    engine.register_provider(Box::new(ComputeProvider))?;
    engine.register_provider(Box::new(DeployProvider))?;
    engine.register_provider(Box::new(RollbackProvider))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    #[test]
    fn transfer_provider_moves_dataset_metadata() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(4).unwrap();
        let ds = crate::data::bragg::generate(&crate::data::BraggConfig::default(), 128, 1)
            .unwrap();
        w.datasets.insert("d1".into(), ds);
        let mut clock = VClock::new();
        let p = Json::parse(
            r#"{"src": "slac#dtn", "dst": "alcf#dtn", "dataset": "d1", "files": 4}"#,
        )
        .unwrap();
        let out = TransferProvider.execute(&mut w, &mut clock, &p).unwrap();
        assert!(out.get("seconds").as_f64().unwrap() > 0.0);
        assert!(w.file_bytes("alcf", "d1").is_ok());
        assert_eq!(clock.now(), out.get("seconds").as_f64().unwrap());
    }

    #[test]
    fn compute_provider_restores_faas_after_failure() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(5).unwrap();
        let mut clock = VClock::new();
        // unknown function -> submit errors, faas must be restored
        let p = Json::parse(
            r#"{"endpoint": "alcf#cluster", "function": "ghost", "args": {}}"#,
        )
        .unwrap();
        assert!(ComputeProvider.execute(&mut w, &mut clock, &p).is_err());
        assert!(w.faas.is_some(), "faas service lost after failure");
    }

    #[test]
    fn deploy_requires_trained_model() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(6).unwrap();
        let mut clock = VClock::new();
        let p = Json::parse(r#"{"model": "braggnn"}"#).unwrap();
        let err = DeployProvider.execute(&mut w, &mut clock, &p).unwrap_err();
        assert!(err.to_string().contains("not been trained"), "{err}");
    }

    #[test]
    fn rollback_deploys_pristine_weights() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(7).unwrap();
        let mut clock = VClock::new();
        let p = Json::parse(r#"{"model": "braggnn"}"#).unwrap();
        let out = RollbackProvider.execute(&mut w, &mut clock, &p).unwrap();
        assert_eq!(out.get("rolled_back").as_bool(), Some(true));
        assert!(w.edge.deployed().is_some());
    }
}
