//! Concrete action providers binding the flows engine to the `World`:
//! Transfer (Globus Transfer), Compute (funcX), Deploy (edge), Rollback.
//!
//! Under the discrete-event scheduler providers return *scheduled
//! completions* instead of advancing a clock: Transfer and Compute
//! submit to their shared fabrics and return tickets (completion time
//! depends on contention with other tenants); Deploy and Rollback are
//! fixed-cost local work and return `Effect::Done` durations.
//!
//! Compute submissions inherit `World.task_origin` into
//! `TaskMeta.origin` (DESIGN.md §16): a closed-loop campaign stamps
//! `TaskOrigin::Drift` so the fabric's slot-time ledgers can attribute
//! drift-admitted retraining separately from exogenous arrivals — the
//! tag survives checkpoint failover migration.

use anyhow::{Context, Result};

use super::world::World;
use crate::flows::{ActionProvider, Effect};
use crate::training::TrainState;
use crate::transfer::TransferRequest;
use crate::util::Json;

/// Wrap a multi-file WAN transfer as a flow action.
/// params: {label?, src, dst, files?, concurrency?, verify_checksum?}
/// plus one payload selector: bytes | dataset | model.
pub struct TransferProvider;

impl ActionProvider<World> for TransferProvider {
    fn name(&self) -> &'static str {
        "transfer"
    }

    fn start(&self, world: &mut World, now: f64, params: &Json) -> Result<Effect> {
        let src = params.get("src").as_str().context("transfer params.src")?;
        let dst = params.get("dst").as_str().context("transfer params.dst")?;
        let bytes = world.payload_bytes(params)?;
        let files = params.get("files").as_usize().unwrap_or(16).max(1);
        let label = params
            .get("label")
            .as_str()
            .unwrap_or("transfer")
            .to_string();
        let mut req = TransferRequest::split_even(label, src.into(), dst.into(), bytes, files);
        if let Some(k) = params.get("concurrency").as_usize() {
            req.concurrency = Some(k);
        }
        if let Some(v) = params.get("verify_checksum").as_bool() {
            req.verify_checksum = v;
        }

        // bookkeeping applied when the fabric delivers the task: the
        // payload materializes at the destination facility's storage
        let dst_facility = dst.split('#').next().unwrap_or(dst).to_string();
        let dataset = params.get("dataset").as_str().map(str::to_string);
        let model = params.get("model").as_str().map(str::to_string);
        let ticket = world.submit_transfer_ticket(now, &req, dst_facility, dataset, model)?;
        Ok(Effect::Pending(ticket))
    }
}

/// Wrap a funcX submission as a flow action.
/// params: {endpoint, function, args, priority?, user?, slots?}
///
/// A flow definition may pin a scheduler `priority` class, tenant
/// `user` tag, or training gang width (`slots`) directly in the action
/// params; each overrides the world's ambient
/// [`Tenant`](super::world::Tenant) for this and subsequent
/// submissions of the same drive (the campaign layer re-asserts its
/// per-user tenant every poll round).
pub struct ComputeProvider;

impl ActionProvider<World> for ComputeProvider {
    fn name(&self) -> &'static str {
        "compute"
    }

    fn start(&self, world: &mut World, now: f64, params: &Json) -> Result<Effect> {
        let endpoint = params
            .get("endpoint")
            .as_str()
            .context("compute params.endpoint")?
            .to_string();
        let func = crate::faas::FuncId(
            params
                .get("function")
                .as_str()
                .context("compute params.function")?
                .to_string(),
        );
        let args = params.get("args").clone();
        if let Some(p) = params.get("priority").as_f64() {
            world.tenant.priority = p as i64;
        }
        if let Some(u) = params.get("user").as_u64() {
            world.tenant.user = u as u32;
        }
        if let Some(s) = params.get("slots").as_u64() {
            world.tenant.train_slots = (s as usize).max(1);
        }
        let ticket = world.submit_compute_ticket(now, &endpoint, &func, &args)?;
        Ok(Effect::Pending(ticket))
    }
}

/// Deploy a trained model onto the edge host (operation **D**).
/// params: {model}
pub struct DeployProvider;

impl ActionProvider<World> for DeployProvider {
    fn name(&self) -> &'static str {
        "deploy"
    }

    fn start(&self, world: &mut World, _now: f64, params: &Json) -> Result<Effect> {
        let model = params.get("model").as_str().context("deploy params.model")?;
        let meta = world.registry.get(model)?.clone();
        let params_copy = world.trained(model)?.params.clone();
        let version = world.edge.deploy(&meta, params_copy)?;

        // smoke inference proves the deployment serves
        let x = crate::runtime::Tensor::zeros(
            std::iter::once(meta.infer_batch)
                .chain(meta.input_shape.iter().copied())
                .collect(),
        );
        let out = world.edge.infer_batch(&x)?;
        anyhow::ensure!(out.is_finite(), "deployed model produced non-finite output");

        // model load + runtime warm-up on the edge box
        Ok(Effect::after(
            1.0 + meta.param_bytes() as f64 / 200e6,
            Json::obj(vec![
                ("model", Json::str(model)),
                ("version", Json::num(version as f64)),
            ]),
        ))
    }
}

/// Re-deploy the *initial* weights (used by ablations / catch handlers to
/// roll the edge back to a known-good model). params: {model}
pub struct RollbackProvider;

impl ActionProvider<World> for RollbackProvider {
    fn name(&self) -> &'static str {
        "rollback"
    }

    fn start(&self, world: &mut World, _now: f64, params: &Json) -> Result<Effect> {
        let model = params.get("model").as_str().context("rollback params.model")?;
        let meta = world.registry.get(model)?.clone();
        let params_init = TrainState::init(&meta)?.params;
        let version = world.edge.deploy(&meta, params_init)?;
        log::warn!("edge rolled back to pristine `{model}` (v{version})");
        Ok(Effect::after(
            1.0,
            Json::obj(vec![
                ("model", Json::str(model)),
                ("version", Json::num(version as f64)),
                ("rolled_back", Json::Bool(true)),
            ]),
        ))
    }
}

/// Register every provider on an engine.
pub fn register_all(engine: &mut crate::flows::FlowEngine<World>) -> Result<()> {
    engine.register_provider(Box::new(TransferProvider))?;
    engine.register_provider(Box::new(ComputeProvider))?;
    engine.register_provider(Box::new(DeployProvider))?;
    engine.register_provider(Box::new(RollbackProvider))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flows::FabricHost;

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    /// Drive the world's fabrics until a ticket resolves.
    fn resolve(world: &mut World, ticket: crate::flows::Ticket) -> (f64, Result<Json>) {
        loop {
            if let Some(done) = world.take_ready(ticket) {
                return done;
            }
            let t = world.next_fabric_event().expect("fabric events pending");
            world.advance_fabrics(t);
        }
    }

    #[test]
    fn transfer_provider_moves_dataset_metadata() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(4).unwrap();
        let ds = crate::data::bragg::generate(&crate::data::BraggConfig::default(), 128, 1)
            .unwrap();
        w.datasets.insert("d1".into(), ds);
        let p = Json::parse(
            r#"{"src": "slac#dtn", "dst": "alcf#dtn", "dataset": "d1", "files": 4}"#,
        )
        .unwrap();
        let eff = TransferProvider.start(&mut w, 0.0, &p).unwrap();
        let Effect::Pending(ticket) = eff else {
            panic!("transfer must submit to the fabric");
        };
        // nothing materialized until the fabric delivers
        assert!(w.file_bytes("alcf", "d1").is_err());
        let (finish, out) = resolve(&mut w, ticket);
        let out = out.unwrap();
        assert!(out.get("seconds").as_f64().unwrap() > 0.0);
        assert!(finish > 0.0);
        assert_eq!(out.get("seconds").as_f64().unwrap(), finish);
        assert!(w.file_bytes("alcf", "d1").is_ok());
        assert_eq!(w.transfer_log.len(), 1);
    }

    #[test]
    fn compute_provider_restores_faas_after_failure() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(5).unwrap();
        // unknown function -> enqueue errors, faas must stay available
        let p = Json::parse(
            r#"{"endpoint": "alcf#cluster", "function": "ghost", "args": {}}"#,
        )
        .unwrap();
        assert!(ComputeProvider.start(&mut w, 0.0, &p).is_err());
        assert!(w.faas.is_some(), "faas service lost after failure");
    }

    #[test]
    fn compute_provider_runs_through_fabric() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(15).unwrap();
        let p = Json::parse(
            r#"{"endpoint": "slac#sim", "function": "generate_data",
                "args": {"model": "braggnn", "n": 64, "seed": 5, "name": "g1"}}"#,
        )
        .unwrap();
        let Effect::Pending(ticket) = ComputeProvider.start(&mut w, 0.0, &p).unwrap() else {
            panic!("compute must queue on the fabric");
        };
        let (finish, out) = resolve(&mut w, ticket);
        let out = out.unwrap();
        assert!(finish > 0.0);
        assert_eq!(out.get("queue_wait_seconds").as_f64(), Some(0.0));
        assert!(out.get("dispatch_seconds").as_f64().unwrap() >= 3.0 - 1e-9);
        assert_eq!(
            out.get("output").get("dataset").as_str(),
            Some("g1")
        );
        assert!(w.datasets.contains_key("g1"));
    }

    #[test]
    fn offline_endpoint_resolves_ticket_immediately() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(16).unwrap();
        w.faas
            .as_mut()
            .unwrap()
            .endpoint_mut("alcf#cerebras")
            .unwrap()
            .status = crate::faas::EndpointStatus::Offline;
        let p = Json::parse(
            r#"{"endpoint": "alcf#cerebras", "function": "train_model", "args": {}}"#,
        )
        .unwrap();
        let Effect::Pending(ticket) = ComputeProvider.start(&mut w, 7.0, &p).unwrap() else {
            panic!("offline submission still returns a ticket");
        };
        // resolves without any fabric event, at the submission instant
        let (tf, res) = w.take_ready(ticket).expect("instant resolution");
        assert_eq!(tf, 7.0);
        assert!(res.unwrap_err().to_string().contains("offline"));
    }

    #[test]
    fn deploy_requires_trained_model() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(6).unwrap();
        let p = Json::parse(r#"{"model": "braggnn"}"#).unwrap();
        let err = DeployProvider.start(&mut w, 0.0, &p).unwrap_err();
        assert!(err.to_string().contains("not been trained"), "{err}");
    }

    #[test]
    fn rollback_deploys_pristine_weights() {
        if !artifacts_present() {
            return;
        }
        let mut w = World::paper(7).unwrap();
        let p = Json::parse(r#"{"model": "braggnn"}"#).unwrap();
        let Effect::Done { duration, output } =
            RollbackProvider.start(&mut w, 0.0, &p).unwrap()
        else {
            panic!("rollback is fixed-cost local work");
        };
        assert_eq!(duration, 1.0);
        assert_eq!(output.get("rolled_back").as_bool(), Some(true));
        assert!(w.edge.deployed().is_some());
    }
}
