//! The coordinator: owns the `World`, the flow engine, the virtual
//! clock, and the user token; runs retraining scenarios end to end and
//! extracts Table 1 breakdowns.

use anyhow::{Context, Result};

use super::flow::{dnn_trainer_flow, FlowShape};
use super::scenario::Scenario;
use super::world::{TrainingMode, World};
use crate::auth::TokenId;
use crate::flows::{FlowEngine, RunReport};
use crate::simnet::VClock;
use crate::util::Json;

/// Table 1 row: the per-phase virtual-time breakdown of one retraining.
#[derive(Debug, Clone)]
pub struct RetrainBreakdown {
    pub model: String,
    pub mode_label: String,
    pub data_transfer_s: Option<f64>,
    pub training_s: f64,
    pub model_transfer_s: Option<f64>,
    /// user-initiation to model-received-at-edge-host (paper §5)
    pub end_to_end_s: f64,
    /// real PJRT training outcome when real training ran
    pub final_loss: Option<f32>,
    pub real_steps: u64,
}

/// Full outcome of a retraining run.
pub struct RetrainOutcome {
    pub report: RunReport,
    pub breakdown: RetrainBreakdown,
}

/// The top-level system object.
pub struct Coordinator {
    pub world: World,
    pub engine: FlowEngine<World>,
    pub clock: VClock,
    pub token: TokenId,
}

impl Coordinator {
    /// Build the paper fabric with every provider/function registered and
    /// a user token carrying the scopes the flow needs.
    pub fn paper(seed: u64) -> Result<Coordinator> {
        let world = World::paper(seed)?;
        let mut engine = FlowEngine::<World>::new();
        super::providers::register_all(&mut engine)?;
        let clock = VClock::new();
        let token = engine
            .auth
            .issue(
                &clock,
                "beamline-scientist",
                &["transfer:use", "compute:use", "deploy:use", "rollback:use"],
                30.0 * 24.0 * 3600.0,
            )
            .id;
        Ok(Coordinator {
            world,
            engine,
            clock,
            token,
        })
    }

    /// Generate the (small, real) training dataset for a scenario.
    pub fn prepare_dataset(&mut self, scenario: &Scenario) -> Result<String> {
        let name = format!("{}-train", scenario.model);
        let mut faas = self.world.faas.take().context("faas missing")?;
        let args = Json::obj(vec![
            ("model", Json::str(scenario.model.clone())),
            ("n", Json::num(scenario.real_samples as f64)),
            ("seed", Json::num(scenario.seed as f64)),
            ("name", Json::str(name.clone())),
        ]);
        let gen = crate::faas::FuncId("generate_data".into());
        let task = faas.submit(
            &mut self.world,
            &mut self.clock,
            "slac#sim",
            &gen,
            &args,
        );
        let result = task.and_then(|t| faas.result(t).cloned());
        self.world.faas = Some(faas);
        result?;
        Ok(name)
    }

    /// Run one retraining scenario through the DNNTrainerFlow.
    pub fn run_retraining(
        &mut self,
        scenario: &Scenario,
        shape_overrides: Option<FlowShape>,
    ) -> Result<RetrainOutcome> {
        let dataset = self.prepare_dataset(scenario)?;
        let shape = shape_overrides.unwrap_or(FlowShape {
            remote: scenario.mode.is_remote(),
            ..Default::default()
        });
        let def = dnn_trainer_flow(&shape)?;
        let input = Json::obj(vec![
            ("model", Json::str(scenario.model.clone())),
            ("dataset", Json::str(dataset)),
            ("dataset_bytes", Json::num(scenario.staged_bytes as f64)),
            (
                "train_endpoint",
                Json::str(scenario.mode.train_endpoint()),
            ),
        ]);

        let run_start = self.clock.now();
        let report = self.engine.run(
            &def,
            &input,
            &self.token,
            &mut self.world,
            &mut self.clock,
        )?;
        anyhow::ensure!(
            report.succeeded,
            "retraining flow failed: {:?}",
            report
                .records
                .iter()
                .map(|r| format!("{}:{:?}", r.id, r.status))
                .collect::<Vec<_>>()
        );

        let breakdown = extract_breakdown(&report, scenario, run_start)?;
        Ok(RetrainOutcome { report, breakdown })
    }

    /// Switch real PJRT training on/off (benches use virtual-only).
    pub fn set_training_mode(&mut self, mode: TrainingMode) {
        self.world.training_mode = mode;
    }
}

/// Extract the Table 1 per-phase breakdown from a DNNTrainerFlow run
/// report (shared by the single-flow coordinator and the multi-tenant
/// campaign layer, whose N=1 case must match it bit for bit).
pub fn extract_breakdown(
    report: &RunReport,
    scenario: &Scenario,
    run_start: f64,
) -> Result<RetrainBreakdown> {
    let action_secs = |id: &str| -> Option<f64> {
        report.record(id).ok().map(|r| r.duration())
    };
    // paper §5: end-to-end = initiation until the model is received
    // at the edge host machine (deploy/verify excluded)
    let received_at = if scenario.mode.is_remote() {
        report.record("return_model")?.end_vt
    } else {
        report.record("train")?.end_vt
    };

    let train_output = report.output("train")?.get("output").clone();
    Ok(RetrainBreakdown {
        model: scenario.model.clone(),
        mode_label: scenario.mode.label().to_string(),
        data_transfer_s: action_secs("stage_data"),
        training_s: action_secs("train").context("train action missing")?,
        model_transfer_s: action_secs("return_model"),
        end_to_end_s: received_at - run_start,
        final_loss: train_output
            .get("final_loss")
            .as_f64()
            .map(|v| v as f32),
        real_steps: train_output.get("real_steps").as_u64().unwrap_or(0),
    })
}

/// Render Table 1 rows as a text table.
pub fn render_table1(rows: &[RetrainBreakdown]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<34} {:<12} {:>14} {:>15} {:>15} {:>14}\n",
        "Mode", "Network", "Data Xfer (s)", "Training (s)", "Model Xfer (s)", "End-to-End (s)"
    ));
    out.push_str(&"-".repeat(108));
    out.push('\n');
    for r in rows {
        let fmt = |v: Option<f64>| match v {
            Some(s) => format!("{s:.1}"),
            None => "N/A".to_string(),
        };
        out.push_str(&format!(
            "{:<34} {:<12} {:>14} {:>15.1} {:>15} {:>14.1}\n",
            r.mode_label,
            r.model,
            fmt(r.data_transfer_s),
            r.training_s,
            fmt(r.model_transfer_s),
            r.end_to_end_s
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::scenario::Mode;

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    #[test]
    fn remote_cerebras_braggnn_matches_table1_shape() {
        if !artifacts_present() {
            return;
        }
        let mut c = Coordinator::paper(42).unwrap();
        c.set_training_mode(TrainingMode::VirtualOnly);
        let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let outcome = c.run_retraining(&scenario, None).unwrap();
        let b = &outcome.breakdown;
        // paper: transfer 7, train 19, model 5, e2e 31 — shape check
        let xfer = b.data_transfer_s.unwrap();
        assert!((4.0..11.0).contains(&xfer), "data xfer {xfer}");
        assert!((15.0..23.0).contains(&b.training_s), "train {}", b.training_s);
        let mx = b.model_transfer_s.unwrap();
        assert!((2.0..8.0).contains(&mx), "model xfer {mx}");
        assert!(
            (22.0..42.0).contains(&b.end_to_end_s),
            "e2e {}",
            b.end_to_end_s
        );
        // edge got the model
        assert!(c.world.edge.deployed().is_some());
    }

    #[test]
    fn local_mode_has_no_transfers_and_is_30x_slower() {
        if !artifacts_present() {
            return;
        }
        let mut c = Coordinator::paper(42).unwrap();
        c.set_training_mode(TrainingMode::VirtualOnly);
        let local = c
            .run_retraining(&Scenario::table1("braggnn", Mode::LocalV100).unwrap(), None)
            .unwrap();
        assert!(local.breakdown.data_transfer_s.is_none());
        assert!(local.breakdown.model_transfer_s.is_none());

        let mut c2 = Coordinator::paper(42).unwrap();
        c2.set_training_mode(TrainingMode::VirtualOnly);
        let remote = c2
            .run_retraining(
                &Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap(),
                None,
            )
            .unwrap();
        let speedup = local.breakdown.end_to_end_s / remote.breakdown.end_to_end_s;
        assert!(speedup > 30.0, "speedup only {speedup:.1}x");
    }

    #[test]
    fn real_training_through_the_full_flow() {
        if !artifacts_present() {
            return;
        }
        let mut c = Coordinator::paper(43).unwrap();
        c.set_training_mode(TrainingMode::Real {
            steps_override: Some(15),
        });
        let mut scenario = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        scenario.real_samples = 256;
        let outcome = c.run_retraining(&scenario, None).unwrap();
        assert_eq!(outcome.breakdown.real_steps, 15);
        let loss = outcome.breakdown.final_loss.unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        // deployed weights are the trained ones, not init
        let trained = c.world.trained("braggnn").unwrap();
        let deployed = c.world.edge.deployed().unwrap();
        assert_eq!(
            trained.params[0].data()[..8],
            deployed.params[0].data()[..8]
        );
    }

    #[test]
    fn render_table_formats() {
        let rows = vec![RetrainBreakdown {
            model: "braggnn".into(),
            mode_label: "Remote (Cerebras, Entire Wafer)".into(),
            data_transfer_s: Some(7.0),
            training_s: 19.0,
            model_transfer_s: Some(5.0),
            end_to_end_s: 31.0,
            final_loss: None,
            real_steps: 0,
        }];
        let table = render_table1(&rows);
        assert!(table.contains("Cerebras"));
        assert!(table.contains("31.0"));
        assert!(table.contains("N/A") == false);
    }
}
