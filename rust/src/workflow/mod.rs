//! The paper's system contribution, composed: the DNNTrainerFlow over
//! the flows engine, funcX fabric, transfer service, accelerator models,
//! PJRT trainer, and edge host.
//!
//! * `world`       — mutable fabric state threaded through actions
//! * `functions`   — faas bodies: generate / label / train / evaluate
//! * `providers`   — flow actions: transfer / compute / deploy / rollback
//! * `flow`        — the declarative DNNTrainerFlow definition
//! * `scenario`    — Table 1 scenario grid
//! * `coordinator` — runs scenarios, extracts the Table 1 breakdown
//! * `campaign`    — N concurrent users on the shared fabric (DES-driven)

pub mod campaign;
pub mod coordinator;
pub mod flow;
pub mod functions;
pub mod providers;
pub mod scenario;
pub mod world;

pub use campaign::{
    parse_mix, run_campaign, CampaignConfig, CampaignReport, CostSummary, EndpointCost,
    EndpointLoad, FairnessSummary, MixEntry, UserOutcome,
};
pub use coordinator::{
    extract_breakdown, render_table1, Coordinator, RetrainBreakdown, RetrainOutcome,
};
pub use flow::{dnn_trainer_flow, FlowShape};
pub use scenario::{Mode, Scenario};
pub use world::{Tenant, TrainedModel, TrainingMode, World};
