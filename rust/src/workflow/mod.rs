//! The paper's system contribution, composed: the DNNTrainerFlow over
//! the flows engine, funcX fabric, transfer service, accelerator models,
//! PJRT trainer, and edge host.
//!
//! * `world`       — mutable fabric state threaded through actions
//! * `functions`   — faas bodies: generate / label / train / evaluate
//! * `providers`   — flow actions: transfer / compute / deploy / rollback
//! * `flow`        — the declarative DNNTrainerFlow definition
//! * `scenario`    — Table 1 scenario grid
//! * `coordinator` — runs scenarios, extracts the Table 1 breakdown
//! * `campaign`    — N concurrent users on the shared fabric, driven by
//!   the discrete-event core (DESIGN.md §3) with pluggable scheduling,
//!   autoscaling, and fault plans (§9), gang-scheduled heterogeneous
//!   tenant mixes with slot-time cost accounting (§10), and per-class
//!   arrival processes plus dollar pricing / per-tenant bills (§11),
//!   spot capacity with checkpointed failover migration (§12), sharded
//!   execution over fabric replicas (§13), bounded-lag window
//!   synchronization for cross-shard WAN contention (§14), brokered
//!   multi-site federation (§15), and closed-loop drift-triggered
//!   retraining with model hot-swap (§16)
//! * `federation`  — sites, the placement broker, and `--sites` parsing
//! * `closedloop`  — serving-drift streams, the trigger policy, and the
//!   staleness/accuracy-loss ledger (§16)

pub mod campaign;
pub mod closedloop;
pub mod coordinator;
pub mod federation;
pub mod flow;
pub mod functions;
pub mod providers;
pub mod scenario;
pub mod world;

pub use campaign::{
    parse_mix, parse_spot, run_campaign, run_campaign_with_pool, sync_window_s, water_fill,
    Burst, CampaignConfig, CampaignReport, CampaignRunner, CostSummary, DollarSummary,
    EndpointCost, EndpointDollars, EndpointLoad, FairnessSummary, MixEntry, SpotSpec,
    TenantDollars, UserOutcome, AUTO_SHARD_USERS,
};
pub use closedloop::{
    per_user_seed, replay_fleet, replay_triggers, ClosedLoopLedger, ClosedLoopSpec,
    DriftStream, ReplayOutcome, ServeOutcome,
};
pub use federation::{
    parse_sites, Broker, FederationSummary, Placement, Site, SiteSummary,
};
pub use coordinator::{
    extract_breakdown, render_table1, Coordinator, RetrainBreakdown, RetrainOutcome,
};
pub use flow::{dnn_trainer_flow, FlowShape};
pub use scenario::{Mode, Scenario};
pub use world::{SpotLedger, Tenant, TrainedModel, TrainingMode, World};
