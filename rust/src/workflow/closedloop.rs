//! Closed-loop serving drift and drift-triggered retraining
//! (DESIGN.md §16).
//!
//! The paper's point is *actionable* retrieval: the edge model serves
//! live beamline batches, its fit residual drifts as the instrument
//! walks away from the training distribution, and a drift trigger
//! admits a retraining flow into the same DCAI fabric the Poisson
//! campaigns exercise. This module holds the pieces that are pure
//! functions of `(spec, seed)`:
//!
//! * [`ClosedLoopSpec`] — every knob of the loop, with the CLI
//!   defaults and the validation the campaign re-runs per shard;
//! * [`DriftStream`] — one user's deterministic fit-residual EWMA over
//!   served batches, with threshold + hysteresis + cooldown trigger
//!   semantics and a hot-swap reset;
//! * [`ClosedLoopLedger`] — the staleness / accuracy-loss integrals
//!   the campaign report carries (`CampaignReport.closed_loop`);
//! * [`replay_triggers`] / [`replay_fleet`] — standalone replays of
//!   the loop against a fixed retrain latency, used by the metamorphic
//!   suite and fanned per-user over [`crate::pool::scope`].
//!
//! The campaign integration (arrival admission, `Wake::Drift` events,
//! hot-swap at flow completion) lives in `workflow::campaign`; nothing
//! here touches the DES, so every test in this file is a pure replay.

use anyhow::{ensure, Result};

use crate::pool::Pool;
use crate::util::rng::Rng;

/// Every knob of the closed loop (CLI: `--closed-loop`,
/// `--drift-threshold`, `--serve-rate`). Copy so shard carving can
/// hand each shard the same spec without sharing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClosedLoopSpec {
    /// Served batches per virtual second (batch gap = `1/serve_rate`).
    /// CLI `--serve-rate`, default 0.1 — the documented default when
    /// `--closed-loop` is passed alone.
    pub serve_rate: f64,
    /// EWMA fit-residual level that fires a retrain trigger
    /// (strictly-greater comparison). CLI `--drift-threshold`.
    pub threshold: f64,
    /// Hysteresis band as a fraction of the threshold: after a fire
    /// the trigger re-arms only once the EWMA falls below
    /// `threshold * (1 - hysteresis)`. Prevents trigger storms.
    pub hysteresis: f64,
    /// Minimum virtual seconds between fires, on top of hysteresis.
    pub cooldown_s: f64,
    /// EWMA smoothing factor in (0, 1]; 1.0 = no smoothing (handy for
    /// hand-traced tests).
    pub ewma_alpha: f64,
    /// Residual growth per virtual second of deployed-model age — the
    /// deterministic part of the drift process.
    pub drift_rate: f64,
    /// Amplitude of the uniform per-batch residual noise drawn from
    /// the stream's seeded `Rng`.
    pub noise: f64,
    /// Forced-trigger backstop: a stream that has served this many
    /// batches since its last hot-swap fires unconditionally, so a
    /// zero-drift user still terminates its campaign.
    pub max_batches: u64,
}

impl Default for ClosedLoopSpec {
    fn default() -> Self {
        ClosedLoopSpec {
            serve_rate: 0.1,
            threshold: 0.35,
            hysteresis: 0.5,
            cooldown_s: 60.0,
            ewma_alpha: 0.3,
            drift_rate: 0.003,
            noise: 0.05,
            max_batches: 10_000,
        }
    }
}

impl ClosedLoopSpec {
    /// Batch gap in virtual seconds.
    pub fn gap_s(&self) -> f64 {
        1.0 / self.serve_rate
    }

    /// Reject degenerate knob values with the same message style the
    /// spot/checkpoint guards use; the campaign re-validates per shard
    /// so a bad spec fails before any DES state exists.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.threshold.is_finite() && self.threshold > 0.0,
            "drift threshold must be a finite positive residual (got {})",
            self.threshold
        );
        ensure!(
            self.serve_rate.is_finite() && self.serve_rate > 0.0,
            "serve rate must be a finite positive batches/s (got {})",
            self.serve_rate
        );
        ensure!(
            self.hysteresis.is_finite() && (0.0..1.0).contains(&self.hysteresis),
            "drift hysteresis must lie in [0, 1) (got {})",
            self.hysteresis
        );
        ensure!(
            self.cooldown_s.is_finite() && self.cooldown_s >= 0.0,
            "drift cooldown must be finite and non-negative (got {})",
            self.cooldown_s
        );
        ensure!(
            self.ewma_alpha.is_finite() && self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0,
            "drift EWMA alpha must lie in (0, 1] (got {})",
            self.ewma_alpha
        );
        ensure!(
            self.drift_rate.is_finite() && self.drift_rate >= 0.0,
            "drift rate must be finite and non-negative (got {})",
            self.drift_rate
        );
        ensure!(
            self.noise.is_finite() && self.noise >= 0.0,
            "drift noise amplitude must be finite and non-negative (got {})",
            self.noise
        );
        ensure!(
            self.max_batches >= 1,
            "drift max-batches backstop must be at least 1"
        );
        Ok(())
    }
}

/// What one served batch did to the trigger state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeOutcome {
    /// Below threshold (or nothing notable): keep serving.
    Quiet,
    /// Threshold crossed and the trigger was armed + off cooldown:
    /// admit a retraining flow.
    Fired,
    /// Batch-count backstop fired (zero-drift termination guarantee).
    ForcedFire,
    /// Above threshold but disarmed or cooling down: counted, not
    /// fired — the hysteresis/cooldown storm suppression at work.
    Suppressed,
}

/// One user's deterministic serving-drift process: fit-residual EWMA
/// over batches served on the edge device, seeded so replays are
/// bit-identical (DESIGN.md §16).
#[derive(Debug, Clone)]
pub struct DriftStream {
    spec: ClosedLoopSpec,
    rng: Rng,
    /// Current fit-residual EWMA (exposed for the accuracy-loss
    /// integral the campaign accumulates per batch).
    pub ewma: f64,
    /// Virtual time the deployed model version was born (hot-swap
    /// resets it; residual age = now - birth).
    pub version_birth_vt: f64,
    armed: bool,
    cooldown_until: f64,
    batches_since_swap: u64,
}

impl DriftStream {
    pub fn new(spec: ClosedLoopSpec, seed: u64) -> DriftStream {
        DriftStream {
            spec,
            rng: Rng::new(seed),
            ewma: 0.0,
            version_birth_vt: 0.0,
            armed: true,
            cooldown_until: 0.0,
            batches_since_swap: 0,
        }
    }

    pub fn spec(&self) -> &ClosedLoopSpec {
        &self.spec
    }

    /// Serve one batch at virtual time `now`: draw the residual,
    /// update the EWMA, and run the threshold + hysteresis + cooldown
    /// trigger policy. Deterministic: the residual is
    /// `noise * U(0,1) + drift_rate * model_age`, all from the seeded
    /// stream.
    pub fn serve(&mut self, now: f64) -> ServeOutcome {
        let u = self.rng.uniform(0.0, 1.0);
        let age = (now - self.version_birth_vt).max(0.0);
        let resid = self.spec.noise * u + self.spec.drift_rate * age;
        self.ewma = self.spec.ewma_alpha * resid + (1.0 - self.spec.ewma_alpha) * self.ewma;
        self.batches_since_swap += 1;

        if !self.armed && self.ewma < self.spec.threshold * (1.0 - self.spec.hysteresis) {
            self.armed = true;
        }
        if self.ewma > self.spec.threshold {
            if self.armed && now >= self.cooldown_until {
                self.armed = false;
                self.cooldown_until = now + self.spec.cooldown_s;
                return ServeOutcome::Fired;
            }
            return ServeOutcome::Suppressed;
        }
        if self.batches_since_swap >= self.spec.max_batches {
            // termination backstop — fires even on a drift-free stream
            self.armed = false;
            self.cooldown_until = now + self.spec.cooldown_s;
            return ServeOutcome::ForcedFire;
        }
        ServeOutcome::Quiet
    }

    /// Retrain completion: the new model version deploys at virtual
    /// time `vt`. Residual state resets; the trigger re-arms.
    pub fn hot_swap(&mut self, vt: f64) {
        self.ewma = 0.0;
        self.version_birth_vt = vt;
        self.armed = true;
        self.batches_since_swap = 0;
    }
}

/// The closed-loop integrals the campaign report carries
/// (`CampaignReport.closed_loop`); shard merge sums fields exactly
/// like `SpotLedger`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClosedLoopLedger {
    /// Batches served across all users/streams.
    pub batches_served: u64,
    /// Threshold triggers fired (includes forced fires).
    pub triggers: u32,
    /// Of those, fires forced by the `max_batches` backstop.
    pub forced_triggers: u32,
    /// Above-threshold batches suppressed by hysteresis/cooldown.
    pub suppressed: u32,
    /// Retraining flows actually admitted into the fabric (a fire
    /// while a retrain is already in flight re-fires later instead).
    pub retrains_admitted: u32,
    /// Model hot-swaps applied at retrain completion.
    pub hot_swaps: u32,
    /// Σ (swap_vt - trigger_vt): seconds users served a known-stale
    /// model while its replacement trained.
    pub staleness_s: f64,
    /// Σ max(ewma - threshold, 0) * batch_gap: the accuracy-loss
    /// integral of serving above the acceptable residual.
    pub accuracy_loss: f64,
    /// Edge-device busy seconds (virtual) spent serving batches.
    pub edge_busy_s: f64,
    /// Fabric slot-seconds attributed to drift-triggered work via
    /// `TaskOrigin::Drift` provenance (cost attribution).
    pub drift_slot_s: f64,
}

/// A standalone replay of one stream against a fixed retrain latency:
/// the pure function of `(spec, seed)` the determinism and
/// metamorphic tests pin (no DES, no fabric).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayOutcome {
    /// Virtual times of every fire (forced included), in order.
    pub triggers: Vec<f64>,
    pub ledger: ClosedLoopLedger,
}

/// Replay a single drift stream over `[0, horizon_s]`: batches at
/// `k * gap`, each fire admits a retrain iff none is in flight, and
/// the swap lands `swap_latency_s` later (staleness = that latency).
pub fn replay_triggers(
    spec: ClosedLoopSpec,
    seed: u64,
    horizon_s: f64,
    swap_latency_s: f64,
) -> ReplayOutcome {
    let mut stream = DriftStream::new(spec, seed);
    let mut out = ReplayOutcome {
        triggers: Vec::new(),
        ledger: ClosedLoopLedger::default(),
    };
    let gap = spec.gap_s();
    let mut in_flight = false;
    let mut swap_at = f64::INFINITY;
    let mut k = 1u64;
    loop {
        let t = k as f64 * gap;
        if t > horizon_s {
            break;
        }
        if in_flight && t >= swap_at {
            out.ledger.staleness_s += swap_latency_s;
            out.ledger.hot_swaps += 1;
            stream.hot_swap(swap_at);
            in_flight = false;
            swap_at = f64::INFINITY;
        }
        let outcome = stream.serve(t);
        out.ledger.batches_served += 1;
        out.ledger.accuracy_loss += (stream.ewma - spec.threshold).max(0.0) * gap;
        match outcome {
            ServeOutcome::Fired | ServeOutcome::ForcedFire => {
                out.ledger.triggers += 1;
                if outcome == ServeOutcome::ForcedFire {
                    out.ledger.forced_triggers += 1;
                }
                out.triggers.push(t);
                if !in_flight {
                    in_flight = true;
                    swap_at = t + swap_latency_s;
                    out.ledger.retrains_admitted += 1;
                }
            }
            ServeOutcome::Suppressed => out.ledger.suppressed += 1,
            ServeOutcome::Quiet => {}
        }
        k += 1;
    }
    out
}

/// Fan per-user replays over [`Pool::scope`] — the `pool::scope`
/// fan-out entry the ROADMAP item carries. Stream `i` gets
/// [`per_user_seed`]`(seed, i)` — the same derivation the campaign
/// applies (to its drift-salted root), so fleet replays share the
/// campaign's per-user decorrelation structure.
pub fn replay_fleet(
    spec: ClosedLoopSpec,
    seed: u64,
    users: usize,
    horizon_s: f64,
    swap_latency_s: f64,
    pool: &Pool,
) -> Vec<ReplayOutcome> {
    let tasks: Vec<crate::pool::ScopeTask<'_, ReplayOutcome>> = (0..users)
        .map(|i| {
            let user_seed = per_user_seed(seed, i);
            let task: crate::pool::ScopeTask<'_, ReplayOutcome> = Box::new(move || {
                replay_triggers(spec, user_seed, horizon_s, swap_latency_s)
            });
            task
        })
        .collect();
    pool.scope(tasks)
}

/// The per-user drift seed derivation shared by [`replay_fleet`] and
/// the campaign's stream construction (golden-ratio odd multiplier
/// decorrelates adjacent users).
pub fn per_user_seed(seed: u64, user: usize) -> u64 {
    seed ^ (user as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Noise 0, alpha 1 spec: ewma == drift_rate * model_age exactly,
    /// so every trigger time is hand-computable.
    fn traced_spec() -> ClosedLoopSpec {
        ClosedLoopSpec {
            serve_rate: 0.5, // gap 2 s
            threshold: 0.1,
            hysteresis: 0.5,
            cooldown_s: 0.0,
            ewma_alpha: 1.0,
            drift_rate: 0.01,
            noise: 0.0,
            max_batches: 1_000_000,
        }
    }

    #[test]
    fn hand_traced_replay_is_exact() {
        // ewma = 0.01 * age; threshold 0.1 crossed strictly at
        // age 12 s (age 10 gives exactly 0.1, not > 0.1). Swap
        // latency 5 s applies at the next batch >= trigger+5.
        let out = replay_triggers(traced_spec(), 7, 50.0, 5.0);
        assert_eq!(out.triggers, vec![12.0, 28.0, 44.0], "{out:?}");
        assert_eq!(out.ledger.batches_served, 25);
        assert_eq!(out.ledger.triggers, 3);
        assert_eq!(out.ledger.forced_triggers, 0);
        assert_eq!(out.ledger.hot_swaps, 3);
        assert_eq!(out.ledger.retrains_admitted, 3);
        // two above-threshold batches after each fire before the
        // swap applies: t = 14,16 / 30,32 / 46,48
        assert_eq!(out.ledger.suppressed, 6);
        // staleness = 3 swaps x 5 s latency, exactly
        assert_eq!(out.ledger.staleness_s, 15.0);
        // excess residual x gap 2 s: cycle 1 is born at 0 (even grid
        // ages; excess 0.02+0.04+0.06 = 0.12), cycles 2 and 3 are born
        // at the swap instants 17 and 33 (odd grid ages 11,13,15;
        // excess 0.01+0.03+0.05 = 0.09) -> 2*(0.12+0.09+0.09) = 0.60
        assert!((out.ledger.accuracy_loss - 0.60).abs() < 1e-12, "{out:?}");
    }

    #[test]
    fn replay_is_pure_function_of_spec_and_seed() {
        let spec = ClosedLoopSpec::default();
        let a = replay_triggers(spec, 42, 5_000.0, 300.0);
        let b = replay_triggers(spec, 42, 5_000.0, 300.0);
        assert_eq!(a, b);
        let c = replay_triggers(spec, 43, 5_000.0, 300.0);
        assert_ne!(a, c, "different seeds should produce different noise");
    }

    #[test]
    fn zero_drift_stream_never_triggers() {
        // drift_rate 0 and noise amplitude < threshold: the EWMA is a
        // convex average of values <= noise < threshold, so it can
        // never exceed it; the horizon keeps batches below the forced
        // backstop, so the replay must be trigger-free.
        let spec = ClosedLoopSpec {
            drift_rate: 0.0,
            ..ClosedLoopSpec::default()
        };
        assert!(spec.noise < spec.threshold);
        let out = replay_triggers(spec, 42, 10_000.0, 300.0);
        assert_eq!(out.ledger.triggers, 0, "{out:?}");
        assert_eq!(out.ledger.suppressed, 0);
        assert_eq!(out.ledger.staleness_s, 0.0);
        assert_eq!(out.ledger.batches_served, 1_000);
    }

    #[test]
    fn hysteresis_prevents_trigger_storms() {
        // Infinite swap latency: the retrain never completes, the EWMA
        // keeps climbing, and hysteresis (disarm until the EWMA falls
        // back below threshold * (1 - h), which a monotone stream
        // never does) must hold the fire count at exactly 1 while
        // every later above-threshold batch lands in `suppressed`.
        let out = replay_triggers(traced_spec(), 7, 400.0, f64::INFINITY);
        assert_eq!(out.ledger.triggers, 1, "{out:?}");
        assert_eq!(out.triggers, vec![12.0]);
        assert_eq!(out.ledger.hot_swaps, 0);
        assert_eq!(out.ledger.retrains_admitted, 1);
        // batches at 2..=400 step 2 -> 200 served; 12 fires, every
        // batch after it (14..=400 -> 194) is suppressed
        assert_eq!(out.ledger.batches_served, 200);
        assert_eq!(out.ledger.suppressed, 194);
    }

    #[test]
    fn cooldown_spaces_fires_without_hysteresis() {
        // Hysteresis off, instant swaps (latency 0: rebirth at the
        // fire instant, applied at the next batch). Drift alone would
        // re-fire every 12 s (ages on the even grid cross 10 at 12);
        // the 15 s cooldown stretches the period to 16 s, pushing two
        // above-threshold batches per cycle into `suppressed`. The
        // point: cooldown alone spaces periodic fires where the
        // hysteresis test above pinned exactly one.
        let spec = ClosedLoopSpec {
            hysteresis: 0.0,
            cooldown_s: 15.0,
            ..traced_spec()
        };
        let out = replay_triggers(spec, 7, 100.0, 0.0);
        // fire at 12 (cooldown until 27, rebirth at 12): ages 12 and
        // 14 land at t = 24, 26 — above threshold but cooling down —
        // and t = 28 fires; each later cycle repeats the shape
        assert_eq!(out.triggers, vec![12.0, 28.0, 44.0, 60.0, 76.0, 92.0]);
        assert_eq!(out.ledger.suppressed, 10);
        assert_eq!(out.ledger.hot_swaps, 6);
        assert_eq!(out.ledger.retrains_admitted, 6);
    }

    #[test]
    fn forced_fire_terminates_zero_drift_streams() {
        let spec = ClosedLoopSpec {
            drift_rate: 0.0,
            noise: 0.0,
            max_batches: 10,
            ..ClosedLoopSpec::default()
        };
        let out = replay_triggers(spec, 1, 1_000.0, 50.0);
        assert!(out.ledger.triggers >= 1, "{out:?}");
        assert_eq!(out.ledger.triggers, out.ledger.forced_triggers);
        assert_eq!(out.triggers[0], 10.0 * spec.gap_s());
    }

    #[test]
    fn fleet_replay_is_pool_width_invariant() {
        let spec = ClosedLoopSpec::default();
        let a = replay_fleet(spec, 42, 12, 2_000.0, 120.0, &Pool::new(1));
        let b = replay_fleet(spec, 42, 12, 2_000.0, 120.0, &Pool::new(8));
        assert_eq!(a, b);
        // per-user seeds decorrelate: not all outcomes identical
        assert!(a.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn validate_rejects_degenerate_specs() {
        let ok = ClosedLoopSpec::default();
        assert!(ok.validate().is_ok());
        for (label, bad) in [
            ("zero threshold", ClosedLoopSpec { threshold: 0.0, ..ok }),
            ("negative threshold", ClosedLoopSpec { threshold: -0.1, ..ok }),
            ("NaN threshold", ClosedLoopSpec { threshold: f64::NAN, ..ok }),
            ("zero serve rate", ClosedLoopSpec { serve_rate: 0.0, ..ok }),
            ("infinite serve rate", ClosedLoopSpec { serve_rate: f64::INFINITY, ..ok }),
            ("hysteresis of 1", ClosedLoopSpec { hysteresis: 1.0, ..ok }),
            ("negative cooldown", ClosedLoopSpec { cooldown_s: -1.0, ..ok }),
            ("zero alpha", ClosedLoopSpec { ewma_alpha: 0.0, ..ok }),
            ("alpha above 1", ClosedLoopSpec { ewma_alpha: 1.5, ..ok }),
            ("negative drift", ClosedLoopSpec { drift_rate: -0.01, ..ok }),
            ("NaN noise", ClosedLoopSpec { noise: f64::NAN, ..ok }),
            ("zero max-batches", ClosedLoopSpec { max_batches: 0, ..ok }),
        ] {
            assert!(bad.validate().is_err(), "{label} should be rejected");
        }
    }

    #[test]
    fn hot_swap_resets_residual_state() {
        let mut s = DriftStream::new(traced_spec(), 3);
        for k in 1..=10 {
            s.serve(k as f64 * 2.0);
        }
        assert!(s.ewma > 0.0);
        s.hot_swap(20.0);
        assert_eq!(s.ewma, 0.0);
        assert_eq!(s.version_birth_vt, 20.0);
        // next batch right after the swap has age 2 s -> tiny residual
        assert_eq!(s.serve(22.0), ServeOutcome::Quiet);
        assert!((s.ewma - 0.02).abs() < 1e-12);
    }
}
