//! Dollar-denominated pricing of the fabric (DESIGN.md §11).
//!
//! The slot-hour cost accounting of DESIGN.md §10 deliberately stops
//! short of money: a Cerebras slot-hour and a 1024-core-cluster
//! slot-hour are incomparable quantities, so summing them across
//! endpoints produces a number with no unit. [`PriceBook`] closes the
//! gap: it maps each endpoint *class* (the part of the endpoint id
//! after `#` — `cerebras`, `cluster`, `v100`, …) to a dollar rate per
//! slot-hour, plus a dollar rate per GB of WAN egress, so the campaign
//! layer can convert its `CostSummary` into provisioned/used/waste
//! dollars and per-tenant bills (`--prices` on `xloop campaign`).
//!
//! Rates are *list-price stand-ins*, not measurements: the point of the
//! paper's economics argument (remote DCAI turns a retraining around
//! ~30× faster than the local GPU *despite* data movement) is only
//! testable once both sides carry the same unit. `PriceBook::paper()`
//! ships defaults in the ballpark of published cloud/DCAI rental rates
//! circa the paper; every study that matters sweeps or overrides them.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Endpoint classes the paper fabric registers (`World::paper`). A
/// `--prices` spec naming anything else is rejected up front — a typo'd
/// class would otherwise silently price nothing.
pub const KNOWN_CLASSES: &[&str] = &["v100", "sim", "cerebras", "sambanova", "gpu8", "cluster"];

/// The reserved `--prices` key for WAN egress ($/GB), priced separately
/// from slot time.
pub const EGRESS_KEY: &str = "egress";

/// Endpoint-class → dollar rates (DESIGN.md §11).
///
/// Unpriced classes cost $0/slot-hour — a book may deliberately price
/// only the endpoints under study (e.g. `cerebras` vs `v100` for the
/// remote-vs-local crossover) without the idle simulation host
/// polluting the totals.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PriceBook {
    /// class → $/slot-hour
    rates: BTreeMap<String, f64>,
    /// $/GB for WAN egress (bytes that crossed the wide-area network,
    /// retransmissions included — the wire does not refund retries)
    pub egress_per_gb: f64,
}

impl PriceBook {
    /// An empty book: every class $0, egress $0.
    pub fn new() -> PriceBook {
        PriceBook::default()
    }

    /// Ballpark list prices for the paper fabric, used when a cost
    /// study needs *some* dollar axis and none was given
    /// (`--cost-sweep` without `--prices`):
    ///
    /// * `cerebras` $42/slot-h — wafer-scale rental is the premium tier
    /// * `sambanova` $30/slot-h, `gpu8` $12/slot-h — DCAI mid-tier
    /// * `v100` $3/slot-h — single cloud V100 on-demand
    /// * `cluster` $1.80/slot-h, `sim` $0.40/slot-h — commodity CPU
    /// * egress $0.09/GB — the classic cloud egress list price
    ///
    /// Every class also carries a `:spot` (preemptible) tier at 30% of
    /// list — the classic ~70% spot discount that makes the
    /// spot-vs-on-demand crossover study interesting (DESIGN.md §12).
    pub fn paper() -> PriceBook {
        let mut book = PriceBook::new();
        for (class, rate) in [
            ("cerebras", 42.0),
            ("sambanova", 30.0),
            ("gpu8", 12.0),
            ("v100", 3.0),
            ("cluster", 1.8),
            ("sim", 0.4),
        ] {
            book.rates.insert(class.to_string(), rate);
            book.rates.insert(format!("{class}:spot"), rate * 0.3);
        }
        book.egress_per_gb = 0.09;
        book
    }

    /// Parse a `--prices` spec: comma-joined `class:rate` entries with
    /// rates in $/slot-hour, plus an optional `egress:rate` in $/GB —
    /// e.g. `cerebras:42.0,cluster:1.8,egress:0.09`. A class may also
    /// price its preemptible tier separately via `class:spot:rate`
    /// (e.g. `cerebras:spot:12.6`). Unknown classes, non-finite or
    /// negative rates, and duplicate entries are all rejected.
    pub fn parse(spec: &str) -> Result<PriceBook> {
        let mut book = PriceBook::new();
        let mut saw_egress = false;
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let Some((class, rest)) = tok.split_once(':') else {
                bail!("bad price entry `{tok}` (want class:dollars_per_slot_hour)");
            };
            // `class:spot:rate` prices the preemptible tier of `class`
            let (key, rate) = match rest.split_once(':') {
                Some(("spot", rate)) => (format!("{class}:spot"), rate),
                Some(_) => bail!("bad price entry `{tok}` (want class:rate or class:spot:rate)"),
                None => (class.to_string(), rest),
            };
            let rate: f64 = rate
                .parse()
                .map_err(|_| anyhow::anyhow!("bad price `{rate}` in `{tok}`"))?;
            if !rate.is_finite() || rate < 0.0 {
                bail!("price must be finite and >= 0 in `{tok}`");
            }
            if class == EGRESS_KEY {
                if key != class {
                    bail!("`{EGRESS_KEY}` has no spot tier (`{tok}`)");
                }
                if saw_egress {
                    bail!("duplicate price entry for `{EGRESS_KEY}`");
                }
                saw_egress = true;
                book.egress_per_gb = rate;
                continue;
            }
            if !KNOWN_CLASSES.contains(&class) {
                bail!(
                    "unknown endpoint class `{class}` (known: {}, plus `{EGRESS_KEY}`)",
                    KNOWN_CLASSES.join(", ")
                );
            }
            if book.rates.insert(key.clone(), rate).is_some() {
                bail!("duplicate price entry for class `{key}`");
            }
        }
        Ok(book)
    }

    /// Chainable egress override — each federated site carries its own
    /// book so `--sites`/`--cost-sweep` can study egress-price
    /// asymmetry (a cheap-egress site wins the dollar placement even
    /// when its queue is longer).
    pub fn with_egress(mut self, dollars_per_gb: f64) -> PriceBook {
        self.egress_per_gb = dollars_per_gb;
        self
    }

    /// The class of an endpoint id: the part after `#` (`alcf#cerebras`
    /// → `cerebras`), or the whole id when there is no `#`.
    pub fn class_of(endpoint: &str) -> &str {
        endpoint.split_once('#').map(|(_, c)| c).unwrap_or(endpoint)
    }

    /// $/slot-hour for an endpoint (0.0 when its class is unpriced).
    pub fn rate_per_slot_hour(&self, endpoint: &str) -> f64 {
        self.rates
            .get(Self::class_of(endpoint))
            .copied()
            .unwrap_or(0.0)
    }

    /// Whether the endpoint's class carries an explicit price.
    pub fn has_price(&self, endpoint: &str) -> bool {
        self.rates.contains_key(Self::class_of(endpoint))
    }

    /// $/slot-hour for an endpoint on a given capacity tier. Spot
    /// endpoints read the `class:spot` rate when one is priced and fall
    /// back to the on-demand rate otherwise — a book that does not
    /// discount spot prices both tiers identically rather than pricing
    /// the spot tier at $0.
    pub fn rate_per_slot_hour_tiered(&self, endpoint: &str, spot: bool) -> f64 {
        if spot {
            let class = Self::class_of(endpoint);
            if let Some(rate) = self.rates.get(&format!("{class}:spot")) {
                return *rate;
            }
        }
        self.rate_per_slot_hour(endpoint)
    }

    /// Dollars for `slot_s` slot-seconds on an endpoint.
    pub fn slot_dollars(&self, endpoint: &str, slot_s: f64) -> f64 {
        self.rate_per_slot_hour(endpoint) * slot_s / 3600.0
    }

    /// Dollars for `slot_s` slot-seconds on an endpoint, tier-aware.
    pub fn slot_dollars_tiered(&self, endpoint: &str, slot_s: f64, spot: bool) -> f64 {
        self.rate_per_slot_hour_tiered(endpoint, spot) * slot_s / 3600.0
    }

    /// Dollars for `bytes` of WAN egress.
    pub fn egress_dollars(&self, bytes: f64) -> f64 {
        self.egress_per_gb * bytes / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_classes_and_egress() {
        let b = PriceBook::parse("cerebras:42.0,cluster:1.8,egress:0.09").unwrap();
        assert_eq!(b.rate_per_slot_hour("alcf#cerebras"), 42.0);
        assert_eq!(b.rate_per_slot_hour("alcf#cluster"), 1.8);
        assert_eq!(b.egress_per_gb, 0.09);
        // unpriced class defaults to $0 but is distinguishable
        assert_eq!(b.rate_per_slot_hour("slac#v100"), 0.0);
        assert!(!b.has_price("slac#v100"));
        assert!(b.has_price("alcf#cerebras"));
        // empty spec is a valid (all-zero) book
        assert_eq!(PriceBook::parse("").unwrap(), PriceBook::new());
        // an hour of one slot at $42/slot-h is $42; 10 GB at $0.09
        assert!((b.slot_dollars("alcf#cerebras", 3600.0) - 42.0).abs() < 1e-12);
        assert!((b.egress_dollars(10e9) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_specs() {
        // unknown class
        assert!(PriceBook::parse("tpu:9.0").unwrap_err().to_string().contains("unknown"));
        // negative and non-finite prices
        assert!(PriceBook::parse("cerebras:-1").is_err());
        assert!(PriceBook::parse("cerebras:inf").is_err());
        assert!(PriceBook::parse("cerebras:abc").is_err());
        // duplicate entries (class and egress alike)
        assert!(PriceBook::parse("cerebras:1,cerebras:2")
            .unwrap_err()
            .to_string()
            .contains("duplicate"));
        assert!(PriceBook::parse("egress:0.1,egress:0.2").is_err());
        // shapeless tokens
        assert!(PriceBook::parse("cerebras").is_err());
        // malformed / disallowed three-part tokens
        assert!(PriceBook::parse("cerebras:ondemand:9.0").is_err());
        assert!(PriceBook::parse("tpu:spot:9.0").unwrap_err().to_string().contains("unknown"));
        assert!(PriceBook::parse("egress:spot:0.1").is_err());
        assert!(PriceBook::parse("cerebras:spot:1,cerebras:spot:2")
            .unwrap_err()
            .to_string()
            .contains("duplicate"));
    }

    #[test]
    fn spot_tier_rates() {
        let b = PriceBook::parse("cerebras:42.0,cerebras:spot:12.6,cluster:1.8").unwrap();
        // on-demand lookups never see the spot rate
        assert_eq!(b.rate_per_slot_hour("alcf#cerebras"), 42.0);
        assert_eq!(b.rate_per_slot_hour_tiered("alcf#cerebras", false), 42.0);
        // spot lookups read the discounted tier when priced...
        assert_eq!(b.rate_per_slot_hour_tiered("alcf#cerebras", true), 12.6);
        // ...and fall back to the on-demand rate when not
        assert_eq!(b.rate_per_slot_hour_tiered("alcf#cluster", true), 1.8);
        assert!((b.slot_dollars_tiered("alcf#cerebras", 3600.0, true) - 12.6).abs() < 1e-12);
        // the paper book discounts every class 70%
        let p = PriceBook::paper();
        for class in KNOWN_CLASSES {
            let ep = format!("x#{class}");
            let full = p.rate_per_slot_hour(&ep);
            let spot = p.rate_per_slot_hour_tiered(&ep, true);
            assert!((spot - full * 0.3).abs() < 1e-12, "{class}: {spot} vs {full}");
        }
    }

    #[test]
    fn class_extraction() {
        assert_eq!(PriceBook::class_of("alcf#cerebras"), "cerebras");
        assert_eq!(PriceBook::class_of("cerebras"), "cerebras");
        assert_eq!(PriceBook::class_of("a#b#c"), "b#c");
    }

    #[test]
    fn paper_book_prices_every_fabric_class() {
        let b = PriceBook::paper();
        for class in KNOWN_CLASSES {
            assert!(b.has_price(&format!("x#{class}")), "{class} unpriced");
        }
        assert!(b.egress_per_gb > 0.0);
        // the premium ordering the crossover study leans on
        assert!(b.rate_per_slot_hour("alcf#cerebras") > b.rate_per_slot_hour("slac#v100"));
    }
}
