//! Equations 1–5 and the crossover analysis behind Fig. 4.

use anyhow::{bail, Result};

/// All constants of the §4.2 instantiation, in microseconds per datum
/// unless noted. One datum = one 11x11 px, 16-bit Bragg-peak patch.
#[derive(Debug, Clone, Copy)]
pub struct CostParams {
    /// move one datum experiment -> data center (µs)
    pub c_move_us: f64,
    /// conventional analysis of one datum on the DC cluster (µs)
    pub c_analyze_us: f64,
    /// return one conventional result to the experiment (µs)
    pub c_return_us: f64,
    /// return one label produced during training-set labeling (µs)
    pub c_label_return_us: f64,
    /// ML-surrogate inference per datum at the edge (µs)
    pub c_estimate_us: f64,
    /// (re)training time on the DCAI system (µs)
    pub t_train_us: f64,
    /// trained-model transfer back to the edge (µs)
    pub t_model_move_us: f64,
    /// fraction of the dataset shipped for labeling + training
    pub p: f64,
}

impl CostParams {
    /// The exact constants of §4.2:
    /// * A: 2000 core·s / 800k peaks on 1024 cores -> 2.44 µs
    /// * E: 280 ms / 800k peaks -> 0.35 µs
    /// * move: 242 B patch at 1 GB/s -> 0.24 µs
    /// * label return: 8 B / datum -> 8e-3 µs
    /// * T: 19 s on Cerebras; model: 3 MB at 1 GB/s -> 3000 µs
    /// * p = 10 %
    pub fn paper() -> CostParams {
        CostParams {
            c_move_us: 0.24,
            c_analyze_us: 2.44,
            c_return_us: 8.0e-3,
            c_label_return_us: 8.0e-3,
            c_estimate_us: 0.35,
            t_train_us: 19.0e6,
            t_model_move_us: 3000.0,
            p: 0.10,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.p) {
            bail!("p must be in [0,1], got {}", self.p);
        }
        for (name, v) in [
            ("c_move_us", self.c_move_us),
            ("c_analyze_us", self.c_analyze_us),
            ("c_return_us", self.c_return_us),
            ("c_label_return_us", self.c_label_return_us),
            ("c_estimate_us", self.c_estimate_us),
            ("t_train_us", self.t_train_us),
            ("t_model_move_us", self.t_model_move_us),
        ] {
            if v < 0.0 || !v.is_finite() {
                bail!("{name} must be finite and non-negative, got {v}");
            }
        }
        Ok(())
    }

    /// Eq. 1/4 — conventional: move all N to the DC, analyze, return.
    pub fn f_conventional_us(&self, n: f64) -> f64 {
        n * (self.c_move_us + self.c_analyze_us + self.c_return_us)
    }

    /// Eq. 3/5 — ML surrogate: ship p·N, label, train, return model,
    /// estimate the remaining (1-p)·N at the edge.
    pub fn f_ml_us(&self, n: f64) -> f64 {
        self.p * n * (self.c_move_us + self.c_analyze_us + self.c_label_return_us)
            + self.t_train_us
            + self.t_model_move_us
            + (1.0 - self.p) * n * self.c_estimate_us
    }

    /// Eq. 2 — analysis fully at the experiment facility, given a local
    /// per-datum analysis cost (the paper leaves C(A_ex) free; a typical
    /// beamline workstation has ~64 cores vs the DC's 1024).
    pub fn f_local_us(&self, n: f64, c_analyze_local_us: f64) -> f64 {
        n * c_analyze_local_us
    }

    /// Closed-form crossover N* where f_ml == f_conventional.
    ///
    /// f_c - f_ml = N*[(1-p)(move+analyze) + return - p*label
    ///              - (1-p)*estimate] - T - model
    pub fn crossover(&self) -> Result<CrossoverReport> {
        self.validate()?;
        let per_datum_gain = (1.0 - self.p) * (self.c_move_us + self.c_analyze_us)
            + self.c_return_us
            - self.p * self.c_label_return_us
            - (1.0 - self.p) * self.c_estimate_us;
        if per_datum_gain <= 0.0 {
            bail!(
                "ML surrogate never wins: per-datum gain {per_datum_gain} µs <= 0"
            );
        }
        let n_star = (self.t_train_us + self.t_model_move_us) / per_datum_gain;
        Ok(CrossoverReport {
            n_star,
            per_datum_gain_us: per_datum_gain,
            fixed_cost_us: self.t_train_us + self.t_model_move_us,
        })
    }
}

/// Paper §7(3), future work: "the training process is mini-batch based
/// which can be started before getting all training samples, we can try
/// to partially overlap A and T in the workflow to shorten end-to-end
/// time." With labeling streaming at a fixed per-sample rate and
/// training consuming mini-batches, the pipelined makespan is the fill
/// time of the first batch plus the slower of the two stages.
pub fn overlapped_label_train_s(label_s: f64, train_s: f64, first_batch_label_s: f64) -> f64 {
    first_batch_label_s + label_s.max(train_s)
}

/// Where the ML path starts to win.
#[derive(Debug, Clone, Copy)]
pub struct CrossoverReport {
    /// dataset size above which f_ml < f_conventional
    pub n_star: f64,
    pub per_datum_gain_us: f64,
    pub fixed_cost_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants_reproduce_eq4_eq5() {
        let p = CostParams::paper();
        // Eq. 4 at N=1e6: 1e6 * (0.24+2.44+0.008) = 2.688e6 µs
        assert!((p.f_conventional_us(1e6) - 2.688e6).abs() < 1.0);
        // Eq. 5 at N=1e6:
        // 0.1e6*(0.24+2.44+0.008) + 19e6 + 3000 + 0.9e6*0.35 = 19.5868e6
        let f_ml = p.f_ml_us(1e6);
        assert!((f_ml - 19.5868e6).abs() < 1.0, "{f_ml}");
    }

    #[test]
    fn crossover_matches_fig4() {
        // Fig. 4: conventional wins only for small N; crossover ~ 9M peaks
        let report = CostParams::paper().crossover().unwrap();
        assert!(
            (8.0e6..10.0e6).contains(&report.n_star),
            "n* = {:.3e}",
            report.n_star
        );
        let p = CostParams::paper();
        // verify by evaluation on both sides
        assert!(p.f_conventional_us(report.n_star * 0.5) < p.f_ml_us(report.n_star * 0.5));
        assert!(p.f_conventional_us(report.n_star * 2.0) > p.f_ml_us(report.n_star * 2.0));
        // and numerically at n*
        let diff = p.f_conventional_us(report.n_star) - p.f_ml_us(report.n_star);
        assert!(diff.abs() / p.f_ml_us(report.n_star) < 1e-9);
    }

    #[test]
    fn ml_asymptotically_faster_by_analysis_ratio() {
        let p = CostParams::paper();
        let big = 1e12;
        let ratio = p.f_conventional_us(big) / p.f_ml_us(big);
        // per-datum: 2.688 vs 0.1*2.688 + 0.9*0.35 = 0.5838 -> ~4.6x
        assert!((4.0..5.0).contains(&ratio), "{ratio}");
    }

    #[test]
    fn p_sweep_monotone_in_fixed_regime() {
        // with more data shipped (higher p), the ML path costs more
        let mut last = 0.0;
        for p10 in 1..=9 {
            let mut c = CostParams::paper();
            c.p = p10 as f64 / 10.0;
            let v = c.f_ml_us(1e8);
            assert!(v > last, "p={} f_ml={v}", c.p);
            last = v;
        }
    }

    #[test]
    fn degenerate_params_rejected() {
        let mut c = CostParams::paper();
        c.p = 1.5;
        assert!(c.crossover().is_err());
        let mut c = CostParams::paper();
        c.c_estimate_us = 10.0; // estimator slower than analysis: never wins
        assert!(c.crossover().is_err());
        let mut c = CostParams::paper();
        c.t_train_us = -1.0;
        assert!(c.crossover().is_err());
    }

    #[test]
    fn overlap_bounds() {
        // pipelined makespan: never worse than serial, never better than
        // the slower stage alone
        for (a, t, fill) in [(10.0, 19.0, 0.5), (30.0, 19.0, 0.5), (5.0, 5.0, 0.1)] {
            let o = overlapped_label_train_s(a, t, fill);
            assert!(o <= a + t, "{o} > serial {a}+{t}");
            assert!(o >= a.max(t), "{o} < max stage");
        }
        // the paper's BraggNN case: labeling 10% of 2M peaks at 2.44 µs
        // (~0.5 s on the cluster) overlaps almost entirely with the 19 s
        // Cerebras training
        let label = 0.2e6 * 2.44e-6;
        let o = overlapped_label_train_s(label, 19.0, 0.01);
        assert!(o < label + 19.0 && (o - 19.0).abs() < 0.1, "{o}");
    }

    #[test]
    fn local_analysis_eq2() {
        let p = CostParams::paper();
        // 64-core local workstation: 2.5 ms/peak/core -> 39 µs/peak
        assert_eq!(p.f_local_us(1000.0, 39.0), 39_000.0);
    }
}
