//! The paper's analytical performance model (§4.1–4.2, Eqs. 1–5).
//!
//! Six basic operations over a datum `d`:
//!   **C**ollect, **S**imulate, **A**nalyze (conventional), **T**rain,
//!   **D**eploy, **E**stimate (ML surrogate inference),
//! plus data movement `a -d-> b`. Costs compose into the two strategies
//! compared in Fig. 4:
//!
//!   Eq. 4 (conventional):  f_c(N)  = N*(c_move + c_analyze + c_return)
//!   Eq. 5 (ML surrogate):  f_ml(N) = p*N*(c_move + c_analyze + c_label)
//!                                    + T_train + T_model_move
//!                                    + (1-p)*N*c_estimate
//!
//! `paper()` uses the exact §4.2 constants (BraggNN / HEDM on a 1024-core
//! cluster, 1 GB/s WAN, Cerebras 19 s training).
//!
//! `pricing` (DESIGN.md §11) adds the *dollar* axis the paper's
//! economics argument implies: a [`PriceBook`] maps endpoint classes to
//! $/slot-hour (plus $/GB WAN egress), which is what lets the campaign
//! layer's slot-time accounting (DESIGN.md §10) be expressed as
//! provisioned/used/waste dollars and per-tenant bills instead of
//! incomparable slot-hours.

pub mod eqs;
pub mod pricing;

pub use eqs::{overlapped_label_train_s, CostParams, CrossoverReport};
pub use pricing::PriceBook;
