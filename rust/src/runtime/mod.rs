//! Runtime layer: the xla-crate PJRT bridge (load + execute artifacts).
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`, with an executable cache and a host
//! `Tensor` type. Python never appears here; the artifacts are the only
//! interface to L2/L1.

pub mod client;
pub mod tensor;

pub use client::{Executable, Runtime};
pub use tensor::Tensor;
