//! PJRT CPU client wrapper: load HLO-text artifacts, compile once, cache,
//! execute. Adapted from /opt/xla-example/load_hlo (see README gotchas:
//! HLO *text* interchange, tuple-rooted entry computations).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::tensor::Tensor;

/// A compiled, loaded XLA executable plus ABI bookkeeping.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// artifact the executable came from (diagnostics)
    pub source: PathBuf,
    /// compile wallclock, recorded for EXPERIMENTS.md §Perf
    pub compile_secs: f64,
}

// SAFETY: PJRT CPU client objects are internally synchronized (the
// underlying TfrtCpuClient is thread-safe); the raw pointers in the xla
// crate wrappers are only non-Send because bindgen cannot know that. All
// mutation goes through the PJRT C API which locks internally.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    ///
    /// aot.py lowers every entry computation with `return_tuple=True`, so
    /// the single result buffer is a tuple literal we decompose here.
    pub fn run(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals (hot path: lets the caller reuse
    /// constant input literals across steps).
    pub fn run_literals(&self, args: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {:?}", self.source))?;
        let buf = outs
            .first()
            .and_then(|d| d.first())
            .context("executable produced no output buffer")?;
        let root = buf.to_literal_sync().context("fetching result literal")?;
        let parts = root.to_tuple().context("decomposing result tuple")?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Execute and return raw literals (for callers that feed outputs
    /// back in as the next step's inputs without host conversion).
    pub fn run_raw(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {:?}", self.source))?;
        let buf = outs
            .first()
            .and_then(|d| d.first())
            .context("executable produced no output buffer")?;
        let root = buf.to_literal_sync().context("fetching result literal")?;
        root.to_tuple().context("decomposing result tuple")
    }
}

/// Process-wide PJRT runtime with an executable cache.
///
/// Compilation of the train-step artifacts takes seconds; every consumer
/// (trainer, edge server, benches) shares one compiled instance per path.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

// SAFETY: see Executable — the PJRT CPU client is thread-safe.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Arc<Runtime>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
        }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by absolute path).
    pub fn load_hlo(&self, path: &Path) -> Result<Arc<Executable>> {
        let key = path
            .canonicalize()
            .with_context(|| format!("artifact not found: {path:?} (run `make artifacts`)"))?;
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let started = Instant::now();
        let path_str = key
            .to_str()
            .with_context(|| format!("non-utf8 path {key:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {key:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {key:?}"))?;
        let compiled = Arc::new(Executable {
            exe,
            source: key.clone(),
            compile_secs: started.elapsed().as_secs_f64(),
        });
        log::debug!(
            "compiled {:?} in {:.2}s",
            key.file_name().unwrap_or_default(),
            compiled.compile_secs
        );
        self.cache
            .lock()
            .unwrap()
            .insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Number of compiled executables currently cached.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// Verify an artifact ABI: arity errors surface at load, not mid-training.
pub fn check_arity(exe_args: usize, meta_args: usize, what: &str) -> Result<()> {
    if exe_args != meta_args {
        bail!("{what}: executable wants {exe_args} args, meta says {meta_args}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::default_artifacts_dir;

    #[test]
    fn pv_surface_executes_and_matches_formula() {
        let dir = default_artifacts_dir();
        if !dir.join("pv_meta.json").exists() {
            return; // artifacts not built
        }
        let meta = crate::models::PvMeta::load(&dir).unwrap();
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo(&meta.hlo_path()).unwrap();

        // params batch: first row is a centered symmetric peak
        let mut params = vec![0.0f32; meta.batch * 7];
        params[0..7].copy_from_slice(&[100.0, 5.0, 5.0, 1.5, 1.5, 0.4, 2.0]);
        for i in 1..meta.batch {
            params[i * 7..i * 7 + 7].copy_from_slice(&[1.0, 5.0, 5.0, 1.0, 1.0, 0.5, 0.0]);
        }
        let t = Tensor::new(vec![meta.batch, 7], params).unwrap();
        let out = exe.run(&[t]).unwrap();
        assert_eq!(out.len(), 1);
        let surf = &out[0];
        assert_eq!(surf.shape(), &[meta.batch, meta.height, meta.width]);
        // center pixel: amp*(eta*1 + (1-eta)*1) + bg = 102
        let center = surf.at(&[0, 5, 5]);
        assert!((center - 102.0).abs() < 1e-3, "center {center}");
        // symmetric peak: corners equal
        let c1 = surf.at(&[0, 0, 0]);
        let c2 = surf.at(&[0, 10, 10]);
        assert!((c1 - c2).abs() < 1e-4);
        // cached on second load
        assert!(Arc::ptr_eq(&exe, &rt.load_hlo(&meta.hlo_path()).unwrap()));
    }

    #[test]
    fn missing_artifact_is_actionable() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_hlo(Path::new("/nonexistent/foo.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
