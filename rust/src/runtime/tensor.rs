//! Host-side f32 tensor + conversions to/from XLA literals.
//!
//! Everything crossing the PJRT boundary in this system is f32 (the
//! train-step ABI flattens params/opt-state/batches to f32 tensors), so a
//! single concrete tensor type keeps the hot path free of dtype dispatch.

use anyhow::{bail, Context, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let elems: usize = shape.iter().product();
        if elems != data.len() {
            bail!(
                "shape {:?} needs {} elems, got {}",
                shape,
                elems,
                data.len()
            );
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let elems = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; elems],
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    pub fn byte_len(&self) -> usize {
        4 * self.data.len()
    }

    /// Scalar extraction (shape [] or [1]).
    pub fn item(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("item() on tensor with {} elems", self.data.len());
        }
        Ok(self.data[0])
    }

    /// Convert to an XLA literal (f32, row-major).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&self.data);
        lit.reshape(&dims)
            .with_context(|| format!("reshaping literal to {:?}", self.shape))
    }

    /// Convert back from an XLA literal, checking the element type.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.shape().context("literal shape")?;
        let dims: Vec<usize> = match &shape {
            xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
            _ => bail!("literal is not an array"),
        };
        let data: Vec<f32> = lit.to_vec().context("literal to_vec<f32>")?;
        Tensor::new(dims, data)
    }

    /// Flat offset for a multi-index (debug/test helper).
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {idx:?} out of {:?} at {i}", self.shape);
            off = off * dim + ix;
        }
        self.data[off]
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_len() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_and_item() {
        let t = Tensor::scalar(4.5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.item().unwrap(), 4.5);
        assert!(Tensor::zeros(vec![2]).item().is_err());
    }

    #[test]
    fn indexing_row_major() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literal_roundtrip() {
        let t = Tensor::scalar(7.0);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }
}
