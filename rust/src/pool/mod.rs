//! Chunked work-stealing thread pool for the crate's CPU hot paths
//! (DESIGN.md §1; methodology and measurements in EXPERIMENTS.md
//! §Perf).
//!
//! Dependency-free: std scoped threads + atomics, no channels. The three
//! hot paths — pseudo-Voigt batch fitting (`analysis::fitter`), dataset
//! generation (`data::bragg` / `data::cookiebox`), and real compute
//! fanned out from the flows/faas layer — all schedule through here, so
//! one knob (`XLOOP_THREADS`) governs the whole process.
//!
//! Scheduling model: the task index space `0..n` is split into one
//! contiguous range per worker, each with an atomic claim cursor. A
//! worker drains its own range with `fetch_add`, then *steals* from the
//! other ranges' cursors round-robin until every range is exhausted —
//! classic chunked self-scheduling with stealing, which keeps skewed
//! workloads (some peaks take 3x the LM iterations of others) balanced
//! without a global lock on the fast path.
//!
//! Determinism: task granularity is fixed by the *caller* (chunk
//! constants in the fitter / generators), never by the thread count, and
//! results are always returned in task order. With `XLOOP_THREADS=1`
//! (or `Pool::new(1)`) everything runs inline on the caller thread — the
//! deterministic single-thread mode tests pin down.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// A heterogeneous task for [`Pool::scope`] / [`scope`].
pub type ScopeTask<'env, R> = Box<dyn FnOnce() -> R + Send + 'env>;

/// Worker-count handle. Threads are scoped per call, so each `run_tasks`
/// pays (workers - 1) spawns plus a join — tens of microseconds per
/// thread. That is noise against the millisecond-scale batches the hot
/// paths submit, but callers with sub-millisecond work should batch it
/// up rather than fan out per item.
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
}

/// One worker's contiguous slice of the task index space.
struct Range {
    next: AtomicUsize,
    end: usize,
}

impl Pool {
    /// A pool with exactly `threads` workers (clamped to >= 1).
    pub fn new(threads: usize) -> Pool {
        Pool {
            threads: threads.max(1),
        }
    }

    /// Sized from `XLOOP_THREADS` if set, else `available_parallelism`.
    pub fn from_env() -> Pool {
        Pool::new(default_threads())
    }

    /// The process-wide pool (first use wins; `XLOOP_THREADS` is read
    /// once).
    pub fn global() -> &'static Pool {
        static GLOBAL: OnceLock<Pool> = OnceLock::new();
        GLOBAL.get_or_init(Pool::from_env)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when work runs inline on the caller thread (deterministic
    /// single-thread mode).
    pub fn is_serial(&self) -> bool {
        self.threads == 1
    }

    /// Run `work(i)` for every `i in 0..n`, work-stealing across the
    /// pool's workers. The caller thread participates, so `threads == 1`
    /// degenerates to a plain loop with no thread spawned at all.
    pub fn run_tasks<F: Fn(usize) + Sync>(&self, n: usize, work: F) {
        if n == 0 {
            return;
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            for i in 0..n {
                work(i);
            }
            return;
        }
        let ranges = split_ranges(n, workers);
        let ranges = &ranges;
        let work = &work;
        std::thread::scope(|s| {
            for w in 1..workers {
                s.spawn(move || drain(w, ranges, work));
            }
            drain(0, ranges, work);
        });
    }

    /// Map `0..n` through `f` in parallel; results come back **in task
    /// order** regardless of which worker ran what.
    pub fn map_tasks<U: Send, F: Fn(usize) -> U + Sync>(&self, n: usize, f: F) -> Vec<U> {
        let slots: Vec<Mutex<Option<U>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.run_tasks(n, |i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap()
                    .expect("pool task produced no value")
            })
            .collect()
    }

    /// Run a set of heterogeneous one-shot tasks to completion, returning
    /// their results in input order. The entry point engine stages fan
    /// out through (`flows`/`faas` re-expose it).
    pub fn scope<'env, R: Send>(&self, tasks: Vec<ScopeTask<'env, R>>) -> Vec<R> {
        let pending: Vec<Mutex<Option<ScopeTask<'env, R>>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let slots: Vec<Mutex<Option<R>>> = (0..pending.len()).map(|_| Mutex::new(None)).collect();
        self.run_tasks(pending.len(), |i| {
            let task = pending[i]
                .lock()
                .unwrap()
                .take()
                .expect("scope task claimed twice");
            *slots[i].lock().unwrap() = Some(task());
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap()
                    .expect("scope task produced no value")
            })
            .collect()
    }
}

/// Fan heterogeneous tasks out on the global pool (results in input
/// order).
pub fn scope<'env, R: Send>(tasks: Vec<ScopeTask<'env, R>>) -> Vec<R> {
    Pool::global().scope(tasks)
}

/// The global pool (convenience alias for `Pool::global()`).
pub fn global() -> &'static Pool {
    Pool::global()
}

/// Worker count from the environment: `XLOOP_THREADS` wins, else the
/// machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    parse_threads(std::env::var("XLOOP_THREADS").ok().as_deref()).unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

fn parse_threads(raw: Option<&str>) -> Option<usize> {
    raw?.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}

fn split_ranges(n: usize, workers: usize) -> Vec<Range> {
    let base = n / workers;
    let rem = n % workers;
    let mut start = 0;
    (0..workers)
        .map(|w| {
            let len = base + usize::from(w < rem);
            let r = Range {
                next: AtomicUsize::new(start),
                end: start + len,
            };
            start += len;
            r
        })
        .collect()
}

/// Worker loop: drain own range, then steal from the others until no
/// range has work left. `fetch_add` hands out each index exactly once;
/// overshooting a drained range is harmless (cursors only grow).
fn drain<F: Fn(usize) + Sync>(me: usize, ranges: &[Range], work: &F) {
    loop {
        let i = ranges[me].next.fetch_add(1, Ordering::Relaxed);
        if i >= ranges[me].end {
            break;
        }
        work(i);
    }
    let workers = ranges.len();
    loop {
        let mut stole = false;
        for off in 1..workers {
            let victim = &ranges[(me + off) % workers];
            if victim.next.load(Ordering::Relaxed) >= victim.end {
                continue;
            }
            let i = victim.next.fetch_add(1, Ordering::Relaxed);
            if i < victim.end {
                work(i);
                stole = true;
            }
        }
        if !stole {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_task_runs_exactly_once() {
        for threads in [1, 2, 4, 7] {
            for n in [0, 1, 5, 64, 257] {
                let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                Pool::new(threads).run_tasks(n, |i| {
                    counts[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, c) in counts.iter().enumerate() {
                    assert_eq!(c.load(Ordering::Relaxed), 1, "threads={threads} task {i}");
                }
            }
        }
    }

    #[test]
    fn map_tasks_preserves_order() {
        let out = Pool::new(4).map_tasks(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn skewed_workload_is_stolen() {
        // all the heavy work lands in worker 0's initial range; with
        // stealing the others must pick some of it up
        let pool = Pool::new(4);
        let done = AtomicU64::new(0);
        pool.run_tasks(64, |i| {
            // tasks 0..16 are ~100x the others
            let spins: u64 = if i < 16 { 20_000 } else { 200 };
            let mut acc = 0u64;
            for k in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            done.fetch_add(std::hint::black_box(acc) | 1, Ordering::Relaxed);
        });
        assert_ne!(done.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn single_thread_mode_runs_inline() {
        let caller = std::thread::current().id();
        let ids = Pool::new(1).map_tasks(8, |_| std::thread::current().id());
        assert!(ids.iter().all(|&id| id == caller));
    }

    #[test]
    fn scope_runs_heterogeneous_tasks_in_order() {
        let base = 10usize;
        let tasks: Vec<ScopeTask<usize>> = (0..20)
            .map(|i| Box::new(move || base + i * i) as ScopeTask<usize>)
            .collect();
        let out = Pool::new(3).scope(tasks);
        assert_eq!(out, (0..20).map(|i| 10 + i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scope_can_borrow_the_environment() {
        let data = vec![1.0f64, 2.0, 3.0];
        let slice = data.as_slice();
        let out = Pool::new(2).scope(vec![
            Box::new(move || slice.iter().sum::<f64>()) as ScopeTask<f64>,
            Box::new(move || slice.iter().product::<f64>()) as ScopeTask<f64>,
        ]);
        assert_eq!(out, vec![6.0, 6.0]);
    }

    #[test]
    fn thread_override_parsing() {
        assert_eq!(parse_threads(None), None);
        assert_eq!(parse_threads(Some("")), None);
        assert_eq!(parse_threads(Some("0")), None);
        assert_eq!(parse_threads(Some("1")), Some(1));
        assert_eq!(parse_threads(Some(" 8 ")), Some(8));
        assert_eq!(parse_threads(Some("lots")), None);
    }

    #[test]
    fn ranges_cover_the_index_space() {
        for n in [1usize, 2, 7, 64, 101] {
            for w in 1..=n.min(9) {
                let ranges = split_ranges(n, w);
                let mut covered = 0;
                let mut expect_start = 0;
                for r in &ranges {
                    assert_eq!(r.next.load(Ordering::Relaxed), expect_start);
                    covered += r.end - r.next.load(Ordering::Relaxed);
                    expect_start = r.end;
                }
                assert_eq!(covered, n, "n={n} w={w}");
                assert_eq!(ranges.last().unwrap().end, n);
            }
        }
    }
}
