//! Globus-Auth analog: tokens, scopes, and per-action authentication.
//!
//! The paper (§3): "Globus Auth is used to authenticate all interactions
//! with Action Providers, Actions and Flows." Every flows-engine action
//! validates a token against the provider's required scope; validation
//! costs virtual time (token introspection is a WAN round trip when the
//! authority is remote).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::simnet::VClock;

/// An issued bearer token.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TokenId(pub u64);

#[derive(Debug, Clone)]
pub struct Token {
    pub id: TokenId,
    pub subject: String,
    pub scopes: Vec<String>,
    /// absolute virtual expiry time
    pub expires_vt: f64,
}

/// Token issuing + validation service.
#[derive(Debug)]
pub struct AuthService {
    tokens: BTreeMap<TokenId, Token>,
    revoked: Vec<TokenId>,
    next_id: u64,
    /// introspection latency charged per validation
    pub introspection_s: f64,
    /// validations performed (metrics)
    pub validations: u64,
}

impl Default for AuthService {
    fn default() -> Self {
        AuthService {
            tokens: BTreeMap::new(),
            revoked: Vec::new(),
            next_id: 1,
            introspection_s: 0.05,
            validations: 0,
        }
    }
}

impl AuthService {
    pub fn new() -> Self {
        Self::default()
    }

    /// Issue a token for a subject with the given scopes and lifetime.
    pub fn issue(
        &mut self,
        clock: &VClock,
        subject: &str,
        scopes: &[&str],
        ttl_s: f64,
    ) -> Token {
        let token = Token {
            id: TokenId(self.next_id),
            subject: subject.to_string(),
            scopes: scopes.iter().map(|s| s.to_string()).collect(),
            expires_vt: clock.now() + ttl_s,
        };
        self.next_id += 1;
        self.tokens.insert(token.id, token.clone());
        token
    }

    /// Validate a token for a scope, charging introspection latency.
    pub fn validate(&mut self, clock: &mut VClock, token: &TokenId, scope: &str) -> Result<()> {
        clock.advance(self.introspection_s);
        self.check(clock.now(), token, scope)
    }

    /// Validate at an explicit virtual instant without touching a clock —
    /// the flow engine charges `introspection_s` on the action timeline
    /// itself and checks at the post-introspection time.
    pub fn check(&mut self, now: f64, token: &TokenId, scope: &str) -> Result<()> {
        self.validations += 1;
        if self.revoked.contains(token) {
            bail!("token {token:?} revoked");
        }
        let Some(t) = self.tokens.get(token) else {
            bail!("unknown token {token:?}");
        };
        if now > t.expires_vt {
            bail!("token {token:?} expired");
        }
        if !t.scopes.iter().any(|s| s == scope) {
            bail!(
                "token {token:?} (subject `{}`) lacks scope `{scope}` (has: {})",
                t.subject,
                t.scopes.join(", ")
            );
        }
        Ok(())
    }

    pub fn revoke(&mut self, token: TokenId) {
        self.revoked.push(token);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_validate_ok() {
        let mut clock = VClock::new();
        let mut auth = AuthService::new();
        let t = auth.issue(&clock, "scientist", &["flows:run", "transfer"], 3600.0);
        assert!(auth.validate(&mut clock, &t.id, "flows:run").is_ok());
        assert!(auth.validate(&mut clock, &t.id, "transfer").is_ok());
        assert_eq!(auth.validations, 2);
        assert!(clock.now() > 0.0); // introspection charged
    }

    #[test]
    fn missing_scope_rejected() {
        let mut clock = VClock::new();
        let mut auth = AuthService::new();
        let t = auth.issue(&clock, "s", &["transfer"], 3600.0);
        let err = auth.validate(&mut clock, &t.id, "compute").unwrap_err();
        assert!(err.to_string().contains("lacks scope"), "{err}");
    }

    #[test]
    fn expiry_enforced() {
        let mut clock = VClock::new();
        let mut auth = AuthService::new();
        let t = auth.issue(&clock, "s", &["x"], 10.0);
        clock.advance(20.0);
        assert!(auth.validate(&mut clock, &t.id, "x").is_err());
    }

    #[test]
    fn revocation_enforced() {
        let mut clock = VClock::new();
        let mut auth = AuthService::new();
        let t = auth.issue(&clock, "s", &["x"], 3600.0);
        auth.revoke(t.id);
        assert!(auth.validate(&mut clock, &t.id, "x").is_err());
    }

    #[test]
    fn unknown_token_rejected() {
        let mut clock = VClock::new();
        let mut auth = AuthService::new();
        assert!(auth.validate(&mut clock, &TokenId(99), "x").is_err());
    }

    #[test]
    fn check_validates_at_explicit_instant() {
        let clock = VClock::new();
        let mut auth = AuthService::new();
        let t = auth.issue(&clock, "s", &["x"], 10.0);
        assert!(auth.check(5.0, &t.id, "x").is_ok());
        assert!(auth.check(20.0, &t.id, "x").is_err()); // expired by then
        assert_eq!(auth.validations, 2);
    }
}
