//! # xloop
//!
//! Production-quality reproduction of *"Bridging Data Center AI Systems
//! with Edge Computing for Actionable Information Retrieval"* (Liu et
//! al., XLOOP @ SC 2021) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: a
//!   geographically distributed workflow fabric (flows engine, federated
//!   FaaS, WAN transfer service) that retrains DNNs on remote
//!   data-center AI systems and deploys them to edge hosts. A
//!   discrete-event scheduler core (`simnet::des`, DESIGN.md §3) lets N
//!   tenants' flows interleave over the shared fabric —
//!   `workflow::campaign` studies turnaround under load.
//! * **L2/L1 (python/, build-time only)** — BraggNN and CookieNetAE in
//!   JAX on Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **runtime** — PJRT CPU bridge executing those artifacts from rust.
//!
//! See the top-level README.md for the architecture map and the
//! campaign CLI cookbook, DESIGN.md for the system inventory and
//! experiment index (doc comments cite it as `DESIGN.md §N`), and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod accel;
pub mod analysis;
pub mod costmodel;
pub mod data;
pub mod edge;
pub mod auth;
pub mod config;
pub mod faas;
pub mod flows;
pub mod models;
pub mod pool;
pub mod simnet;
pub mod training;
pub mod transfer;
pub mod runtime;
pub mod util;
pub mod workflow;
