//! Edge host: model deployment + streaming inference (the paper's
//! operations **D** and **E**).
//!
//! "Once the DNN is trained, we use another set of AI accelerators
//! specialized for model inference, called edge-AI, to process experiment
//! data near the data acquisition in real-time" (§2). The edge host keeps
//! the currently deployed model version, answers batched inference with
//! *real* PJRT executions, and reports both real latency statistics and
//! the modeled edge-device virtual time.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::accel::AcceleratorModel;
use crate::data::Dataset;
use crate::models::ModelMeta;
use crate::runtime::{Executable, Runtime, Tensor};
use crate::util::stats::{percentile, Summary};

/// A model deployed on the edge.
pub struct DeployedModel {
    pub meta: ModelMeta,
    pub params: Vec<Tensor>,
    pub version: u32,
    exe: Arc<Executable>,
}

/// The edge inference host co-located with the experiment.
pub struct EdgeHost {
    pub name: String,
    rt: Arc<Runtime>,
    deployed: Option<DeployedModel>,
    versions: u32,
    /// virtual-time model of the edge accelerator
    pub device: AcceleratorModel,
    /// closed-loop hot-swap log (DESIGN.md §16): `(virtual time, model)`
    /// per retrain-completion version bump, in swap order
    swaps: Vec<(f64, String)>,
}

/// Streaming-serving outcome.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub model: String,
    pub version: u32,
    pub batches: u64,
    pub samples: u64,
    /// real per-batch latency (s): mean/p50/p99
    pub real_mean_s: f64,
    pub real_p50_s: f64,
    pub real_p99_s: f64,
    /// real end-to-end throughput (samples/s)
    pub real_throughput: f64,
    /// modeled edge-device time for the same work (s)
    pub virtual_total_s: f64,
    /// mean output finite-ness check passed
    pub outputs_finite: bool,
}

/// A lightweight edge inference device (Jetson/edge-GPU class).
pub fn edge_device() -> AcceleratorModel {
    AcceleratorModel {
        name: "edge-gpu".into(),
        peak_flops: 10.0e12,
        efficiency: 0.25,
        per_step_overhead_s: 0.8e-3,
        data_parallel: 1,
        allreduce: None,
        setup_s: 2.0,
    }
}

impl EdgeHost {
    pub fn new(name: impl Into<String>, rt: Arc<Runtime>) -> EdgeHost {
        EdgeHost {
            name: name.into(),
            rt,
            deployed: None,
            versions: 0,
            device: edge_device(),
            swaps: Vec::new(),
        }
    }

    /// Record a closed-loop model hot-swap at virtual time `vt`
    /// (DESIGN.md §16): the retrained `model` replaces the serving
    /// version the moment its flow completes. Virtual-time
    /// bookkeeping only — campaigns run `TrainingMode::VirtualOnly`,
    /// so there are no real params to [`EdgeHost::deploy`]; the
    /// version counter still bumps so the swap is observable.
    pub fn note_swap(&mut self, vt: f64, model: &str) -> u32 {
        self.versions += 1;
        self.swaps.push((vt, model.to_string()));
        self.versions
    }

    /// The closed-loop hot-swap log, in virtual-time order.
    pub fn swaps(&self) -> &[(f64, String)] {
        &self.swaps
    }

    /// Install a trained model (compiles the inference artifact once).
    pub fn deploy(&mut self, meta: &ModelMeta, params: Vec<Tensor>) -> Result<u32> {
        if params.len() != meta.params.len() {
            bail!(
                "deploy `{}`: {} tensors, expected {}",
                meta.name,
                params.len(),
                meta.params.len()
            );
        }
        for (spec, t) in meta.params.iter().zip(&params) {
            if t.shape() != spec.shape.as_slice() {
                bail!("deploy `{}`: `{}` shape mismatch", meta.name, spec.name);
            }
            if !t.is_finite() {
                bail!("deploy `{}`: `{}` has non-finite weights", meta.name, spec.name);
            }
        }
        let exe = self.rt.load_hlo(&meta.infer_hlo_path())?;
        self.versions += 1;
        self.deployed = Some(DeployedModel {
            meta: meta.clone(),
            params,
            version: self.versions,
            exe,
        });
        log::info!(
            "edge `{}`: deployed {} v{}",
            self.name,
            meta.name,
            self.versions
        );
        Ok(self.versions)
    }

    pub fn deployed(&self) -> Option<&DeployedModel> {
        self.deployed.as_ref()
    }

    /// Real batched inference on the deployed model.
    pub fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
        let dep = self
            .deployed
            .as_ref()
            .context("no model deployed on this edge host")?;
        let want: Vec<usize> = std::iter::once(dep.meta.infer_batch)
            .chain(dep.meta.input_shape.iter().copied())
            .collect();
        if x.shape() != want.as_slice() {
            bail!("infer batch shape {:?} != {:?}", x.shape(), want);
        }
        let mut args: Vec<xla::Literal> = dep
            .params
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        args.push(x.to_literal()?);
        let mut out = dep.exe.run_literals(&args)?;
        if out.len() != 1 {
            bail!("inference returned {} outputs", out.len());
        }
        Ok(out.remove(0))
    }

    /// Serve `n_batches` from a dataset stream, measuring real latency and
    /// modeling edge-device virtual time.
    pub fn serve_stream(&self, dataset: &Dataset, n_batches: u64) -> Result<ServeReport> {
        let dep = self
            .deployed
            .as_ref()
            .context("no model deployed on this edge host")?;
        let b = dep.meta.infer_batch;
        let mut latencies = Vec::with_capacity(n_batches as usize);
        let mut summary = Summary::new();
        let mut finite = true;
        let started = std::time::Instant::now();
        for i in 0..n_batches {
            let idx: Vec<usize> = (0..b).map(|k| (i as usize * b + k) % dataset.n).collect();
            let (x, _) = dataset.gather_batch(&idx)?;
            let t0 = std::time::Instant::now();
            let out = self.infer_batch(&x)?;
            let dt = t0.elapsed().as_secs_f64();
            latencies.push(dt);
            summary.add(dt);
            finite &= out.is_finite();
        }
        let total = started.elapsed().as_secs_f64();
        let flops_per_batch = dep.meta.fwd_flops_per_sample * b as f64;
        let virtual_total_s = n_batches as f64 * self.device.infer_time(flops_per_batch);
        Ok(ServeReport {
            model: dep.meta.name.clone(),
            version: dep.version,
            batches: n_batches,
            samples: n_batches * b as u64,
            real_mean_s: summary.mean(),
            real_p50_s: percentile(&latencies, 50.0),
            real_p99_s: percentile(&latencies, 99.0),
            real_throughput: (n_batches * b as u64) as f64 / total,
            virtual_total_s,
            outputs_finite: finite,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::BraggConfig;
    use crate::models::{default_artifacts_dir, ModelMeta};
    use crate::training::TrainState;

    fn setup() -> Option<(EdgeHost, ModelMeta)> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let meta = ModelMeta::load(&dir, "braggnn").unwrap();
        let rt = Runtime::cpu().unwrap();
        Some((EdgeHost::new("slac-edge", rt), meta))
    }

    #[test]
    fn deploy_and_infer() {
        let Some((mut edge, meta)) = setup() else { return };
        assert!(edge.infer_batch(&Tensor::zeros(vec![1])).is_err()); // nothing deployed
        let params = TrainState::init(&meta).unwrap().params;
        let v = edge.deploy(&meta, params).unwrap();
        assert_eq!(v, 1);
        let x = Tensor::zeros(
            std::iter::once(meta.infer_batch)
                .chain(meta.input_shape.iter().copied())
                .collect(),
        );
        let out = edge.infer_batch(&x).unwrap();
        assert_eq!(out.shape(), &[meta.infer_batch, 2]);
        assert!(out.is_finite());
    }

    #[test]
    fn redeploy_bumps_version() {
        let Some((mut edge, meta)) = setup() else { return };
        let params = TrainState::init(&meta).unwrap().params;
        assert_eq!(edge.deploy(&meta, params.clone()).unwrap(), 1);
        assert_eq!(edge.deploy(&meta, params).unwrap(), 2);
    }

    #[test]
    fn deploy_rejects_bad_params() {
        let Some((mut edge, meta)) = setup() else { return };
        let mut params = TrainState::init(&meta).unwrap().params;
        params.pop();
        assert!(edge.deploy(&meta, params).is_err());
        let mut params = TrainState::init(&meta).unwrap().params;
        params[0].data_mut()[0] = f32::NAN;
        assert!(edge.deploy(&meta, params).is_err());
    }

    #[test]
    fn note_swap_bumps_versions_and_logs_in_order() {
        let Ok(rt) = Runtime::cpu() else { return };
        let mut edge = EdgeHost::new("slac-edge", rt);
        assert!(edge.swaps().is_empty());
        assert_eq!(edge.note_swap(120.5, "braggnn"), 1);
        assert_eq!(edge.note_swap(380.0, "cookienetae"), 2);
        assert_eq!(
            edge.swaps(),
            &[(120.5, "braggnn".to_string()), (380.0, "cookienetae".to_string())]
        );
    }

    #[test]
    fn serve_stream_reports() {
        let Some((mut edge, meta)) = setup() else { return };
        let params = TrainState::init(&meta).unwrap().params;
        edge.deploy(&meta, params).unwrap();
        let ds = crate::data::bragg::generate(&BraggConfig::default(), 600, 2).unwrap();
        let rep = edge.serve_stream(&ds, 5).unwrap();
        assert_eq!(rep.batches, 5);
        assert_eq!(rep.samples, 5 * meta.infer_batch as u64);
        assert!(rep.outputs_finite);
        assert!(rep.real_throughput > 0.0);
        assert!(rep.real_p99_s >= rep.real_p50_s);
        assert!(rep.virtual_total_s > 0.0);
    }
}
