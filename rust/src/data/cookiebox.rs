//! Synthetic CookieBox eToF data (paper §5.2).
//!
//! The CookieBox is "an angular array of sixteen electron Time-of-Flight
//! spectrometers"; CookieNetAE maps an image of 16 empirical energy
//! histograms (128 x 1 eV bins per channel, sparse when few electrons are
//! detected) to the true energy-angle probability density.
//!
//! The generator follows that physics shape: a per-shot ground-truth pdf
//! (two spectral lines whose center sweeps sinusoidally over the 16
//! angular channels — the circular-polarization streaking signature),
//! from which a small number of electrons is Poisson-sampled into the
//! input histogram. Input = sparse histogram, target = true pdf.

use anyhow::Result;

use super::container::Dataset;
use crate::pool::Pool;
use crate::util::Rng;

pub const CHANNELS: usize = 16;
pub const BINS: usize = 128;

/// Shots per generation chunk, each with its own RNG stream (fixed, so
/// datasets are thread-count independent — same scheme as `bragg`).
pub const GEN_CHUNK: usize = 64;

#[derive(Debug, Clone)]
pub struct CookieConfig {
    /// mean detected electrons per channel (low = hard, as in the paper)
    pub electrons_per_channel: f64,
    /// energy-line width range (bins)
    pub line_width: (f64, f64),
    /// sweep amplitude of the line center across channels (bins)
    pub streak_amplitude: (f64, f64),
}

impl Default for CookieConfig {
    fn default() -> Self {
        CookieConfig {
            electrons_per_channel: 25.0,
            line_width: (2.0, 6.0),
            streak_amplitude: (5.0, 20.0),
        }
    }
}

/// Ground-truth pdf for one shot: [CHANNELS * BINS], each channel
/// normalized to peak 1 (ReLU-friendly regression target).
fn shot_pdf(cfg: &CookieConfig, rng: &mut Rng) -> Vec<f32> {
    let c1 = rng.uniform(30.0, 90.0);
    let c2 = c1 + rng.uniform(15.0, 35.0);
    let w1 = rng.uniform(cfg.line_width.0, cfg.line_width.1);
    let w2 = rng.uniform(cfg.line_width.0, cfg.line_width.1);
    let a2 = rng.uniform(0.3, 1.0);
    let streak = rng.uniform(cfg.streak_amplitude.0, cfg.streak_amplitude.1);
    let phase = rng.uniform(0.0, 2.0 * std::f64::consts::PI);

    let mut pdf = vec![0.0f32; CHANNELS * BINS];
    for ch in 0..CHANNELS {
        let theta = 2.0 * std::f64::consts::PI * ch as f64 / CHANNELS as f64 + phase;
        let shift = streak * theta.cos();
        let m1 = c1 + shift;
        let m2 = c2 + shift;
        let mut peak = 0.0f64;
        let mut row = [0.0f64; BINS];
        for (b, slot) in row.iter_mut().enumerate() {
            let e = b as f64;
            let g1 = (-0.5 * ((e - m1) / w1).powi(2)).exp();
            let g2 = a2 * (-0.5 * ((e - m2) / w2).powi(2)).exp();
            *slot = g1 + g2;
            peak = peak.max(*slot);
        }
        if peak > 0.0 {
            for (b, &v) in row.iter().enumerate() {
                pdf[ch * BINS + b] = (v / peak) as f32;
            }
        }
    }
    pdf
}

/// Poisson-sample an empirical histogram from a pdf, normalized to its
/// own peak (what the detector + binning pipeline produces).
fn sample_histogram(pdf: &[f32], electrons: f64, rng: &mut Rng) -> Vec<f32> {
    let mut hist = vec![0.0f32; pdf.len()];
    for ch in 0..CHANNELS {
        let row = &pdf[ch * BINS..(ch + 1) * BINS];
        let total: f32 = row.iter().sum();
        if total <= 0.0 {
            continue;
        }
        let mut peak = 0.0f32;
        for b in 0..BINS {
            let lambda = electrons * (row[b] / total) as f64;
            let c = rng.poisson(lambda) as f32;
            hist[ch * BINS + b] = c;
            peak = peak.max(c);
        }
        if peak > 0.0 {
            for b in 0..BINS {
                hist[ch * BINS + b] /= peak;
            }
        }
    }
    hist
}

/// Generate a CookieNetAE dataset: x = sparse histograms, y = true pdfs,
/// both [n, 16, 128, 1]. Runs on the process-wide pool.
pub fn generate(cfg: &CookieConfig, n: usize, seed: u64) -> Result<Dataset> {
    generate_with_pool(Pool::global(), cfg, n, seed)
}

/// Generate on an explicit pool: chunk seeds are drawn serially from the
/// root stream, then each `GEN_CHUNK`-shot chunk simulates with its own
/// substream — identical output for any worker count.
pub fn generate_with_pool(pool: &Pool, cfg: &CookieConfig, n: usize, seed: u64) -> Result<Dataset> {
    let mut rng = Rng::new(seed);
    let n_chunks = n.div_ceil(GEN_CHUNK);
    let seeds: Vec<u64> = (0..n_chunks).map(|_| rng.next_u64()).collect();
    let chunks: Vec<(Vec<f32>, Vec<f32>)> = pool.map_tasks(n_chunks, |ci| {
        let lo = ci * GEN_CHUNK;
        let hi = ((ci + 1) * GEN_CHUNK).min(n);
        let mut crng = Rng::new(seeds[ci]);
        let mut cx = Vec::with_capacity((hi - lo) * CHANNELS * BINS);
        let mut cy = Vec::with_capacity((hi - lo) * CHANNELS * BINS);
        for _ in lo..hi {
            let pdf = shot_pdf(cfg, &mut crng);
            let hist = sample_histogram(&pdf, cfg.electrons_per_channel, &mut crng);
            cx.extend_from_slice(&hist);
            cy.extend_from_slice(&pdf);
        }
        (cx, cy)
    });
    let mut x = Vec::with_capacity(n * CHANNELS * BINS);
    let mut y = Vec::with_capacity(n * CHANNELS * BINS);
    for (cx, cy) in chunks {
        x.extend_from_slice(&cx);
        y.extend_from_slice(&cy);
    }
    Dataset::new(
        format!("cookiebox-{n}"),
        vec![CHANNELS, BINS, 1],
        vec![CHANNELS, BINS, 1],
        x,
        y,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let d = generate(&CookieConfig::default(), 4, 1).unwrap();
        assert_eq!(d.n, 4);
        assert_eq!(d.input_shape, vec![16, 128, 1]);
        assert_eq!(d.target_shape, vec![16, 128, 1]);
        for v in d.x.iter().chain(&d.y) {
            assert!((0.0..=1.0).contains(v), "{v}");
        }
    }

    #[test]
    fn target_rows_peak_at_one() {
        let d = generate(&CookieConfig::default(), 2, 2).unwrap();
        for s in 0..d.n {
            for ch in 0..CHANNELS {
                let off = s * CHANNELS * BINS + ch * BINS;
                let peak = d.y[off..off + BINS].iter().cloned().fold(0.0f32, f32::max);
                assert!((peak - 1.0).abs() < 1e-6, "sample {s} ch {ch}: {peak}");
            }
        }
    }

    #[test]
    fn histogram_is_sparser_than_pdf() {
        let d = generate(&CookieConfig::default(), 4, 3).unwrap();
        let nz_x = d.x.iter().filter(|&&v| v > 0.0).count();
        let nz_y = d.y.iter().filter(|&&v| v > 0.01).count();
        assert!(
            nz_x < nz_y,
            "histogram ({nz_x} nonzero) should be sparser than pdf ({nz_y})"
        );
    }

    #[test]
    fn streaking_moves_lines_across_channels() {
        // the per-channel argmax must not be constant (circular
        // polarization sweeps the energy center)
        let d = generate(&CookieConfig::default(), 3, 4).unwrap();
        for s in 0..d.n {
            let mut argmaxes = vec![];
            for ch in 0..CHANNELS {
                let off = s * CHANNELS * BINS + ch * BINS;
                let row = &d.y[off..off + BINS];
                let am = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                argmaxes.push(am);
            }
            let min = *argmaxes.iter().min().unwrap();
            let max = *argmaxes.iter().max().unwrap();
            assert!(max - min >= 4, "no streaking: {argmaxes:?}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&CookieConfig::default(), 2, 11).unwrap();
        let b = generate(&CookieConfig::default(), 2, 11).unwrap();
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn reproducible_across_thread_counts() {
        // 130 shots spans three GEN_CHUNK streams
        let cfg = CookieConfig::default();
        let a = generate_with_pool(&Pool::new(1), &cfg, 130, 17).unwrap();
        for threads in [2, 5] {
            let b = generate_with_pool(&Pool::new(threads), &cfg, 130, 17).unwrap();
            assert_eq!(a.x, b.x, "{threads} threads changed the histograms");
            assert_eq!(a.y, b.y, "{threads} threads changed the pdfs");
        }
    }
}
