//! In-memory supervised dataset + batch iteration.

use anyhow::{bail, Result};

use crate::runtime::Tensor;
use crate::util::Rng;

/// A supervised dataset of flattened f32 samples.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    /// per-sample input shape (e.g. [11, 11, 1])
    pub input_shape: Vec<usize>,
    /// per-sample target shape (e.g. [2])
    pub target_shape: Vec<usize>,
    /// row-major [n, input_shape...]
    pub x: Vec<f32>,
    /// row-major [n, target_shape...]
    pub y: Vec<f32>,
    pub n: usize,
    /// bytes of one sample on the wire (detector pixels are 16-bit)
    pub wire_sample_bytes: usize,
}

impl Dataset {
    pub fn new(
        name: impl Into<String>,
        input_shape: Vec<usize>,
        target_shape: Vec<usize>,
        x: Vec<f32>,
        y: Vec<f32>,
    ) -> Result<Dataset> {
        let in_elems: usize = input_shape.iter().product();
        let out_elems: usize = target_shape.iter().product();
        if in_elems == 0 || x.len() % in_elems != 0 {
            bail!("x length {} not a multiple of sample size {in_elems}", x.len());
        }
        let n = x.len() / in_elems;
        if y.len() != n * out_elems {
            bail!("y length {} != {} samples x {out_elems}", y.len(), n);
        }
        let wire_sample_bytes = 2 * in_elems + 4 * out_elems;
        Ok(Dataset {
            name: name.into(),
            input_shape,
            target_shape,
            x,
            y,
            n,
            wire_sample_bytes,
        })
    }

    pub fn in_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn out_elems(&self) -> usize {
        self.target_shape.iter().product()
    }

    /// Total wire size (what the transfer service moves).
    pub fn wire_bytes(&self) -> u64 {
        (self.n * self.wire_sample_bytes) as u64
    }

    /// Build batch tensors from explicit sample indices (wraps around).
    pub fn gather_batch(&self, indices: &[usize]) -> Result<(Tensor, Tensor)> {
        let ie = self.in_elems();
        let oe = self.out_elems();
        let b = indices.len();
        let mut bx = Vec::with_capacity(b * ie);
        let mut by = Vec::with_capacity(b * oe);
        for &raw in indices {
            let i = raw % self.n;
            bx.extend_from_slice(&self.x[i * ie..(i + 1) * ie]);
            by.extend_from_slice(&self.y[i * oe..(i + 1) * oe]);
        }
        let mut xs = vec![b];
        xs.extend(&self.input_shape);
        let mut ys = vec![b];
        ys.extend(&self.target_shape);
        Ok((Tensor::new(xs, bx)?, Tensor::new(ys, by)?))
    }

    /// Split into (train, validation) at a fraction.
    pub fn split(&self, train_frac: f64) -> Result<(Dataset, Dataset)> {
        if !(0.0..1.0).contains(&train_frac) || self.n < 2 {
            bail!("bad split {train_frac} of {} samples", self.n);
        }
        let k = ((self.n as f64 * train_frac) as usize).clamp(1, self.n - 1);
        let ie = self.in_elems();
        let oe = self.out_elems();
        let a = Dataset::new(
            format!("{}-train", self.name),
            self.input_shape.clone(),
            self.target_shape.clone(),
            self.x[..k * ie].to_vec(),
            self.y[..k * oe].to_vec(),
        )?;
        let b = Dataset::new(
            format!("{}-val", self.name),
            self.input_shape.clone(),
            self.target_shape.clone(),
            self.x[k * ie..].to_vec(),
            self.y[k * oe..].to_vec(),
        )?;
        Ok((a, b))
    }
}

/// Shuffled epoch-based batch index iterator.
#[derive(Debug)]
pub struct BatchIter {
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Rng,
}

impl BatchIter {
    pub fn new(n: usize, batch: usize, seed: u64) -> BatchIter {
        assert!(n > 0 && batch > 0);
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        BatchIter {
            order,
            cursor: 0,
            batch,
            rng,
        }
    }

    /// Next batch of indices (reshuffles each epoch; wraps the tail).
    pub fn next_batch(&mut self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(
            "toy",
            vec![2, 2],
            vec![1],
            (0..40).map(|v| v as f32).collect(), // 10 samples of 4
            (0..10).map(|v| v as f32).collect(),
        )
        .unwrap()
    }

    #[test]
    fn shapes_and_sizes() {
        let d = toy();
        assert_eq!(d.n, 10);
        assert_eq!(d.wire_sample_bytes, 2 * 4 + 4);
        assert_eq!(d.wire_bytes(), 120);
    }

    #[test]
    fn gather_batch_layout() {
        let d = toy();
        let (x, y) = d.gather_batch(&[2, 0]).unwrap();
        assert_eq!(x.shape(), &[2, 2, 2]);
        assert_eq!(&x.data()[..4], &[8.0, 9.0, 10.0, 11.0]); // sample 2
        assert_eq!(y.data(), &[2.0, 0.0]);
    }

    #[test]
    fn split_preserves_counts() {
        let d = toy();
        let (a, b) = d.split(0.8).unwrap();
        assert_eq!(a.n, 8);
        assert_eq!(b.n, 2);
        assert!(d.split(1.5).is_err());
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let mut it = BatchIter::new(10, 3, 1);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            for i in it.next_batch() {
                seen.insert(i);
            }
        }
        assert_eq!(seen.len(), 10); // full epoch covered within 12 draws
    }

    #[test]
    fn mismatched_lengths_rejected() {
        assert!(Dataset::new("bad", vec![2], vec![1], vec![0.0; 5], vec![0.0; 2]).is_err());
        assert!(Dataset::new("bad", vec![2], vec![1], vec![0.0; 4], vec![0.0; 3]).is_err());
    }
}
