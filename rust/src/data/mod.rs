//! Synthetic data substrates (the paper's operation **S**): Bragg-peak
//! patches for BraggNN, CookieBox eToF histograms for CookieNetAE, plus
//! the in-memory dataset container and batch iterator the trainer uses.

pub mod bragg;
pub mod container;
pub mod cookiebox;

pub use bragg::{BraggConfig, PATCH};
pub use container::{BatchIter, Dataset};
pub use cookiebox::{CookieConfig, BINS, CHANNELS};
