//! Synthetic HEDM Bragg-peak patches (the paper's operation **S**).
//!
//! Each sample is an 11x11 detector patch holding one pseudo-Voigt peak
//! with Poisson counting noise; the label is the true sub-pixel center,
//! normalized to [0, 1]^2 — exactly what BraggNN regresses.
//!
//! Two render paths produce identical surfaces (tested against each
//! other):
//! * `render_cpu` — the rust formula in `analysis::pseudo_voigt`;
//! * `render_pjrt` — the AOT-lowered L1 Pallas kernel
//!   (`artifacts/pv_surface.hlo.txt`), putting the Pallas kernel on the
//!   runtime data path.

use anyhow::{bail, Result};

use super::container::Dataset;
use crate::analysis::pseudo_voigt::{value, N_PARAMS};
use crate::models::PvMeta;
use crate::pool::Pool;
use crate::runtime::{Runtime, Tensor};
use crate::util::Rng;

pub const PATCH: usize = 11;

/// Patches per render+noise chunk, each with its own RNG stream. Fixed —
/// never derived from the thread count — so a dataset is a pure function
/// of (config, n, seed) no matter how many workers render it.
pub const GEN_CHUNK: usize = 256;

/// Peak parameter sampling ranges (kept well inside the patch so the
/// conventional fitter and BraggNN both have a fair task).
#[derive(Debug, Clone)]
pub struct BraggConfig {
    pub amp: (f64, f64),
    pub center_margin: f64,
    pub sigma: (f64, f64),
    pub eta: (f64, f64),
    pub bg: (f64, f64),
    pub poisson_noise: bool,
    /// scale each patch to peak 1 (BraggNN's input normalization)
    pub normalize: bool,
}

impl Default for BraggConfig {
    fn default() -> Self {
        BraggConfig {
            amp: (80.0, 400.0),
            center_margin: 3.0,
            sigma: (0.8, 2.2),
            eta: (0.1, 0.9),
            bg: (1.0, 8.0),
            poisson_noise: true,
            normalize: true,
        }
    }
}

/// Draw `n` sets of pseudo-Voigt parameters.
pub fn sample_params(cfg: &BraggConfig, n: usize, rng: &mut Rng) -> Vec<[f64; N_PARAMS]> {
    let lo = cfg.center_margin;
    let hi = (PATCH - 1) as f64 - cfg.center_margin;
    (0..n)
        .map(|_| {
            [
                rng.uniform(cfg.amp.0, cfg.amp.1),
                rng.uniform(lo, hi),
                rng.uniform(lo, hi),
                rng.uniform(cfg.sigma.0, cfg.sigma.1),
                rng.uniform(cfg.sigma.0, cfg.sigma.1),
                rng.uniform(cfg.eta.0, cfg.eta.1),
                rng.uniform(cfg.bg.0, cfg.bg.1),
            ]
        })
        .collect()
}

/// Render surfaces with the rust formula.
pub fn render_cpu(params: &[[f64; N_PARAMS]]) -> Vec<f32> {
    let mut out = Vec::with_capacity(params.len() * PATCH * PATCH);
    for p in params {
        for r in 0..PATCH {
            for c in 0..PATCH {
                out.push(value(p, c as f64, r as f64) as f32);
            }
        }
    }
    out
}

/// Render surfaces by executing the AOT Pallas kernel via PJRT.
pub fn render_pjrt(
    rt: &Runtime,
    pv: &PvMeta,
    params: &[[f64; N_PARAMS]],
) -> Result<Vec<f32>> {
    if pv.height != PATCH || pv.width != PATCH {
        bail!("pv artifact is {}x{}, expected {PATCH}x{PATCH}", pv.height, pv.width);
    }
    let exe = rt.load_hlo(&pv.hlo_path())?;
    let mut out = Vec::with_capacity(params.len() * PATCH * PATCH);
    for chunk in params.chunks(pv.batch) {
        // the artifact has a fixed batch; pad the tail with benign rows
        let mut flat = Vec::with_capacity(pv.batch * 7);
        for p in chunk {
            flat.extend(p.iter().map(|&v| v as f32));
        }
        for _ in chunk.len()..pv.batch {
            flat.extend_from_slice(&[0.0, 0.0, 0.0, 1.0, 1.0, 0.5, 0.0]);
        }
        let t = Tensor::new(vec![pv.batch, 7], flat)?;
        let res = exe.run(&[t])?;
        let surf = &res[0];
        out.extend_from_slice(&surf.data()[..chunk.len() * PATCH * PATCH]);
    }
    Ok(out)
}

/// Apply Poisson counting noise in place.
pub fn add_poisson_noise(surfaces: &mut [f32], rng: &mut Rng) {
    for v in surfaces.iter_mut() {
        *v = rng.poisson((*v).max(0.0) as f64) as f32;
    }
}

/// Scale each patch to peak intensity 1 (BraggNN input convention).
pub fn normalize_patches(surfaces: &mut [f32]) {
    for patch in surfaces.chunks_mut(PATCH * PATCH) {
        let max = patch.iter().cloned().fold(0.0f32, f32::max);
        if max > 0.0 {
            for v in patch.iter_mut() {
                *v /= max;
            }
        }
    }
}

/// Labels: true centers normalized by the patch extent (col, row order —
/// matching the (x, y) the paper's BraggNN predicts).
pub fn labels(params: &[[f64; N_PARAMS]]) -> Vec<f32> {
    let denom = (PATCH - 1) as f64;
    params
        .iter()
        .flat_map(|p| [(p[1] / denom) as f32, (p[2] / denom) as f32])
        .collect()
}

/// Per-chunk noise seeds, drawn serially from the root stream so they
/// depend only on (seed, n) — the parallel render replays them in chunk
/// order on any number of workers.
fn chunk_seeds(rng: &mut Rng, n_chunks: usize) -> Vec<u64> {
    (0..n_chunks).map(|_| rng.next_u64()).collect()
}

/// Render + noise + normalize one chunk with its own RNG stream.
fn finish_chunk(cfg: &BraggConfig, params: &[[f64; N_PARAMS]], seed: u64) -> Vec<f32> {
    let mut x = render_cpu(params);
    let mut rng = Rng::new(seed);
    if cfg.poisson_noise {
        add_poisson_noise(&mut x, &mut rng);
    }
    if cfg.normalize {
        normalize_patches(&mut x);
    }
    x
}

/// Generate a full dataset (CPU render path) on the process-wide pool.
pub fn generate(cfg: &BraggConfig, n: usize, seed: u64) -> Result<Dataset> {
    generate_with_pool(Pool::global(), cfg, n, seed)
}

/// Generate on an explicit pool. Output is identical for any thread
/// count: parameters are sampled serially from the root stream, and each
/// `GEN_CHUNK`-patch chunk renders + noises with its own substream whose
/// seed was drawn serially up front.
pub fn generate_with_pool(pool: &Pool, cfg: &BraggConfig, n: usize, seed: u64) -> Result<Dataset> {
    let mut rng = Rng::new(seed);
    let params = sample_params(cfg, n, &mut rng);
    let n_chunks = n.div_ceil(GEN_CHUNK);
    let seeds = chunk_seeds(&mut rng, n_chunks);
    let params_ref = &params;
    let chunks: Vec<Vec<f32>> = pool.map_tasks(n_chunks, |ci| {
        let lo = ci * GEN_CHUNK;
        let hi = ((ci + 1) * GEN_CHUNK).min(n);
        finish_chunk(cfg, &params_ref[lo..hi], seeds[ci])
    });
    let mut x = Vec::with_capacity(n * PATCH * PATCH);
    for c in chunks {
        x.extend_from_slice(&c);
    }
    let y = labels(&params);
    Dataset::new(
        format!("bragg-{n}"),
        vec![PATCH, PATCH, 1],
        vec![2],
        x,
        y,
    )
}

/// Generate via the PJRT Pallas kernel (noise still rust-side, with the
/// same per-chunk streams as the CPU path so the two datasets share one
/// noise model).
pub fn generate_pjrt(
    rt: &Runtime,
    pv: &PvMeta,
    cfg: &BraggConfig,
    n: usize,
    seed: u64,
) -> Result<Dataset> {
    let mut rng = Rng::new(seed);
    let params = sample_params(cfg, n, &mut rng);
    let n_chunks = n.div_ceil(GEN_CHUNK);
    let seeds = chunk_seeds(&mut rng, n_chunks);
    let mut x = render_pjrt(rt, pv, &params)?;
    for ci in 0..n_chunks {
        let lo = ci * GEN_CHUNK * PATCH * PATCH;
        let hi = (((ci + 1) * GEN_CHUNK) * PATCH * PATCH).min(x.len());
        let chunk = &mut x[lo..hi];
        let mut crng = Rng::new(seeds[ci]);
        if cfg.poisson_noise {
            add_poisson_noise(chunk, &mut crng);
        }
        if cfg.normalize {
            normalize_patches(chunk);
        }
    }
    let y = labels(&params);
    Dataset::new(
        format!("bragg-pjrt-{n}"),
        vec![PATCH, PATCH, 1],
        vec![2],
        x,
        y,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_shapes_and_label_range() {
        let d = generate(&BraggConfig::default(), 64, 3).unwrap();
        assert_eq!(d.n, 64);
        assert_eq!(d.input_shape, vec![11, 11, 1]);
        assert_eq!(d.wire_sample_bytes, 2 * 121 + 8);
        for v in &d.y {
            assert!((0.0..=1.0).contains(v), "{v}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&BraggConfig::default(), 8, 42).unwrap();
        let b = generate(&BraggConfig::default(), 8, 42).unwrap();
        assert_eq!(a.x, b.x);
        let c = generate(&BraggConfig::default(), 8, 43).unwrap();
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn reproducible_across_thread_counts() {
        // 600 patches spans three GEN_CHUNK streams; every pool size must
        // produce the identical dataset for one root seed
        let cfg = BraggConfig::default();
        let a = generate_with_pool(&Pool::new(1), &cfg, 600, 42).unwrap();
        for threads in [2, 4, 7] {
            let b = generate_with_pool(&Pool::new(threads), &cfg, 600, 42).unwrap();
            assert_eq!(a.x, b.x, "{threads} threads changed the patches");
            assert_eq!(a.y, b.y, "{threads} threads changed the labels");
        }
    }

    #[test]
    fn peak_lands_where_label_says() {
        let mut cfg = BraggConfig::default();
        cfg.poisson_noise = false;
        let d = generate(&cfg, 16, 7).unwrap();
        for i in 0..d.n {
            let patch = &d.x[i * 121..(i + 1) * 121];
            let (mut best, mut br, mut bc) = (f32::NEG_INFINITY, 0usize, 0usize);
            for r in 0..11 {
                for c in 0..11 {
                    if patch[r * 11 + c] > best {
                        best = patch[r * 11 + c];
                        br = r;
                        bc = c;
                    }
                }
            }
            let lx = d.y[2 * i] * 10.0;
            let ly = d.y[2 * i + 1] * 10.0;
            assert!((bc as f32 - lx).abs() <= 1.0, "sample {i}: col {bc} vs {lx}");
            assert!((br as f32 - ly).abs() <= 1.0, "sample {i}: row {br} vs {ly}");
        }
    }

    #[test]
    fn pjrt_render_matches_cpu_render() {
        let dir = crate::models::default_artifacts_dir();
        if !dir.join("pv_meta.json").exists() {
            return; // artifacts not built
        }
        let pv = PvMeta::load(&dir).unwrap();
        let rt = Runtime::cpu().unwrap();
        let mut rng = Rng::new(5);
        // deliberately not a multiple of the artifact batch
        let params = sample_params(&BraggConfig::default(), 300, &mut rng);
        let cpu = render_cpu(&params);
        let pjrt = render_pjrt(&rt, &pv, &params).unwrap();
        assert_eq!(cpu.len(), pjrt.len());
        for (a, b) in cpu.iter().zip(&pjrt) {
            assert!((a - b).abs() < 1e-2 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn conventional_fitter_recovers_generated_labels() {
        // closes the loop: generator -> analyzer A -> label accuracy
        let mut cfg = BraggConfig::default();
        cfg.poisson_noise = true;
        let d = generate(&cfg, 24, 9).unwrap();
        let (fits, per_peak) =
            crate::analysis::label_patches(&d.x, d.n, 11, 11).unwrap();
        let mut worst: f64 = 0.0;
        for (i, fit) in fits.iter().enumerate() {
            let (x, y) = fit.center();
            let lx = d.y[2 * i] as f64 * 10.0;
            let ly = d.y[2 * i + 1] as f64 * 10.0;
            worst = worst.max((x - lx).abs()).max((y - ly).abs());
        }
        assert!(worst < 0.35, "worst center error {worst} px");
        assert!(per_peak < 0.1, "labeling took {per_peak}s/peak");
    }
}
