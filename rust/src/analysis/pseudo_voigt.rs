//! 2-D pseudo-Voigt profile: value + analytic Jacobian.
//!
//! This is the peak shape HEDM pipelines fit to detector patches (the
//! paper's conventional operation **A**). The formula matches
//! `python/compile/kernels/ref.py::pseudo_voigt_ref` exactly — the L1
//! Pallas kernel synthesizes with it, this module fits with it.

/// Parameter vector layout: [amp, x0, y0, sigma_x, sigma_y, eta, bg].
pub const N_PARAMS: usize = 7;

pub const P_AMP: usize = 0;
pub const P_X0: usize = 1;
pub const P_Y0: usize = 2;
pub const P_SX: usize = 3;
pub const P_SY: usize = 4;
pub const P_ETA: usize = 5;
pub const P_BG: usize = 6;

/// Profile value at pixel (x=col, y=row).
pub fn value(p: &[f64; N_PARAMS], x: f64, y: f64) -> f64 {
    let dx = x - p[P_X0];
    let dy = y - p[P_Y0];
    let gx = dx * dx / (p[P_SX] * p[P_SX]);
    let gy = dy * dy / (p[P_SY] * p[P_SY]);
    let gauss = (-0.5 * (gx + gy)).exp();
    let lorentz = 1.0 / (1.0 + gx + gy);
    p[P_AMP] * (p[P_ETA] * lorentz + (1.0 - p[P_ETA]) * gauss) + p[P_BG]
}

/// Analytic partial derivatives at pixel (x, y), in parameter order.
pub fn jacobian(p: &[f64; N_PARAMS], x: f64, y: f64) -> [f64; N_PARAMS] {
    value_jacobian(p, x, y).1
}

/// Fused value + Jacobian at pixel (x, y): the exp, the Lorentzian and
/// the shared shape factors are evaluated once and feed both outputs.
/// This is the `LeastSquares::residual_jacobian` specialization the LM
/// accumulation sweep runs on — the single most executed scalar kernel
/// in the conventional analyzer.
pub fn value_jacobian(p: &[f64; N_PARAMS], x: f64, y: f64) -> (f64, [f64; N_PARAMS]) {
    let (amp, x0, y0, sx, sy, eta) = (p[P_AMP], p[P_X0], p[P_Y0], p[P_SX], p[P_SY], p[P_ETA]);
    let dx = x - x0;
    let dy = y - y0;
    // same operation order as `value` so surfaces stay bit-identical
    let gx = dx * dx / (sx * sx);
    let gy = dy * dy / (sy * sy);
    let g = (-0.5 * (gx + gy)).exp();
    let l = 1.0 / (1.0 + gx + gy);
    let shape = eta * l + (1.0 - eta) * g;
    // common factor d(F)/d(gx) = d(F)/d(gy) = -(eta*l^2 + 0.5*(1-eta)*g)
    let df_dg = eta * l * l + 0.5 * (1.0 - eta) * g;

    let mut out = [0.0; N_PARAMS];
    out[P_AMP] = shape;
    out[P_X0] = amp * df_dg * 2.0 * dx / (sx * sx);
    out[P_Y0] = amp * df_dg * 2.0 * dy / (sy * sy);
    out[P_SX] = amp * df_dg * 2.0 * dx * dx / (sx * sx * sx);
    out[P_SY] = amp * df_dg * 2.0 * dy * dy / (sy * sy * sy);
    out[P_ETA] = amp * (l - g);
    out[P_BG] = 1.0;
    (amp * shape + p[P_BG], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_params() -> [f64; N_PARAMS] {
        [120.0, 4.3, 6.1, 1.4, 2.1, 0.35, 3.0]
    }

    #[test]
    fn value_limits() {
        let mut p = sample_params();
        // at the exact center both G and L are 1 -> amp + bg
        assert!((value(&p, 4.3, 6.1) - 123.0).abs() < 1e-12);
        // eta=0 pure Gaussian, eta=1 pure Lorentzian at one test pixel
        p[P_ETA] = 0.0;
        let dx: f64 = 2.0 / 1.4;
        let dy: f64 = -1.0 / 2.1;
        let g = (-0.5 * (dx * dx + dy * dy)).exp();
        assert!((value(&p, 6.3, 5.1) - (120.0 * g + 3.0)).abs() < 1e-9);
        p[P_ETA] = 1.0;
        let l = 1.0 / (1.0 + dx * dx + dy * dy);
        assert!((value(&p, 6.3, 5.1) - (120.0 * l + 3.0)).abs() < 1e-9);
    }

    #[test]
    fn jacobian_matches_finite_differences() {
        let p = sample_params();
        for (x, y) in [(4.0, 6.0), (0.0, 0.0), (10.0, 3.0), (4.3, 6.1)] {
            let jac = jacobian(&p, x, y);
            for i in 0..N_PARAMS {
                let h = 1e-6 * p[i].abs().max(1e-3);
                let mut pp = p;
                pp[i] += h;
                let mut pm = p;
                pm[i] -= h;
                let fd = (value(&pp, x, y) - value(&pm, x, y)) / (2.0 * h);
                assert!(
                    (jac[i] - fd).abs() < 1e-4 * fd.abs().max(1.0),
                    "param {i} at ({x},{y}): analytic {} vs fd {fd}",
                    jac[i]
                );
            }
        }
    }

    #[test]
    fn fused_value_jacobian_is_bit_identical_to_split() {
        let p = sample_params();
        for (x, y) in [(4.0, 6.0), (0.0, 0.0), (10.0, 3.0), (4.3, 6.1), (7.7, 0.2)] {
            let (v, j) = value_jacobian(&p, x, y);
            assert_eq!(v, value(&p, x, y), "value at ({x},{y})");
            assert_eq!(j, jacobian(&p, x, y), "jacobian at ({x},{y})");
        }
    }

    #[test]
    fn matches_kernel_formula_symmetry() {
        // symmetric params -> symmetric surface (same invariant the L1
        // kernel test checks)
        let p = [100.0, 5.0, 5.0, 1.5, 1.5, 0.4, 2.0];
        assert!((value(&p, 0.0, 0.0) - value(&p, 10.0, 10.0)).abs() < 1e-12);
        assert!((value(&p, 0.0, 10.0) - value(&p, 10.0, 0.0)).abs() < 1e-12);
    }
}
