//! Levenberg–Marquardt nonlinear least squares.
//!
//! Generic over the model: the caller supplies residual + Jacobian rows.
//! Used by the pseudo-Voigt fitter (the conventional baseline **A**);
//! written dimension-generically so tests can exercise it on independent
//! problems.

use anyhow::{bail, Result};

/// A least-squares problem of `N` parameters.
pub trait LeastSquares<const N: usize> {
    /// Number of residuals (data points).
    fn n_residuals(&self) -> usize;

    /// Residual r_i = model_i(params) - observation_i.
    fn residual(&self, params: &[f64; N], i: usize) -> f64;

    /// d r_i / d params.
    fn jacobian_row(&self, params: &[f64; N], i: usize) -> [f64; N];

    /// Clamp parameters into their feasible region after each step.
    fn project(&self, _params: &mut [f64; N]) {}
}

/// Solver configuration.
#[derive(Debug, Clone, Copy)]
pub struct LmOptions {
    pub max_iters: u32,
    pub lambda_init: f64,
    pub lambda_up: f64,
    pub lambda_down: f64,
    /// stop when the relative cost improvement falls below this
    pub ftol: f64,
}

impl Default for LmOptions {
    fn default() -> Self {
        LmOptions {
            max_iters: 100,
            lambda_init: 1e-3,
            lambda_up: 10.0,
            lambda_down: 0.3,
            ftol: 1e-10,
        }
    }
}

/// Fit outcome.
#[derive(Debug, Clone, Copy)]
pub struct LmResult<const N: usize> {
    pub params: [f64; N],
    pub cost: f64,
    pub iterations: u32,
    pub converged: bool,
}

fn cost<const N: usize>(prob: &impl LeastSquares<N>, p: &[f64; N]) -> f64 {
    (0..prob.n_residuals())
        .map(|i| {
            let r = prob.residual(p, i);
            r * r
        })
        .sum::<f64>()
        * 0.5
}

/// Solve the damped normal equations (JtJ + λ diag(JtJ)) δ = -Jt r.
pub fn solve<const N: usize>(
    prob: &impl LeastSquares<N>,
    init: [f64; N],
    opts: LmOptions,
) -> Result<LmResult<N>> {
    if prob.n_residuals() < N {
        bail!(
            "underdetermined: {} residuals for {N} parameters",
            prob.n_residuals()
        );
    }
    let mut params = init;
    prob.project(&mut params);
    let mut lambda = opts.lambda_init;
    let mut current_cost = cost(prob, &params);
    let mut converged = false;
    let mut iters = 0;

    for _ in 0..opts.max_iters {
        iters += 1;
        // accumulate JtJ and Jt r
        let mut jtj = [[0.0f64; N]; N];
        let mut jtr = [0.0f64; N];
        for i in 0..prob.n_residuals() {
            let r = prob.residual(&params, i);
            let row = prob.jacobian_row(&params, i);
            for a in 0..N {
                jtr[a] += row[a] * r;
                for b in a..N {
                    jtj[a][b] += row[a] * row[b];
                }
            }
        }
        for a in 0..N {
            for b in 0..a {
                jtj[a][b] = jtj[b][a];
            }
        }

        // try steps until one reduces the cost (or lambda explodes)
        let mut improved = false;
        for _ in 0..20 {
            let mut damped = jtj;
            for (a, row) in damped.iter_mut().enumerate() {
                row[a] += lambda * jtj[a][a].max(1e-12);
            }
            let Some(delta) = solve_spd::<N>(&damped, &jtr) else {
                lambda *= opts.lambda_up;
                continue;
            };
            let mut trial = params;
            for a in 0..N {
                trial[a] -= delta[a];
            }
            prob.project(&mut trial);
            let trial_cost = cost(prob, &trial);
            if trial_cost < current_cost {
                let rel = (current_cost - trial_cost) / current_cost.max(1e-300);
                params = trial;
                current_cost = trial_cost;
                lambda = (lambda * opts.lambda_down).max(1e-12);
                improved = true;
                if rel < opts.ftol {
                    converged = true;
                }
                break;
            }
            lambda *= opts.lambda_up;
        }
        if !improved {
            // cannot improve: local minimum (or flat) — call it converged
            converged = true;
        }
        if converged {
            break;
        }
    }

    Ok(LmResult {
        params,
        cost: current_cost,
        iterations: iters,
        converged,
    })
}

/// Gaussian elimination with partial pivoting for the (small) SPD system.
fn solve_spd<const N: usize>(a: &[[f64; N]; N], b: &[f64; N]) -> Option<[f64; N]> {
    let mut m = *a;
    let mut rhs = *b;
    for col in 0..N {
        let piv = (col..N).max_by(|&i, &j| m[i][col].abs().total_cmp(&m[j][col].abs()))?;
        if m[piv][col].abs() < 1e-300 {
            return None;
        }
        m.swap(col, piv);
        rhs.swap(col, piv);
        for row in col + 1..N {
            let f = m[row][col] / m[col][col];
            for k in col..N {
                m[row][k] -= f * m[col][k];
            }
            rhs[row] -= f * rhs[col];
        }
    }
    let mut x = [0.0; N];
    for row in (0..N).rev() {
        let mut acc = rhs[row];
        for k in row + 1..N {
            acc -= m[row][k] * x[k];
        }
        x[row] = acc / m[row][row];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// y = a * exp(-b x) observed at fixed xs.
    struct ExpDecay {
        xs: Vec<f64>,
        ys: Vec<f64>,
    }

    impl LeastSquares<2> for ExpDecay {
        fn n_residuals(&self) -> usize {
            self.xs.len()
        }
        fn residual(&self, p: &[f64; 2], i: usize) -> f64 {
            p[0] * (-p[1] * self.xs[i]).exp() - self.ys[i]
        }
        fn jacobian_row(&self, p: &[f64; 2], i: usize) -> [f64; 2] {
            let e = (-p[1] * self.xs[i]).exp();
            [e, -p[0] * self.xs[i] * e]
        }
        fn project(&self, p: &mut [f64; 2]) {
            p[0] = p[0].max(1e-9);
            p[1] = p[1].clamp(1e-9, 100.0);
        }
    }

    #[test]
    fn recovers_exponential_decay() {
        let truth = [5.0, 0.7];
        let xs: Vec<f64> = (0..50).map(|i| i as f64 * 0.1).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| truth[0] * (-truth[1] * x).exp()).collect();
        let prob = ExpDecay { xs, ys };
        let fit = solve(&prob, [1.0, 0.1], LmOptions::default()).unwrap();
        assert!(fit.converged);
        assert!((fit.params[0] - 5.0).abs() < 1e-6, "{:?}", fit.params);
        assert!((fit.params[1] - 0.7).abs() < 1e-6, "{:?}", fit.params);
        assert!(fit.cost < 1e-12);
    }

    #[test]
    fn noisy_fit_stays_close() {
        let truth = [5.0, 0.7];
        let mut rng = crate::util::Rng::new(9);
        let xs: Vec<f64> = (0..200).map(|i| i as f64 * 0.05).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| truth[0] * (-truth[1] * x).exp() + 0.02 * rng.normal())
            .collect();
        let prob = ExpDecay { xs, ys };
        let fit = solve(&prob, [2.0, 0.2], LmOptions::default()).unwrap();
        assert!((fit.params[0] - 5.0).abs() < 0.05, "{:?}", fit.params);
        assert!((fit.params[1] - 0.7).abs() < 0.02, "{:?}", fit.params);
    }

    #[test]
    fn underdetermined_rejected() {
        let prob = ExpDecay {
            xs: vec![1.0],
            ys: vec![1.0],
        };
        assert!(solve(&prob, [1.0, 1.0], LmOptions::default()).is_err());
    }

    #[test]
    fn projection_respected() {
        // start outside the feasible box; solution must stay inside
        let xs: Vec<f64> = (0..20).map(|i| i as f64 * 0.1).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 5.0 * (-0.7f64 * x).exp()).collect();
        let prob = ExpDecay { xs, ys };
        let fit = solve(&prob, [-3.0, -5.0], LmOptions::default()).unwrap();
        assert!(fit.params[0] > 0.0 && fit.params[1] > 0.0);
    }
}
