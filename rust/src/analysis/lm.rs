//! Levenberg–Marquardt nonlinear least squares.
//!
//! Generic over the model: the caller supplies residual + Jacobian rows,
//! or (for the hot paths) a fused `residual_jacobian` that shares the
//! expensive subexpressions between value and gradient. One sweep per
//! iteration accumulates JtJ, Jtr *and* the cost; the accepted trial
//! cost is reused instead of recomputed; the damped normal equations are
//! solved by Cholesky factorization (they are SPD by construction).
//!
//! Used by the pseudo-Voigt fitter (the conventional baseline **A**);
//! written dimension-generically so tests can exercise it on independent
//! problems.

use anyhow::{bail, Result};

/// A least-squares problem of `N` parameters.
pub trait LeastSquares<const N: usize> {
    /// Number of residuals (data points).
    fn n_residuals(&self) -> usize;

    /// Residual r_i = model_i(params) - observation_i.
    fn residual(&self, params: &[f64; N], i: usize) -> f64;

    /// d r_i / d params.
    fn jacobian_row(&self, params: &[f64; N], i: usize) -> [f64; N];

    /// Fused residual + Jacobian row. The solver's accumulation sweep
    /// calls only this; the default just delegates, so overriding it to
    /// share work (e.g. one exp/Lorentzian evaluation feeding both value
    /// and gradient) speeds the whole fit up without touching the solver.
    fn residual_jacobian(&self, params: &[f64; N], i: usize) -> (f64, [f64; N]) {
        (self.residual(params, i), self.jacobian_row(params, i))
    }

    /// Clamp parameters into their feasible region after each step.
    fn project(&self, _params: &mut [f64; N]) {}
}

/// Solver configuration.
#[derive(Debug, Clone, Copy)]
pub struct LmOptions {
    pub max_iters: u32,
    pub lambda_init: f64,
    pub lambda_up: f64,
    pub lambda_down: f64,
    /// stop when the relative cost improvement falls below this
    pub ftol: f64,
    /// a stalled step search only counts as converged when the gradient
    /// inf-norm is below `gtol * max(1, cost)` (i.e. we are actually at a
    /// stationary point, not merely unable to find a descent step)
    pub gtol: f64,
}

impl Default for LmOptions {
    fn default() -> Self {
        LmOptions {
            max_iters: 100,
            lambda_init: 1e-3,
            lambda_up: 10.0,
            lambda_down: 0.3,
            ftol: 1e-10,
            gtol: 1e-8,
        }
    }
}

/// How the solve ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LmOutcome {
    /// ftol satisfied, or the step search stalled at a stationary point.
    Converged,
    /// The step search could not find a descent direction even after
    /// escalating lambda, and the gradient is *not* small: the iterate is
    /// stuck, not at a minimum. (The seed conflated this with
    /// convergence.)
    Stalled,
    /// Iteration budget exhausted while still improving.
    MaxIters,
}

/// Fit outcome.
#[derive(Debug, Clone, Copy)]
pub struct LmResult<const N: usize> {
    pub params: [f64; N],
    pub cost: f64,
    pub iterations: u32,
    pub outcome: LmOutcome,
}

impl<const N: usize> LmResult<N> {
    pub fn converged(&self) -> bool {
        self.outcome == LmOutcome::Converged
    }
}

fn cost<const N: usize>(prob: &impl LeastSquares<N>, p: &[f64; N]) -> f64 {
    (0..prob.n_residuals())
        .map(|i| {
            let r = prob.residual(p, i);
            r * r
        })
        .sum::<f64>()
        * 0.5
}

/// One fused sweep: cost, JtJ and Jtr from a single residual+Jacobian
/// pass over the data.
fn normal_equations<const N: usize>(
    prob: &impl LeastSquares<N>,
    p: &[f64; N],
) -> (f64, [[f64; N]; N], [f64; N]) {
    let mut c = 0.0f64;
    let mut jtj = [[0.0f64; N]; N];
    let mut jtr = [0.0f64; N];
    for i in 0..prob.n_residuals() {
        let (r, row) = prob.residual_jacobian(p, i);
        c += r * r;
        for a in 0..N {
            jtr[a] += row[a] * r;
            for b in a..N {
                jtj[a][b] += row[a] * row[b];
            }
        }
    }
    for a in 0..N {
        for b in 0..a {
            jtj[a][b] = jtj[b][a];
        }
    }
    (c * 0.5, jtj, jtr)
}

/// Solve the damped normal equations (JtJ + λ diag(JtJ)) δ = -Jt r.
pub fn solve<const N: usize>(
    prob: &impl LeastSquares<N>,
    init: [f64; N],
    opts: LmOptions,
) -> Result<LmResult<N>> {
    if prob.n_residuals() < N {
        bail!(
            "underdetermined: {} residuals for {N} parameters",
            prob.n_residuals()
        );
    }
    let mut params = init;
    prob.project(&mut params);
    if opts.max_iters == 0 {
        return Ok(LmResult {
            cost: cost(prob, &params),
            params,
            iterations: 0,
            outcome: LmOutcome::MaxIters,
        });
    }
    let mut lambda = opts.lambda_init;
    let mut current_cost = f64::INFINITY;
    let mut outcome = LmOutcome::MaxIters;
    let mut iters = 0;

    'outer: for _ in 0..opts.max_iters {
        iters += 1;
        // single fused pass: cost + JtJ + Jtr. After an accepted step the
        // cost term merely re-confirms the trial cost we already hold, so
        // only the first sweep's cost is consumed.
        let (sweep_cost, jtj, jtr) = normal_equations(prob, &params);
        if iters == 1 {
            current_cost = sweep_cost;
        }

        // try steps until one reduces the cost (or lambda explodes)
        let mut improved = false;
        for _ in 0..20 {
            let mut damped = jtj;
            for (a, row) in damped.iter_mut().enumerate() {
                row[a] += lambda * jtj[a][a].max(1e-12);
            }
            let Some(delta) = solve_spd::<N>(&damped, &jtr) else {
                lambda *= opts.lambda_up;
                continue;
            };
            let mut trial = params;
            for a in 0..N {
                trial[a] -= delta[a];
            }
            prob.project(&mut trial);
            let trial_cost = cost(prob, &trial);
            if trial_cost < current_cost {
                let rel = (current_cost - trial_cost) / current_cost.max(1e-300);
                params = trial;
                // reuse the accepted trial cost — never recomputed
                current_cost = trial_cost;
                lambda = (lambda * opts.lambda_down).max(1e-12);
                improved = true;
                if rel < opts.ftol {
                    outcome = LmOutcome::Converged;
                    break 'outer;
                }
                break;
            }
            lambda *= opts.lambda_up;
        }
        if !improved {
            // step search stalled: convergence only if we are at a
            // stationary point; otherwise report the stall honestly
            let gmax = jtr.iter().fold(0.0f64, |m, &g| m.max(g.abs()));
            outcome = if gmax <= opts.gtol * current_cost.max(1.0) {
                LmOutcome::Converged
            } else {
                LmOutcome::Stalled
            };
            break;
        }
    }

    Ok(LmResult {
        params,
        cost: current_cost,
        iterations: iters,
        outcome,
    })
}

/// Cholesky solve of the (small) damped-normal-equation system. The
/// damped matrix is SPD whenever JtJ has full numerical rank, so LLᵀ
/// factorization is both faster than elimination with pivoting and a
/// built-in positive-definiteness check: a non-positive pivot returns
/// `None` and the caller escalates lambda.
fn solve_spd<const N: usize>(a: &[[f64; N]; N], b: &[f64; N]) -> Option<[f64; N]> {
    let mut l = [[0.0f64; N]; N];
    for j in 0..N {
        let mut d = a[j][j];
        for k in 0..j {
            d -= l[j][k] * l[j][k];
        }
        // `!(d > ...)` also rejects NaN
        if !(d > 1e-300) {
            return None;
        }
        let ljj = d.sqrt();
        l[j][j] = ljj;
        for i in j + 1..N {
            let mut s = a[i][j];
            for k in 0..j {
                s -= l[i][k] * l[j][k];
            }
            l[i][j] = s / ljj;
        }
    }
    // L y = b
    let mut y = [0.0f64; N];
    for i in 0..N {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i][k] * y[k];
        }
        y[i] = s / l[i][i];
    }
    // Lᵀ x = y
    let mut x = [0.0f64; N];
    for i in (0..N).rev() {
        let mut s = y[i];
        for k in i + 1..N {
            s -= l[k][i] * x[k];
        }
        x[i] = s / l[i][i];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// y = a * exp(-b x) observed at fixed xs.
    struct ExpDecay {
        xs: Vec<f64>,
        ys: Vec<f64>,
    }

    impl LeastSquares<2> for ExpDecay {
        fn n_residuals(&self) -> usize {
            self.xs.len()
        }
        fn residual(&self, p: &[f64; 2], i: usize) -> f64 {
            p[0] * (-p[1] * self.xs[i]).exp() - self.ys[i]
        }
        fn jacobian_row(&self, p: &[f64; 2], i: usize) -> [f64; 2] {
            let e = (-p[1] * self.xs[i]).exp();
            [e, -p[0] * self.xs[i] * e]
        }
        fn project(&self, p: &mut [f64; 2]) {
            p[0] = p[0].max(1e-9);
            p[1] = p[1].clamp(1e-9, 100.0);
        }
    }

    /// Same model, but with the fused path overridden to share the exp —
    /// must be numerically identical to the default split evaluation.
    struct FusedExpDecay(ExpDecay);

    impl LeastSquares<2> for FusedExpDecay {
        fn n_residuals(&self) -> usize {
            self.0.n_residuals()
        }
        fn residual(&self, p: &[f64; 2], i: usize) -> f64 {
            self.0.residual(p, i)
        }
        fn jacobian_row(&self, p: &[f64; 2], i: usize) -> [f64; 2] {
            self.0.jacobian_row(p, i)
        }
        fn residual_jacobian(&self, p: &[f64; 2], i: usize) -> (f64, [f64; 2]) {
            let e = (-p[1] * self.0.xs[i]).exp();
            (p[0] * e - self.0.ys[i], [e, -p[0] * self.0.xs[i] * e])
        }
        fn project(&self, p: &mut [f64; 2]) {
            self.0.project(p)
        }
    }

    fn decay_problem(n: usize, dt: f64, noise: Option<u64>) -> ExpDecay {
        let truth = [5.0, 0.7];
        let xs: Vec<f64> = (0..n).map(|i| i as f64 * dt).collect();
        let mut rng = noise.map(crate::util::Rng::new);
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| {
                truth[0] * (-truth[1] * x).exp()
                    + rng.as_mut().map(|r| 0.02 * r.normal()).unwrap_or(0.0)
            })
            .collect();
        ExpDecay { xs, ys }
    }

    #[test]
    fn recovers_exponential_decay() {
        let prob = decay_problem(50, 0.1, None);
        let fit = solve(&prob, [1.0, 0.1], LmOptions::default()).unwrap();
        assert!(fit.converged(), "{:?}", fit.outcome);
        assert!((fit.params[0] - 5.0).abs() < 1e-6, "{:?}", fit.params);
        assert!((fit.params[1] - 0.7).abs() < 1e-6, "{:?}", fit.params);
        assert!(fit.cost < 1e-12);
    }

    #[test]
    fn noisy_fit_stays_close() {
        let prob = decay_problem(200, 0.05, Some(9));
        let fit = solve(&prob, [2.0, 0.2], LmOptions::default()).unwrap();
        assert!((fit.params[0] - 5.0).abs() < 0.05, "{:?}", fit.params);
        assert!((fit.params[1] - 0.7).abs() < 0.02, "{:?}", fit.params);
    }

    #[test]
    fn fused_override_matches_default_path_exactly() {
        let split = decay_problem(200, 0.05, Some(9));
        let fused = FusedExpDecay(decay_problem(200, 0.05, Some(9)));
        let a = solve(&split, [2.0, 0.2], LmOptions::default()).unwrap();
        let b = solve(&fused, [2.0, 0.2], LmOptions::default()).unwrap();
        assert_eq!(a.params, b.params);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.outcome, b.outcome);
    }

    #[test]
    fn underdetermined_rejected() {
        let prob = ExpDecay {
            xs: vec![1.0],
            ys: vec![1.0],
        };
        assert!(solve(&prob, [1.0, 1.0], LmOptions::default()).is_err());
    }

    #[test]
    fn projection_respected() {
        // start outside the feasible box; solution must stay inside
        let prob = decay_problem(20, 0.1, None);
        let fit = solve(&prob, [-3.0, -5.0], LmOptions::default()).unwrap();
        assert!(fit.params[0] > 0.0 && fit.params[1] > 0.0);
    }

    /// Cost is flat in the parameters but the (deliberately inconsistent)
    /// Jacobian promises descent: every trial step leaves the cost
    /// unchanged, so the step search stalls with a large gradient. The
    /// seed reported this as `converged = true`; it must be `Stalled`.
    struct FlatCostLyingJacobian;

    impl LeastSquares<1> for FlatCostLyingJacobian {
        fn n_residuals(&self) -> usize {
            8
        }
        fn residual(&self, _p: &[f64; 1], _i: usize) -> f64 {
            1.0
        }
        fn jacobian_row(&self, _p: &[f64; 1], _i: usize) -> [f64; 1] {
            [1.0]
        }
    }

    #[test]
    fn stalled_step_search_is_not_convergence() {
        let fit = solve(&FlatCostLyingJacobian, [0.0], LmOptions::default()).unwrap();
        assert_eq!(fit.outcome, LmOutcome::Stalled);
        assert!(!fit.converged());
        assert_eq!(fit.iterations, 1);
        assert!((fit.cost - 4.0).abs() < 1e-12, "{}", fit.cost); // 0.5 * 8 * 1^2
    }

    #[test]
    fn stall_at_stationary_point_is_convergence() {
        // start exactly at the global minimum of a perfect-data problem:
        // no step can strictly improve, but the gradient is ~0, so the
        // stall is genuine convergence
        let prob = decay_problem(50, 0.1, None);
        let fit = solve(&prob, [5.0, 0.7], LmOptions::default()).unwrap();
        assert_eq!(fit.outcome, LmOutcome::Converged);
        assert!(fit.cost < 1e-20);
    }

    #[test]
    fn cholesky_matches_known_solution() {
        // A = [[4,2],[2,3]], b = [10, 9] -> x = [1.5, 2]
        let a = [[4.0, 2.0], [2.0, 3.0]];
        let b = [10.0, 9.0];
        let x = solve_spd::<2>(&a, &b).unwrap();
        assert!((x[0] - 1.5).abs() < 1e-12, "{x:?}");
        assert!((x[1] - 2.0).abs() < 1e-12, "{x:?}");
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        // negative-definite and rank-deficient matrices must both fail
        assert!(solve_spd::<2>(&[[-1.0, 0.0], [0.0, 1.0]], &[1.0, 1.0]).is_none());
        assert!(solve_spd::<2>(&[[1.0, 1.0], [1.0, 1.0]], &[1.0, 1.0]).is_none());
    }
}
