//! The conventional Bragg-peak analyzer **A**: pseudo-Voigt LM fitting of
//! detector patches — the baseline BraggNN replaces (paper §4.2/§5.2:
//! "positions are typically computed by fitting the observed intensities
//! ... to a theoretical peak shape such as pseudo-Voigt").
//!
//! Real compute, really run: `label_patches` fits on the process-wide
//! work-stealing pool (`XLOOP_THREADS` to override) and measures both
//! its wallclock and the summed per-worker busy time, so EXPERIMENTS.md
//! reports an honest C(A) on this machine — delivered latency *and*
//! per-peak CPU cost, which stays thread-count independent.

use std::time::Instant;

use anyhow::Result;

use super::lm::{solve, LeastSquares, LmOptions, LmOutcome, LmResult};
use super::pseudo_voigt::{
    value, value_jacobian, N_PARAMS, P_AMP, P_BG, P_ETA, P_SX, P_SY, P_X0, P_Y0,
};
use crate::pool::Pool;

/// Patches per pool task. Small enough that work stealing levels the
/// iteration-count skew between easy and hard peaks, large enough that
/// claim/merge overhead vanishes; fixed so scheduling never depends on
/// the thread count.
pub const FIT_CHUNK: usize = 8;

/// One fitted peak.
#[derive(Debug, Clone, Copy)]
pub struct PeakFit {
    /// [amp, x0, y0, sigma_x, sigma_y, eta, bg]
    pub params: [f64; N_PARAMS],
    pub cost: f64,
    pub iterations: u32,
    pub converged: bool,
}

impl PeakFit {
    pub fn center(&self) -> (f64, f64) {
        (self.params[P_X0], self.params[P_Y0])
    }
}

/// Timing of one batch-labeling run.
#[derive(Debug, Clone, Copy)]
pub struct BatchTiming {
    pub n: usize,
    /// end-to-end wallclock of the batch
    pub wall_s: f64,
    /// busy time summed over every worker's chunks — the thread-count
    /// independent compute cost of the conventional analyzer
    pub cpu_s: f64,
    pub threads: usize,
}

impl BatchTiming {
    /// Delivered latency per peak (what the beamline experiences).
    pub fn per_peak_wall_s(&self) -> f64 {
        self.wall_s / self.n.max(1) as f64
    }

    /// CPU cost per peak (the paper's per-core C(A)).
    pub fn per_peak_cpu_s(&self) -> f64 {
        self.cpu_s / self.n.max(1) as f64
    }

    /// Effective parallel speedup actually realized by this run.
    pub fn speedup(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.cpu_s / self.wall_s
        } else {
            1.0
        }
    }
}

struct PatchProblem<'a> {
    patch: &'a [f32],
    height: usize,
    width: usize,
}

impl LeastSquares<N_PARAMS> for PatchProblem<'_> {
    fn n_residuals(&self) -> usize {
        self.patch.len()
    }

    fn residual(&self, p: &[f64; N_PARAMS], i: usize) -> f64 {
        let y = (i / self.width) as f64;
        let x = (i % self.width) as f64;
        value(p, x, y) - self.patch[i] as f64
    }

    fn jacobian_row(&self, p: &[f64; N_PARAMS], i: usize) -> [f64; N_PARAMS] {
        self.residual_jacobian(p, i).1
    }

    // fused path: one exp + one Lorentzian feed both residual and row
    fn residual_jacobian(&self, p: &[f64; N_PARAMS], i: usize) -> (f64, [f64; N_PARAMS]) {
        let y = (i / self.width) as f64;
        let x = (i % self.width) as f64;
        let (v, row) = value_jacobian(p, x, y);
        (v - self.patch[i] as f64, row)
    }

    fn project(&self, p: &mut [f64; N_PARAMS]) {
        p[P_AMP] = p[P_AMP].max(1e-3);
        p[P_X0] = p[P_X0].clamp(0.0, (self.width - 1) as f64);
        p[P_Y0] = p[P_Y0].clamp(0.0, (self.height - 1) as f64);
        p[P_SX] = p[P_SX].clamp(0.2, self.width as f64);
        p[P_SY] = p[P_SY].clamp(0.2, self.height as f64);
        p[P_ETA] = p[P_ETA].clamp(0.0, 1.0);
        p[P_BG] = p[P_BG].max(0.0);
    }
}

/// Moment-based initial guess: background from the patch border, centroid
/// and second moments from background-subtracted intensity.
pub fn initial_guess(patch: &[f32], height: usize, width: usize) -> [f64; N_PARAMS] {
    let mut bg = f64::INFINITY;
    for r in 0..height {
        for c in 0..width {
            if r == 0 || c == 0 || r == height - 1 || c == width - 1 {
                bg = bg.min(patch[r * width + c] as f64);
            }
        }
    }
    if !bg.is_finite() {
        bg = 0.0;
    }
    let mut mass = 0.0;
    let mut mx = 0.0;
    let mut my = 0.0;
    let mut peak = 0.0f64;
    for r in 0..height {
        for c in 0..width {
            let v = (patch[r * width + c] as f64 - bg).max(0.0);
            mass += v;
            mx += v * c as f64;
            my += v * r as f64;
            peak = peak.max(v);
        }
    }
    let (x0, y0) = if mass > 0.0 {
        (mx / mass, my / mass)
    } else {
        ((width / 2) as f64, (height / 2) as f64)
    };
    let mut vx = 0.0;
    let mut vy = 0.0;
    if mass > 0.0 {
        for r in 0..height {
            for c in 0..width {
                let v = (patch[r * width + c] as f64 - bg).max(0.0);
                vx += v * (c as f64 - x0).powi(2);
                vy += v * (r as f64 - y0).powi(2);
            }
        }
        vx /= mass;
        vy /= mass;
    }
    [
        peak.max(1e-3),
        x0,
        y0,
        vx.sqrt().clamp(0.5, width as f64 / 2.0),
        vy.sqrt().clamp(0.5, height as f64 / 2.0),
        0.5,
        bg,
    ]
}

/// Fit one patch (row-major `height x width` intensities).
pub fn fit_patch(patch: &[f32], height: usize, width: usize) -> Result<PeakFit> {
    let prob = PatchProblem {
        patch,
        height,
        width,
    };
    let init = initial_guess(patch, height, width);
    let LmResult {
        params,
        cost,
        iterations,
        outcome,
    } = solve(&prob, init, LmOptions::default())?;
    Ok(PeakFit {
        params,
        cost,
        iterations,
        converged: outcome == LmOutcome::Converged,
    })
}

/// Batch labeling on an explicit pool. Fits are returned in patch order
/// and are bit-identical for any thread count (each fit is an
/// independent, deterministic computation; the pool only changes *where*
/// it runs).
pub fn label_patches_with(
    pool: &Pool,
    patches: &[f32],
    n: usize,
    height: usize,
    width: usize,
) -> Result<(Vec<PeakFit>, BatchTiming)> {
    let px = height * width;
    assert_eq!(patches.len(), n * px, "patch buffer size mismatch");
    let started = Instant::now();
    let n_chunks = n.div_ceil(FIT_CHUNK);
    let per_chunk: Vec<Result<(Vec<PeakFit>, f64)>> = pool.map_tasks(n_chunks, |ci| {
        let busy = Instant::now();
        let lo = ci * FIT_CHUNK;
        let hi = ((ci + 1) * FIT_CHUNK).min(n);
        let mut fits = Vec::with_capacity(hi - lo);
        for i in lo..hi {
            fits.push(fit_patch(&patches[i * px..(i + 1) * px], height, width)?);
        }
        Ok((fits, busy.elapsed().as_secs_f64()))
    });
    let mut fits = Vec::with_capacity(n);
    let mut cpu_s = 0.0;
    for chunk in per_chunk {
        let (f, busy) = chunk?;
        fits.extend(f);
        cpu_s += busy;
    }
    let timing = BatchTiming {
        n,
        wall_s: started.elapsed().as_secs_f64(),
        cpu_s,
        threads: pool.threads(),
    };
    Ok((fits, timing))
}

/// Batch labeling on the process-wide pool, with full timing.
pub fn label_patches_timed(
    patches: &[f32],
    n: usize,
    height: usize,
    width: usize,
) -> Result<(Vec<PeakFit>, BatchTiming)> {
    label_patches_with(Pool::global(), patches, n, height, width)
}

/// Batch labeling routed through [`crate::pool::scope`] stage fan-out:
/// the batch is cut into `FIT_CHUNK`-sized one-shot tasks on the
/// process-wide pool — the same entry point the flows/faas layers expose
/// (`FlowEngine::scope` / `FaasService::scope`), so callers living at
/// that layer (e.g. `workflow::functions::label_data`) share the one
/// `XLOOP_THREADS` knob. Chunking matches `label_patches_with`, so the
/// fits are bit-identical to the serial path for any thread count.
pub fn label_patches_scoped(
    patches: &[f32],
    n: usize,
    height: usize,
    width: usize,
) -> Result<(Vec<PeakFit>, BatchTiming)> {
    let px = height * width;
    assert_eq!(patches.len(), n * px, "patch buffer size mismatch");
    let started = Instant::now();
    let n_chunks = n.div_ceil(FIT_CHUNK);
    let tasks: Vec<crate::pool::ScopeTask<Result<(Vec<PeakFit>, f64)>>> = (0..n_chunks)
        .map(|ci| {
            Box::new(move || {
                let busy = Instant::now();
                let lo = ci * FIT_CHUNK;
                let hi = ((ci + 1) * FIT_CHUNK).min(n);
                let mut fits = Vec::with_capacity(hi - lo);
                for i in lo..hi {
                    fits.push(fit_patch(&patches[i * px..(i + 1) * px], height, width)?);
                }
                Ok((fits, busy.elapsed().as_secs_f64()))
            }) as crate::pool::ScopeTask<Result<(Vec<PeakFit>, f64)>>
        })
        .collect();
    let per_chunk = crate::pool::scope(tasks);
    let mut fits = Vec::with_capacity(n);
    let mut cpu_s = 0.0;
    for chunk in per_chunk {
        let (f, busy) = chunk?;
        fits.extend(f);
        cpu_s += busy;
    }
    let timing = BatchTiming {
        n,
        wall_s: started.elapsed().as_secs_f64(),
        cpu_s,
        threads: Pool::global().threads(),
    };
    Ok((fits, timing))
}

/// Strictly serial batch labeling — the seed baseline, kept as the
/// reference path `cargo bench --bench micro` compares the pool against.
pub fn label_patches_serial(
    patches: &[f32],
    n: usize,
    height: usize,
    width: usize,
) -> Result<(Vec<PeakFit>, BatchTiming)> {
    label_patches_with(&Pool::new(1), patches, n, height, width)
}

/// Batch labeling (the paper's A over a staged dataset): returns fits and
/// the measured wallclock per peak in seconds.
pub fn label_patches(
    patches: &[f32],
    n: usize,
    height: usize,
    width: usize,
) -> Result<(Vec<PeakFit>, f64)> {
    let (fits, timing) = label_patches_timed(patches, n, height, width)?;
    Ok((fits, timing.per_peak_wall_s()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn render(params: &[f64; N_PARAMS], h: usize, w: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; h * w];
        for r in 0..h {
            for c in 0..w {
                out[r * w + c] = value(params, c as f64, r as f64) as f32;
            }
        }
        out
    }

    #[test]
    fn recovers_clean_peak_to_subpixel() {
        let truth = [150.0, 4.6, 5.8, 1.3, 1.9, 0.3, 4.0];
        let patch = render(&truth, 11, 11);
        let fit = fit_patch(&patch, 11, 11).unwrap();
        let (x, y) = fit.center();
        assert!((x - 4.6).abs() < 0.02, "x {x}");
        assert!((y - 5.8).abs() < 0.02, "y {y}");
        assert!((fit.params[P_SX] - 1.3).abs() < 0.05);
        assert!((fit.params[P_ETA] - 0.3).abs() < 0.1);
    }

    #[test]
    fn recovers_noisy_peak_within_tenth_pixel() {
        let truth = [200.0, 5.4, 4.2, 1.6, 1.4, 0.5, 6.0];
        let clean = render(&truth, 11, 11);
        let mut rng = crate::util::Rng::new(11);
        let noisy: Vec<f32> = clean
            .iter()
            .map(|&v| rng.poisson(v as f64) as f32)
            .collect();
        let fit = fit_patch(&noisy, 11, 11).unwrap();
        let (x, y) = fit.center();
        assert!((x - 5.4).abs() < 0.1, "x {x}");
        assert!((y - 4.2).abs() < 0.1, "y {y}");
    }

    #[test]
    fn initial_guess_is_reasonable() {
        let truth = [100.0, 3.0, 7.0, 1.0, 1.0, 0.4, 2.0];
        let patch = render(&truth, 11, 11);
        let g = initial_guess(&patch, 11, 11);
        assert!((g[P_X0] - 3.0).abs() < 1.0, "{g:?}");
        assert!((g[P_Y0] - 7.0).abs() < 1.0, "{g:?}");
        assert!(g[P_BG] <= 3.0 + 1e-6);
    }

    #[test]
    fn flat_patch_does_not_explode() {
        let patch = vec![5.0f32; 121];
        let fit = fit_patch(&patch, 11, 11).unwrap();
        assert!(fit.params.iter().all(|v| v.is_finite()), "{fit:?}");
    }

    #[test]
    fn batch_labeling_times_per_peak() {
        let truth = [150.0, 5.0, 5.0, 1.5, 1.5, 0.4, 3.0];
        let one = render(&truth, 11, 11);
        let mut all = Vec::new();
        for _ in 0..16 {
            all.extend_from_slice(&one);
        }
        let (fits, per_peak) = label_patches(&all, 16, 11, 11).unwrap();
        assert_eq!(fits.len(), 16);
        assert!(per_peak > 0.0 && per_peak < 0.1, "{per_peak}");
    }

    /// The acceptance property of the parallel path: same fits, same
    /// order, bit for bit, whatever the thread count.
    #[test]
    fn parallel_labeling_is_bit_identical_to_serial() {
        // 37 noisy patches: not a multiple of FIT_CHUNK, several chunks
        let mut rng = crate::util::Rng::new(21);
        let mut all = Vec::new();
        for _ in 0..37 {
            let truth = [
                rng.uniform(80.0, 300.0),
                rng.uniform(3.0, 7.0),
                rng.uniform(3.0, 7.0),
                rng.uniform(0.9, 2.0),
                rng.uniform(0.9, 2.0),
                rng.uniform(0.1, 0.9),
                rng.uniform(1.0, 6.0),
            ];
            let clean = render(&truth, 11, 11);
            all.extend(clean.iter().map(|&v| rng.poisson(v as f64) as f32));
        }
        let (serial, st) = label_patches_with(&Pool::new(1), &all, 37, 11, 11).unwrap();
        let (parallel, pt) = label_patches_with(&Pool::new(4), &all, 37, 11, 11).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.params, b.params);
            assert_eq!(a.cost, b.cost);
            assert_eq!(a.iterations, b.iterations);
        }
        assert_eq!(st.threads, 1);
        assert_eq!(pt.threads, 4);
        assert!(st.cpu_s > 0.0 && pt.cpu_s > 0.0);
    }

    /// The scope-routed entry point must produce the same fits as the
    /// serial path, bit for bit (same FIT_CHUNK decomposition).
    #[test]
    fn scoped_labeling_is_bit_identical_to_serial() {
        let mut rng = crate::util::Rng::new(33);
        let mut all = Vec::new();
        for _ in 0..21 {
            let truth = [
                rng.uniform(80.0, 300.0),
                rng.uniform(3.0, 7.0),
                rng.uniform(3.0, 7.0),
                rng.uniform(0.9, 2.0),
                rng.uniform(0.9, 2.0),
                rng.uniform(0.1, 0.9),
                rng.uniform(1.0, 6.0),
            ];
            let clean = render(&truth, 11, 11);
            all.extend(clean.iter().map(|&v| rng.poisson(v as f64) as f32));
        }
        let (serial, _) = label_patches_with(&Pool::new(1), &all, 21, 11, 11).unwrap();
        let (scoped, t) = label_patches_scoped(&all, 21, 11, 11).unwrap();
        assert_eq!(serial.len(), scoped.len());
        for (a, b) in serial.iter().zip(&scoped) {
            assert_eq!(a.params, b.params);
            assert_eq!(a.cost, b.cost);
        }
        assert!(t.cpu_s > 0.0 && t.wall_s > 0.0);
    }

    #[test]
    fn timing_fields_are_consistent() {
        let truth = [150.0, 5.0, 5.0, 1.5, 1.5, 0.4, 3.0];
        let one = render(&truth, 11, 11);
        let mut all = Vec::new();
        for _ in 0..24 {
            all.extend_from_slice(&one);
        }
        let (fits, t) = label_patches_timed(&all, 24, 11, 11).unwrap();
        assert_eq!(fits.len(), 24);
        assert_eq!(t.n, 24);
        assert!(t.wall_s > 0.0 && t.cpu_s > 0.0);
        assert!(t.per_peak_wall_s() < 0.1);
        assert!(t.speedup() > 0.0);
    }

    #[test]
    fn empty_batch_is_fine() {
        let (fits, t) = label_patches_timed(&[], 0, 11, 11).unwrap();
        assert!(fits.is_empty());
        assert_eq!(t.n, 0);
        assert_eq!(t.per_peak_wall_s(), t.wall_s);
    }
}
