//! The conventional Bragg-peak analyzer **A**: pseudo-Voigt LM fitting of
//! detector patches — the baseline BraggNN replaces (paper §4.2/§5.2:
//! "positions are typically computed by fitting the observed intensities
//! ... to a theoretical peak shape such as pseudo-Voigt").
//!
//! Real compute, really run: `label_patches` measures its own wallclock
//! so EXPERIMENTS.md reports an honest C(A) on this machine.

use anyhow::Result;

use super::lm::{solve, LeastSquares, LmOptions, LmResult};
use super::pseudo_voigt::{jacobian, value, N_PARAMS, P_AMP, P_BG, P_ETA, P_SX, P_SY, P_X0, P_Y0};

/// One fitted peak.
#[derive(Debug, Clone, Copy)]
pub struct PeakFit {
    /// [amp, x0, y0, sigma_x, sigma_y, eta, bg]
    pub params: [f64; N_PARAMS],
    pub cost: f64,
    pub iterations: u32,
    pub converged: bool,
}

impl PeakFit {
    pub fn center(&self) -> (f64, f64) {
        (self.params[P_X0], self.params[P_Y0])
    }
}

struct PatchProblem<'a> {
    patch: &'a [f32],
    height: usize,
    width: usize,
}

impl LeastSquares<N_PARAMS> for PatchProblem<'_> {
    fn n_residuals(&self) -> usize {
        self.patch.len()
    }

    fn residual(&self, p: &[f64; N_PARAMS], i: usize) -> f64 {
        let y = (i / self.width) as f64;
        let x = (i % self.width) as f64;
        value(p, x, y) - self.patch[i] as f64
    }

    fn jacobian_row(&self, p: &[f64; N_PARAMS], i: usize) -> [f64; N_PARAMS] {
        let y = (i / self.width) as f64;
        let x = (i % self.width) as f64;
        jacobian(p, x, y)
    }

    fn project(&self, p: &mut [f64; N_PARAMS]) {
        p[P_AMP] = p[P_AMP].max(1e-3);
        p[P_X0] = p[P_X0].clamp(0.0, (self.width - 1) as f64);
        p[P_Y0] = p[P_Y0].clamp(0.0, (self.height - 1) as f64);
        p[P_SX] = p[P_SX].clamp(0.2, self.width as f64);
        p[P_SY] = p[P_SY].clamp(0.2, self.height as f64);
        p[P_ETA] = p[P_ETA].clamp(0.0, 1.0);
        p[P_BG] = p[P_BG].max(0.0);
    }
}

/// Moment-based initial guess: background from the patch border, centroid
/// and second moments from background-subtracted intensity.
pub fn initial_guess(patch: &[f32], height: usize, width: usize) -> [f64; N_PARAMS] {
    let mut bg = f64::INFINITY;
    for r in 0..height {
        for c in 0..width {
            if r == 0 || c == 0 || r == height - 1 || c == width - 1 {
                bg = bg.min(patch[r * width + c] as f64);
            }
        }
    }
    if !bg.is_finite() {
        bg = 0.0;
    }
    let mut mass = 0.0;
    let mut mx = 0.0;
    let mut my = 0.0;
    let mut peak = 0.0f64;
    for r in 0..height {
        for c in 0..width {
            let v = (patch[r * width + c] as f64 - bg).max(0.0);
            mass += v;
            mx += v * c as f64;
            my += v * r as f64;
            peak = peak.max(v);
        }
    }
    let (x0, y0) = if mass > 0.0 {
        (mx / mass, my / mass)
    } else {
        ((width / 2) as f64, (height / 2) as f64)
    };
    let mut vx = 0.0;
    let mut vy = 0.0;
    if mass > 0.0 {
        for r in 0..height {
            for c in 0..width {
                let v = (patch[r * width + c] as f64 - bg).max(0.0);
                vx += v * (c as f64 - x0).powi(2);
                vy += v * (r as f64 - y0).powi(2);
            }
        }
        vx /= mass;
        vy /= mass;
    }
    [
        peak.max(1e-3),
        x0,
        y0,
        vx.sqrt().clamp(0.5, width as f64 / 2.0),
        vy.sqrt().clamp(0.5, height as f64 / 2.0),
        0.5,
        bg,
    ]
}

/// Fit one patch (row-major `height x width` intensities).
pub fn fit_patch(patch: &[f32], height: usize, width: usize) -> Result<PeakFit> {
    let prob = PatchProblem {
        patch,
        height,
        width,
    };
    let init = initial_guess(patch, height, width);
    let LmResult {
        params,
        cost,
        iterations,
        converged,
    } = solve(&prob, init, LmOptions::default())?;
    Ok(PeakFit {
        params,
        cost,
        iterations,
        converged,
    })
}

/// Batch labeling (the paper's A over a staged dataset): returns fits and
/// the measured wallclock per peak in seconds.
pub fn label_patches(
    patches: &[f32],
    n: usize,
    height: usize,
    width: usize,
) -> Result<(Vec<PeakFit>, f64)> {
    let px = height * width;
    assert_eq!(patches.len(), n * px, "patch buffer size mismatch");
    let started = std::time::Instant::now();
    let fits = (0..n)
        .map(|i| fit_patch(&patches[i * px..(i + 1) * px], height, width))
        .collect::<Result<Vec<_>>>()?;
    let per_peak = started.elapsed().as_secs_f64() / n.max(1) as f64;
    Ok((fits, per_peak))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn render(params: &[f64; N_PARAMS], h: usize, w: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; h * w];
        for r in 0..h {
            for c in 0..w {
                out[r * w + c] = value(params, c as f64, r as f64) as f32;
            }
        }
        out
    }

    #[test]
    fn recovers_clean_peak_to_subpixel() {
        let truth = [150.0, 4.6, 5.8, 1.3, 1.9, 0.3, 4.0];
        let patch = render(&truth, 11, 11);
        let fit = fit_patch(&patch, 11, 11).unwrap();
        let (x, y) = fit.center();
        assert!((x - 4.6).abs() < 0.02, "x {x}");
        assert!((y - 5.8).abs() < 0.02, "y {y}");
        assert!((fit.params[P_SX] - 1.3).abs() < 0.05);
        assert!((fit.params[P_ETA] - 0.3).abs() < 0.1);
    }

    #[test]
    fn recovers_noisy_peak_within_tenth_pixel() {
        let truth = [200.0, 5.4, 4.2, 1.6, 1.4, 0.5, 6.0];
        let clean = render(&truth, 11, 11);
        let mut rng = crate::util::Rng::new(11);
        let noisy: Vec<f32> = clean
            .iter()
            .map(|&v| rng.poisson(v as f64) as f32)
            .collect();
        let fit = fit_patch(&noisy, 11, 11).unwrap();
        let (x, y) = fit.center();
        assert!((x - 5.4).abs() < 0.1, "x {x}");
        assert!((y - 4.2).abs() < 0.1, "y {y}");
    }

    #[test]
    fn initial_guess_is_reasonable() {
        let truth = [100.0, 3.0, 7.0, 1.0, 1.0, 0.4, 2.0];
        let patch = render(&truth, 11, 11);
        let g = initial_guess(&patch, 11, 11);
        assert!((g[P_X0] - 3.0).abs() < 1.0, "{g:?}");
        assert!((g[P_Y0] - 7.0).abs() < 1.0, "{g:?}");
        assert!(g[P_BG] <= 3.0 + 1e-6);
    }

    #[test]
    fn flat_patch_does_not_explode() {
        let patch = vec![5.0f32; 121];
        let fit = fit_patch(&patch, 11, 11).unwrap();
        assert!(fit.params.iter().all(|v| v.is_finite()), "{fit:?}");
    }

    #[test]
    fn batch_labeling_times_per_peak() {
        let truth = [150.0, 5.0, 5.0, 1.5, 1.5, 0.4, 3.0];
        let one = render(&truth, 11, 11);
        let mut all = Vec::new();
        for _ in 0..16 {
            all.extend_from_slice(&one);
        }
        let (fits, per_peak) = label_patches(&all, 16, 11, 11).unwrap();
        assert_eq!(fits.len(), 16);
        assert!(per_peak > 0.0 && per_peak < 0.1, "{per_peak}");
    }
}
