//! Conventional analysis baseline **A**: pseudo-Voigt peak fitting via
//! Levenberg–Marquardt — the method BraggNN replaces, implemented for
//! real (it also produces the training labels in the DNNTrainerFlow).

pub mod fitter;
pub mod lm;
pub mod pseudo_voigt;

pub use fitter::{
    fit_patch, initial_guess, label_patches, label_patches_scoped, label_patches_serial,
    label_patches_timed, label_patches_with, BatchTiming, PeakFit, FIT_CHUNK,
};
pub use lm::{solve as lm_solve, LeastSquares, LmOptions, LmOutcome, LmResult};
