//! Typed views over the AOT artifact metadata (`artifacts/*_meta.json`).
//!
//! aot.py emits, per model, the flat train/infer ABI (tensor order,
//! shapes, output counts), Adam hyperparameters, analytic FLOP counts and
//! initial-parameter snapshots. This module parses those sidecars so the
//! trainer and runtime can feed PJRT executables positionally without any
//! Python at runtime.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// One parameter tensor of a model.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// artifact-relative path of the He-init snapshot (raw LE f32)
    pub init_file: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// ABI of one lowered executable (train step or inference).
#[derive(Debug, Clone)]
pub struct PhaseMeta {
    /// artifact-relative HLO text file
    pub file: String,
    pub n_args: usize,
    pub n_outputs: usize,
    pub arg_shapes: Vec<Vec<usize>>,
}

/// Adam hyperparameters baked into the train-step HLO (informational —
/// the values live inside the artifact; these let reports show them).
#[derive(Debug, Clone, Copy)]
pub struct AdamMeta {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

/// Full metadata for one model's artifacts.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub param_count: usize,
    pub params: Vec<TensorSpec>,
    pub input_shape: Vec<usize>,
    pub target_shape: Vec<usize>,
    pub train_batch: usize,
    pub infer_batch: usize,
    pub adam: AdamMeta,
    pub fwd_flops_per_sample: f64,
    pub train_flops_per_step: f64,
    /// wire size of one (input, label) sample in bytes (16-bit pixels)
    pub sample_bytes: usize,
    pub train: PhaseMeta,
    pub infer: PhaseMeta,
    /// directory the artifact-relative paths resolve against
    pub artifacts_dir: PathBuf,
}

impl ModelMeta {
    pub fn load(artifacts_dir: &Path, model: &str) -> Result<ModelMeta> {
        let path = artifacts_dir.join(format!("{model}_meta.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(&j, artifacts_dir)
    }

    pub fn from_json(j: &Json, artifacts_dir: &Path) -> Result<ModelMeta> {
        let name = j
            .get("name")
            .as_str()
            .context("meta missing `name`")?
            .to_string();
        let params = j
            .get("params")
            .as_arr()
            .context("meta missing `params`")?
            .iter()
            .map(|p| {
                Ok(TensorSpec {
                    name: p.get("name").as_str().context("param name")?.to_string(),
                    shape: parse_shape(p.get("shape"))?,
                    init_file: p.get("init").as_str().context("param init")?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let param_count = j
            .get("param_count")
            .as_usize()
            .context("meta missing `param_count`")?;
        let declared: usize = params.iter().map(|p| p.elems()).sum();
        if declared != param_count {
            bail!("param_count {param_count} != sum of tensor sizes {declared}");
        }
        let adam = AdamMeta {
            lr: j.get("adam").get("lr").as_f64().context("adam lr")?,
            beta1: j.get("adam").get("beta1").as_f64().context("adam beta1")?,
            beta2: j.get("adam").get("beta2").as_f64().context("adam beta2")?,
            eps: j.get("adam").get("eps").as_f64().context("adam eps")?,
        };
        let meta = ModelMeta {
            param_count,
            input_shape: parse_shape(j.get("input_shape"))?,
            target_shape: parse_shape(j.get("target_shape"))?,
            train_batch: j.get("train_batch").as_usize().context("train_batch")?,
            infer_batch: j.get("infer_batch").as_usize().context("infer_batch")?,
            adam,
            fwd_flops_per_sample: j
                .get("fwd_flops_per_sample")
                .as_f64()
                .context("fwd_flops_per_sample")?,
            train_flops_per_step: j
                .get("train_flops_per_step")
                .as_f64()
                .context("train_flops_per_step")?,
            sample_bytes: j.get("sample_bytes").as_usize().context("sample_bytes")?,
            train: parse_phase(j.get("train"))?,
            infer: parse_phase(j.get("infer"))?,
            params,
            name,
            artifacts_dir: artifacts_dir.to_path_buf(),
        };
        meta.validate()?;
        Ok(meta)
    }

    fn validate(&self) -> Result<()> {
        let n = self.params.len();
        if self.train.n_args != 3 * n + 3 {
            bail!(
                "train ABI mismatch: n_args {} != 3*{n}+3",
                self.train.n_args
            );
        }
        if self.train.n_outputs != 3 * n + 2 {
            bail!(
                "train ABI mismatch: n_outputs {} != 3*{n}+2",
                self.train.n_outputs
            );
        }
        if self.infer.n_args != n + 1 {
            bail!("infer ABI mismatch: n_args {} != {n}+1", self.infer.n_args);
        }
        for (i, p) in self.params.iter().enumerate() {
            for k in [i, n + i, 2 * n + i] {
                if self.train.arg_shapes[k] != p.shape {
                    bail!("train arg {k} shape != param `{}`", p.name);
                }
            }
        }
        Ok(())
    }

    pub fn train_hlo_path(&self) -> PathBuf {
        self.artifacts_dir.join(&self.train.file)
    }

    pub fn infer_hlo_path(&self) -> PathBuf {
        self.artifacts_dir.join(&self.infer.file)
    }

    /// Load the He-init parameter snapshots (raw little-endian f32).
    pub fn load_init_params(&self) -> Result<Vec<Vec<f32>>> {
        self.params
            .iter()
            .map(|p| {
                let path = self.artifacts_dir.join(&p.init_file);
                let bytes = std::fs::read(&path)
                    .with_context(|| format!("reading init snapshot {path:?}"))?;
                if bytes.len() != 4 * p.elems() {
                    bail!(
                        "init `{}`: {} bytes, expected {}",
                        p.name,
                        bytes.len(),
                        4 * p.elems()
                    );
                }
                Ok(bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect())
            })
            .collect()
    }

    /// Total parameter bytes (f32), e.g. the "model transfer" payload.
    pub fn param_bytes(&self) -> u64 {
        4 * self.param_count as u64
    }

    /// Dataset wire size for `n` samples.
    pub fn dataset_bytes(&self, n: u64) -> u64 {
        n * self.sample_bytes as u64
    }
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|d| d.as_usize().context("shape dim not a non-negative int"))
        .collect()
}

fn parse_phase(j: &Json) -> Result<PhaseMeta> {
    Ok(PhaseMeta {
        file: j.get("file").as_str().context("phase file")?.to_string(),
        n_args: j.get("n_args").as_usize().context("phase n_args")?,
        n_outputs: j.get("n_outputs").as_usize().context("phase n_outputs")?,
        arg_shapes: j
            .get("arg_shapes")
            .as_arr()
            .context("phase arg_shapes")?
            .iter()
            .map(parse_shape)
            .collect::<Result<Vec<_>>>()?,
    })
}

/// Metadata for the pseudo-Voigt synthesis artifact.
#[derive(Debug, Clone)]
pub struct PvMeta {
    pub file: String,
    pub batch: usize,
    pub height: usize,
    pub width: usize,
    pub artifacts_dir: PathBuf,
}

impl PvMeta {
    pub fn load(artifacts_dir: &Path) -> Result<PvMeta> {
        let path = artifacts_dir.join("pv_meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text)?;
        let order: Vec<&str> = j
            .get("param_order")
            .as_arr()
            .context("pv param_order")?
            .iter()
            .filter_map(|v| v.as_str())
            .collect();
        if order != ["amp", "x0", "y0", "sigma_x", "sigma_y", "eta", "bg"] {
            bail!("pv param order changed: {order:?}");
        }
        Ok(PvMeta {
            file: j.get("file").as_str().context("pv file")?.to_string(),
            batch: j.get("batch").as_usize().context("pv batch")?,
            height: j.get("height").as_usize().context("pv height")?,
            width: j.get("width").as_usize().context("pv width")?,
            artifacts_dir: artifacts_dir.to_path_buf(),
        })
    }

    pub fn hlo_path(&self) -> PathBuf {
        self.artifacts_dir.join(&self.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_meta_json() -> String {
        // 2-tensor toy model with a consistent ABI
        r#"{
          "name": "toy",
          "param_count": 8,
          "params": [
            {"name": "w", "shape": [2, 3], "init": "init/toy_p0.bin"},
            {"name": "b", "shape": [2], "init": "init/toy_p1.bin"}
          ],
          "input_shape": [3], "target_shape": [2],
          "train_batch": 4, "infer_batch": 8,
          "adam": {"lr": 0.001, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8},
          "fwd_flops_per_sample": 12,
          "train_flops_per_step": 224,
          "sample_bytes": 14,
          "train": {
            "file": "toy_train.hlo.txt", "n_args": 9, "n_outputs": 8,
            "arg_shapes": [[2,3],[2],[2,3],[2],[2,3],[2],[],[4,3],[4,2]]
          },
          "infer": {
            "file": "toy_infer.hlo.txt", "n_args": 3, "n_outputs": 1,
            "arg_shapes": [[2,3],[2],[8,3]]
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_consistent_meta() {
        let j = Json::parse(&fake_meta_json()).unwrap();
        let m = ModelMeta::from_json(&j, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.name, "toy");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.param_bytes(), 32);
        assert_eq!(m.dataset_bytes(10), 140);
        assert_eq!(m.train_hlo_path(), PathBuf::from("/tmp/a/toy_train.hlo.txt"));
    }

    #[test]
    fn rejects_bad_param_count() {
        let text = fake_meta_json().replace("\"param_count\": 8", "\"param_count\": 9");
        let j = Json::parse(&text).unwrap();
        assert!(ModelMeta::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_bad_abi() {
        let text = fake_meta_json().replace("\"n_args\": 9", "\"n_args\": 8");
        let j = Json::parse(&text).unwrap();
        let err = ModelMeta::from_json(&j, Path::new("/tmp")).unwrap_err();
        assert!(err.to_string().contains("ABI"), "{err}");
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = crate::models::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return; // `make artifacts` not run yet
        }
        for name in ["braggnn", "cookienetae"] {
            let m = ModelMeta::load(&dir, name).unwrap();
            assert!(m.param_count > 10_000, "{name}");
            assert!(m.train_flops_per_step > 1e6, "{name}");
            let init = m.load_init_params().unwrap();
            assert_eq!(init.len(), m.params.len());
        }
        let pv = PvMeta::load(&dir).unwrap();
        assert_eq!((pv.height, pv.width), (11, 11));
    }
}
