//! Lookup of every model the artifact directory carries, plus the model
//! repository abstraction the paper's Future Work §7(1) sketches (pick a
//! foundation model to fine-tune instead of retraining from scratch).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::meta::{ModelMeta, PvMeta};
use crate::util::Json;

/// All models known to an artifact directory.
#[derive(Debug)]
pub struct ModelRegistry {
    dir: PathBuf,
    models: BTreeMap<String, ModelMeta>,
    pv: Option<PvMeta>,
}

impl ModelRegistry {
    /// Read `manifest.json` and load every model's metadata.
    pub fn load(dir: &Path) -> Result<ModelRegistry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Json::parse(&text)?;
        let mut models = BTreeMap::new();
        if let Some(obj) = manifest.get("models").as_obj() {
            for name in obj.keys() {
                models.insert(name.clone(), ModelMeta::load(dir, name)?);
            }
        }
        if models.is_empty() {
            bail!("manifest {manifest_path:?} lists no models");
        }
        let pv = if manifest.get("pv").is_null() {
            None
        } else {
            Some(PvMeta::load(dir)?)
        };
        Ok(ModelRegistry {
            dir: dir.to_path_buf(),
            models,
            pv,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn get(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).with_context(|| {
            format!(
                "unknown model `{name}` (available: {})",
                self.names().join(", ")
            )
        })
    }

    pub fn pv(&self) -> Result<&PvMeta> {
        self.pv
            .as_ref()
            .context("artifacts carry no pv_surface module")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_registry_if_present() {
        let dir = crate::models::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let reg = ModelRegistry::load(&dir).unwrap();
        assert_eq!(reg.names(), vec!["braggnn", "cookienetae"]);
        assert!(reg.get("braggnn").is_ok());
        assert!(reg.get("nope").is_err());
        assert!(reg.pv().is_ok());
    }

    #[test]
    fn missing_dir_is_actionable() {
        let err = ModelRegistry::load(Path::new("/nonexistent/path")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
