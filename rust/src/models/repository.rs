//! Model repository — the paper's Future Work §7(1), implemented:
//! "building the model repository ... so as to pick up the right model
//! as foundation to fine-tune using new dataset instead of retraining
//! from scratch, to further accelerate the training process."
//!
//! The repository stores versioned trained checkpoints per model, tagged
//! with the experiment context they came from; `select_foundation` picks
//! the best warm start for a new context (same model + closest context,
//! lowest validation loss); the trainer then fine-tunes from it, which
//! the warm-start ablation (`xloop::workflow` tests and the `micro`
//! bench) shows converges in a fraction of the cold-start steps.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::runtime::Tensor;

/// Experiment context a checkpoint was trained under (used for
/// similarity matching when choosing a foundation).
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentTag {
    /// sample / beamline descriptor, free-form ("Ti64-layer3")
    pub sample: String,
    /// detector distance or comparable numeric knob (arbitrary units)
    pub setting: f64,
}

impl ExperimentTag {
    /// Similarity distance: different sample dominates, then the knob.
    pub fn distance(&self, other: &ExperimentTag) -> f64 {
        let sample_penalty = if self.sample == other.sample { 0.0 } else { 10.0 };
        sample_penalty + (self.setting - other.setting).abs()
    }
}

/// One stored checkpoint.
pub struct Checkpoint {
    pub model: String,
    pub version: u32,
    pub params: Vec<Tensor>,
    pub val_loss: f32,
    pub tag: ExperimentTag,
    /// virtual time the producing run spent training
    pub train_virtual_s: f64,
}

/// Versioned checkpoint store, per model.
#[derive(Default)]
pub struct ModelRepository {
    store: BTreeMap<String, Vec<Checkpoint>>,
}

impl ModelRepository {
    pub fn new() -> Self {
        Self::default()
    }

    /// Store a checkpoint; returns its version (1-based per model).
    pub fn publish(
        &mut self,
        model: &str,
        params: Vec<Tensor>,
        val_loss: f32,
        tag: ExperimentTag,
        train_virtual_s: f64,
    ) -> Result<u32> {
        if params.is_empty() {
            bail!("refusing to publish `{model}` with no parameter tensors");
        }
        if !val_loss.is_finite() {
            bail!("refusing to publish `{model}` with non-finite val loss");
        }
        let entry = self.store.entry(model.to_string()).or_default();
        let version = entry.len() as u32 + 1;
        entry.push(Checkpoint {
            model: model.to_string(),
            version,
            params,
            val_loss,
            tag,
            train_virtual_s,
        });
        Ok(version)
    }

    pub fn versions(&self, model: &str) -> usize {
        self.store.get(model).map(|v| v.len()).unwrap_or(0)
    }

    pub fn get(&self, model: &str, version: u32) -> Result<&Checkpoint> {
        self.store
            .get(model)
            .and_then(|v| v.get(version as usize - 1))
            .with_context(|| format!("no checkpoint `{model}` v{version}"))
    }

    /// Pick the foundation checkpoint for a new experiment context:
    /// minimize (context distance, then val loss). `None` when the
    /// repository has nothing for this model (cold start).
    pub fn select_foundation(
        &self,
        model: &str,
        tag: &ExperimentTag,
    ) -> Option<&Checkpoint> {
        self.store.get(model)?.iter().min_by(|a, b| {
            (a.tag.distance(tag), a.val_loss)
                .partial_cmp(&(b.tag.distance(tag), b.val_loss))
                .unwrap()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> Vec<Tensor> {
        vec![Tensor::zeros(vec![2, 2])]
    }

    fn tag(sample: &str, setting: f64) -> ExperimentTag {
        ExperimentTag {
            sample: sample.into(),
            setting,
        }
    }

    #[test]
    fn publish_and_version() {
        let mut repo = ModelRepository::new();
        assert_eq!(repo.versions("braggnn"), 0);
        assert_eq!(
            repo.publish("braggnn", params(), 0.1, tag("Ti64", 1.0), 19.0).unwrap(),
            1
        );
        assert_eq!(
            repo.publish("braggnn", params(), 0.05, tag("Ti64", 2.0), 19.0).unwrap(),
            2
        );
        assert_eq!(repo.versions("braggnn"), 2);
        assert_eq!(repo.get("braggnn", 2).unwrap().val_loss, 0.05);
        assert!(repo.get("braggnn", 3).is_err());
        assert!(repo.get("cookienetae", 1).is_err());
    }

    #[test]
    fn selection_prefers_same_sample_then_loss() {
        let mut repo = ModelRepository::new();
        repo.publish("m", params(), 0.50, tag("A", 1.0), 19.0).unwrap();
        repo.publish("m", params(), 0.01, tag("B", 1.0), 19.0).unwrap();
        repo.publish("m", params(), 0.20, tag("A", 1.2), 19.0).unwrap();
        // same sample (A) wins over better loss on sample B; closer
        // setting breaks the tie within A
        let best = repo.select_foundation("m", &tag("A", 1.15)).unwrap();
        assert_eq!(best.version, 3);
        // unknown model -> cold start
        assert!(repo.select_foundation("x", &tag("A", 1.0)).is_none());
    }

    #[test]
    fn rejects_bad_checkpoints() {
        let mut repo = ModelRepository::new();
        assert!(repo.publish("m", vec![], 0.1, tag("A", 0.0), 1.0).is_err());
        assert!(repo
            .publish("m", params(), f32::NAN, tag("A", 0.0), 1.0)
            .is_err());
    }
}
