//! Model registry: typed access to the AOT artifacts the Python layer
//! built (`make artifacts`). No Python runs past this point.

pub mod meta;
pub mod registry;
pub mod repository;

pub use meta::{ModelMeta, PhaseMeta, PvMeta, TensorSpec};
pub use registry::ModelRegistry;
pub use repository::{Checkpoint, ExperimentTag, ModelRepository};

use std::path::PathBuf;

/// Default artifact directory: `$XLOOP_ARTIFACTS` or `<repo>/artifacts`
/// (resolved relative to the crate root so tests work from any cwd).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("XLOOP_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
