//! Config system: JSON fabric/scenario configuration with defaults.
//!
//! Everything the paper fabric hard-codes can be overridden from a
//! config file (CLI: `--config path.json`): topology, transfer tunables,
//! accelerator constants, and scenario parameters. Partial configs are
//! fine — anything omitted keeps the paper-calibrated default.
//!
//! ```json
//! {
//!   "topology": { "facilities": [...], "links": [...], "routes": [...] },
//!   "transfer": { "per_flow_cap_gbps": 4.0, "auto_concurrency": 16 },
//!   "accelerators": { "alcf#cerebras": { "per_step_overhead_ms": 0.2 } },
//!   "scenario":  { "staged_gb": 5.0, "real_samples": 1024, "seed": 7 }
//! }
//! ```

use std::path::Path;

use anyhow::{Context, Result};

use crate::simnet::Topology;
use crate::util::Json;
use crate::workflow::{Coordinator, Scenario};

/// Parsed configuration (all sections optional).
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub topology: Option<Topology>,
    pub transfer: Option<TransferOverrides>,
    pub accelerators: Vec<AccelOverride>,
    pub scenario: Option<ScenarioOverrides>,
}

#[derive(Debug, Clone, Default)]
pub struct TransferOverrides {
    pub per_file_startup_s: Option<f64>,
    pub per_flow_cap_gbps: Option<f64>,
    pub auto_concurrency: Option<usize>,
    pub submit_overhead_s: Option<f64>,
    pub completion_detect_s: Option<f64>,
}

#[derive(Debug, Clone)]
pub struct AccelOverride {
    pub endpoint: String,
    pub peak_tflops: Option<f64>,
    pub efficiency: Option<f64>,
    pub per_step_overhead_ms: Option<f64>,
    pub setup_s: Option<f64>,
}

#[derive(Debug, Clone, Default)]
pub struct ScenarioOverrides {
    pub staged_gb: Option<f64>,
    pub real_samples: Option<usize>,
    pub seed: Option<u64>,
}

impl Config {
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing config {path:?}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Config> {
        let mut cfg = Config::default();
        if !j.get("topology").is_null() {
            cfg.topology = Some(Topology::from_json(j.get("topology"))?);
        }
        let t = j.get("transfer");
        if !t.is_null() {
            cfg.transfer = Some(TransferOverrides {
                per_file_startup_s: t.get("per_file_startup_s").as_f64(),
                per_flow_cap_gbps: t.get("per_flow_cap_gbps").as_f64(),
                auto_concurrency: t.get("auto_concurrency").as_usize(),
                submit_overhead_s: t.get("submit_overhead_s").as_f64(),
                completion_detect_s: t.get("completion_detect_s").as_f64(),
            });
        }
        if let Some(obj) = j.get("accelerators").as_obj() {
            for (endpoint, a) in obj {
                cfg.accelerators.push(AccelOverride {
                    endpoint: endpoint.clone(),
                    peak_tflops: a.get("peak_tflops").as_f64(),
                    efficiency: a.get("efficiency").as_f64(),
                    per_step_overhead_ms: a.get("per_step_overhead_ms").as_f64(),
                    setup_s: a.get("setup_s").as_f64(),
                });
            }
        }
        let s = j.get("scenario");
        if !s.is_null() {
            cfg.scenario = Some(ScenarioOverrides {
                staged_gb: s.get("staged_gb").as_f64(),
                real_samples: s.get("real_samples").as_usize(),
                seed: s.get("seed").as_u64(),
            });
        }
        Ok(cfg)
    }

    /// Apply to a built coordinator (topology swaps the whole transfer
    /// fabric; endpoints must exist in the new topology when swapped).
    pub fn apply(&self, c: &mut Coordinator) -> Result<()> {
        if let Some(topo) = &self.topology {
            // validate the paper endpoints still resolve
            for ep in ["slac", "alcf"] {
                topo.facility(ep)
                    .with_context(|| format!("custom topology must keep facility `{ep}`"))?;
            }
            c.world.transfer.topo = topo.clone();
        }
        if let Some(t) = &self.transfer {
            let p = &mut c.world.transfer.params;
            if let Some(v) = t.per_file_startup_s {
                p.per_file_startup_s = v;
            }
            if let Some(v) = t.per_flow_cap_gbps {
                p.per_flow_cap_bps = v * 1e9 / 8.0;
            }
            if let Some(v) = t.auto_concurrency {
                p.auto_concurrency = v;
            }
            if let Some(v) = t.submit_overhead_s {
                p.submit_overhead_s = v;
            }
            if let Some(v) = t.completion_detect_s {
                p.completion_detect_s = v;
            }
        }
        for ov in &self.accelerators {
            let accel = c
                .world
                .accels
                .get_mut(&ov.endpoint)
                .with_context(|| format!("no accelerator endpoint `{}`", ov.endpoint))?;
            if let Some(v) = ov.peak_tflops {
                accel.peak_flops = v * 1e12;
            }
            if let Some(v) = ov.efficiency {
                accel.efficiency = v;
            }
            if let Some(v) = ov.per_step_overhead_ms {
                accel.per_step_overhead_s = v / 1e3;
            }
            if let Some(v) = ov.setup_s {
                accel.setup_s = v;
            }
        }
        Ok(())
    }

    /// Apply the scenario section onto a scenario.
    pub fn apply_scenario(&self, s: &mut Scenario) {
        if let Some(ov) = &self.scenario {
            if let Some(gb) = ov.staged_gb {
                s.staged_bytes = (gb * 1e9) as u64;
            }
            if let Some(n) = ov.real_samples {
                s.real_samples = n;
            }
            if let Some(seed) = ov.seed {
                s.seed = seed;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::Mode;

    fn artifacts_present() -> bool {
        crate::models::default_artifacts_dir()
            .join("manifest.json")
            .exists()
    }

    #[test]
    fn empty_config_is_noop() {
        let cfg = Config::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(cfg.topology.is_none());
        assert!(cfg.transfer.is_none());
        assert!(cfg.accelerators.is_empty());
    }

    #[test]
    fn parses_and_applies_overrides() {
        if !artifacts_present() {
            return;
        }
        let j = Json::parse(
            r#"{
              "transfer": {"per_flow_cap_gbps": 8.0, "auto_concurrency": 16},
              "accelerators": {"alcf#cerebras": {"per_step_overhead_ms": 0.1, "setup_s": 1.0}},
              "scenario": {"staged_gb": 1.0, "real_samples": 64, "seed": 5}
            }"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        let mut c = Coordinator::paper(1).unwrap();
        cfg.apply(&mut c).unwrap();
        assert_eq!(c.world.transfer.params.auto_concurrency, 16);
        assert!((c.world.transfer.params.per_flow_cap_bps - 1e9).abs() < 1.0);
        let a = c.world.accel("alcf#cerebras").unwrap();
        assert!((a.per_step_overhead_s - 1e-4).abs() < 1e-12);
        assert_eq!(a.setup_s, 1.0);

        let mut s = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        cfg.apply_scenario(&mut s);
        assert_eq!(s.staged_bytes, 1_000_000_000);
        assert_eq!(s.real_samples, 64);
        assert_eq!(s.seed, 5);
    }

    #[test]
    fn unknown_accelerator_rejected() {
        if !artifacts_present() {
            return;
        }
        let j = Json::parse(r#"{"accelerators": {"moon#tpu": {"setup_s": 1.0}}}"#).unwrap();
        let cfg = Config::from_json(&j).unwrap();
        let mut c = Coordinator::paper(1).unwrap();
        let err = cfg.apply(&mut c).unwrap_err();
        assert!(err.to_string().contains("moon#tpu"), "{err}");
    }

    #[test]
    fn custom_topology_must_keep_facilities() {
        if !artifacts_present() {
            return;
        }
        let j = Json::parse(
            r#"{"topology": {
              "facilities": ["x", "y"],
              "links": [{"name": "l", "gbps": 1.0, "latency_ms": 1.0}],
              "routes": [{"from": "x", "to": "y", "links": ["l"]}]
            }}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        let mut c = Coordinator::paper(1).unwrap();
        assert!(cfg.apply(&mut c).is_err());
    }

    #[test]
    fn faster_cerebras_config_shrinks_training_time() {
        if !artifacts_present() {
            return;
        }
        let j = Json::parse(
            r#"{"accelerators": {"alcf#cerebras": {"per_step_overhead_ms": 0.05}}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        let mut c = Coordinator::paper(2).unwrap();
        c.set_training_mode(crate::workflow::TrainingMode::VirtualOnly);
        cfg.apply(&mut c).unwrap();
        let s = Scenario::table1("braggnn", Mode::RemoteCerebras).unwrap();
        let outcome = c.run_retraining(&s, None).unwrap();
        // 76k steps * 0.05ms ~ 4s (default overhead would give ~18s)
        assert!(
            outcome.breakdown.training_s < 10.0,
            "{}",
            outcome.breakdown.training_s
        );
    }
}
