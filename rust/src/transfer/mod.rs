//! WAN transfer service (Globus Transfer analog): endpoints, windowed
//! multi-file tasks over the simnet fabric, checksums, fault recovery,
//! concurrent tasks sharing bandwidth max-min fairly under the
//! discrete-event scheduler, and the paper's `T = x/v + S` predictive
//! model.

pub mod endpoint;
pub mod model;
pub mod service;
pub mod task;

pub use endpoint::{Endpoint, EndpointId, EndpointRegistry};
pub use model::{LinearModel, Observation};
pub use service::{TransferHandle, TransferParams, TransferService};
pub use task::{FileReport, FileSpec, TransferReport, TransferRequest};
