//! The paper's empirical transfer-time model `T = x/v + S` (§4.1,
//! refs [33, 34]) with least-squares fitting from observed transfers.
//!
//! `x` = bytes, `v` = achievable rate, `S` = startup cost that "mainly
//! depends on the number of files in the dataset" — so we fit
//! `T = x/v + s0 + s1 * n_files`.

use anyhow::{bail, Result};

/// One observed (or simulated) transfer for fitting.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub bytes: f64,
    pub n_files: f64,
    pub seconds: f64,
}

/// Fitted linear transfer-time model.
#[derive(Debug, Clone, Copy)]
pub struct LinearModel {
    /// effective rate v (bytes/s)
    pub rate_bps: f64,
    /// constant startup s0 (s)
    pub startup_s: f64,
    /// per-file startup s1 (s/file)
    pub per_file_s: f64,
}

impl LinearModel {
    pub fn predict(&self, bytes: f64, n_files: f64) -> f64 {
        bytes / self.rate_bps + self.startup_s + self.per_file_s * n_files
    }

    /// Ordinary least squares on T ~ a*x + s0 + s1*n, a = 1/v.
    /// Needs >= 3 observations spanning different sizes and file counts.
    pub fn fit(obs: &[Observation]) -> Result<LinearModel> {
        if obs.len() < 3 {
            bail!("need at least 3 observations, got {}", obs.len());
        }
        // normal equations for [a, s0, s1]
        let mut ata = [[0.0f64; 3]; 3];
        let mut atb = [0.0f64; 3];
        for o in obs {
            let row = [o.bytes, 1.0, o.n_files];
            for i in 0..3 {
                for j in 0..3 {
                    ata[i][j] += row[i] * row[j];
                }
                atb[i] += row[i] * o.seconds;
            }
        }
        let sol = solve3(ata, atb)?;
        let (a, s0, s1) = (sol[0], sol[1], sol[2]);
        if a <= 0.0 {
            bail!("degenerate fit: non-positive rate coefficient {a}");
        }
        Ok(LinearModel {
            rate_bps: 1.0 / a,
            startup_s: s0,
            per_file_s: s1,
        })
    }

    /// Mean relative error of the model over a sample set.
    pub fn mean_rel_error(&self, obs: &[Observation]) -> f64 {
        if obs.is_empty() {
            return f64::NAN;
        }
        obs.iter()
            .map(|o| ((self.predict(o.bytes, o.n_files) - o.seconds) / o.seconds).abs())
            .sum::<f64>()
            / obs.len() as f64
    }
}

/// Solve a 3x3 linear system by Gaussian elimination with partial pivots.
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> Result<[f64; 3]> {
    for col in 0..3 {
        // pivot
        let piv = (col..3)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap();
        if a[piv][col].abs() < 1e-12 {
            bail!("singular system (observations not diverse enough)");
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in col + 1..3 {
            let f = a[row][col] / a[col][col];
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0; 3];
    for row in (0..3).rev() {
        let mut acc = b[row];
        for k in row + 1..3 {
            acc -= a[row][k] * x[k];
        }
        x[row] = acc / a[row][row];
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_linear_data() {
        // T = x/2e9 + 1.5 + 0.25*n
        let truth = LinearModel {
            rate_bps: 2e9,
            startup_s: 1.5,
            per_file_s: 0.25,
        };
        let obs: Vec<Observation> = [
            (1e9, 1.0),
            (5e9, 4.0),
            (2e9, 16.0),
            (8e9, 2.0),
            (4e8, 32.0),
        ]
        .iter()
        .map(|&(bytes, n_files)| Observation {
            bytes,
            n_files,
            seconds: truth.predict(bytes, n_files),
        })
        .collect();
        let fit = LinearModel::fit(&obs).unwrap();
        assert!((fit.rate_bps - 2e9).abs() / 2e9 < 1e-9);
        assert!((fit.startup_s - 1.5).abs() < 1e-9);
        assert!((fit.per_file_s - 0.25).abs() < 1e-9);
        assert!(fit.mean_rel_error(&obs) < 1e-12);
    }

    #[test]
    fn needs_enough_diversity() {
        let same = Observation {
            bytes: 1e9,
            n_files: 4.0,
            seconds: 2.0,
        };
        assert!(LinearModel::fit(&[same, same, same]).is_err());
        assert!(LinearModel::fit(&[same]).is_err());
    }

    #[test]
    fn fits_simulated_transfers() {
        use crate::simnet::VClock;
        use crate::transfer::{TransferRequest, TransferService};
        let mut svc = TransferService::paper(7);
        let mut obs = vec![];
        for &(gb, n) in &[(0.5, 4usize), (1.0, 8), (2.0, 16), (4.0, 8), (1.0, 32)] {
            let mut clock = VClock::new();
            let mut req = TransferRequest::split_even(
                "fit",
                "slac#dtn".into(),
                "alcf#dtn".into(),
                (gb * 1e9) as u64,
                n,
            );
            req.concurrency = Some(8);
            let rep = svc.execute(&mut clock, &req).unwrap();
            obs.push(Observation {
                bytes: rep.bytes as f64,
                n_files: n as f64,
                seconds: rep.duration(),
            });
        }
        let fit = LinearModel::fit(&obs).unwrap();
        // the fitted rate should land near the fabric cap (1.25 GB/s)
        assert!(
            (1.0e9..1.5e9).contains(&fit.rate_bps),
            "rate {:.3e}",
            fit.rate_bps
        );
        assert!(fit.mean_rel_error(&obs) < 0.05);
    }
}
